//! The declarative experiment API: spec-layer guarantees (JSON round-trip,
//! strict rejection), CLI→`Experiment` golden equivalence for the flag
//! surface, and behavioral identity between the one `run()` dispatcher and
//! the legacy `SweepEngine`/`report` entry points it replaced.

use std::path::Path;

use chiplet_cloud::config::experiment::{EngineKnobs, Experiment, SpaceSpec, Task, WorkloadPoint};
use chiplet_cloud::config::{
    ArrivalProcess, FaultSpec, ModelSpec, OvercommitSpec, ServeSpec, SloSpec, TierSpec, TokenDist,
    TrafficSpec, Workload,
};
use chiplet_cloud::evaluate::{self, SweepEngine};
use chiplet_cloud::experiment::{self, cli, Engine, Outcome};
use chiplet_cloud::perf::events::{simulate_replicated, simulate_trace, IterCost, SimConfig};
use chiplet_cloud::report;
use chiplet_cloud::sched::{ContinuousBatch, KvBudget, RoutePolicy};
use chiplet_cloud::util::cli::Args;
use chiplet_cloud::util::json::Json;
use chiplet_cloud::util::rng::Rng;

fn args(argv: &[&str]) -> Args {
    Args::parse(argv.iter().map(|s| s.to_string()))
}

fn translate(argv: &[&str]) -> chiplet_cloud::Result<Experiment> {
    let a = args(argv);
    cli::from_args(&a.positional[0], &a)
}

// ---------------------------------------------------------------------------
// Spec layer: round-trip, strictness, shipped files.

/// Every checked-in `experiments/*.json` spec must strict-parse, validate,
/// and round-trip through the canonical serializer.
#[test]
fn shipped_specs_parse_validate_and_round_trip() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../experiments");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("experiments/ exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        seen += 1;
        let text = std::fs::read_to_string(&path).unwrap();
        let e = Experiment::from_json_str(&text)
            .unwrap_or_else(|err| panic!("{}: {err}", path.display()));
        e.validate().unwrap_or_else(|err| panic!("{}: {err}", path.display()));
        let back = Experiment::from_json_str(&e.to_json_string()).unwrap();
        assert_eq!(back, e, "{}", path.display());
    }
    assert!(seen >= 3, "expected the shipped example specs, found {seen}");
}

/// Seeded property: parse ∘ serialize = id over randomized specs covering
/// every task, arrival process, routing policy and knob combination —
/// including unconstrained (∞) SLO targets, which travel as JSON null.
#[test]
fn json_round_trip_property() {
    let mut r = Rng::new(0xE5EED);
    let names = ["gpt2", "megatron", "gpt3", "palm"];
    for case in 0..60 {
        let task = *r.pick(&[Task::Sweep, Task::ServeSim, Task::Optimize]);
        let models: Vec<String> =
            (0..1 + r.below(3)).map(|_| r.pick(&names).to_string()).collect();
        let lo = 1 + r.below(64);
        let arrival = match r.below(3) {
            0 => ArrivalProcess::Poisson { rps: r.f64() * 100.0 },
            1 => ArrivalProcess::Bursty { rps: r.f64() * 50.0, burst: 1 + r.below(16) },
            _ => ArrivalProcess::ClosedLoop { clients: 1 + r.below(64), think_s: r.f64() },
        };
        let slo = SloSpec::new(
            if r.chance(0.5) { f64::INFINITY } else { 0.001 + r.f64() },
            if r.chance(0.5) { f64::INFINITY } else { 0.001 + r.f64() },
        );
        let tiers = r.chance(0.3).then(|| {
            TierSpec::new(
                r.f64(),
                1 + r.below(8),
                9 + r.below(32),
                SloSpec::new(0.001 + r.f64(), 0.001 + r.f64()),
                if r.chance(0.5) { SloSpec::unconstrained() } else { SloSpec::new(10.0, 1.0) },
            )
            .with_fairness(r.below(8))
        });
        let serve = ServeSpec {
            traffic: TrafficSpec {
                arrival,
                requests: 1 + r.below(500),
                prompt_tokens: r.below(128),
                new_tokens_lo: lo,
                new_tokens_hi: lo + r.below(100),
                new_tokens_dist: if r.chance(0.3) {
                    TokenDist::Pareto { alpha: 0.5 + r.f64() * 2.0 }
                } else {
                    TokenDist::Uniform
                },
                tiers,
                seed: r.below(1_000_000) as u64,
            },
            slo,
            prefill_chunk: r.below(64),
            paged_kv: r.chance(0.5),
            replicas: 1 + r.below(4),
            route: *r.pick(&[RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::JsqTokens]),
            quantum: if r.chance(0.5) { 0.0 } else { 0.001 + r.f64() * 0.1 },
            trace_file: None,
            faults: if r.chance(0.3) {
                FaultSpec::mtbf(10.0 + r.f64() * 100.0, 1.0 + r.f64() * 10.0, r.below(1 << 30) as u64)
            } else {
                FaultSpec::none()
            },
            overcommit: match r.below(3) {
                0 => Some(OvercommitSpec::quantile(0.05 + r.f64() * 0.9)),
                1 => Some(OvercommitSpec::running_mean()),
                _ => None,
            },
            goodput_window_s: if r.chance(0.5) { 0.0 } else { 1.0 + r.f64() * 60.0 },
        };
        let e = Experiment {
            name: format!("spec-{case}"),
            task,
            models,
            space: *r.pick(&[SpaceSpec::Coarse, SpaceSpec::Full]),
            workload: r
                .chance(0.5)
                .then(|| WorkloadPoint { ctx: 1 + r.below(4096), batch: 1 + r.below(512) }),
            serve: r.chance(0.7).then_some(serve),
            load: 0.1 + r.f64(),
            engine: EngineKnobs { threads: r.below(8), seq: r.chance(0.5) },
            shard: None,
        };
        let text = e.to_json_string();
        let back = Experiment::from_json_str(&text)
            .unwrap_or_else(|err| panic!("case {case}: {err}\n{text}"));
        assert_eq!(back, e, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// CLI → Experiment golden equivalence: the flag surface is a pure
// translation, pinned combination by combination.

#[test]
fn cli_sweep_goldens() {
    let base = Experiment {
        name: "sweep-gpt3".into(),
        task: Task::Sweep,
        models: vec!["gpt3".into()],
        space: SpaceSpec::Coarse,
        workload: None,
        serve: None,
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    assert_eq!(translate(&["sweep"]).unwrap(), base);

    let mut full = base.clone();
    full.name = "sweep-megatron".into();
    full.models = vec!["megatron".into()];
    full.space = SpaceSpec::Full;
    full.engine = EngineKnobs { threads: 2, seq: false };
    assert_eq!(
        translate(&["sweep", "--model", "megatron", "--threads", "2", "--full"]).unwrap(),
        full
    );

    let mut seq = base.clone();
    seq.engine = EngineKnobs { threads: 0, seq: true };
    assert_eq!(translate(&["sweep", "--seq"]).unwrap(), seq);

    // A binding SLO with no trace flags defaults to a saturating closed
    // loop of 64 clients.
    let mut slo = base.clone();
    slo.serve = Some(ServeSpec::new(
        TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop { clients: 64, think_s: 0.0 },
            requests: 400,
            prompt_tokens: 64,
            new_tokens_lo: 16,
            new_tokens_hi: 128,
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: 42,
        },
        SloSpec::new(f64::INFINITY, 0.05),
    ));
    assert_eq!(translate(&["sweep", "--slo-tpot", "0.05"]).unwrap(), slo);

    // The CI smoke flag combination, pinned exactly.
    let mut smoke = base.clone();
    smoke.name = "sweep-gpt2".into();
    smoke.models = vec!["gpt2".into()];
    smoke.engine = EngineKnobs { threads: 2, seq: false };
    smoke.serve = Some(ServeSpec::new(
        TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop { clients: 16, think_s: 0.0 },
            requests: 80,
            prompt_tokens: 64,
            new_tokens_lo: 8,
            new_tokens_hi: 32,
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: 42,
        },
        SloSpec::new(2.0, 0.05),
    ));
    assert_eq!(
        translate(&[
            "sweep", "--model", "gpt2", "--slo-ttft", "2.0", "--slo-tpot", "0.05", "--trace",
            "closed", "--requests", "80", "--clients", "16", "--tokens-lo", "8", "--tokens-hi",
            "32", "--threads", "2",
        ])
        .unwrap(),
        smoke
    );

    // Serving-model knobs ride along once an SLO binds; an explicit --rps
    // keeps the open-loop trace.
    let mut knobs = base.clone();
    knobs.serve = Some(
        ServeSpec::new(
            TrafficSpec {
                arrival: ArrivalProcess::Poisson { rps: 12.5 },
                requests: 400,
                prompt_tokens: 64,
                new_tokens_lo: 16,
                new_tokens_hi: 128,
                new_tokens_dist: TokenDist::Uniform,
                tiers: None,
                seed: 42,
            },
            SloSpec::new(f64::INFINITY, 0.05),
        )
        .with_chunked_prefill(16)
        .with_paged_kv()
        .with_replicas(2, RoutePolicy::JsqTokens),
    );
    assert_eq!(
        translate(&[
            "sweep",
            "--slo-tpot",
            "0.05",
            "--rps",
            "12.5",
            "--paged",
            "--prefill-chunk",
            "16",
            "--replicas",
            "2",
            "--route",
            "jsq-tokens",
        ])
        .unwrap(),
        knobs
    );
}

#[test]
fn cli_serve_sim_goldens() {
    // The CI smoke preset.
    let smoke = Experiment {
        name: "serve-sim-gpt2".into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 1024, batch: 32 }),
        serve: Some(ServeSpec::new(
            TrafficSpec {
                arrival: ArrivalProcess::Poisson { rps: 0.0 },
                requests: 120,
                prompt_tokens: 32,
                new_tokens_lo: 8,
                new_tokens_hi: 32,
                new_tokens_dist: TokenDist::Uniform,
                tiers: None,
                seed: 42,
            },
            SloSpec::unconstrained(),
        )),
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    assert_eq!(translate(&["serve-sim", "--smoke"]).unwrap(), smoke);

    // Every serving flag at once.
    let full = Experiment {
        name: "serve-sim-gpt3".into(),
        task: Task::ServeSim,
        models: vec!["gpt3".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 2048, batch: 64 }),
        serve: Some(
            ServeSpec::new(
                TrafficSpec {
                    arrival: ArrivalProcess::Bursty { rps: 3.5, burst: 4 },
                    requests: 50,
                    prompt_tokens: 16,
                    new_tokens_lo: 4,
                    new_tokens_hi: 8,
                    new_tokens_dist: TokenDist::Uniform,
                    tiers: None,
                    seed: 7,
                },
                SloSpec::new(1.5, 0.02),
            )
            .with_paged_kv()
            .with_replicas(3, RoutePolicy::Jsq)
            .with_overcommit(OvercommitSpec::quantile(0.8))
            .with_goodput_window(5.0),
        ),
        load: 0.5,
        engine: EngineKnobs::default(),
        shard: None,
    };
    assert_eq!(
        translate(&[
            "serve-sim", "--ctx", "2048", "--batch", "64", "--load", "0.5", "--trace", "bursty",
            "--rps", "3.5", "--burst", "4", "--requests", "50", "--prompt-tokens", "16",
            "--tokens-lo", "4", "--tokens-hi", "8", "--seed", "7", "--slo-ttft", "1.5",
            "--slo-tpot", "0.02", "--paged", "--replicas", "3", "--route", "jsq", "--overcommit",
            "0.8", "--goodput-window", "5",
        ])
        .unwrap(),
        full
    );

    // The running-mean estimator spells as the literal 'mean'.
    let e = translate(&["serve-sim", "--paged", "--overcommit", "mean"]).unwrap();
    let s = e.serve.expect("serve-sim carries a serve spec");
    assert_eq!(s.overcommit, Some(OvercommitSpec::running_mean()));
    assert_eq!(s.goodput_window_s, 0.0, "window stays inert without its flag");
}

#[test]
fn cli_optimize_and_table2_goldens() {
    let opt = translate(&["optimize"]).unwrap();
    assert_eq!(opt.task, Task::Optimize);
    assert_eq!(opt.models, vec!["gpt3".to_string()]);
    assert_eq!(opt.name, "optimize-gpt3");
    assert!(opt.serve.is_none() && opt.workload.is_none());

    let palm = translate(&["optimize", "--model", "palm"]).unwrap();
    assert_eq!(palm.models, vec!["palm".to_string()]);

    let t2 = translate(&["table2", "--full"]).unwrap();
    assert_eq!(t2.name, "table2");
    assert_eq!(t2.space, SpaceSpec::Full);
    let expected: Vec<String> =
        ModelSpec::paper_models().iter().map(|m| m.name.to_string()).collect();
    assert_eq!(t2.models, expected);
    assert_eq!(t2.models.len(), 8);
}

#[test]
fn cli_rejects_bad_flag_combinations() {
    let err = |argv: &[&str]| translate(argv).unwrap_err().to_string();
    // Serving knobs without a binding SLO misrepresent the optimum.
    assert!(err(&["sweep", "--paged"]).contains("no effect"));
    assert!(err(&["sweep", "--replicas", "2"]).contains("no effect"));
    // Unparsable or degenerate numbers error instead of defaulting.
    assert!(err(&["sweep", "--slo-ttft", "abc"]).contains("must be a number"));
    assert!(err(&["sweep", "--slo-tpot", "0"]).contains("positive"));
    assert!(err(&["serve-sim", "--tokens-lo", "9", "--tokens-hi", "3"]).contains("exceeds"));
    assert!(err(&["serve-sim", "--requests", "0"]).contains(">= 1"));
    // Typo'd enums error instead of silently defaulting.
    assert!(err(&["serve-sim", "--route", "fastest", "--slo-tpot", "0.05"]).contains("--route"));
    assert!(err(&["serve-sim", "--trace", "what"]).contains("--trace"));
    // Unknown models are caught by spec validation.
    assert!(err(&["sweep", "--model", "gpt9000"]).contains("unknown model"));
    // Quantized-time flag: degenerate values error instead of defaulting.
    assert!(err(&["serve-sim", "--quantum", "0"]).contains("positive"));
    assert!(err(&["serve-sim", "--quantum", "abc"]).contains("must be a number"));
    // A trace file replays recorded arrivals: synthetic-arrival flags
    // contradict it, and the error names the offending flag.
    assert!(err(&["serve-sim", "--trace-file", "t.csv", "--trace", "poisson"])
        .contains("drop --trace"));
    assert!(err(&["serve-sim", "--trace-file", "t.csv", "--rps", "5"]).contains("drop --rps"));
    assert!(err(&["serve-sim", "--trace-file", "t.csv", "--clients", "4"])
        .contains("drop --clients"));
    // Serving knobs (trace file included) still need a binding SLO on sweeps.
    assert!(err(&["sweep", "--trace-file", "t.csv"]).contains("no effect"));
    // Overcommit admission: degenerate quantiles error, the flag needs a
    // binding SLO on sweeps, and spec validation requires the paged ledger.
    assert!(err(&["serve-sim", "--paged", "--overcommit", "1.5"]).contains("quantile"));
    assert!(err(&["serve-sim", "--paged", "--overcommit", "abc"]).contains("quantile"));
    assert!(err(&["sweep", "--overcommit", "0.8"]).contains("no effect"));
    assert!(err(&["sweep", "--goodput-window", "5"]).contains("no effect"));
    assert!(err(&["serve-sim", "--overcommit", "0.8"]).contains("paged_kv"));
}

/// `--trace-file` and `--quantum` translate into the spec verbatim; the
/// file's existence is deliberately a run-time concern, so translation
/// succeeds on any path.
#[test]
fn cli_trace_file_and_quantum_goldens() {
    let e = translate(&["serve-sim", "--trace-file", "arrivals.csv", "--quantum", "0.5"]).unwrap();
    let s = e.serve.expect("serve-sim carries a serve spec");
    assert_eq!(s.trace_file.as_deref(), Some("arrivals.csv"));
    assert!((s.quantum - 0.5).abs() < 1e-15);
    // Defaults stay inert: no flag, no quantum, no trace file.
    let e = translate(&["serve-sim"]).unwrap();
    let s = e.serve.expect("serve-sim carries a serve spec");
    assert_eq!(s.trace_file, None);
    assert_eq!(s.quantum, 0.0);
}

// ---------------------------------------------------------------------------
// Behavioral identity: run() vs the legacy entry points.

/// `run()` on a sweep spec must select exactly what the deprecated
/// `SweepEngine::best_over_grid_stats` path selects — and the outcome JSON
/// outside the "engine" section must be invariant across thread counts.
#[test]
fn run_sweep_matches_direct_engine_and_json_is_engine_invariant() {
    let e = Experiment {
        name: "sweep-gpt2".into(),
        task: Task::Sweep,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: None,
        serve: None,
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    let outcome = experiment::run(&e).unwrap();
    let Outcome::Sweep(sw) = &outcome else { panic!("sweep spec → Sweep outcome") };
    let ctx = report::Ctx::coarse();
    let grid = Workload::study_grid(&ModelSpec::gpt2());
    let (direct, _) =
        SweepEngine::default().best_over_grid_stats(&ctx.space, &ctx.servers, &grid);
    let (dw, dp) = direct.expect("gpt2 feasible");
    let (ow, op) = sw.best.as_ref().expect("outcome feasible");
    assert_eq!((ow.ctx, ow.batch), (dw.ctx, dw.batch));
    assert_eq!(op.mapping, dp.mapping);
    assert_eq!(op.server, dp.server);
    assert_eq!(op.tco_per_token.to_bits(), dp.tco_per_token.to_bits());
    assert_eq!(sw.grid_len, grid.len());
    assert_eq!(sw.feasible_servers, ctx.servers.len());

    // Thread-count invariance of the machine-readable outcome (the CI
    // fast-vs-reference golden diff relies on this split).
    let mut inline = e.clone();
    inline.engine = EngineKnobs { threads: 1, seq: false };
    let strip = |o: &Outcome| match o.to_json() {
        Json::Obj(mut m) => {
            assert!(m.remove("engine").is_some(), "leaf outcomes carry an engine section");
            Json::Obj(m)
        }
        other => other,
    };
    let a = strip(&outcome);
    let b = strip(&experiment::run(&inline).unwrap());
    assert_eq!(a, b, "outcome JSON must not depend on engine configuration");
    // And the document itself must be valid JSON.
    let text = report::to_json(&outcome);
    Json::parse(&text).expect("outcome JSON parses");
}

/// `run()` on a serve-sim spec reproduces the direct simulator calls the
/// legacy `report::serve_sim` harness makes — row for row, to the bit.
#[test]
fn run_serve_sim_matches_direct_simulation() {
    let traffic = TrafficSpec::poisson(4.0, 60, 16, 4, 16).with_seed(11);
    let spec = ServeSpec::new(traffic, SloSpec::unconstrained())
        .with_replicas(2, RoutePolicy::RoundRobin);
    let e = Experiment {
        name: "serve-sim-gpt2".into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 1024, batch: 32 }),
        serve: Some(spec),
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    let outcome = experiment::run(&e).unwrap();
    let Outcome::Serve(so) = &outcome else { panic!("serve-sim spec → Serve outcome") };
    assert!(so.feasible);
    // static + continuous + rr/jsq/jsq-tokens routing rows
    assert_eq!(so.rows.len(), 5);
    assert!(so.slo.is_none(), "unconstrained SLO adds no selection row");

    // Rebuild the simulator inputs exactly as the harness does and check
    // the continuous-batching and routed rows bit for bit.
    let ctx = report::Ctx::coarse();
    let w = Workload::new(ModelSpec::gpt2(), 1024, 32);
    let best = evaluate::best_point(&ctx.space, &ctx.servers, &w).expect("feasible");
    let cfg = SimConfig::new(
        w.batch,
        KvBudget::from_design(&best.server, &w, &best.mapping),
        IterCost::from_perf(&best.perf, &w),
        false,
    );
    let mut single = traffic;
    if let ArrivalProcess::Poisson { rps } = &mut single.arrival {
        *rps /= 2.0;
    }
    let slo = SloSpec::unconstrained();
    let direct_cont = simulate_trace(&cfg, &mut ContinuousBatch, &single, &slo);
    assert_eq!(so.rows[1].1.fingerprint(), direct_cont.fingerprint());
    let direct_jsqt = simulate_replicated(
        &cfg,
        2,
        RoutePolicy::JsqTokens,
        &ContinuousBatch,
        &traffic,
        &slo,
    );
    assert_eq!(so.rows[4].1.fingerprint(), direct_jsqt.fingerprint());
    assert_eq!(so.rows[4].0, direct_jsqt.policy);
}

/// The optimize outcome renders byte-identically to the legacy
/// `report::table2` harness (which now delegates to it) — and matches the
/// deprecated `evaluate::best_over_grid` selection.
#[test]
fn optimize_outcome_equals_table2_shim() {
    let ctx = report::Ctx::coarse();
    let models = [ModelSpec::megatron()];
    let engine = SweepEngine::default();
    let outcome = experiment::optimize_outcome(&ctx, &models, &engine);
    let shim = report::table2(&ctx, &models, None);
    assert_eq!(outcome.to_table().render(), shim.render());
    assert_eq!(outcome.rows.len(), 1);
    let grid = Workload::study_grid(&ModelSpec::megatron());
    let (_, direct) = evaluate::best_over_grid(&ctx.space, &ctx.servers, &grid).unwrap();
    assert_eq!(
        outcome.rows[0].point.tco_per_token.to_bits(),
        direct.tco_per_token.to_bits()
    );
}

/// The serve-sim shim renders byte-identically to the outcome table.
#[test]
fn serve_sim_shim_equals_outcome_table() {
    let ctx = report::Ctx::coarse();
    let w = Workload::new(ModelSpec::gpt2(), 1024, 16);
    let spec = ServeSpec::new(TrafficSpec::poisson(3.0, 40, 16, 4, 8), SloSpec::unconstrained());
    let engine = SweepEngine::default();
    let outcome = experiment::serve_outcome(&ctx, &w, &spec, 0.8, &engine).unwrap();
    let shim = report::serve_sim(&ctx, &w, &spec, 0.8, None).unwrap();
    assert_eq!(outcome.to_table().render(), shim.render());
}

/// End-to-end trace-file replay: a recorded CSV drives the whole
/// experiment path, the offered count comes from the file (the spec's own
/// request count is ignored), and a file that vanishes before the run
/// surfaces as a located config error absorbed into [`Outcome::Error`].
#[test]
fn trace_file_replay_end_to_end() {
    let path = std::env::temp_dir().join(format!("cc-e2e-trace-{}.csv", std::process::id()));
    let mut csv = String::from("at_s,prompt_tokens,new_tokens\n");
    for i in 0..24 {
        csv.push_str(&format!("{},16,{}\n", i as f64 * 0.05, 4 + (i % 8)));
    }
    std::fs::write(&path, csv).unwrap();
    let mk = |p: &str| Experiment {
        name: "trace-replay".into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 1024, batch: 16 }),
        serve: Some(
            ServeSpec::new(TrafficSpec::poisson(0.0, 1, 16, 4, 8), SloSpec::unconstrained())
                .with_trace_file(p),
        ),
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    let e = mk(path.to_str().unwrap());
    e.validate().expect("a trace-file spec with inert synthetic arrivals validates");
    let outcome = experiment::run(&e).unwrap();
    let Outcome::Serve(so) = &outcome else { panic!("serve-sim spec → Serve outcome") };
    assert!(so.feasible);
    for (label, rep) in &so.rows {
        assert_eq!(rep.offered, 24, "{label}: offered count must come from the file");
        assert_eq!(rep.completed, 24, "{label}: every recorded request must be served");
    }
    // The machine-readable outcome names the file it replayed.
    let json = outcome.to_json().to_string();
    assert!(json.contains("trace_file"), "{json}");
    std::fs::remove_file(&path).unwrap();
    // Same spec, vanished file: a located error, not a panic.
    let o = experiment::run(&mk(path.to_str().unwrap())).unwrap();
    let Outcome::Error(msg) = o else { panic!("missing trace file → error outcome") };
    assert!(msg.contains("cannot open trace file"), "{msg}");
    assert!(msg.contains("cc-e2e-trace"), "{msg}");
}

/// A campaign shares one Phase-1 context across same-space specs and
/// returns outcomes in input order.
#[test]
fn campaign_shares_phase1_context_and_preserves_order() {
    let serve = |name: &str, seed: u64| Experiment {
        name: name.into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 1024, batch: 16 }),
        serve: Some(ServeSpec::new(
            TrafficSpec::poisson(3.0, 30, 16, 4, 8).with_seed(seed),
            SloSpec::unconstrained(),
        )),
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    let specs = [serve("first", 1), serve("second", 2)];
    let mut engine = Engine::new();
    let results = engine.run_campaign(&specs);
    assert_eq!(engine.contexts(), 1, "same space ⇒ one shared Phase-1 sweep");
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].0, "first");
    assert_eq!(results[1].0, "second");
    for (_, o) in &results {
        assert!(matches!(o, Outcome::Serve(s) if s.feasible));
    }
    // The campaign wrapper renders each member under its own name.
    let wrapped = Outcome::Campaign(results);
    let tables = wrapped.named_tables("campaign");
    assert_eq!(tables.len(), 2);
    assert_eq!(tables[0].0, "first");
    let json = wrapped.to_json().to_string();
    let doc = Json::parse(&json).unwrap();
    assert_eq!(doc.get("kind").and_then(|k| k.as_str()), Some("campaign"));
    assert_eq!(doc.get("experiments").and_then(|e| e.as_arr()).map(|a| a.len()), Some(2));
}

/// A multi-model sweep spec fans out into a per-model campaign outcome.
#[test]
fn multi_model_spec_dispatches_a_campaign() {
    let e = Experiment {
        name: "pair".into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into(), "megatron".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 1024, batch: 16 }),
        serve: Some(ServeSpec::new(
            TrafficSpec::poisson(3.0, 20, 16, 4, 8),
            SloSpec::unconstrained(),
        )),
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    let outcome = experiment::run(&e).unwrap();
    let Outcome::Campaign(members) = outcome else { panic!("multi-model → campaign") };
    assert_eq!(members.len(), 2);
    assert_eq!(members[0].0, "pair-gpt2");
    assert_eq!(members[1].0, "pair-megatron");
}

/// Invalid specs fail `run()` with a config error, not a panic.
#[test]
fn run_rejects_invalid_specs() {
    let mut e = Experiment {
        name: "bad".into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: None,
        serve: None,
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    };
    assert!(experiment::run(&e).is_err(), "serve-sim without workload must be rejected");
    e.models = vec![];
    assert!(experiment::run(&e).is_err(), "empty model list must be rejected");
}
