//! Integration tests over the serving stack: AOT artifacts → PJRT engine →
//! coordinator, with exact-numerics checks against the Python fixture.
//!
//! These tests are skipped (with a notice) when `make artifacts` has not
//! been run — `make test` always builds artifacts first.

use std::path::PathBuf;
use std::time::Duration;

use chiplet_cloud::coordinator::{Coordinator, CoordinatorConfig};
use chiplet_cloud::runtime::{Manifest, ModelEngine};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("cc-tiny.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// The core end-to-end numerics contract: Rust PJRT generation ==
/// the JAX reference generation, token for token.
#[test]
fn rust_generation_matches_jax_fixture() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir, "cc-tiny").unwrap();
    let (prompt, expected) = engine.manifest.load_fixture().unwrap();
    let got = engine.generate(&prompt, expected[0].len()).unwrap();
    assert_eq!(got, expected);
}

/// The Pallas-kernel-lowered artifact serves the same interface: the
/// cc-tiny artifact was built with `--pallas`, proving L1 kernels lower
/// into the HLO the Rust runtime loads.
#[test]
fn pallas_artifact_flag_recorded() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir, "cc-tiny").unwrap();
    assert!(m.use_pallas, "cc-tiny must be the Pallas-path artifact");
}

/// Decode must respect the KV capacity: stepping past max_ctx errors
/// instead of corrupting the cache.
#[test]
fn context_exhaustion_is_an_error() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir, "cc-tiny").unwrap();
    let (prompt, _) = engine.manifest.load_fixture().unwrap();
    let (mut toks, mut state) = engine.prefill(&prompt).unwrap();
    let budget = engine.manifest.max_ctx - engine.manifest.prompt_len;
    for _ in 0..budget {
        toks = engine.decode_step(&toks, &mut state).unwrap();
    }
    assert!(engine.decode_step(&toks, &mut state).is_err());
}

/// Coordinator end-to-end: mixed prompt lengths, queueing, padded batches.
#[test]
fn coordinator_serves_mixed_stream() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::start(
        &dir,
        "cc-tiny",
        CoordinatorConfig { max_wait: Duration::from_millis(10), ..CoordinatorConfig::default() },
    )
    .unwrap();
    let mut ids = Vec::new();
    for i in 0..9usize {
        // prompt lengths 1..40: exercises truncation and padding
        let prompt: Vec<i32> = (0..(1 + i * 5)).map(|j| (j % 100) as i32 + 1).collect();
        ids.push(coord.submit(prompt, 3 + (i % 3)));
    }
    let metrics = coord.metrics.clone();
    let rs = coord.shutdown().unwrap();
    assert_eq!(rs.len(), 9);
    for (i, r) in rs.iter().enumerate() {
        assert_eq!(r.id, ids[i]);
        assert_eq!(r.tokens.len(), 3 + (i % 3));
    }
    let s = metrics.summary();
    assert_eq!(s.completed, 9);
    assert!(s.decode_tokens_per_s > 0.0);
    assert!(s.occupancy > 0.0 && s.occupancy <= 1.0);
}

/// Two serving runs of the same stream produce identical tokens — the
/// whole stack is deterministic.
#[test]
fn serving_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let run = || {
        let coord =
            Coordinator::start(&dir, "cc-tiny", CoordinatorConfig::default()).unwrap();
        let a = coord.submit(vec![11, 22, 33, 44], 6);
        let b = coord.submit(vec![5; 20], 6);
        let rs = coord.shutdown().unwrap();
        let find = |id| rs.iter().find(|r| r.id == id).unwrap().tokens.clone();
        (find(a), find(b))
    };
    assert_eq!(run(), run());
}
