//! Distributed campaigns: the shard/merge bit-identity contract, the
//! supervising orchestrator's robustness paths (timeouts, retries,
//! checkpoints, resume, fault injection), and the file-handling hardening
//! around spec/checkpoint IO.
//!
//! The in-process property tests pin `merge(shard(spec, N))` byte-identical
//! (outside `"engine"`) to `Engine::run(spec)`; the process tests drive the
//! actual `ccloud` binary (`env!("CARGO_BIN_EXE_ccloud")`) through the
//! distributed orchestrator under seeded `CC_FAULT_PLAN` faults.

use std::path::{Path, PathBuf};
use std::process::Command;

use chiplet_cloud::config::experiment::{EngineKnobs, Experiment, SpaceSpec, Task, WorkloadPoint};
use chiplet_cloud::config::{ArrivalProcess, ServeSpec, SloSpec, TrafficSpec};
use chiplet_cloud::experiment::shard::{merge, plan, strip_engine, Envelope};
use chiplet_cloud::experiment::{Engine, Outcome};
use chiplet_cloud::util::json::Json;
use chiplet_cloud::util::prop;

fn spec(task: Task, models: &[&str]) -> Experiment {
    let models: Vec<String> = models.iter().map(|s| s.to_string()).collect();
    Experiment {
        name: Experiment::default_name(task, &models),
        task,
        models,
        space: SpaceSpec::Coarse,
        workload: None,
        serve: None,
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    }
}

fn serve_spec(seed: u64, slo: bool) -> ServeSpec {
    ServeSpec::new(
        TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop { clients: 8, think_s: 0.0 },
            ..TrafficSpec::poisson(0.0, 40, 16, 4, 16)
        }
        .with_seed(seed),
        if slo {
            SloSpec::new(2.0, 0.5)
        } else {
            SloSpec::unconstrained()
        },
    )
}

/// Run every shard in-process through `engine` and merge the envelopes.
fn run_sharded(e: &Experiment, workers: usize, engine: &mut Engine) -> Json {
    let shards = plan(e, workers, engine).expect("plan");
    let envs: Vec<Envelope> = shards
        .iter()
        .map(|s| {
            let outcome = engine.run(s).expect("shard runs");
            Envelope::new(s.clone(), outcome.to_json())
        })
        .collect();
    let merged = merge(&envs).expect("merge");
    assert!(merged.missing.is_empty(), "complete runs have no missing shards");
    merged.outcome
}

// ---------------------------------------------------------------------------
// Bit-identity: merge ∘ shard = run, modulo the "engine" counters.

/// Seeded property: for randomized specs (task, models, SLO, traffic seed)
/// and every worker count in {1, 2, 3, 8}, the merged shard outcome is
/// *byte-identical* (string equality of the canonical JSON) to the
/// single-process outcome outside `"engine"`.
#[test]
fn merged_shards_are_byte_identical_to_single_process() {
    let mut engine = Engine::new();
    prop::check("merge(shard(e, N)) == run(e)", 5, |r| {
        let mut e = match r.below(4) {
            0 => spec(Task::Sweep, &["gpt2"]),
            1 => spec(Task::Sweep, &["gpt2", "megatron"]),
            2 => spec(Task::Optimize, &["gpt2", "megatron", "gpt3"]),
            _ => {
                let mut e = spec(Task::ServeSim, &["gpt2", "megatron"]);
                e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
                e.serve = Some(serve_spec(r.below(1_000_000) as u64, false));
                e
            }
        };
        if e.task == Task::Sweep && r.chance(0.5) {
            e.serve = Some(serve_spec(r.below(1_000_000) as u64, true));
        }
        // A fresh engine per case would re-sweep Phase 1; sharing is
        // answer-preserving (pinned by integration_experiment.rs).
        let mut engine = Engine::new();
        let single = engine.run(&e).expect("single-process run");
        let golden = strip_engine(&single.to_json()).to_string();
        for workers in [1usize, 2, 3, 8] {
            let merged = run_sharded(&e, workers, &mut engine);
            assert_eq!(
                strip_engine(&merged).to_string(),
                golden,
                "{} sharded {workers}-way diverged from the single-process outcome",
                e.name
            );
        }
    });
    // Deterministic anchor outside the property loop: the SLO-constrained
    // sweep (stage 2 runs the event simulator) merges bit-identically too.
    let mut e = spec(Task::Sweep, &["gpt2"]);
    e.serve = Some(serve_spec(7, true));
    let single = engine.run(&e).expect("runs");
    let golden = strip_engine(&single.to_json()).to_string();
    for workers in [2usize, 8] {
        let merged = run_sharded(&e, workers, &mut engine);
        assert_eq!(strip_engine(&merged).to_string(), golden);
    }
}

/// A partial merge (one shard withheld) degrades gracefully: the document
/// carries the surviving members plus an explicit `missing_shards`
/// manifest, and never panics.
#[test]
fn partial_merge_reports_missing_shards() {
    let mut engine = Engine::new();
    let e = spec(Task::Optimize, &["gpt2", "megatron", "gpt3"]);
    let shards = plan(&e, 3, &mut engine).expect("plan");
    let envs: Vec<Envelope> = shards
        .iter()
        .filter(|s| s.shard.as_ref().unwrap().index != 1)
        .map(|s| Envelope::new(s.clone(), engine.run(s).expect("runs").to_json()))
        .collect();
    let merged = merge(&envs).expect("partial merge still merges");
    assert_eq!(merged.missing, vec![1]);
    let manifest = merged.outcome.get("missing_shards").expect("manifest present");
    assert_eq!(manifest.as_arr().unwrap().len(), 1);
    // The surviving models' rows are intact.
    let rows = merged.outcome.get("rows").unwrap().as_arr().unwrap();
    assert!(!rows.is_empty());
}

// ---------------------------------------------------------------------------
// Campaign graceful degradation (satellite 1).

/// One bad spec inside a campaign must not abort the rest: its slot
/// carries `Outcome::Error` (rendered as a failure table and as
/// `{"kind":"error"}` JSON) while every other spec still runs.
#[test]
fn campaign_degrades_per_spec_instead_of_aborting() {
    let good = spec(Task::Sweep, &["gpt2"]);
    let mut bad = spec(Task::Sweep, &["gpt2"]);
    bad.name = "bad".into();
    bad.models = vec!["no-such-model".into()];
    let mut engine = Engine::new();
    let results = engine.run_campaign(&[bad.clone(), good.clone()]);
    assert_eq!(results.len(), 2);
    let Outcome::Error(err) = &results[0].1 else { panic!("bad spec → Error outcome") };
    assert!(err.contains("no-such-model"), "{err}");
    assert!(matches!(results[1].1, Outcome::Sweep(_)), "good spec still ran");
    // Rendering: the failure row appears in the campaign tables…
    let campaign = Outcome::Campaign(results);
    let tables = campaign.named_tables("campaign");
    let rendered: String = tables.iter().map(|(_, t)| t.render()).collect();
    assert!(rendered.contains("Failed experiment"));
    assert!(rendered.contains("no-such-model"));
    // …and as a structured member in the JSON.
    let json = campaign.to_json().to_string();
    assert!(json.contains("\"kind\":\"error\""), "{json}");
}

/// Shard slice bounds are validated against run-time facts: a grid slice
/// past the study grid is a located config error, not a panic.
#[test]
fn out_of_range_shard_slices_error_cleanly() {
    let mut engine = Engine::new();
    let mut e = spec(Task::Sweep, &["gpt2"]);
    let shards = plan(&e, 2, &mut engine).expect("plan");
    let mut sel = shards[0].shard.clone().unwrap();
    sel.grid = Some((0, 10_000));
    e.shard = Some(sel);
    let err = engine.run(&e).unwrap_err().to_string();
    assert!(err.contains("study grid"), "{err}");
}

// ---------------------------------------------------------------------------
// Process-level: the real binary under the supervising orchestrator.

fn ccloud() -> Command {
    Command::new(env!("CARGO_BIN_EXE_ccloud"))
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cc-shard-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_spec(dir: &Path, e: &Experiment) -> PathBuf {
    let p = dir.join("spec.json");
    std::fs::write(&p, format!("{}\n", e.to_json())).unwrap();
    p
}

/// `ccloud run <spec> --json` single-process golden, engine-stripped.
fn golden_json(spec_path: &Path) -> String {
    let out = ccloud().args(["run"]).arg(spec_path).arg("--json").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let v = Json::parse(std::str::from_utf8(&out.stdout).unwrap().trim()).unwrap();
    strip_engine(&v).to_string()
}

fn read_outcome(run_dir: &Path) -> Json {
    let text = std::fs::read_to_string(run_dir.join("outcome.json")).unwrap();
    Json::parse(text.trim()).unwrap()
}

fn read_status(run_dir: &Path) -> Json {
    let text = std::fs::read_to_string(run_dir.join("status.json")).unwrap();
    Json::parse(text.trim()).unwrap()
}

fn shard_row(status: &Json, index: usize) -> Json {
    status.get("status").unwrap().as_arr().unwrap()[index].clone()
}

/// Kill, corrupt *and* delay faults on first attempts across different
/// shards: every one is retried, the run succeeds, and the merged outcome
/// is byte-identical to the single-process run. The fault plan arrives via
/// the `CC_FAULT_PLAN` environment variable, as CI injects it.
#[test]
fn distributed_run_retries_injected_faults_and_matches_golden() {
    let dir = temp_dir("faults");
    let spec_path = write_spec(&dir, &spec(Task::Sweep, &["gpt2"]));
    let golden = golden_json(&spec_path);
    let run_dir = dir.join("run");
    let out = ccloud()
        .args(["run"])
        .arg(&spec_path)
        .args(["--distributed", "--run-dir"])
        .arg(&run_dir)
        .args(["--workers", "3", "--retries", "2", "--backoff-ms", "1", "--timeout-s", "60"])
        .env("CC_FAULT_PLAN", "kill:1@0,corrupt:2@0")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(strip_engine(&read_outcome(&run_dir)).to_string(), golden);
    let status = read_status(&run_dir);
    assert_eq!(status.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(shard_row(&status, 0).get("attempts").and_then(Json::as_usize), Some(1));
    assert_eq!(shard_row(&status, 1).get("attempts").and_then(Json::as_usize), Some(2));
    assert_eq!(shard_row(&status, 2).get("attempts").and_then(Json::as_usize), Some(2));
    // The status table renders (retries visible to the operator).
    let table = ccloud()
        .args(["run"])
        .arg(&spec_path)
        .args(["--resume"])
        .arg(&run_dir)
        .output()
        .unwrap();
    assert!(table.status.success());
    let text = String::from_utf8_lossy(&table.stdout).to_string();
    assert!(text.contains("Distributed campaign status"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A shard whose every attempt is killed exhausts its retries: the run
/// exits nonzero but still writes the partial merged outcome with the
/// explicit missing-shard manifest, and the other shards' work survives.
#[test]
fn exhausted_retries_degrade_to_partial_outcome() {
    let dir = temp_dir("exhaust");
    let spec_path = write_spec(&dir, &spec(Task::Sweep, &["gpt2"]));
    let run_dir = dir.join("run");
    let out = ccloud()
        .args(["run"])
        .arg(spec_path)
        .args(["--distributed", "--run-dir"])
        .arg(&run_dir)
        .args(["--workers", "2", "--retries", "1", "--backoff-ms", "1"])
        .args(["--fault-plan", "kill:0@0,kill:0@1"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "exhausted retries must exit nonzero");
    let outcome = read_outcome(&run_dir);
    let missing = outcome.get("missing_shards").expect("manifest in partial outcome");
    assert_eq!(missing.as_arr().unwrap()[0].as_usize(), Some(0));
    let status = read_status(&run_dir);
    assert_eq!(status.get("ok").and_then(Json::as_bool), Some(false));
    let row = shard_row(&status, 0);
    assert_eq!(row.get("attempts").and_then(Json::as_usize), Some(2));
    assert!(row.get("error").and_then(Json::as_str).unwrap().contains("exhausted"));
    assert_eq!(shard_row(&status, 1).get("ok").and_then(Json::as_bool), Some(true));
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--resume` re-runs only the missing shard: the surviving checkpoint is
/// adopted (0 attempts, marked resumed) and the final outcome matches the
/// single-process golden.
#[test]
fn resume_reruns_only_the_missing_shard() {
    let dir = temp_dir("resume");
    let spec_path = write_spec(&dir, &spec(Task::Sweep, &["gpt2"]));
    let golden = golden_json(&spec_path);
    let run_dir = dir.join("run");
    let ok = ccloud()
        .args(["run"])
        .arg(&spec_path)
        .args(["--distributed", "--run-dir"])
        .arg(&run_dir)
        .args(["--workers", "2"])
        .output()
        .unwrap();
    assert!(ok.status.success(), "{}", String::from_utf8_lossy(&ok.stderr));
    // Fresh-run protection: the same directory without --resume is refused.
    let again = ccloud()
        .args(["run"])
        .arg(&spec_path)
        .args(["--distributed", "--run-dir"])
        .arg(&run_dir)
        .output()
        .unwrap();
    assert!(!again.status.success());
    assert!(String::from_utf8_lossy(&again.stderr).contains("--resume"));
    // Delete one checkpoint, corrupt nothing else; resume.
    std::fs::remove_file(run_dir.join("shards/shard-001.outcome.json")).unwrap();
    let resumed = ccloud()
        .args(["run"])
        .arg(&spec_path)
        .args(["--resume"])
        .arg(&run_dir)
        .output()
        .unwrap();
    assert!(resumed.status.success(), "{}", String::from_utf8_lossy(&resumed.stderr));
    let status = read_status(&run_dir);
    let row0 = shard_row(&status, 0);
    assert_eq!(row0.get("from_checkpoint").and_then(Json::as_bool), Some(true));
    assert_eq!(row0.get("attempts").and_then(Json::as_usize), Some(0));
    let row1 = shard_row(&status, 1);
    assert_eq!(row1.get("from_checkpoint").and_then(Json::as_bool), Some(false));
    assert_eq!(row1.get("attempts").and_then(Json::as_usize), Some(1));
    assert_eq!(strip_engine(&read_outcome(&run_dir)).to_string(), golden);
    // A corrupt checkpoint is re-run too (reported per-file, not a panic).
    let ckpt = run_dir.join("shards/shard-000.outcome.json");
    std::fs::write(ckpt, "{\"spec\": {tru").unwrap();
    let resumed = ccloud()
        .args(["run"])
        .arg(&spec_path)
        .args(["--resume"])
        .arg(&run_dir)
        .output()
        .unwrap();
    assert!(resumed.status.success(), "{}", String::from_utf8_lossy(&resumed.stderr));
    assert!(String::from_utf8_lossy(&resumed.stderr).contains("corrupt checkpoint"));
    assert_eq!(strip_engine(&read_outcome(&run_dir)).to_string(), golden);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A delayed child trips the per-shard timeout, is killed and reaped, and
/// the retry (no fault on attempt 1) succeeds.
#[test]
fn timeout_kills_and_retries() {
    let dir = temp_dir("timeout");
    let spec_path = write_spec(&dir, &spec(Task::Sweep, &["gpt2"]));
    let run_dir = dir.join("run");
    let out = ccloud()
        .args(["run"])
        .arg(spec_path)
        .args(["--distributed", "--run-dir"])
        .arg(&run_dir)
        .args(["--workers", "2", "--retries", "1", "--backoff-ms", "1"])
        .args(["--timeout-s", "1", "--fault-plan", "delay:0@0:20000"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let row = shard_row(&read_status(&run_dir), 0);
    assert_eq!(row.get("timeouts").and_then(Json::as_usize), Some(1));
    assert_eq!(row.get("attempts").and_then(Json::as_usize), Some(2));
    assert_eq!(row.get("ok").and_then(Json::as_bool), Some(true));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `shard` and `merge` subcommands round-trip through files: shard to
/// a directory, run each shard spec via `run --json`, merge the hand-built
/// envelopes, and match the single-process golden.
#[test]
fn shard_and_merge_subcommands_round_trip() {
    let dir = temp_dir("cli");
    let spec_path = write_spec(&dir, &spec(Task::Optimize, &["gpt2", "megatron"]));
    let golden = golden_json(&spec_path);
    let shards_dir = dir.join("shards");
    let out = ccloud()
        .args(["shard"])
        .arg(&spec_path)
        .args(["--workers", "2", "--out"])
        .arg(shards_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let mut envelope_paths = Vec::new();
    for line in String::from_utf8_lossy(&out.stdout).lines() {
        let shard_path = PathBuf::from(line.trim());
        let run = ccloud().args(["run"]).arg(&shard_path).arg("--json").output().unwrap();
        assert!(run.status.success(), "{}", String::from_utf8_lossy(&run.stderr));
        let spec_json = Json::parse(&std::fs::read_to_string(&shard_path).unwrap()).unwrap();
        let outcome = Json::parse(std::str::from_utf8(&run.stdout).unwrap().trim()).unwrap();
        let env_path = shard_path.with_extension("outcome.json");
        let mut m = std::collections::BTreeMap::new();
        m.insert("spec".to_string(), spec_json);
        m.insert("outcome".to_string(), outcome);
        std::fs::write(&env_path, format!("{}\n", Json::Obj(m))).unwrap();
        envelope_paths.push(env_path);
    }
    assert_eq!(envelope_paths.len(), 2);
    let merged = ccloud().args(["merge"]).args(&envelope_paths).output().unwrap();
    assert!(merged.status.success(), "{}", String::from_utf8_lossy(&merged.stderr));
    let v = Json::parse(std::str::from_utf8(&merged.stdout).unwrap().trim()).unwrap();
    assert_eq!(strip_engine(&v).to_string(), golden);
    // Dropping one envelope: partial merge, manifest on stdout, exit 1.
    let partial = ccloud().args(["merge"]).arg(&envelope_paths[0]).output().unwrap();
    assert!(!partial.status.success());
    let v = Json::parse(std::str::from_utf8(&partial.stdout).unwrap().trim()).unwrap();
    assert!(v.get("missing_shards").is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// File-handling hardening (satellite 2).

/// Missing and corrupt input files are located errors with a nonzero
/// exit — for `run`, for `run-shard`, and per-file for `merge`.
#[test]
fn file_errors_are_located_and_nonzero() {
    let dir = temp_dir("files");
    // Missing spec file.
    let missing = dir.join("nope.json");
    let out = ccloud().args(["run"]).arg(missing).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("nope.json"));
    // Truncated spec file.
    let truncated = dir.join("truncated.json");
    std::fs::write(&truncated, "{\"name\": \"x\", \"ta").unwrap();
    let out = ccloud().args(["run"]).arg(&truncated).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("truncated.json"));
    // Corrupt envelopes during merge: each is reported with its path; the
    // valid remainder still merges (exit 1 signals the degradation).
    let mut engine = Engine::new();
    let e = spec(Task::Optimize, &["gpt2", "megatron"]);
    let shards = plan(&e, 2, &mut engine).expect("plan");
    let good_env = Envelope::new(
        shards[0].clone(),
        engine.run(&shards[0]).expect("runs").to_json(),
    );
    let good = dir.join("good.outcome.json");
    std::fs::write(&good, format!("{}\n", good_env.to_json())).unwrap();
    let bad = dir.join("bad.outcome.json");
    std::fs::write(&bad, "not json at all").unwrap();
    let out = ccloud()
        .args(["merge"])
        .arg(&good)
        .arg(&bad)
        .arg(dir.join("absent.json"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(stderr.contains("bad.outcome.json"), "{stderr}");
    assert!(stderr.contains("absent.json"), "{stderr}");
    let v = Json::parse(std::str::from_utf8(&out.stdout).unwrap().trim()).unwrap();
    assert!(v.get("missing_shards").is_some(), "partial merge still printed");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `run --distributed` refuses several specs, a missing run dir flag, and
/// a resume against the wrong spec (fingerprint mismatch).
#[test]
fn distributed_flag_validation() {
    let dir = temp_dir("flags");
    let a = write_spec(&dir, &spec(Task::Sweep, &["gpt2"]));
    let out = ccloud().args(["run"]).arg(&a).args(["--distributed"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--run-dir"));
    // Wrong spec against an existing run dir.
    let run_dir = dir.join("run");
    let ok = ccloud()
        .args(["run"])
        .arg(&a)
        .args(["--distributed", "--run-dir"])
        .arg(&run_dir)
        .args(["--workers", "2"])
        .output()
        .unwrap();
    assert!(ok.status.success(), "{}", String::from_utf8_lossy(&ok.stderr));
    let b_spec = {
        let mut e = spec(Task::Sweep, &["megatron"]);
        e.name = "other".into();
        e
    };
    let b = dir.join("other.json");
    std::fs::write(&b, format!("{}\n", b_spec.to_json())).unwrap();
    let out = ccloud().args(["run"]).arg(&b).args(["--resume"]).arg(&run_dir).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fingerprint"));
    let _ = std::fs::remove_dir_all(&dir);
}
