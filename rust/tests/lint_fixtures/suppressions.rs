//! Fixture: malformed, reason-less, unknown-rule and stale suppressions —
//! each is itself a finding. Scanned as `src/fixture.rs` (Library class).

fn missing_reason(x: Option<u32>) -> u32 {
    x.unwrap() // cc-lint: allow(no-panic)
}

fn unknown_rule() {
    // cc-lint: allow(no-such-rule) the id does not exist
}

fn not_an_allow() {
    // cc-lint: forbid(no-panic) only allow(...) is a directive
}

fn stale() {
    // cc-lint: allow(no-panic) nothing on this or the next line panics
}
