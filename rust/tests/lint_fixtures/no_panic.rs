//! Fixture: `no-panic` violations, a suppressed occurrence, and clean code.
//! Scanned by `integration_lint.rs` as `src/fixture.rs` (Library class);
//! this directory is excluded from the workspace walk.

fn violations(x: Option<u32>, y: Result<u32, ()>) -> u32 {
    let a = x.unwrap();
    let b = y.expect("present");
    if a + b == 0 {
        panic!("boom");
    }
    todo!();
}

fn unfinished() {
    unimplemented!();
}

fn suppressed(m: &std::sync::Mutex<u32>) -> u32 {
    // cc-lint: allow(no-panic) lock poisoning is recovered by the caller's retry loop
    *m.lock().unwrap()
}

fn clean(x: Option<u32>, v: &[f64]) -> u32 {
    assert!(!v.is_empty());
    debug_assert_eq!(v.len(), v.len());
    x.unwrap_or_default()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        let x: Option<u32> = Some(1);
        assert_eq!(x.unwrap(), 1);
    }
}
