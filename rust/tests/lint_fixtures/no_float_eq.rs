//! Fixture: `no-float-eq` violations — bare literal float comparisons and
//! the NaN-panicking comparator — plus a suppressed exact comparison and
//! clean alternatives. Scanned as `src/fixture.rs` (Library class).

fn bare_comparisons(x: f64, y: f64) -> bool {
    let a = x == 0.0;
    let b = 0.5 != y;
    let c = x == -1.0;
    let d = y == 1e15;
    a && b && c && d
}

fn nan_hazard(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

fn suppressed(x: f64) -> bool {
    // cc-lint: allow(no-float-eq) 0.0 is the codec's exact absent-field sentinel
    x == 0.0
}

fn clean(v: &mut [f64], x: f64, y: f64) -> bool {
    v.sort_by(|a, b| a.total_cmp(b));
    let close = (x - y).abs() < 1e-9;
    let ints = (x as u64) == 3;
    let range = (1.0..=2.0).contains(&x);
    close && ints && range && x <= 0.5
}
