//! Fixture: a library file with zero findings. Every hazard the rules
//! police appears here only in its approved form — or hidden inside
//! strings, chars and comments, which the lexer must see through.

use std::collections::BTreeMap;

// unwrap() panic!() Instant::now() HashMap == 0.0  <- comment, not code
const DOC: &str = "unwrap() and HashMap and x == 0.0 inside a string";
const RAW: &str = r#"process::exit(1) in a raw string"#;
const BYTE: &[u8] = b"SystemTime in a byte string";
const CH: char = '"';

fn recover(x: Option<f64>, v: &mut [f64]) -> f64 {
    v.sort_by(|a, b| a.total_cmp(b));
    let y = x.unwrap_or(0.0);
    if (y - 1.0).abs() < f64::EPSILON {
        return v.first().copied().unwrap_or_default();
    }
    y
}

fn tabulate(rows: &[(String, u64)]) -> BTreeMap<String, u64> {
    let map: BTreeMap<String, u64> = rows.iter().cloned().collect();
    assert!(map.len() <= rows.len());
    map
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_panic_and_compare_exactly() {
        let x: Option<f64> = Some(2.0);
        assert!(x.unwrap() == 2.0);
        let s = DOC.to_string();
        assert!(!s.is_empty());
    }
}
