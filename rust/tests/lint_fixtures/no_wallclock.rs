//! Fixture: `no-wallclock` violations plus a suppressed occurrence.
//! Scanned as `src/perf/fixture.rs` (in scope) and as
//! `src/coordinator/fixture.rs` (allowlisted prefix — must be silent).

use std::time::{Duration, Instant};

fn violations() -> Duration {
    let t0 = Instant::now();
    let _epoch = std::time::SystemTime::UNIX_EPOCH;
    t0.elapsed()
}

fn suppressed() -> Instant {
    // cc-lint: allow(no-wallclock) operator-log timestamp, never enters a simulated quantity
    Instant::now()
}

fn clean(t: Instant, d: Duration) -> bool {
    t.elapsed() >= d
}
