//! Fixture: `no-unordered-iter` violations plus the ordered alternative.
//! Scanned as `src/report/fixture.rs` (serialization-adjacent, in scope)
//! and as `src/explore/fixture.rs` (out of scope — must be silent).

use std::collections::BTreeMap;
use std::collections::HashMap;

fn violation(rows: &[(String, f64)]) -> HashMap<String, f64> {
    rows.iter().cloned().collect()
}

fn suppressed(rows: &[(String, f64)]) -> usize {
    // cc-lint: allow(no-unordered-iter) counted then discarded; iteration order never escapes
    let m: std::collections::HashSet<&str> = rows.iter().map(|(k, _)| k.as_str()).collect();
    m.len()
}

fn clean(rows: &[(String, f64)]) -> BTreeMap<String, f64> {
    rows.iter().cloned().collect()
}
