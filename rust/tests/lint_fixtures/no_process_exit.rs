//! Fixture: `no-process-exit` violations. Scanned as `src/fixture.rs`
//! (flagged), as `tests/fixture.rs` (still flagged — the rule pierces
//! tests), and as `src/main.rs` (Binary class — silent).

fn violation(code: i32) -> ! {
    std::process::exit(code)
}

fn suppressed(code: i32) -> ! {
    // cc-lint: allow(no-process-exit) fault-injection child must die without unwinding
    std::process::exit(code)
}

fn clean(code: i32) -> Result<(), String> {
    if code != 0 {
        return Err(format!("exit code {code}"));
    }
    Ok(())
}
