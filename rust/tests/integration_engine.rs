//! Regression and property tests for the parallel, Pareto-guided,
//! branch-and-bound sweep engine: the pruned/parallel search must return
//! exactly what the seed's exhaustive sequential search returns — the
//! Pareto guidance and lower-bound cutoff may only change wall-clock,
//! never the optimum.

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, ServeSpec, SloSpec, TrafficSpec, Workload};
use chiplet_cloud::evaluate::{self, SloSelection, SweepEngine, WorkloadBounds};
use chiplet_cloud::explore::{pareto, phase1, phase1_seq};
use chiplet_cloud::sched::RoutePolicy;
use chiplet_cloud::util::prop::check;

fn setup() -> (ExploreSpace, Vec<chiplet_cloud::arch::ServerDesign>) {
    let space = ExploreSpace::coarse();
    let (servers, _) = phase1(&space);
    (space, servers)
}

/// The headline regression: parallel + pruned + Pareto-ordered sweep ==
/// exhaustive sequential sweep on `ExploreSpace::coarse()`, bit-exact.
#[test]
fn engine_best_point_matches_sequential_exhaustive() {
    let (space, servers) = setup();
    let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
    let seq = SweepEngine::sequential().best_point(&space, &servers, &w).expect("feasible");
    let eng = SweepEngine::default().best_point(&space, &servers, &w).expect("feasible");
    assert_eq!(eng.mapping, seq.mapping);
    assert_eq!(eng.server, seq.server);
    assert_eq!(eng.n_servers, seq.n_servers);
    assert_eq!(eng.tco_per_token.to_bits(), seq.tco_per_token.to_bits());
    assert_eq!(eng.perf.tokens_per_s.to_bits(), seq.perf.tokens_per_s.to_bits());
}

/// Grid version of the regression, over a multi-workload grid.
#[test]
fn engine_best_over_grid_matches_sequential_exhaustive() {
    let (space, servers) = setup();
    let m = ModelSpec::megatron();
    let grid: Vec<Workload> = [(1024usize, 32usize), (1024, 128), (2048, 64)]
        .iter()
        .map(|&(c, b)| Workload::new(m.clone(), c, b))
        .collect();
    let (w_seq, p_seq) =
        SweepEngine::sequential().best_over_grid(&space, &servers, &grid).expect("feasible");
    let (w_eng, p_eng) =
        SweepEngine::default().best_over_grid(&space, &servers, &grid).expect("feasible");
    assert_eq!((w_eng.ctx, w_eng.batch), (w_seq.ctx, w_seq.batch));
    assert_eq!(p_eng.mapping, p_seq.mapping);
    assert_eq!(p_eng.server, p_seq.server);
    assert_eq!(p_eng.tco_per_token.to_bits(), p_seq.tco_per_token.to_bits());
}

/// The per-server scatter (Fig. 7 input) must also be identical — order,
/// length, and every point.
#[test]
fn engine_sweep_scatter_matches_sequential() {
    let (space, servers) = setup();
    let w = Workload::new(ModelSpec::megatron(), 1024, 64);
    let seq = SweepEngine::sequential().sweep(&space, &servers, &w);
    let eng = SweepEngine::default().sweep(&space, &servers, &w);
    assert_eq!(seq.len(), eng.len());
    for (a, b) in seq.iter().zip(eng.iter()) {
        assert_eq!(a.server, b.server);
        assert_eq!(a.mapping, b.mapping);
        assert_eq!(a.tco_per_token.to_bits(), b.tco_per_token.to_bits());
    }
}

/// Property: across randomized workloads, the Pareto-guided pruned engine
/// never drops the global TCO/Token optimum — it returns exactly the
/// exhaustive optimum (model, context, and batch drawn from a seeded RNG).
#[test]
fn property_pruned_engine_never_drops_the_optimum() {
    let (space, servers) = setup();
    let models = [ModelSpec::megatron(), ModelSpec::llama2_70b()];
    check("pruned engine == exhaustive optimum", 4, |rng| {
        let m = rng.pick(&models).clone();
        let ctx = 1024 << rng.below(2); // 1024 or 2048
        let batch = 1 << rng.below(9); // 1..256
        let w = Workload::new(m, ctx, batch);
        let seq = SweepEngine::sequential().best_point(&space, &servers, &w);
        let eng = SweepEngine::default().best_point(&space, &servers, &w);
        match (seq, eng) {
            (None, None) => {}
            (Some(s), Some(e)) => {
                assert_eq!(
                    e.tco_per_token.to_bits(),
                    s.tco_per_token.to_bits(),
                    "optimum diverged at ctx {ctx} batch {batch}"
                );
                assert_eq!(e.mapping, s.mapping);
                assert_eq!(e.server, s.server);
            }
            (s, e) => panic!(
                "feasibility diverged at ctx {ctx} batch {batch}: seq={} eng={}",
                s.is_some(),
                e.is_some()
            ),
        }
    });
}

/// Property: the admissible lower bound really is admissible — it never
/// exceeds the true TCO/Token of any evaluated design point.
#[test]
fn property_lower_bound_is_admissible() {
    let (space, servers) = setup();
    check("TCO/Token lower bound admissible", 4, |rng| {
        let m = if rng.chance(0.5) { ModelSpec::megatron() } else { ModelSpec::gpt3() };
        let w = Workload::new(m, 1024 << rng.below(2), 8 << rng.below(5));
        let wb = WorkloadBounds::new(&w);
        // Sample a slice of the server set to keep the property fast.
        let start = rng.below(servers.len().max(1));
        let sample: Vec<_> = servers.iter().skip(start).step_by(17).cloned().collect();
        for p in evaluate::sweep(&space, &sample, &w) {
            let lb = wb.server_lower_bound(&space, &p.server);
            assert!(
                lb <= p.tco_per_token * (1.0 + 1e-12),
                "bound {lb} > true {} (die {})",
                p.tco_per_token,
                p.server.chiplet.die_mm2
            );
        }
    });
}

/// Compare two SLO selections: the chosen design byte-identical, the
/// winner's confirming report `meets`-equivalent *and* tail-identical.
fn assert_selection_identical(reference: Option<SloSelection>, fast: Option<SloSelection>) {
    match (reference, fast) {
        (Some(r), Some(f)) => {
            assert_eq!(f.point.mapping, r.point.mapping, "mapping diverged");
            assert_eq!(f.point.server, r.point.server, "server diverged");
            assert_eq!(f.point.n_servers, r.point.n_servers, "server count diverged");
            assert_eq!(
                f.point.tco_per_token.to_bits(),
                r.point.tco_per_token.to_bits(),
                "TCO/Token diverged"
            );
            assert!(!f.report.aborted_early, "a winning validation must never abort");
            assert_eq!(f.report.completed, r.report.completed);
            assert_eq!(f.report.tokens, r.report.tokens);
            assert_eq!(f.report.iterations, r.report.iterations);
            assert_eq!(f.report.ttft_p99_s.to_bits(), r.report.ttft_p99_s.to_bits());
            assert_eq!(f.report.tpot_p99_s.to_bits(), r.report.tpot_p99_s.to_bits());
            assert_eq!(f.report.makespan_s.to_bits(), r.report.makespan_s.to_bits());
            assert_eq!(f.report.occupancy.to_bits(), r.report.occupancy.to_bits());
        }
        (None, None) => {}
        (r, f) => panic!(
            "feasibility diverged: reference={} fast={}",
            r.is_some(),
            f.is_some()
        ),
    }
}

/// The acceptance regression for the fast SLO-validation path:
/// fast-forward + early abort + speculative parallel stage-2 select a
/// byte-identical design (and a `meets`-equivalent, tail-identical
/// winner's report) versus the sequential reference scan, on the coarse
/// space — under the plain serving model *and* with every serving-model
/// knob on (chunked prefill, paged KV, 2 replicas behind JSQ).
#[test]
fn fast_slo_stage2_selects_identically_to_reference() {
    let (space, servers) = setup();
    let w = Workload::new(ModelSpec::megatron(), 1024, 64);
    let fastest = SweepEngine::sequential()
        .sweep(&space, &servers, &w)
        .iter()
        .map(|p| p.perf.token_period)
        .fold(f64::INFINITY, f64::min);
    assert!(fastest.is_finite());
    let reference_engine = SweepEngine::sequential();
    let fast_engine = SweepEngine { threads: 0, prune: true, pareto_order: true, fast_sim: true };

    // Plain serving model, binding TPOT over a queueing open-loop trace.
    let slo = SloSpec::new(f64::INFINITY, fastest * 2.0);
    let plain = ServeSpec::new(TrafficSpec::poisson(0.5, 50, 24, 16, 64).with_seed(29), slo);
    assert_selection_identical(
        reference_engine.best_point_slo(&space, &servers, &w, &plain),
        fast_engine.best_point_slo(&space, &servers, &w, &plain),
    );

    // Full serving model: chunked prefill + paged KV + 2 replicas (JSQ).
    let full = ServeSpec::new(TrafficSpec::closed_loop(8, 0.0, 40, 512, 16, 64).with_seed(31), slo)
        .with_chunked_prefill(64)
        .with_paged_kv()
        .with_replicas(2, RoutePolicy::Jsq);
    assert_selection_identical(
        reference_engine.best_point_slo(&space, &servers, &w, &full),
        fast_engine.best_point_slo(&space, &servers, &w, &full),
    );

    // An impossible SLO agrees on infeasibility.
    let impossible = ServeSpec::new(
        TrafficSpec::poisson(0.5, 30, 24, 16, 64),
        SloSpec::new(f64::INFINITY, 1e-15),
    );
    assert_selection_identical(
        reference_engine.best_point_slo(&space, &servers, &w, &impossible),
        fast_engine.best_point_slo(&space, &servers, &w, &impossible),
    );
}

/// The speculative wave size must never change the selection: 1, 2 and
/// auto threads agree bit-for-bit (waves only trade speculative work for
/// wall-clock; results commit in ascending-TCO order).
#[test]
fn stage2_wave_size_never_changes_the_selection() {
    let (space, servers) = setup();
    let w = Workload::new(ModelSpec::megatron(), 1024, 64);
    let fastest = SweepEngine::sequential()
        .sweep(&space, &servers, &w)
        .iter()
        .map(|p| p.perf.token_period)
        .fold(f64::INFINITY, f64::min);
    let slo = SloSpec::new(f64::INFINITY, fastest * 2.0);
    let spec = ServeSpec::new(TrafficSpec::poisson(0.5, 40, 24, 16, 64).with_seed(41), slo);
    let base = SweepEngine { threads: 1, prune: true, pareto_order: true, fast_sim: true }
        .best_point_slo(&space, &servers, &w, &spec);
    for threads in [2usize, 0] {
        let engine = SweepEngine { threads, prune: true, pareto_order: true, fast_sim: true };
        assert_selection_identical(
            base.clone(),
            engine.best_point_slo(&space, &servers, &w, &spec),
        );
    }
}

/// The Pareto frontier is consistent with phase 1 and the engine ordering:
/// a permutation that never loses a server (no hard drops on dominance).
#[test]
fn pareto_order_covers_every_server() {
    let (_, servers) = setup();
    let mut order = pareto::frontier_first_order(&servers);
    assert_eq!(order.len(), servers.len());
    order.sort_unstable();
    assert!(order.iter().copied().eq(0..servers.len()));
    let frontier = pareto::frontier_indices(&servers);
    assert!(!frontier.is_empty() && frontier.len() < servers.len());
}

/// Parallel phase 1 must be order- and value-identical to the sequential
/// sweep (the chiplet derivation is hoisted and shared per tuple).
#[test]
fn parallel_phase1_identical_to_sequential() {
    let space = ExploreSpace::coarse();
    let (par, _) = phase1(&space);
    let (seq, _) = phase1_seq(&space);
    assert_eq!(par, seq);
}
