//! Cross-module property tests and failure injection: invariants that must
//! hold over randomized inputs, plus edge/error paths through the stack.

use chiplet_cloud::arch::ChipletDesign;
use chiplet_cloud::config::hardware::{ExploreSpace, TechParams};
use chiplet_cloud::config::{ModelSpec, Workload};
use chiplet_cloud::cost::{die_cost, die_yield, TcoModel};
use chiplet_cloud::mapping::{optimizer, Mapping};
use chiplet_cloud::perf::simulate;
use chiplet_cloud::util::prop::check;
use chiplet_cloud::util::rng::Rng;

fn random_chip(rng: &mut Rng) -> ChipletDesign {
    let die = 40.0 + rng.f64() * 600.0;
    let tflops = 2.0 + rng.f64() * 30.0;
    let bw = tflops * 1e3 * (0.1 + rng.f64());
    ChipletDesign {
        die_mm2: die,
        sram_mb: 50.0 + rng.f64() * 500.0,
        tflops,
        mem_bw_gbps: bw,
        n_bank_groups: 16 + rng.below(256),
        io_link_gbps: 25.0,
        io_links: 4,
        tdp_w: 5.0 + rng.f64() * 30.0,
    }
}

fn random_server(rng: &mut Rng) -> chiplet_cloud::arch::ServerDesign {
    chiplet_cloud::arch::ServerDesign {
        chiplet: random_chip(rng),
        chips_per_lane: 1 + rng.below(20),
        lanes: 8,
        server_power_w: 500.0 + rng.f64() * 2000.0,
        server_capex: 2_000.0 + rng.f64() * 30_000.0,
    }
}

/// Yield and die cost are monotone in area for any valid defect density.
#[test]
fn die_economics_monotone_property() {
    check("die cost monotone in area", 100, |rng| {
        let mut t = TechParams::default();
        t.defect_density_per_cm2 = 0.05 + rng.f64() * 0.3;
        let a = 20.0 + rng.f64() * 350.0;
        let b = a + 10.0 + rng.f64() * 300.0;
        assert!(die_yield(&t, a) > die_yield(&t, b));
        assert!(die_cost(&t, a) < die_cost(&t, b));
    });
}

/// TCO accounting identities hold for any inputs.
#[test]
fn tco_identities_property() {
    check("tco identities", 100, |rng| {
        let m = TcoModel::default();
        let capex = rng.f64() * 1e5;
        let watts = rng.f64() * 3e3;
        let tco = m.server_tco(capex, watts);
        let sum = tco.capex + tco.energy + tco.facility + tco.maintenance;
        assert!((tco.total() - sum).abs() < 1e-9);
        assert!(tco.capex_frac() >= 0.0 && tco.capex_frac() <= 1.0);
        let tput = 1.0 + rng.f64() * 1e5;
        assert!((tco.per_mtok(tput) - tco.per_token(tput) * 1e6).abs() < 1e-9);
    });
}

/// Simulation sanity over random hardware/mapping: throughput positive,
/// utilizations in [0,1], and the pipeline law period = max(l_mb, n·l_s).
#[test]
fn simulation_invariants_property() {
    let model = ModelSpec::megatron();
    check("simulate invariants", 150, |rng| {
        let server = random_server(rng);
        let w = Workload::new(model.clone(), 1024 << rng.below(3), 1 << rng.below(9));
        let pp = *rng.pick(&optimizer::divisors(model.n_layers));
        let n_min = optimizer::min_chips(&server, &w);
        let tp = n_min.div_ceil(pp).max(1);
        let mapping = Mapping { tp, pp, microbatch: 1 << rng.below(4) };
        if let Some(p) = simulate(&server, &w, &mapping) {
            assert!(p.tokens_per_s > 0.0);
            assert!((0.0..=1.0).contains(&p.compute_util));
            assert!((0.0..=1.0).contains(&p.mem_util));
            assert!((0.0..=1.0).contains(&p.comm_frac));
            let n_micro = mapping.n_micro(w.batch);
            let expect = p.microbatch_latency.max(n_micro as f64 * p.stage_latency);
            assert!((p.token_period - expect).abs() / expect < 1e-9);
            assert!(
                (p.tokens_per_s - w.batch as f64 / p.token_period).abs() / p.tokens_per_s < 1e-9
            );
        }
    });
}

/// Feasibility is monotone in SRAM: if a mapping fits a chip, it fits any
/// chip with more SRAM (all else equal).
#[test]
fn memory_feasibility_monotone_property() {
    let model = ModelSpec::llama2_70b();
    check("sram monotonicity", 100, |rng| {
        let mut server = random_server(rng);
        let w = Workload::new(model.clone(), 2048, 1 << rng.below(7));
        let pp = *rng.pick(&optimizer::divisors(model.n_layers));
        let tp = optimizer::min_chips(&server, &w).div_ceil(pp).max(1);
        let mapping = Mapping { tp, pp, microbatch: 1 };
        let fits_small = simulate(&server, &w, &mapping).is_some();
        server.chiplet.sram_mb *= 2.0;
        let fits_big = simulate(&server, &w, &mapping).is_some();
        if fits_small {
            assert!(fits_big, "doubling SRAM must not break feasibility");
        }
    });
}

/// More sparsity never increases the stored footprint, and the read scale
/// is never below dense.
#[test]
fn sparsity_scales_property() {
    check("sparsity scales", 100, |rng| {
        let s1 = rng.f64() * 0.9;
        let s2 = s1 + rng.f64() * (0.9 - s1);
        let m = ModelSpec::opt_175b();
        let w1 = Workload::new(m.clone(), 2048, 8).with_sparsity(s1);
        let w2 = Workload::new(m.clone(), 2048, 8).with_sparsity(s2);
        assert!(w2.stored_weight_bytes() <= w1.stored_weight_bytes() + 1e-3);
        assert!(w1.weight_read_scale >= 1.0 && w2.weight_read_scale >= 1.0);
    });
}

/// Phase-1 → Phase-2 composition never produces a design point violating
/// the hard constraints it was filtered by.
#[test]
fn phase2_points_respect_phase1_constraints() {
    let space = ExploreSpace::coarse();
    let (servers, _) = chiplet_cloud::explore::phase1(&space);
    let w = Workload::new(ModelSpec::gpt3(), 2048, 64);
    for p in chiplet_cloud::evaluate::sweep(&space, &servers, &w).iter().take(200) {
        assert!(p.server.chiplet.die_mm2 <= space.tech.reticle_mm2);
        assert!(
            p.server.chiplet.power_density() <= space.tech.max_power_density_w_mm2 + 1e-9
        );
        assert!(p.n_servers * p.server.chips() >= p.mapping.n_chips());
        assert!(p.tco.total() > 0.0);
        assert!(p.tco_per_token.is_finite());
    }
}

/// Failure injection: unknown models, impossible workloads, and broken
/// artifacts fail loudly rather than corrupting results.
#[test]
fn failure_paths_are_errors() {
    // unknown model name
    assert!(ModelSpec::by_name("gpt17-zeta").is_none());
    // unmappable: pipeline deeper than the layer count
    let server = {
        let mut rng = Rng::new(5);
        random_server(&mut rng)
    };
    let w = Workload::new(ModelSpec::megatron(), 1024, 8);
    assert!(simulate(&server, &w, &Mapping { tp: 4, pp: 10_000, microbatch: 1 }).is_none());
    // microbatch larger than batch
    assert!(simulate(&server, &w, &Mapping { tp: 400, pp: 8, microbatch: 64 }).is_none());
    // broken artifact dir
    assert!(chiplet_cloud::runtime::Manifest::load("/nonexistent", "cc-tiny").is_err());
    // malformed manifest JSON
    let dir = std::env::temp_dir().join("cc-bad-artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.manifest.json"), b"{not json").unwrap();
    assert!(chiplet_cloud::runtime::Manifest::load(&dir, "bad").is_err());
}

/// A request served in a padded (partial) batch generates exactly the same
/// tokens as when its batch is full — per-sequence independence through
/// the entire AOT/PJRT/coordinator stack.
#[test]
fn padded_batch_matches_full_batch() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("cc-tiny.manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    use chiplet_cloud::coordinator::{Coordinator, CoordinatorConfig};
    use std::time::Duration;
    let probe_prompt = vec![42, 7, 99, 3];
    let run = |extra: usize| {
        let coord = Coordinator::start(
            &dir,
            "cc-tiny",
            CoordinatorConfig { max_wait: Duration::from_millis(5), ..CoordinatorConfig::default() },
        )
        .unwrap();
        let id = coord.submit(probe_prompt.clone(), 5);
        for i in 0..extra {
            coord.submit(vec![i as i32 + 1; 6], 5);
        }
        let rs = coord.shutdown().unwrap();
        rs.into_iter().find(|r| r.id == id).unwrap().tokens
    };
    let alone = run(0); // padded batch (1 live slot of 4)
    let full = run(3); // full batch
    assert_eq!(alone, full, "padding slots must not perturb live sequences");
}
