//! Integration tests over the full two-phase DSE pipeline: the qualitative
//! shape of every paper result must hold on the coarse sweep (who wins, by
//! roughly what factor, where optima/crossovers fall).

use chiplet_cloud::baselines::{gpu, tpu};
use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, Workload};
use chiplet_cloud::evaluate::{self, sparsity};
use chiplet_cloud::explore::phase1;
use chiplet_cloud::report::{self, Ctx};
use chiplet_cloud::util::stats::total_cmp_f64;

fn ctx() -> Ctx {
    Ctx::coarse()
}

/// Table 2 headline: GPT-3's optimal TCO/1M tokens is ~\$0.161; shape
/// tolerance ±3x on the coarse grid.
#[test]
fn table2_gpt3_cost_magnitude() {
    let c = ctx();
    let grid = Workload::study_grid(&ModelSpec::gpt3());
    let (w, p) = evaluate::best_over_grid(&c.space, &c.servers, &grid).expect("design");
    assert!((0.05..=0.5).contains(&p.tco_per_mtok()), "$/1M = {}", p.tco_per_mtok());
    // paper: all TCO-optimal designs use batch >= 32
    assert!(w.batch >= 32, "optimal batch {}", w.batch);
    // tokens/s/chip is design-dependent (the coarse grid can pick a more
    // compute-dense chip than Table 2's); the Table-2-like fixed-server
    // comparison (8.1 tok/s/chip ±50%) lives in perf::simulator tests.
    assert!(p.perf.tokens_per_s_chip > 3.0, "tok/s/chip {}", p.perf.tokens_per_s_chip);
}

/// Fig. 7: the TCO-optimal die is well below the reticle limit, and
/// reticle-class dies cost ~2x more for the same throughput target.
#[test]
fn fig7_small_dies_win() {
    let c = ctx();
    let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
    let pts = evaluate::sweep(&c.space, &c.servers, &w);
    let best = pts
        .iter()
        .min_by(|a, b| total_cmp_f64(&a.tco_per_token, &b.tco_per_token))
        .unwrap();
    assert!(best.server.chiplet.die_mm2 <= 400.0, "optimal die {}", best.server.chiplet.die_mm2);
    // best big-die (>=700) point vs best overall
    let big = pts
        .iter()
        .filter(|p| p.server.chiplet.die_mm2 >= 700.0)
        .map(|p| p.tco_per_token)
        .fold(f64::INFINITY, f64::min);
    if big.is_finite() {
        let ratio = big / best.tco_per_token;
        assert!(ratio > 1.3, "big-die penalty only {ratio}");
    }
}

/// Fig. 8: for MHA models TCO/Token degrades at batch 1024 vs the optimum,
/// while MQA (PaLM) stays near-optimal at 1024.
#[test]
fn fig8_attention_variant_batch_behaviour() {
    let c = ctx();
    let best_at = |m: &ModelSpec, batch: usize| {
        evaluate::best_point(&c.space, &c.servers, &Workload::new(m.clone(), 2048, batch))
            .map(|p| p.tco_per_token)
    };
    // MHA: GPT-3
    let gpt3_opt = [32, 64, 128, 256]
        .iter()
        .filter_map(|&b| best_at(&ModelSpec::gpt3(), b))
        .fold(f64::INFINITY, f64::min);
    let gpt3_1024 = best_at(&ModelSpec::gpt3(), 1024).unwrap();
    let mha_penalty = gpt3_1024 / gpt3_opt;
    // MQA: PaLM
    let palm_opt = [32, 64, 128, 256]
        .iter()
        .filter_map(|&b| best_at(&ModelSpec::palm(), b))
        .fold(f64::INFINITY, f64::min);
    let palm_1024 = best_at(&ModelSpec::palm(), 1024).unwrap();
    let mqa_penalty = palm_1024 / palm_opt;
    assert!(
        mha_penalty > mqa_penalty,
        "MHA batch-1024 penalty ({mha_penalty:.2}) must exceed MQA's ({mqa_penalty:.2})"
    );
    assert!(mqa_penalty < 1.4, "PaLM stays near-optimal at 1024: {mqa_penalty:.2}");
}

/// Fig. 10 headline: at Google-search scale the rented-GPU/TPU to CC
/// improvement is ~97x / ~18x (we assert the order of magnitude).
#[test]
fn fig10_headline_ratios() {
    let c = ctx();
    let cc_gpt3 = evaluate::best_over_grid(
        &c.space,
        &c.servers,
        &Workload::study_grid(&ModelSpec::gpt3()),
    )
    .unwrap()
    .1
    .tco_per_token;
    let cc_palm = evaluate::best_over_grid(
        &c.space,
        &c.servers,
        &Workload::study_grid(&ModelSpec::palm()),
    )
    .unwrap()
    .1
    .tco_per_token;
    // Google scale: 99k q/s * 500 tokens * 1 year => NRE fully amortized
    let tokens = 99_000.0 * 500.0 * 365.25 * 86400.0;
    let nre = chiplet_cloud::cost::nre::NreModel::default();
    let x_gpu = gpu::rented_tco_per_token(&gpu::a100()) / nre.nre_plus_tco_per_token(cc_gpt3, tokens);
    let x_tpu = tpu::rented_tco_per_token(&tpu::tpu_v4()) / nre.nre_plus_tco_per_token(cc_palm, tokens);
    assert!((40.0..=300.0).contains(&x_gpu), "GPU improvement {x_gpu} (paper 97x)");
    assert!((8.0..=60.0).contains(&x_tpu), "TPU improvement {x_tpu} (paper 18x)");
    assert!(x_gpu > x_tpu, "GPU margin exceeds TPU margin");
}

/// Fig. 12: Chiplet Cloud's advantage over TPUv4 is largest at small batch
/// (paper: up to 3.7x at batch 4) and shrinks at large batch.
#[test]
fn fig12_small_batch_advantage() {
    let c = ctx();
    let spec = tpu::tpu_v4();
    let tpu_fab = tpu::fabricated_tco(&spec, &c.space);
    let adv = |batch: usize| -> Option<f64> {
        let w = Workload::new(ModelSpec::palm(), 2048, batch);
        let cc = evaluate::best_point(&c.space, &c.servers, &w)?.tco_per_token;
        let t = tpu_fab.per_token(tpu::palm_tokens_per_chip(&spec, batch));
        Some(t / cc)
    };
    let a4 = adv(4).expect("batch 4 feasible");
    let a1024 = adv(1024).expect("batch 1024 feasible");
    assert!(a4 > a1024, "small-batch advantage {a4:.2} must exceed large-batch {a1024:.2}");
    assert!(a4 > 1.5, "CC wins at batch 4: {a4:.2} (paper 3.7x)");
}

/// Fig. 13: 60% sparsity reduces TCO/Token while 10–20% increases it.
#[test]
fn fig13_sparsity_knee() {
    let c = ctx();
    let pts = sparsity::sparsity_sweep(
        &c.space,
        &c.servers,
        &ModelSpec::opt_175b(),
        2048,
        64,
        &[0.2, 0.6],
    );
    let at = |s: f64| pts.iter().find(|p| (p.sparsity - s).abs() < 1e-9).unwrap();
    assert!(at(0.2).tco_delta_frac >= -0.005, "20%: {}", at(0.2).tco_delta_frac);
    assert!(at(0.6).tco_delta_frac < 0.0, "60%: {}", at(0.6).tco_delta_frac);
}

/// Phase-1 feasible-design volume matches the paper's "tens of thousands"
/// on the full grid.
#[test]
fn phase1_full_volume() {
    let (designs, _) = phase1(&ExploreSpace::default());
    assert!(designs.len() > 5_000, "{}", designs.len());
}

/// All report harnesses produce non-empty tables on the coarse context.
#[test]
fn all_harnesses_nonempty() {
    let c = ctx();
    assert!(report::table2(&c, &[ModelSpec::megatron()], None).len() == 1);
    assert!(!report::fig7(&c, None).is_empty());
    assert!(!report::fig9(&c, &[64], None).is_empty());
    assert!(!report::fig10(&c, None).is_empty());
    assert!(!report::fig12(&c, None).is_empty());
    assert!(!report::fig15(None).is_empty());
}
