//! Integration tests over the trace-driven serving layer: the shared
//! scheduling policies, the discrete-event simulator, and the
//! SLO-constrained design selection.
//!
//! These run entirely on analytic/virtual time — no artifacts needed.

use chiplet_cloud::arch::{ChipletDesign, ServerDesign};
use chiplet_cloud::config::{ArrivalProcess, ModelSpec, SloSpec, TrafficSpec, Workload};
use chiplet_cloud::mapping::Mapping;
use chiplet_cloud::perf::events::{open_loop_trace, simulate_trace, IterCost, SimConfig};
use chiplet_cloud::perf::simulate;
use chiplet_cloud::sched::{ContinuousBatch, KvBudget, StaticBatch};
use chiplet_cloud::util::prop::check;

fn synthetic_cfg(slots: usize) -> SimConfig {
    SimConfig {
        max_slots: slots,
        kv: KvBudget::unlimited(),
        cost: IterCost { prefill_s_per_token: 0.0001, decode_step_s: 0.01 },
    }
}

/// The Table-2 GPT-3 design used by the perf simulator's own tests.
fn gpt3_server() -> ServerDesign {
    ServerDesign {
        chiplet: ChipletDesign {
            die_mm2: 140.0,
            sram_mb: 225.8,
            tflops: 5.5,
            mem_bw_gbps: 2750.0,
            n_bank_groups: 172,
            io_link_gbps: 25.0,
            io_links: 4,
            tdp_w: 14.1,
        },
        chips_per_lane: 17,
        lanes: 8,
        server_power_w: 2020.0,
        server_capex: 5300.0,
    }
}

/// Deterministic seeded-trace golden test: the same spec always produces
/// the same trace and the same simulated tails, and a different seed
/// produces a different schedule.
#[test]
fn seeded_trace_golden() {
    let t = TrafficSpec::poisson(35.0, 250, 24, 4, 40).with_seed(2024);
    let run = |seed: u64| {
        let t = t.with_seed(seed);
        let rep = simulate_trace(&synthetic_cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        (
            rep.completed,
            rep.tokens,
            rep.iterations,
            rep.ttft_p99_s.to_bits(),
            rep.tpot_p99_s.to_bits(),
            rep.makespan_s.to_bits(),
        )
    };
    let a = run(2024);
    assert_eq!(a, run(2024), "same seed must replay bit-identically");
    assert_eq!(a.0, 250);
    let b = run(77);
    assert!(a.3 != b.3 || a.5 != b.5, "different seeds must differ");
    // The trace itself is stable too.
    let arr = open_loop_trace(&t);
    let arr2 = open_loop_trace(&t);
    assert_eq!(arr.len(), 250);
    for (x, y) in arr.iter().zip(&arr2) {
        assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
        assert_eq!(x.new_tokens, y.new_tokens);
    }
}

/// Property: closed-loop occupancy never exceeds the KV-capacity budget,
/// across random budgets, client counts and token shapes.
#[test]
fn closed_loop_never_exceeds_kv_budget() {
    check("closed-loop occupancy respects the KV budget", 40, |r| {
        let slots = 2 + r.below(15);
        let kv_seqs = 1 + r.below(slots + 4); // sometimes tighter than slots
        let clients = 1 + r.below(30);
        let t = TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop {
                clients,
                think_s: r.f64() * 0.02,
            },
            requests: 30 + r.below(60),
            prompt_tokens: 1 + r.below(32),
            new_tokens_lo: 1,
            new_tokens_hi: 1 + r.below(24),
            seed: r.next_u64(),
        };
        let cfg = SimConfig {
            max_slots: slots,
            kv: KvBudget::seqs(kv_seqs),
            cost: IterCost { prefill_s_per_token: 0.0002, decode_step_s: 0.005 },
        };
        let rep = simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let cap = kv_seqs.min(slots);
        assert!(
            rep.peak_live <= cap,
            "peak live {} exceeds budget {} (slots {}, kv {})",
            rep.peak_live,
            cap,
            slots,
            kv_seqs
        );
        assert_eq!(rep.completed, t.requests, "every request must complete");
    });
}

/// Sanity: with no latency constraint and saturating closed-loop traffic,
/// the event simulator's throughput converges to the steady-state
/// simulator's tokens/s (±10%) — the two performance models agree where
/// their domains overlap.
#[test]
fn event_sim_converges_to_steady_state_throughput() {
    let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
    let mapping = Mapping { tp: 136, pp: 96, microbatch: 2 };
    let perf = simulate(&gpt3_server(), &w, &mapping).expect("fits");

    // Tiny prompts + long generations keep the (decode-rate) steady-state
    // metric comparable; clients == batch keeps every slot busy.
    let t = TrafficSpec::closed_loop(256, 0.0, 1024, 1, 200, 200).with_seed(5);
    let cfg = SimConfig {
        max_slots: w.batch,
        kv: KvBudget::from_design(&gpt3_server(), &w, &mapping),
        cost: IterCost::from_perf(&perf, &w),
    };
    let rep = simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
    assert_eq!(rep.completed, 1024);
    assert!(rep.occupancy > 0.9, "saturating trace must fill slots: {}", rep.occupancy);
    let ratio = rep.tokens_per_s / perf.tokens_per_s;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "event-sim {} vs steady-state {} tokens/s (ratio {ratio})",
        rep.tokens_per_s,
        perf.tokens_per_s
    );
}

/// The headline acceptance property: on a seeded high-load Poisson trace,
/// continuous batching beats the static batch-synchronous policy on both
/// goodput and p99 TTFT.
#[test]
fn continuous_beats_static_at_high_load() {
    // 8 slots at 10 ms/step ⇒ ~800 tok/s capacity; mean 18 tokens/request
    // ⇒ ~44 req/s saturation. 30 req/s is high load without overload.
    let t = TrafficSpec::poisson(30.0, 400, 16, 4, 32).with_seed(11);
    let slo = SloSpec::new(0.25, 0.015);
    let cfg = synthetic_cfg(8);
    let st = simulate_trace(&cfg, &mut StaticBatch::new(0.05), &t, &slo);
    let co = simulate_trace(&cfg, &mut ContinuousBatch, &t, &slo);
    assert_eq!(st.completed, 400);
    assert_eq!(co.completed, 400);
    assert!(
        co.goodput_tokens_per_s > st.goodput_tokens_per_s,
        "continuous goodput {} must beat static {}",
        co.goodput_tokens_per_s,
        st.goodput_tokens_per_s
    );
    assert!(
        co.ttft_p99_s < st.ttft_p99_s,
        "continuous p99 TTFT {} must beat static {}",
        co.ttft_p99_s,
        st.ttft_p99_s
    );
    // Same total work, so raw token throughput is also no worse.
    assert!(co.tokens_per_s >= st.tokens_per_s * 0.999);
}

/// Mirror of the live-coordinator regression: even under a pathological
/// arrival pattern the simulator never executes an empty iteration — every
/// iteration has at least one live or admitted sequence.
#[test]
fn no_empty_iterations_under_sparse_traffic() {
    // Arrivals far apart relative to service time: the scheduler must idle
    // between them, not spin.
    let t = TrafficSpec::poisson(0.5, 20, 8, 2, 4).with_seed(3);
    let rep = simulate_trace(&synthetic_cfg(4), &mut StaticBatch::new(0.01), &t, &SloSpec::unconstrained());
    assert_eq!(rep.completed, 20);
    // Each request needs at most 1 admission + (tokens-1) decode
    // iterations; idle time must never manifest as extra iterations.
    let max_iters: u64 = rep.per_request.iter().map(|r| r.tokens as u64).sum();
    assert!(rep.iterations <= max_iters, "{} > {}", rep.iterations, max_iters);
    assert!(rep.occupancy > 0.0);
}
