//! Integration tests over the trace-driven serving layer: the shared
//! scheduling policies, the discrete-event simulator, and the
//! SLO-constrained design selection.
//!
//! These run entirely on analytic/virtual time — no artifacts needed.

use chiplet_cloud::arch::{ChipletDesign, ServerDesign};
use chiplet_cloud::config::{
    ArrivalProcess, FaultSpec, ModelSpec, OvercommitSpec, ServeSpec, SloSpec, TierSpec, TokenDist,
    TrafficSpec, Workload,
};
use chiplet_cloud::mapping::Mapping;
use chiplet_cloud::perf::events::{
    open_loop_trace, simulate_replicated, simulate_replicated_faults, simulate_trace, IterCost,
    SimConfig,
};
use chiplet_cloud::perf::simulate;
use chiplet_cloud::sched::{ContinuousBatch, KvBudget, RoutePolicy, StaticBatch};
use chiplet_cloud::util::prop::check;

fn synthetic_cfg(slots: usize) -> SimConfig {
    SimConfig::new(
        slots,
        KvBudget::unlimited(),
        IterCost { prefill_s_per_token: 0.0001, decode_step_s: 0.01, prefill_chunk: 0 },
        false,
    )
}

/// The Table-2 GPT-3 design used by the perf simulator's own tests.
fn gpt3_server() -> ServerDesign {
    ServerDesign {
        chiplet: ChipletDesign {
            die_mm2: 140.0,
            sram_mb: 225.8,
            tflops: 5.5,
            mem_bw_gbps: 2750.0,
            n_bank_groups: 172,
            io_link_gbps: 25.0,
            io_links: 4,
            tdp_w: 14.1,
        },
        chips_per_lane: 17,
        lanes: 8,
        server_power_w: 2020.0,
        server_capex: 5300.0,
    }
}

/// Deterministic seeded-trace golden test: the same spec always produces
/// the same trace and the same simulated tails, and a different seed
/// produces a different schedule.
#[test]
fn seeded_trace_golden() {
    let t = TrafficSpec::poisson(35.0, 250, 24, 4, 40).with_seed(2024);
    let run = |seed: u64| {
        let t = t.with_seed(seed);
        let rep =
            simulate_trace(&synthetic_cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        (
            rep.completed,
            rep.tokens,
            rep.iterations,
            rep.ttft_p99_s.to_bits(),
            rep.tpot_p99_s.to_bits(),
            rep.makespan_s.to_bits(),
        )
    };
    let a = run(2024);
    assert_eq!(a, run(2024), "same seed must replay bit-identically");
    assert_eq!(a.0, 250);
    let b = run(77);
    assert!(a.3 != b.3 || a.5 != b.5, "different seeds must differ");
    // The trace itself is stable too.
    let arr = open_loop_trace(&t);
    let arr2 = open_loop_trace(&t);
    assert_eq!(arr.len(), 250);
    for (x, y) in arr.iter().zip(&arr2) {
        assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
        assert_eq!(x.new_tokens, y.new_tokens);
    }
}

/// Property: closed-loop occupancy never exceeds the KV-capacity budget,
/// across random budgets, client counts and token shapes.
#[test]
fn closed_loop_never_exceeds_kv_budget() {
    check("closed-loop occupancy respects the KV budget", 40, |r| {
        let slots = 2 + r.below(15);
        let kv_seqs = 1 + r.below(slots + 4); // sometimes tighter than slots
        let clients = 1 + r.below(30);
        let t = TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop {
                clients,
                think_s: r.f64() * 0.02,
            },
            requests: 30 + r.below(60),
            prompt_tokens: 1 + r.below(32),
            new_tokens_lo: 1,
            new_tokens_hi: 1 + r.below(24),
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: r.next_u64(),
        };
        let cfg = SimConfig::new(
            slots,
            KvBudget::seqs(kv_seqs),
            IterCost { prefill_s_per_token: 0.0002, decode_step_s: 0.005, prefill_chunk: 0 },
            false,
        );
        let rep = simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let cap = kv_seqs.min(slots);
        assert!(
            rep.peak_live <= cap,
            "peak live {} exceeds budget {} (slots {}, kv {})",
            rep.peak_live,
            cap,
            slots,
            kv_seqs
        );
        assert_eq!(rep.completed, t.requests, "every request must complete");
    });
}

/// Sanity: with no latency constraint and saturating closed-loop traffic,
/// the event simulator's throughput converges to the steady-state
/// simulator's tokens/s (±10%) — the two performance models agree where
/// their domains overlap.
#[test]
fn event_sim_converges_to_steady_state_throughput() {
    let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
    let mapping = Mapping { tp: 136, pp: 96, microbatch: 2 };
    let perf = simulate(&gpt3_server(), &w, &mapping).expect("fits");

    // Tiny prompts + long generations keep the (decode-rate) steady-state
    // metric comparable; clients == batch keeps every slot busy.
    let t = TrafficSpec::closed_loop(256, 0.0, 1024, 1, 200, 200).with_seed(5);
    let cfg = SimConfig::new(
        w.batch,
        KvBudget::from_design(&gpt3_server(), &w, &mapping),
        IterCost::from_perf(&perf, &w),
        false,
    );
    let rep = simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
    assert_eq!(rep.completed, 1024);
    assert!(rep.occupancy > 0.9, "saturating trace must fill slots: {}", rep.occupancy);
    let ratio = rep.tokens_per_s / perf.tokens_per_s;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "event-sim {} vs steady-state {} tokens/s (ratio {ratio})",
        rep.tokens_per_s,
        perf.tokens_per_s
    );
}

/// The headline acceptance property: on a seeded high-load Poisson trace,
/// continuous batching beats the static batch-synchronous policy on both
/// goodput and p99 TTFT.
#[test]
fn continuous_beats_static_at_high_load() {
    // 8 slots at 10 ms/step ⇒ ~800 tok/s capacity; mean 18 tokens/request
    // ⇒ ~44 req/s saturation. 30 req/s is high load without overload.
    let t = TrafficSpec::poisson(30.0, 400, 16, 4, 32).with_seed(11);
    let slo = SloSpec::new(0.25, 0.015);
    let cfg = synthetic_cfg(8);
    let st = simulate_trace(&cfg, &mut StaticBatch::new(0.05), &t, &slo);
    let co = simulate_trace(&cfg, &mut ContinuousBatch, &t, &slo);
    assert_eq!(st.completed, 400);
    assert_eq!(co.completed, 400);
    assert!(
        co.goodput_tokens_per_s > st.goodput_tokens_per_s,
        "continuous goodput {} must beat static {}",
        co.goodput_tokens_per_s,
        st.goodput_tokens_per_s
    );
    assert!(
        co.ttft_p99_s < st.ttft_p99_s,
        "continuous p99 TTFT {} must beat static {}",
        co.ttft_p99_s,
        st.ttft_p99_s
    );
    // Same total work, so raw token throughput is also no worse.
    assert!(co.tokens_per_s >= st.tokens_per_s * 0.999);
}

/// Property: the paged ledger never lets resident KV tokens exceed the
/// capacity [`KvBudget::from_design`] derives, across random engine
/// shapes, capacities shrunk until they bind, and saturating traffic.
#[test]
fn paged_ledger_never_exceeds_design_capacity() {
    let server = gpt3_server();
    let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
    let mapping = Mapping { tp: 136, pp: 96, microbatch: 2 };
    let design = KvBudget::from_design(&server, &w, &mapping);
    assert!(design.capacity_tokens >= 256 * 2048, "Table-2 design must fit its own batch");
    check("paged residency respects the derived capacity", 40, |r| {
        let slots = 2 + r.below(15);
        let prompt = 1 + r.below(64);
        let hi = 1 + r.below(32);
        let footprint = prompt + hi;
        // Shrink the design capacity until it binds for this trace (a few
        // requests' worth), keeping the bank-geometry block size.
        let cap = footprint + r.below(footprint * slots * 2);
        let kv = KvBudget {
            max_seqs: design.max_seqs,
            capacity_tokens: cap.min(design.capacity_tokens),
            block_tokens: design.block_tokens,
        };
        let cfg = SimConfig::new(
            slots,
            kv,
            IterCost {
                prefill_s_per_token: 0.0002,
                decode_step_s: 0.005,
                prefill_chunk: if r.chance(0.5) { 1 + r.below(32) } else { 0 },
            },
            true,
        );
        let t =
            TrafficSpec::poisson(500.0, 30 + r.below(40), prompt, 1, hi).with_seed(r.next_u64());
        let rep = simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(
            rep.peak_kv_tokens <= kv.capacity_tokens,
            "resident {} exceeds capacity {} (slots {slots}, block {})",
            rep.peak_kv_tokens,
            kv.capacity_tokens,
            kv.block_tokens
        );
        assert!(rep.peak_live <= slots);
        // every request whose footprint fits must eventually complete
        if kv.ledger().blocks_for(footprint) <= kv.ledger().capacity_blocks() {
            assert_eq!(rep.completed, t.requests, "fitting requests must all complete");
        }
    });
}

/// Golden chunked-prefill trace: seeded, bit-reproducible, and the
/// acceptance property — chunked prefill strictly improves the p99 TPOT
/// of resident decoders over the stall-the-batch model on the same trace,
/// while completing identical work.
#[test]
fn chunked_prefill_golden_and_tpot_acceptance() {
    // Long prompts (1024 tokens ≈ 0.1 s of prefill at 0.1 ms/token)
    // against 10 ms decode steps: every admission stalls incumbents for
    // the full prompt under chunk 0, for at most 32 tokens under chunk 32.
    let t = TrafficSpec::poisson(20.0, 200, 1024, 4, 48).with_seed(4242);
    let run = |chunk: usize| {
        let mut cfg = synthetic_cfg(8);
        cfg.cost = cfg.cost.with_chunk(chunk);
        simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained())
    };
    let stall = run(0);
    let chunked = run(32);
    // identical offered work, bit-identical replay
    for rep in [&stall, &chunked] {
        assert_eq!(rep.completed, 200);
    }
    assert_eq!(stall.tokens, chunked.tokens, "chunking must not change the work served");
    let again = run(32);
    assert_eq!(chunked.iterations, again.iterations);
    assert_eq!(chunked.ttft_p99_s.to_bits(), again.ttft_p99_s.to_bits());
    assert_eq!(chunked.tpot_p99_s.to_bits(), again.tpot_p99_s.to_bits());
    // chunking runs more, shorter iterations...
    assert!(chunked.iterations > stall.iterations);
    // ...and strictly improves the decoders' p99 TPOT (the acceptance bar)
    assert!(
        chunked.tpot_p99_s < stall.tpot_p99_s,
        "chunked p99 TPOT {} must strictly beat stall-the-batch {}",
        chunked.tpot_p99_s,
        stall.tpot_p99_s
    );
}

/// Two-replica routing under skewed (bursty, wide token range) load:
/// join-shortest-queue reacts to the imbalance round-robin ignores, so
/// its p99 TTFT is no worse on any seed and better in aggregate.
#[test]
fn jsq_routing_beats_round_robin_under_skew() {
    // Near-saturation load (two 4-slot replicas at 10 ms/step serve
    // ~800 tok/s; 12 req/s x ~64-token mean ≈ 0.97 load): bursts leave a
    // residual backlog whose imbalance round-robin's blind 8/8 split
    // compounds and JSQ's arrival-instant routing corrects.
    let (mut jsq_sum, mut rr_sum) = (0.0f64, 0.0f64);
    for seed in [11u64, 29, 71] {
        let t = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 12.0, burst: 16 },
            ..TrafficSpec::poisson(12.0, 320, 16, 1, 128)
        }
        .with_seed(seed);
        let run = |route: RoutePolicy| {
            simulate_replicated(
                &synthetic_cfg(4),
                2,
                route,
                &ContinuousBatch,
                &t,
                &SloSpec::unconstrained(),
            )
        };
        let jsq = run(RoutePolicy::Jsq);
        let rr = run(RoutePolicy::RoundRobin);
        assert_eq!(jsq.completed, 320, "seed {seed}");
        assert_eq!(rr.completed, 320, "seed {seed}");
        // Per-seed with a small tolerance (queue *length* is JSQ's load
        // signal, and token-count variance can momentarily mislead it);
        // the aggregate below must be strictly better.
        assert!(
            jsq.ttft_p99_s <= rr.ttft_p99_s * 1.1,
            "seed {seed}: JSQ p99 TTFT {} must be <= round-robin {}",
            jsq.ttft_p99_s,
            rr.ttft_p99_s
        );
        jsq_sum += jsq.ttft_p99_s;
        rr_sum += rr.ttft_p99_s;
    }
    assert!(jsq_sum < rr_sum, "JSQ must win in aggregate: {jsq_sum} vs {rr_sum}");
}

/// The acceptance scenario for paged accounting: on a long-prompt
/// workload (ctx 2048, decode <= 256 per request), the SLO-constrained
/// selection with per-slot paged accounting is never costlier than the
/// full-reservation baseline on the same traffic — each request's actual
/// footprint (prompt + budget < ctx) admits at least the concurrency the
/// full-context reservation would — and the winning design still passes
/// event-sim validation.
#[test]
fn paged_accounting_selects_no_worse_design_under_slo() {
    use chiplet_cloud::config::hardware::ExploreSpace;
    use chiplet_cloud::evaluate::SweepEngine;
    use chiplet_cloud::explore::phase1;

    let space = ExploreSpace::coarse();
    let (servers, _) = phase1(&space);
    let w = Workload::new(ModelSpec::megatron(), 2048, 32);
    let engine = SweepEngine::default();

    // A satisfiable-but-real TPOT target: a comfortable multiple of the
    // fastest token period any per-server optimum achieves.
    let fastest = SweepEngine::sequential()
        .sweep(&space, &servers, &w)
        .iter()
        .map(|p| p.perf.token_period)
        .fold(f64::INFINITY, f64::min);
    assert!(fastest.is_finite());
    let slo = SloSpec::new(f64::INFINITY, fastest * 8.0);
    // Long prompts, short decodes: footprint 1600 + <=64 << ctx 2048.
    // Closed loop self-paces, so the comparison is about KV admission,
    // not overload; chunked prefill (128) applies to both runs.
    let traffic = TrafficSpec::closed_loop(8, 0.0, 40, 1600, 16, 64).with_seed(13);
    let base = ServeSpec::new(traffic, slo).with_chunked_prefill(128);
    let paged_spec = base.with_paged_kv();

    let paged = engine
        .best_point_slo(&space, &servers, &w, &paged_spec)
        .expect("paged selection must exist at an 8x-period TPOT target");
    assert!(paged.report.meets(&slo), "winner must pass event-sim validation");
    assert_eq!(paged.report.completed, 40);

    if let Some(full) = engine.best_point_slo(&space, &servers, &w, &base) {
        assert!(
            paged.point.tco_per_token <= full.point.tco_per_token * (1.0 + 1e-12),
            "paged TCO/token {} must be <= full-reservation {}",
            paged.point.tco_per_token,
            full.point.tco_per_token
        );
    }
}


/// The tentpole property: decode fast-forward produces **bit-identical**
/// `ServeReport`s to the step-by-step reference across randomized
/// Poisson / bursty / closed-loop traces, paged and full-context KV,
/// chunked and unchunked prefill, static and continuous policies, and
/// 1 and 2 replicas under both routing policies.
#[test]
fn fast_forward_matches_reference_step_bit_for_bit() {
    check("fast-forward == reference stepping", 30, |r| {
        let slots = 2 + r.below(10);
        let requests = 20 + r.below(60);
        let prompt = r.below(48); // 0-prompt requests included
        let lo = 1 + r.below(16);
        let hi = lo + r.below(200); // up to ~216 tokens: long decode runs
        let seed = r.next_u64();
        let arrival = match r.below(3) {
            0 => ArrivalProcess::Poisson { rps: 0.5 + r.f64() * 50.0 },
            1 => ArrivalProcess::Bursty { rps: 0.5 + r.f64() * 30.0, burst: 1 + r.below(8) },
            _ => ArrivalProcess::ClosedLoop { clients: 1 + r.below(8), think_s: r.f64() * 0.05 },
        };
        let t = TrafficSpec {
            arrival,
            requests,
            prompt_tokens: prompt,
            new_tokens_lo: lo,
            new_tokens_hi: hi,
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed,
        };
        let mut cfg = synthetic_cfg(slots);
        if r.chance(0.5) {
            cfg.cost = cfg.cost.with_chunk(1 + r.below(24));
        }
        if r.chance(0.5) {
            // Binding paged budget around a few requests' worth.
            let footprint = prompt + hi;
            cfg.kv = KvBudget::tokens(footprint + r.below(footprint * slots + 1), 8);
            cfg.paged_kv = true;
        } else if r.chance(0.3) {
            cfg.kv = KvBudget::seqs(1 + r.below(slots + 2));
        }
        let mut reference = cfg;
        reference.reference_step = true;
        let replicas = 1 + r.below(2);
        let route = if r.chance(0.5) { RoutePolicy::Jsq } else { RoutePolicy::RoundRobin };
        let use_static = r.chance(0.3);
        let wait_s = r.f64() * 0.05;
        let slo = SloSpec::unconstrained();
        let run = |c: &SimConfig| {
            if use_static {
                let p = StaticBatch::new(wait_s);
                simulate_replicated(c, replicas, route, &p, &t, &slo)
            } else {
                simulate_replicated(c, replicas, route, &ContinuousBatch, &t, &slo)
            }
        };
        let a = run(&reference);
        let b = run(&cfg);
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "fast-forward diverged (slots {slots}, requests {requests}, prompt {prompt}, \
             tokens {lo}..{hi}, replicas {replicas}, static {use_static}, paged {}, chunk {})",
            cfg.paged_kv,
            cfg.cost.prefill_chunk
        );
    });
}

/// Early-abort soundness property: across randomized traces and SLO
/// targets, the abort-enabled run reaches the same feasibility verdict as
/// the full simulation, never costs more iterations, and — whenever the
/// verdict is "meets" — produces the identical full-fidelity report.
#[test]
fn early_abort_verdict_always_matches_the_full_run() {
    check("early abort is verdict-preserving", 25, |r| {
        let slots = 2 + r.below(8);
        let requests = 30 + r.below(80);
        let t = TrafficSpec::poisson(
            1.0 + r.f64() * 40.0,
            requests,
            1 + r.below(32),
            1 + r.below(8),
            8 + r.below(40),
        )
        .with_seed(r.next_u64());
        // Targets straddling the achievable band: decode step is 10 ms, so
        // TPOT targets in [5 ms, 45 ms] and TTFT in [10 ms, 2 s] produce a
        // healthy mix of passes, near-misses and hopeless runs.
        let slo = SloSpec::new(0.01 + r.f64() * 2.0, 0.005 + r.f64() * 0.04);
        let cfg = synthetic_cfg(slots);
        let mut abort_cfg = cfg;
        abort_cfg.early_abort = true;
        let full = simulate_trace(&cfg, &mut ContinuousBatch, &t, &slo);
        let fast = simulate_trace(&abort_cfg, &mut ContinuousBatch, &t, &slo);
        assert_eq!(
            full.meets(&slo),
            fast.meets(&slo),
            "verdict diverged (slots {slots}, requests {requests})"
        );
        assert!(fast.iterations <= full.iterations, "abort may never cost extra work");
        if full.meets(&slo) {
            assert!(!fast.aborted_early, "a passing run must never abort");
            assert_eq!(full.fingerprint(), fast.fingerprint());
        }
        if fast.aborted_early {
            assert!(!full.meets(&slo), "abort on a feasible run is unsound");
        }
    });
}

/// Quantized-time decode ([`SimConfig::quantum`]) against the default
/// bit-exact fast-forward across randomized open-loop traces, chunked
/// prefill, binding paged budgets and 1–2 replicas: identical
/// completed/token/rejected counts, and every latency tail within the
/// documented `2·decode_step + 1e-6·|reference|` bound.
#[test]
fn quantized_time_stays_within_epsilon_of_reference() {
    check("quantized time respects the epsilon contract", 30, |r| {
        let slots = 2 + r.below(10);
        let requests = 30 + r.below(80);
        let prompt = r.below(48);
        // lo >= 2 keeps every request multi-token, so the TPOT percentile
        // vectors are never empty (NaN would defeat the epsilon compare).
        let lo = 2 + r.below(16);
        let hi = lo + r.below(120);
        let arrival = if r.chance(0.5) {
            ArrivalProcess::Poisson { rps: 0.5 + r.f64() * 40.0 }
        } else {
            ArrivalProcess::Bursty { rps: 0.5 + r.f64() * 25.0, burst: 1 + r.below(8) }
        };
        let t = TrafficSpec {
            arrival,
            requests,
            prompt_tokens: prompt,
            new_tokens_lo: lo,
            new_tokens_hi: hi,
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: r.next_u64(),
        };
        let mut cfg = synthetic_cfg(slots);
        if r.chance(0.4) {
            cfg.cost = cfg.cost.with_chunk(1 + r.below(24));
        }
        if r.chance(0.4) {
            // A budget that binds (queueing) but admits every footprint —
            // +8 absorbs block rounding so even the largest request fits.
            let footprint = prompt + hi;
            cfg.kv = KvBudget::tokens(footprint * (1 + r.below(slots + 1)) + 8, 8);
            cfg.paged_kv = true;
        }
        let mut quant = cfg;
        quant.quantum = 0.01 + r.f64() * 0.2; // 1 to ~21 decode steps per jump
        let replicas = 1 + r.below(2);
        let route = if r.chance(0.5) { RoutePolicy::Jsq } else { RoutePolicy::RoundRobin };
        let slo = SloSpec::unconstrained();
        let a = simulate_replicated(&cfg, replicas, route, &ContinuousBatch, &t, &slo);
        let b = simulate_replicated(&quant, replicas, route, &ContinuousBatch, &t, &slo);
        let tag = format!(
            "slots {slots}, requests {requests}, tokens {lo}..{hi}, replicas {replicas}, \
             paged {}, chunk {}, quantum {}",
            cfg.paged_kv, cfg.cost.prefill_chunk, quant.quantum
        );
        assert_eq!(a.completed, b.completed, "{tag}");
        assert_eq!(a.tokens, b.tokens, "{tag}");
        assert_eq!(a.rejected, b.rejected, "{tag}");
        let step = cfg.cost.decode_step_s;
        for (q, refv, what) in [
            (b.ttft_p50_s, a.ttft_p50_s, "ttft p50"),
            (b.ttft_p99_s, a.ttft_p99_s, "ttft p99"),
            (b.tpot_p50_s, a.tpot_p50_s, "tpot p50"),
            (b.tpot_p99_s, a.tpot_p99_s, "tpot p99"),
            (b.total_p99_s, a.total_p99_s, "total p99"),
            (b.makespan_s, a.makespan_s, "makespan"),
        ] {
            assert!(
                (q - refv).abs() <= 2.0 * step + 1e-6 * refv.abs(),
                "{what}: quantized {q} vs reference {refv} ({tag})"
            );
        }
    });
}

/// Satellite of the early-abort rule: counting requests *already waiting*
/// past a finite TTFT target against the violation budget must preserve
/// the feasibility verdict across randomized overload levels — including
/// in quantized-time mode, where the abort decision points sit on coarser
/// clock jumps.
#[test]
fn in_flight_ttft_abort_is_verdict_preserving() {
    check("queue-wait abort preserves the verdict", 25, |r| {
        let slots = 1 + r.below(4);
        let requests = 30 + r.below(60);
        // Long decodes on few slots: a healthy mix of keep-up runs and
        // queues that grow without bound, where the waiting-time lower
        // bound fires long before the stranded requests complete.
        let t = TrafficSpec::poisson(
            2.0 + r.f64() * 40.0,
            requests,
            1 + r.below(32),
            4 + r.below(16),
            20 + r.below(120),
        )
        .with_seed(r.next_u64());
        let slo = SloSpec::new(0.005 + r.f64() * 0.5, f64::INFINITY);
        let mut cfg = synthetic_cfg(slots);
        if r.chance(0.5) {
            cfg.quantum = 0.01 + r.f64() * 0.1;
        }
        let mut abort_cfg = cfg;
        abort_cfg.early_abort = true;
        let full = simulate_trace(&cfg, &mut ContinuousBatch, &t, &slo);
        let fast = simulate_trace(&abort_cfg, &mut ContinuousBatch, &t, &slo);
        assert_eq!(
            full.meets(&slo),
            fast.meets(&slo),
            "verdict diverged (slots {slots}, requests {requests}, quantum {})",
            cfg.quantum
        );
        assert!(fast.iterations <= full.iterations, "abort may never cost extra work");
        if full.meets(&slo) {
            assert!(!fast.aborted_early, "a passing run must never abort");
            assert_eq!(full.fingerprint(), fast.fingerprint());
        }
        if fast.aborted_early {
            assert!(!full.meets(&slo), "abort on a feasible run is unsound");
        }
    });
}

/// Failure-model identity property: with `FaultSpec::none` the
/// failure-aware entry point is **fingerprint-identical** to the default
/// replicated path across randomized traces, policies, routes, paged
/// budgets and replica counts — the fault model cannot perturb a
/// fault-free run by even a bit.
#[test]
fn fault_none_is_fingerprint_identical_to_the_default_path() {
    check("FaultSpec::none == simulate_replicated", 30, |r| {
        let slots = 2 + r.below(10);
        let requests = 20 + r.below(60);
        let arrival = match r.below(3) {
            0 => ArrivalProcess::Poisson { rps: 0.5 + r.f64() * 40.0 },
            1 => ArrivalProcess::Bursty { rps: 0.5 + r.f64() * 25.0, burst: 1 + r.below(8) },
            _ => ArrivalProcess::ClosedLoop { clients: 1 + r.below(8), think_s: r.f64() * 0.05 },
        };
        let t = TrafficSpec {
            arrival,
            requests,
            prompt_tokens: 1 + r.below(47),
            new_tokens_lo: 1 + r.below(8),
            new_tokens_hi: 9 + r.below(60),
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: r.next_u64(),
        };
        let mut cfg = synthetic_cfg(slots);
        if r.chance(0.4) {
            cfg.cost = cfg.cost.with_chunk(1 + r.below(24));
        }
        if r.chance(0.4) {
            let footprint = t.prompt_tokens + t.new_tokens_hi;
            cfg.kv = KvBudget::tokens(footprint * (1 + r.below(slots + 1)) + 8, 8);
            cfg.paged_kv = true;
        }
        let replicas = 1 + r.below(3);
        let route = match r.below(3) {
            0 => RoutePolicy::RoundRobin,
            1 => RoutePolicy::Jsq,
            _ => RoutePolicy::JsqTokens,
        };
        let slo = SloSpec::unconstrained();
        let (a, b) = if r.chance(0.3) {
            let p = StaticBatch::new(r.f64() * 0.05);
            (
                simulate_replicated(&cfg, replicas, route, &p, &t, &slo),
                simulate_replicated_faults(&cfg, replicas, route, &p, &t, &FaultSpec::none(), &slo),
            )
        } else {
            let p = ContinuousBatch;
            (
                simulate_replicated(&cfg, replicas, route, &p, &t, &slo),
                simulate_replicated_faults(&cfg, replicas, route, &p, &t, &FaultSpec::none(), &slo),
            )
        };
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "fault-free fault path diverged (slots {slots}, requests {requests}, \
             replicas {replicas})"
        );
        assert_eq!(b.redispatched, 0);
        assert_eq!(b.lost, 0);
        assert_eq!(b.downtime_frac.to_bits(), 0.0f64.to_bits());
    });
}

/// Conservation invariant under faults: across poisson/bursty arrivals,
/// rr/jsq/jsq-tokens routing, paged and full-reservation KV, and both
/// scripted and stochastic fault schedules, every offered request is
/// accounted for exactly once: completed + rejected + lost == offered.
/// Runs are also bit-reproducible under replay.
#[test]
fn fault_conservation_holds_across_the_matrix() {
    let routes = [RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::JsqTokens];
    let slo = SloSpec::unconstrained();
    for (ai, arrival) in [
        ArrivalProcess::Poisson { rps: 45.0 },
        ArrivalProcess::Bursty { rps: 30.0, burst: 8 },
    ]
    .into_iter()
    .enumerate()
    {
        for route in routes {
            for paged in [false, true] {
                for (fi, faults) in [
                    FaultSpec::scripted(
                        FaultSpec::parse_plan("fail:0@0.5,recover:0@2.0,fail:1@1.0").unwrap(),
                    ),
                    FaultSpec::mtbf(1.0, 0.4, 7 + ai as u64),
                ]
                .into_iter()
                .enumerate()
                {
                    let t = TrafficSpec {
                        arrival,
                        requests: 150,
                        prompt_tokens: 16,
                        new_tokens_lo: 4,
                        new_tokens_hi: 24,
                        new_tokens_dist: TokenDist::Uniform,
                        tiers: None,
                        seed: 1000 + fi as u64,
                    };
                    let mut cfg = synthetic_cfg(4);
                    if paged {
                        cfg.kv = KvBudget::tokens((16 + 24) * 6 + 8, 8);
                        cfg.paged_kv = true;
                    }
                    let run = || {
                        simulate_replicated_faults(
                            &cfg,
                            2,
                            route,
                            &ContinuousBatch,
                            &t,
                            &faults,
                            &slo,
                        )
                    };
                    let rep = run();
                    let tag = format!(
                        "arrival {ai}, route {}, paged {paged}, faults {fi}",
                        route.name()
                    );
                    assert_eq!(
                        rep.completed + rep.rejected + rep.lost,
                        rep.offered,
                        "conservation broke: {tag}"
                    );
                    assert_eq!(rep.offered, 150, "{tag}");
                    assert_eq!(rep.fingerprint(), run().fingerprint(), "replay diverged: {tag}");
                    assert!(
                        (0.0..1.0).contains(&rep.downtime_frac),
                        "downtime_frac {} out of range: {tag}",
                        rep.downtime_frac
                    );
                }
            }
        }
    }
}

/// Scripted-plan golden: killing 1 of 3 replicas mid-run strictly degrades
/// p99 TTFT versus the fault-free fleet, and recovery restores goodput
/// versus losing the replica forever (the backlog drains once the third
/// replica returns, instead of stretching the makespan).
#[test]
fn killing_one_of_three_degrades_ttft_and_recovery_restores_goodput() {
    // 3 replicas x 4 slots at 10 ms/step ≈ 1200 tok/s fleet capacity;
    // 66 req/s x ~20-token mean ≈ 1320 tok/s offered: mild overload, so a
    // backlog exists fleet-wide from early on and JSQ keeps every replica
    // busy — the kill at t=1.0 is guaranteed to hit in-flight work.
    let t = TrafficSpec::poisson(66.0, 300, 16, 8, 32).with_seed(5);
    let cfg = synthetic_cfg(4);
    let slo = SloSpec::unconstrained();
    let run = |faults: &FaultSpec| {
        simulate_replicated_faults(&cfg, 3, RoutePolicy::Jsq, &ContinuousBatch, &t, faults, &slo)
    };
    let clean = run(&FaultSpec::none());
    let recover =
        run(&FaultSpec::scripted(FaultSpec::parse_plan("fail:0@1.0,recover:0@2.5").unwrap()));
    let forever = run(&FaultSpec::scripted(FaultSpec::parse_plan("fail:0@1.0").unwrap()));
    for (rep, tag) in [(&clean, "clean"), (&recover, "recover"), (&forever, "forever")] {
        assert_eq!(
            rep.completed + rep.rejected + rep.lost,
            rep.offered,
            "conservation broke: {tag}"
        );
    }
    assert_eq!(clean.completed, 300);
    // Two live replicas absorb the traffic, so nothing is lost — the kill
    // shows up purely as re-dispatch work and latency.
    assert_eq!(recover.lost, 0);
    assert_eq!(forever.lost, 0);
    assert!(recover.redispatched > 0, "in-flight work on the dead replica must re-dispatch");
    assert!(recover.downtime_frac > 0.0);
    assert!(
        forever.downtime_frac > recover.downtime_frac,
        "an unrecovered replica accrues more downtime: {} vs {}",
        forever.downtime_frac,
        recover.downtime_frac
    );
    // The outage strictly degrades the p99 TTFT tail...
    assert!(
        recover.ttft_p99_s > clean.ttft_p99_s,
        "kill must degrade p99 TTFT: faulted {} vs clean {}",
        recover.ttft_p99_s,
        clean.ttft_p99_s
    );
    // ...and recovery restores goodput relative to the never-recovered
    // fleet, which serves the tail at 2/3 capacity and stretches the run.
    assert!(
        recover.goodput_tokens_per_s > forever.goodput_tokens_per_s,
        "recovery must restore goodput: {} vs {}",
        recover.goodput_tokens_per_s,
        forever.goodput_tokens_per_s
    );
}

/// End-to-end acceptance on the checked-in availability spec: the
/// selection buys a strictly more redundant — and strictly costlier —
/// fleet than the fault-free optimum, and its confirming report passes
/// the availability target under the scripted faults.
#[test]
fn availability_spec_buys_redundancy_end_to_end() {
    use chiplet_cloud::experiment::{Engine, Experiment, Outcome};
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../experiments/availability-serve.json");
    let text = std::fs::read_to_string(path).expect("checked-in availability spec");
    let e = Experiment::from_json_str(&text).expect("spec parses");
    let mut engine = Engine::new();
    let out = engine.run(&e).expect("spec runs");
    let Outcome::Serve(o) = out else { panic!("serve-sim spec must yield a serve outcome") };
    let sel = o
        .slo
        .as_ref()
        .expect("binding SLO")
        .as_ref()
        .expect("a spare-equipped fleet must meet the availability target");
    assert!(
        sel.replicas > o.spec.replicas,
        "availability target must buy spares: {} vs base {}",
        sel.replicas,
        o.spec.replicas
    );
    assert!(sel.report.meets_available(&o.spec.slo, o.spec.faults.availability));
    assert_eq!(
        sel.report.completed + sel.report.rejected + sel.report.lost,
        sel.report.offered,
        "conservation broke on the confirming report"
    );
    // The same spec with the fault model stripped selects the base fleet —
    // and the sized fleet is strictly costlier.
    let mut free = e.clone();
    free.serve.as_mut().expect("serve spec").faults = FaultSpec::none();
    let Outcome::Serve(o2) = engine.run(&free).expect("fault-free spec runs") else {
        panic!("serve-sim spec must yield a serve outcome")
    };
    let base = o2
        .slo
        .as_ref()
        .expect("binding SLO")
        .as_ref()
        .expect("the fault-free selection must be feasible");
    assert_eq!(base.replicas, o2.spec.replicas);
    assert!(
        sel.point.tco_per_token * sel.replicas as f64
            > base.point.tco_per_token * base.replicas as f64,
        "the sized fleet must be strictly costlier than the fault-free optimum"
    );
}

/// Mirror of the live-coordinator regression: even under a pathological
/// arrival pattern the simulator never executes an empty iteration — every
/// iteration has at least one live or admitted sequence.
#[test]
fn no_empty_iterations_under_sparse_traffic() {
    // Arrivals far apart relative to service time: the scheduler must idle
    // between them, not spin.
    let t = TrafficSpec::poisson(0.5, 20, 8, 2, 4).with_seed(3);
    let cfg = synthetic_cfg(4);
    let rep = simulate_trace(&cfg, &mut StaticBatch::new(0.01), &t, &SloSpec::unconstrained());
    assert_eq!(rep.completed, 20);
    // Each request needs at most 1 admission + (tokens-1) decode
    // iterations; idle time must never manifest as extra iterations.
    let max_iters: u64 = rep.per_request.iter().map(|r| r.tokens as u64).sum();
    assert!(rep.iterations <= max_iters, "{} > {}", rep.iterations, max_iters);
    assert!(rep.occupancy > 0.0);
}

// ---------------------------------------------------------------------------
// Overcommit admission + priority tiers.

/// Conservation invariant under preemption: across poisson/bursty
/// arrivals, 1–2 replicas, both residency estimators and tiers on/off, a
/// block-bound paged pool forces mid-decode preemptions, yet every offered
/// request is accounted for exactly once (preempted requests re-queue and
/// recompute rather than vanish), per-tier preemption tallies sum to the
/// aggregate, and runs replay bit-identically.
#[test]
fn overcommit_preemption_conserves_across_the_matrix() {
    let slo = SloSpec::unconstrained();
    let mut total_preempted = 0usize;
    for (ai, arrival) in [
        ArrivalProcess::Poisson { rps: 200.0 },
        ArrivalProcess::Bursty { rps: 200.0, burst: 8 },
    ]
    .into_iter()
    .enumerate()
    {
        for replicas in [1usize, 2] {
            for tiered in [false, true] {
                for (oi, oc) in
                    [OvercommitSpec::quantile(0.5), OvercommitSpec::running_mean()]
                        .into_iter()
                        .enumerate()
                {
                    let mut t = TrafficSpec {
                        arrival,
                        ..TrafficSpec::poisson(200.0, 120, 8, 4, 48)
                    }
                    .with_seed(500 + ai as u64);
                    if tiered {
                        t = t.with_tiers(
                            TierSpec::new(0.4, 2, 8, SloSpec::new(0.5, 0.05), slo)
                                .with_fairness(2),
                        );
                    }
                    // 12 blocks of 8 tokens: the 0.5-quantile charge
                    // (8 + 26 = 34 tokens, 5 blocks) admits pairs that can
                    // each grow to 56 tokens (7 blocks) — exhaustion, and
                    // therefore preemption, is routine.
                    let mut cfg = synthetic_cfg(4);
                    cfg.kv = KvBudget::tokens(96, 8);
                    cfg.paged_kv = true;
                    cfg.overcommit = Some(oc);
                    let run = || {
                        simulate_replicated(
                            &cfg,
                            replicas,
                            RoutePolicy::Jsq,
                            &ContinuousBatch,
                            &t,
                            &slo,
                        )
                    };
                    let rep = run();
                    let tag = format!(
                        "arrival {ai}, replicas {replicas}, tiered {tiered}, estimator {oi}"
                    );
                    assert_eq!(
                        rep.completed + rep.rejected + rep.lost,
                        rep.offered,
                        "conservation broke: {tag}"
                    );
                    assert_eq!(rep.offered, 120, "{tag}");
                    assert_eq!(rep.lost, 0, "no faults, nothing may be lost: {tag}");
                    if tiered {
                        assert_eq!(rep.tiers.len(), 2, "{tag}");
                        let by_tier: usize = rep.tiers.iter().map(|t| t.preempted).sum();
                        assert_eq!(by_tier, rep.preempted, "tier tallies must sum: {tag}");
                        assert_eq!(
                            rep.tiers.iter().map(|t| t.completed).sum::<usize>(),
                            rep.completed,
                            "{tag}"
                        );
                    } else {
                        assert!(rep.tiers.is_empty(), "{tag}");
                    }
                    assert_eq!(rep.fingerprint(), run().fingerprint(), "replay diverged: {tag}");
                    total_preempted += rep.preempted;
                }
            }
        }
    }
    assert!(total_preempted > 0, "the block-bound matrix must preempt somewhere");
}

/// Identity property: with overcommit and tiers off, randomized runs carry
/// no tier/window/preemption state — the report fingerprint keeps exactly
/// the pre-overcommit aggregate arity — and an overcommit spec on an
/// unpaged config is inert (expected-residency admission is a paged-KV
/// mechanism), leaving runs bit-identical to the plain path.
#[test]
fn overcommit_off_and_inert_paths_stay_fingerprint_identical() {
    check("overcommit off/inert identity", 25, |r| {
        let slots = 2 + r.below(10);
        let arrival = match r.below(3) {
            0 => ArrivalProcess::Poisson { rps: 0.5 + r.f64() * 40.0 },
            1 => ArrivalProcess::Bursty { rps: 0.5 + r.f64() * 25.0, burst: 1 + r.below(8) },
            _ => ArrivalProcess::ClosedLoop { clients: 1 + r.below(8), think_s: r.f64() * 0.05 },
        };
        let t = TrafficSpec {
            arrival,
            ..TrafficSpec::poisson(0.0, 20 + r.below(60), 1 + r.below(32), 1, 1 + r.below(24))
        }
        .with_seed(r.next_u64());
        let replicas = 1 + r.below(2);
        let mut cfg = synthetic_cfg(slots);
        if r.chance(0.5) {
            let footprint = t.prompt_tokens + t.new_tokens_hi;
            cfg.kv = KvBudget::tokens(footprint * (1 + r.below(slots + 1)) + 8, 8);
            cfg.paged_kv = true;
        }
        let route = RoutePolicy::Jsq;
        let plain =
            simulate_replicated(&cfg, replicas, route, &ContinuousBatch, &t, &SloSpec::unconstrained());
        // Off path: no preemption state, no tier or window rows, and the
        // aggregate fingerprint keeps its fixed arity.
        assert_eq!(plain.preempted, 0);
        assert!(plain.tiers.is_empty());
        assert!(plain.windows.is_empty());
        assert_eq!(plain.fingerprint().0.len(), 24);
        // Inert path: overcommit on an unpaged config changes nothing.
        if !cfg.paged_kv {
            let mut oc_cfg = cfg.clone();
            oc_cfg.overcommit = Some(if r.chance(0.5) {
                OvercommitSpec::quantile(0.2 + r.f64() * 0.6)
            } else {
                OvercommitSpec::running_mean()
            });
            let oc = simulate_replicated(
                &oc_cfg,
                replicas,
                route,
                &ContinuousBatch,
                &t,
                &SloSpec::unconstrained(),
            );
            assert_eq!(plain.fingerprint(), oc.fingerprint(), "unpaged overcommit must be inert");
        }
    });
}

/// Fairness bound: at feasible load with an ample pool, tier-ordered
/// admission with a finite `max_consecutive_interactive` never starves the
/// batch tier — every offered request of both tiers completes — and the
/// windowed goodput rows partition the completions exactly.
#[test]
fn batch_tier_is_never_starved_at_feasible_load() {
    // 4 slots at 10 ms/step ≈ 400 tok/s capacity; 10 req/s at ≤ 24 tokens
    // ≈ 140 tok/s offered: comfortably feasible.
    let t = TrafficSpec::poisson(10.0, 100, 8, 4, 24)
        .with_seed(77)
        .with_tiers(
            TierSpec::new(0.6, 2, 8, SloSpec::new(2.0, 0.5), SloSpec::unconstrained())
                .with_fairness(1),
        );
    let mut cfg = synthetic_cfg(4);
    // 64 blocks of 8: four max-footprint residents need 16 blocks, so the
    // pool never binds and no preemption can occur.
    cfg.kv = KvBudget::tokens(512, 8);
    cfg.paged_kv = true;
    cfg.overcommit = Some(OvercommitSpec::quantile(0.8));
    cfg.window_s = 2.0;
    let rep = simulate_replicated(
        &cfg,
        1,
        RoutePolicy::RoundRobin,
        &ContinuousBatch,
        &t,
        &SloSpec::unconstrained(),
    );
    assert_eq!(rep.completed, rep.offered, "feasible load must fully drain");
    assert_eq!(rep.preempted, 0, "an ample pool must not preempt");
    assert_eq!(rep.tiers.len(), 2);
    for tr in &rep.tiers {
        assert!(tr.completed > 0, "tier {} starved", tr.tier);
        assert!(tr.tokens > 0, "tier {} generated nothing", tr.tier);
    }
    assert!(!rep.windows.is_empty(), "window rows must be emitted");
    assert_eq!(rep.windows.iter().map(|w| w.completed).sum::<usize>(), rep.completed);
    assert_eq!(rep.windows.iter().map(|w| w.tokens).sum::<usize>(), rep.tokens);
}

/// End-to-end acceptance on the checked-in overcommit spec: heavy-tailed
/// Pareto budgets over a block-bound pool make the fleet preempt, the
/// interactive tier still meets its SLO, and expected-residency admission
/// strictly beats the reservation (max-footprint) baseline on goodput per
/// TCO-dollar whenever that baseline is feasible at all.
#[test]
fn overcommit_tiers_spec_wins_goodput_per_tco_end_to_end() {
    use chiplet_cloud::experiment::{Engine, Experiment, Outcome};
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../experiments/overcommit-tiers-serve.json");
    let text = std::fs::read_to_string(path).expect("checked-in overcommit spec");
    let e = Experiment::from_json_str(&text).expect("spec parses");
    e.validate().expect("spec validates");
    let mut engine = Engine::new();
    let out = engine.run(&e).expect("spec runs");
    let Outcome::Serve(o) = out else { panic!("serve-sim spec must yield a serve outcome") };
    let spec = &o.spec;
    let tiers = spec.traffic.tiers.as_ref().expect("spec carries tiers");
    let sel = o
        .slo
        .as_ref()
        .expect("the interactive tier's SLO binds the selection")
        .as_ref()
        .expect("some design must serve the interactive tier");
    let rep = &sel.report;
    assert_eq!(
        rep.completed + rep.rejected + rep.lost,
        rep.offered,
        "conservation broke on the confirming report"
    );
    assert!(rep.preempted > 0, "the heavy-tailed trace must force preemptions");
    assert_eq!(rep.tiers.len(), 2, "per-tier rows must be reported");
    assert!(!rep.windows.is_empty(), "windowed goodput rows must be reported");
    assert!(
        rep.meets_tier(0, &tiers.interactive_slo),
        "interactive p99 must hold: ttft {} tpot {}",
        rep.tiers[0].ttft_p99_s,
        rep.tiers[0].tpot_p99_s
    );
    // The reservation baseline (same spec, overcommit stripped) rides
    // along in the outcome; when it is feasible, lazy admission must win
    // on goodput per TCO-dollar.
    let reserved = o.reserved.as_ref().expect("an overcommit run must carry its baseline");
    if let Some(base) = reserved.as_ref() {
        let oc_value = rep.goodput_tokens_per_s / sel.point.tco_per_token;
        let rs_value = base.report.goodput_tokens_per_s / base.point.tco_per_token;
        assert!(
            oc_value > rs_value,
            "overcommit must win goodput/TCO: {oc_value} vs {rs_value}"
        );
    }
}
