//! Integration tests for `ccloud lint`: each rule demonstrated against the
//! fixture corpus in `tests/lint_fixtures/` (deliberate violations, so the
//! directory is excluded from the workspace walk), plus the self-check —
//! the analyzer run over its own workspace must report zero findings.

use std::path::Path;

use chiplet_cloud::analysis::{self, classify, scan_source, FileClass, Finding, Rule};
use chiplet_cloud::util::json::Json;

const NO_PANIC: &str = include_str!("lint_fixtures/no_panic.rs");
const NO_WALLCLOCK: &str = include_str!("lint_fixtures/no_wallclock.rs");
const NO_UNORDERED: &str = include_str!("lint_fixtures/no_unordered_iter.rs");
const NO_FLOAT_EQ: &str = include_str!("lint_fixtures/no_float_eq.rs");
const NO_PROCESS_EXIT: &str = include_str!("lint_fixtures/no_process_exit.rs");
const SUPPRESSIONS: &str = include_str!("lint_fixtures/suppressions.rs");
const CLEAN: &str = include_str!("lint_fixtures/clean.rs");

/// `(line, rule)` pairs of a finding list, for golden comparisons.
fn shape(fs: &[Finding]) -> Vec<(u32, Rule)> {
    fs.iter().map(|f| (f.line, f.rule)).collect()
}

#[test]
fn no_panic_golden() {
    // unwrap, expect, panic!, todo!, unimplemented! — one finding each;
    // the suppressed lock (line 20) and the #[cfg(test)] mod are silent.
    let fs = scan_source("src/fixture.rs", FileClass::Library, NO_PANIC);
    let want = vec![
        (6, Rule::NoPanic),
        (7, Rule::NoPanic),
        (9, Rule::NoPanic),
        (11, Rule::NoPanic),
        (15, Rule::NoPanic),
    ];
    assert_eq!(shape(&fs), want, "{fs:#?}");
    // The property harness is allowlisted — every panic is fine there, and
    // the now-pointless suppression surfaces as the only finding.
    let fs = scan_source("src/util/prop.rs", FileClass::Library, NO_PANIC);
    assert_eq!(shape(&fs), vec![(19, Rule::UnusedSuppression)], "{fs:#?}");
}

#[test]
fn no_wallclock_golden() {
    // Instant::now() and the SystemTime mention; suppressed one silent.
    let fs = scan_source("src/perf/fixture.rs", FileClass::Library, NO_WALLCLOCK);
    let want = vec![(8, Rule::NoWallclock), (9, Rule::NoWallclock)];
    assert_eq!(shape(&fs), want, "{fs:#?}");
    // The serving stack measures real latency — allowlisted prefix.
    let fs = scan_source("src/coordinator/fixture.rs", FileClass::Library, NO_WALLCLOCK);
    assert_eq!(shape(&fs), vec![(14, Rule::UnusedSuppression)], "{fs:#?}");
}

#[test]
fn no_unordered_iter_golden() {
    // The `use` and the return type each mention HashMap; the counted
    // HashSet carries a suppression with its reason.
    let fs = scan_source("src/report/fixture.rs", FileClass::Library, NO_UNORDERED);
    let want = vec![(6, Rule::NoUnorderedIter), (8, Rule::NoUnorderedIter)];
    assert_eq!(shape(&fs), want, "{fs:#?}");
    // Outside the serialization-adjacent modules the rule is silent.
    let fs = scan_source("src/explore/fixture.rs", FileClass::Library, NO_UNORDERED);
    assert_eq!(shape(&fs), vec![(13, Rule::UnusedSuppression)], "{fs:#?}");
}

#[test]
fn no_float_eq_golden() {
    // Four bare literal comparisons plus the NaN-panicking comparator —
    // whose `.unwrap()` is also a no-panic violation in library code.
    let fs = scan_source("src/fixture.rs", FileClass::Library, NO_FLOAT_EQ);
    let want = vec![
        (6, Rule::NoFloatEq),
        (7, Rule::NoFloatEq),
        (8, Rule::NoFloatEq),
        (9, Rule::NoFloatEq),
        (14, Rule::NoPanic),
        (14, Rule::NoFloatEq),
    ];
    assert_eq!(shape(&fs), want, "{fs:#?}");
    // In test code the bare comparisons are fine, but the NaN hazard in a
    // sort comparator pierces; the exact-sentinel suppression (line 18)
    // has nothing left to suppress and is reported stale.
    let fs = scan_source("tests/fixture.rs", FileClass::Tests, NO_FLOAT_EQ);
    let want = vec![(14, Rule::NoFloatEq), (18, Rule::UnusedSuppression)];
    assert_eq!(shape(&fs), want, "{fs:#?}");
}

#[test]
fn no_process_exit_golden() {
    // Flagged in library code AND in tests (exit kills the harness)...
    for (path, class) in
        [("src/fixture.rs", FileClass::Library), ("tests/fixture.rs", FileClass::Tests)]
    {
        let fs = scan_source(path, class, NO_PROCESS_EXIT);
        assert_eq!(shape(&fs), vec![(6, Rule::NoProcessExit)], "{path}: {fs:#?}");
    }
    // ...but exiting is main.rs's prerogative, where the fixture's
    // suppression consequently suppresses nothing.
    let fs = scan_source("src/main.rs", FileClass::Binary, NO_PROCESS_EXIT);
    assert_eq!(shape(&fs), vec![(10, Rule::UnusedSuppression)], "{fs:#?}");
}

#[test]
fn suppression_misuse_golden() {
    let fs = scan_source("src/fixture.rs", FileClass::Library, SUPPRESSIONS);
    let want = vec![
        // Reason-less allow: the directive is rejected AND the unwrap it
        // meant to cover is reported.
        (5, Rule::NoPanic),
        (5, Rule::BadSuppression),
        (9, Rule::BadSuppression),
        (13, Rule::BadSuppression),
        (17, Rule::UnusedSuppression),
    ];
    assert_eq!(shape(&fs), want, "{fs:#?}");
}

#[test]
fn clean_fixture_is_clean() {
    let fs = scan_source("src/fixture.rs", FileClass::Library, CLEAN);
    assert!(fs.is_empty(), "{fs:#?}");
}

#[test]
fn classify_and_scan_file_agree() {
    assert_eq!(classify("src/main.rs"), FileClass::Binary);
    assert_eq!(classify("src/analysis/rules.rs"), FileClass::Library);
    assert_eq!(classify("tests/integration_lint.rs"), FileClass::Tests);
    assert_eq!(classify("benches/fig7.rs"), FileClass::Benches);
    // scan_file derives the class from the path: main.rs may exit.
    let fs = analysis::scan_file("src/main.rs", "fn f() { std::process::exit(0); }");
    assert!(fs.is_empty(), "{fs:#?}");
}

#[test]
fn workspace_self_check_is_finding_free() {
    // The contract the CI lint step enforces, asserted from `cargo test`:
    // the workspace that ships this analyzer passes it.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = analysis::run(root).expect("lint walk succeeds");
    assert!(
        findings.is_empty(),
        "workspace must be lint-clean:\n{}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn json_report_round_trips() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = analysis::run(root).expect("lint walk succeeds");
    let report = analysis::report_json(root, &findings);
    let v = Json::parse(&report).expect("report is valid JSON");
    assert_eq!(v.get("version").and_then(Json::as_usize), Some(1));
    assert_eq!(v.get("count").and_then(Json::as_usize), Some(findings.len()));
    let arr = v.get("findings").and_then(Json::as_arr).expect("findings array");
    assert_eq!(arr.len(), findings.len());
}
