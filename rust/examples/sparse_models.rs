//! Sparsity showcase: Store-as-Compressed, Load-as-Dense end to end —
//! the tile-CSR codec, the CC-MEM decoder cycle model, and the Fig.-13
//! system-level TCO effect.
//!
//! ```sh
//! cargo run --release --example sparse_models
//! ```

use chiplet_cloud::ccmem::decoder::Decoder;
use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::ModelSpec;
use chiplet_cloud::evaluate::sparsity::sparsity_sweep;
use chiplet_cloud::explore::phase1;
use chiplet_cloud::sparse::{compression_ratio, SparseMatrix, SparseTile, TILE_COLS, TILE_ROWS};
use chiplet_cloud::util::rng::Rng;

fn main() -> chiplet_cloud::Result<()> {
    // 1. The codec: encode a 60%-sparse matrix, verify the exact roundtrip.
    let mut rng = Rng::new(11);
    let (rows, cols) = (512, 512);
    let dense: Vec<u16> = (0..rows * cols)
        .map(|_| if rng.chance(0.6) { 0 } else { rng.below(65536) as u16 })
        .collect();
    let m = SparseMatrix::encode(&dense, rows, cols);
    assert_eq!(m.decode(), dense);
    println!(
        "tile-CSR codec: {}x{} @ {:.0}% sparsity -> {:.0} KB compressed ({:.2}x), roundtrip exact",
        rows,
        cols,
        m.sparsity() * 100.0,
        m.total_bytes() / 1e3,
        (rows * cols) as f64 * 2.0 / m.total_bytes()
    );

    // 2. The decoder: cycle-accurate Fig.-4 replay on one tile.
    let tile_dense: Vec<u16> = (0..TILE_ROWS * TILE_COLS)
        .map(|_| if rng.chance(0.6) { 0 } else { 1 + rng.below(65535) as u16 })
        .collect();
    let tile = SparseTile::encode(&tile_dense);
    let mut dec = Decoder::new();
    let (decoded, cycles) = dec.decode_tile_trace(&tile);
    assert_eq!(decoded, tile_dense);
    println!(
        "CC-MEM decoder: {}-NZV tile decoded dense in {} cycles ({} dense words/cycle sustained)",
        tile.nnz(),
        cycles,
        (TILE_ROWS * TILE_COLS) as u64 / cycles
    );

    // 3. The economics: compression only wins above 1/3 sparsity.
    println!("\ncompression ratio by sparsity (24-bit words => breakeven at 33%):");
    for s in [0.0, 0.1, 0.2, 0.33, 0.5, 0.6, 0.8] {
        println!("  {:>3.0}%: {:.2}x", s * 100.0, compression_ratio(s));
    }

    // 4. The system effect (Fig. 13): OPT-175B TCO/Token under sparsity.
    println!("\nOPT-175B TCO/Token vs sparsity (coarse DSE):");
    let space = ExploreSpace::coarse();
    let (servers, _) = phase1(&space);
    let pts = sparsity_sweep(
        &space,
        &servers,
        &ModelSpec::opt_175b(),
        2048,
        64,
        &[0.1, 0.2, 0.4, 0.6, 0.8],
    );
    for p in &pts {
        println!(
            "  {:>3.0}%: TCO/Token {:+.1}%  perplexity {:.2}  (chips: {})",
            p.sparsity * 100.0,
            p.tco_delta_frac * 100.0,
            p.perplexity,
            p.point.mapping.n_chips()
        );
    }
    println!("\n60% is the sweet spot: cheaper AND still near-dense perplexity (paper Fig. 13).");
    Ok(())
}
