//! End-to-end serving driver (the DESIGN.md §6 validation run).
//!
//! Loads the ~110M-parameter `cc-gpt-mini` AOT artifacts (JAX-lowered,
//! PJRT-executed — no Python anywhere), starts the coordinator, submits a
//! Poisson stream of prompts, generates with dynamic batching, and reports
//! latency percentiles + throughput. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example serve_llm                    # full model
//! cargo run --release --example serve_llm -- --model cc-tiny # fast smoke
//! cargo run --release --example serve_llm -- --requests 32 --tokens 32
//! ```

use std::time::{Duration, Instant};

use chiplet_cloud::coordinator::{Coordinator, CoordinatorConfig};
use chiplet_cloud::util::cli::Args;
use chiplet_cloud::util::rng::Rng;

fn main() -> chiplet_cloud::Result<()> {
    let args = Args::from_env();
    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let model = args.get("model").unwrap_or("cc-gpt-mini").to_string();
    let n_requests: usize = args.get_or("requests", 24);
    let n_tokens: usize = args.get_or("tokens", 24);
    let arrival_rate: f64 = args.get_or("rate", 64.0); // requests/s offered

    println!("== loading {model} from {dir}/ (PJRT CPU; Python is not involved)");
    let t0 = Instant::now();
    let coord = Coordinator::start(
        &dir,
        &model,
        CoordinatorConfig {
            max_wait: Duration::from_millis(40),
            replicas: args.get_or("replicas", 1),
            ..CoordinatorConfig::default()
        },
    )?;
    println!("   engine up in {:.1}s", t0.elapsed().as_secs_f64());

    // Poisson arrivals of varied prompts.
    let mut rng = Rng::new(7);
    println!("== submitting {n_requests} requests (~{arrival_rate}/s, {n_tokens} tokens each)");
    for i in 0..n_requests {
        let len = 8 + rng.below(24);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(1000) as i32 + 2).collect();
        coord.submit(prompt, n_tokens);
        if i + 1 < n_requests {
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(arrival_rate)));
        }
    }

    let metrics = coord.metrics.clone();
    let responses = coord.shutdown()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("== done: {} responses in {:.1}s wall", responses.len(), wall);
    let s = metrics.summary();
    println!("   {}", s.render());
    println!(
        "   throughput: {:.1} tokens/s wall ({:.1} tokens/s lockstep decode, {} tokens)",
        s.wall_tokens_per_s, s.decode_tokens_per_s, s.tokens,
    );
    // sanity: every response satisfied its budget
    assert!(responses.iter().all(|r| r.tokens.len() == n_tokens.min(r.tokens.len())));
    assert_eq!(responses.len(), n_requests);
    println!("   OK — all requests served");
    Ok(())
}
