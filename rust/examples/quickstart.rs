//! Quickstart: run the two-phase co-design methodology for one model and
//! print the TCO/Token-optimal Chiplet Cloud system.
//!
//! ```sh
//! cargo run --release --example quickstart            # GPT-3, coarse sweep
//! cargo run --release --example quickstart -- --model palm --full
//! ```

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, Workload};
use chiplet_cloud::evaluate;
use chiplet_cloud::explore::phase1;
use chiplet_cloud::util::cli::Args;
use chiplet_cloud::util::fmt_dollars;

fn main() -> chiplet_cloud::Result<()> {
    let args = Args::from_env();
    let name = args.get("model").unwrap_or("gpt3");
    let model = ModelSpec::by_name(name)
        .ok_or_else(|| chiplet_cloud::Error::Config(format!("unknown model {name} (try gpt3, palm, llama2-70b)")))?;
    let space = if args.has("full") { ExploreSpace::default() } else { ExploreSpace::coarse() };

    // Phase 1: LLM-agnostic hardware exploration.
    println!("== Phase 1: hardware exploration ({} raw points)", space.n_points());
    let (servers, stats) = phase1(&space);
    println!(
        "   {} feasible server designs (rejected: geometry {}, silicon {}, power {}, thermal {})",
        servers.len(),
        stats.rejected_geometry,
        stats.rejected_silicon,
        stats.rejected_power,
        stats.rejected_thermal
    );

    // Phase 2: workload-aware software evaluation over the paper's grid.
    println!("== Phase 2: software evaluation for {} ({:.1}B params)", model.display, model.n_params() / 1e9);
    let grid = Workload::study_grid(&model);
    let (w, p) = evaluate::best_over_grid(&space, &servers, &grid)
        .ok_or_else(|| chiplet_cloud::Error::Config("no feasible design — widen the space".to_string()))?;

    let chip = &p.server.chiplet;
    println!("\nTCO/Token-optimal Chiplet Cloud for {}:", model.display);
    println!("  chiplet:   {:.0} mm², {:.1} MB CC-MEM, {:.2} TFLOPS, {:.2} TB/s, {:.1} W",
        chip.die_mm2, chip.sram_mb, chip.tflops, chip.mem_bw_gbps / 1e3, chip.tdp_w);
    println!("  server:    {} chips ({} lanes × {}), {:.0} W wall, {} CapEx",
        p.server.chips(), p.server.lanes, p.server.chips_per_lane,
        p.server.server_power_w, fmt_dollars(p.server.server_capex));
    println!("  system:    {} servers, {} chips total", p.n_servers, p.perf.n_chips);
    println!("  mapping:   TP={} PP={} batch={} µbatch={} (ctx {})",
        p.mapping.tp, p.mapping.pp, w.batch, p.mapping.microbatch, w.ctx);
    println!("  decode:    {:.1} tokens/s/chip, {:.0}% compute util, {:.0}% of stage in comm",
        p.perf.tokens_per_s_chip, p.perf.compute_util * 100.0, p.perf.comm_frac * 100.0);
    println!("  cost:      {}/1M tokens  (CapEx share {:.0}%)",
        fmt_dollars(p.tco_per_mtok()), p.tco.capex_frac() * 100.0);
    Ok(())
}
