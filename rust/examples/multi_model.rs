//! Chip flexibility across models (paper §6.3 / Fig. 14): one chip design
//! re-deployed for different LLMs by re-sizing servers and re-optimizing
//! the mapping, plus the multi-model (geomean TCO/Token) chip objective.
//!
//! ```sh
//! cargo run --release --example multi_model
//! ```

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, Workload};
use chiplet_cloud::evaluate::{best_point, multi_model};
use chiplet_cloud::explore::phase1;

fn main() -> chiplet_cloud::Result<()> {
    let space = ExploreSpace::coarse();
    let (servers, _) = phase1(&space);

    let operating: Vec<(ModelSpec, usize, usize)> = vec![
        (ModelSpec::llama2_70b(), 2048, 64),
        (ModelSpec::gopher(), 2048, 64),
        (ModelSpec::gpt3(), 2048, 64),
    ];

    // Per-model optimal chips and costs.
    let mut chips = Vec::new();
    let mut opt = Vec::new();
    for (m, ctx, b) in &operating {
        let w = Workload::new(m.clone(), *ctx, *b);
        let p = best_point(&space, &servers, &w)
            .ok_or_else(|| chiplet_cloud::Error::Config(format!("no design for {}", m.display)))?;
        println!(
            "{:<10} optimal chip: {:>4.0} mm², {:>6.1} MB, {:>5.2} TFLOPS  -> ${:.4}/1M tok",
            m.display,
            p.server.chiplet.die_mm2,
            p.server.chiplet.sram_mb,
            p.server.chiplet.tflops,
            p.tco_per_mtok()
        );
        chips.push(p.server.chiplet.clone());
        opt.push(p.tco_per_token);
    }

    // Cross-model overhead matrix.
    println!("\nTCO/Token overhead running model (column) on chip optimized for (row):");
    print!("{:<12}", "");
    for (m, _, _) in &operating {
        print!("{:>12}", m.display);
    }
    println!();
    for (ci, (cm, _, _)) in operating.iter().enumerate() {
        print!("{:<12}", cm.display);
        for (mi, (m, ctx, b)) in operating.iter().enumerate() {
            match multi_model::best_for_chip(&space, &chips[ci], m, *ctx, *b) {
                Some(p) => print!("{:>11.2}x", p.tco_per_token / opt[mi]),
                None => print!("{:>12}", "-"),
            }
        }
        println!();
    }

    // Multi-model objective.
    if let Some(r) = multi_model::multi_model_search(&space, &chips, &operating) {
        println!(
            "\nmulti-model chip (geomean objective): {:.0} mm², {:.1} MB, {:.2} TFLOPS",
            r.chip.die_mm2, r.chip.sram_mb, r.chip.tflops
        );
        let mut overhead = 1.0f64;
        for (mi, p) in r.per_model.iter().enumerate() {
            let x = p.tco_per_token / opt[mi];
            overhead *= x;
            println!(
                "  on {:<10} {:.2}x of its model-optimized TCO/Token ({} chips)",
                operating[mi].0.display,
                x,
                p.mapping.n_chips()
            );
        }
        println!(
            "  geomean overhead {:.2}x (paper: 1.16x average over 8 models)",
            overhead.powf(1.0 / r.per_model.len() as f64)
        );
    }
    Ok(())
}
