//! Full design-space sweep for one model with CSV export — the raw data
//! behind Fig. 7-style scatter plots (TCO vs throughput per die size).
//!
//! ```sh
//! cargo run --release --example design_sweep -- --model gpt3 --out results
//! ```

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, Workload};
use chiplet_cloud::evaluate;
use chiplet_cloud::explore::phase1;
use chiplet_cloud::util::cli::Args;
use chiplet_cloud::util::csv::write_csv;

fn main() -> chiplet_cloud::Result<()> {
    let args = Args::from_env();
    let name = args.get("model").unwrap_or("gpt3");
    let model =
        ModelSpec::by_name(name).ok_or_else(|| chiplet_cloud::Error::Config(format!("unknown model {name}")))?;
    let ctx: usize = args.get_or("ctx", 2048);
    let batch: usize = args.get_or("batch", 256);
    let space = if args.has("full") { ExploreSpace::default() } else { ExploreSpace::coarse() };

    let (servers, _) = phase1(&space);
    let w = Workload::new(model.clone(), ctx, batch);
    println!(
        "sweeping {} server designs for {} (ctx {ctx}, batch {batch}) ...",
        servers.len(),
        model.display
    );
    let points = evaluate::sweep(&space, &servers, &w);
    println!("{} evaluable design points", points.len());

    let mut rows = vec![vec![
        "die_mm2".to_string(),
        "sram_mb".to_string(),
        "tflops".to_string(),
        "bw_gbps".to_string(),
        "chips_per_server".to_string(),
        "n_servers".to_string(),
        "tp".to_string(),
        "pp".to_string(),
        "microbatch".to_string(),
        "tokens_per_s".to_string(),
        "tco_usd".to_string(),
        "tco_per_mtok".to_string(),
        "compute_util".to_string(),
    ]];
    for p in &points {
        rows.push(vec![
            format!("{}", p.server.chiplet.die_mm2),
            format!("{:.1}", p.server.chiplet.sram_mb),
            format!("{:.2}", p.server.chiplet.tflops),
            format!("{:.0}", p.server.chiplet.mem_bw_gbps),
            format!("{}", p.server.chips()),
            format!("{}", p.n_servers),
            format!("{}", p.mapping.tp),
            format!("{}", p.mapping.pp),
            format!("{}", p.mapping.microbatch),
            format!("{:.1}", p.perf.tokens_per_s),
            format!("{:.0}", p.tco.total()),
            format!("{:.4}", p.tco_per_mtok()),
            format!("{:.3}", p.perf.compute_util),
        ]);
    }
    let out = args.get("out").unwrap_or("results");
    let path = format!("{out}/sweep_{}.csv", model.name);
    write_csv(&path, &rows)?;
    println!("wrote {path}");

    // headline: the best point
    if let Some(best) =
        points.iter().min_by(|a, b| a.tco_per_token.partial_cmp(&b.tco_per_token).unwrap())
    {
        println!(
            "best: {:.0} mm² die, {} servers, ${:.4}/1M tokens",
            best.server.chiplet.die_mm2,
            best.n_servers,
            best.tco_per_mtok()
        );
    }
    Ok(())
}
