//! Offline stub of the `xla` PJRT bindings.
//!
//! The chiplet_cloud serving runtime ([`crate`]'s consumer,
//! `chiplet_cloud::runtime`) talks to AOT-compiled HLO through the vendored
//! `xla` crate on images that ship the XLA extension libraries. This stub
//! provides the exact API surface the runtime uses so the whole workspace
//! builds (and the design-space-exploration side runs) with **no** native
//! XLA dependency. Every operation that would need a real PJRT client
//! returns an [`Error`] at runtime; the runtime's callers already treat
//! artifact loading as fallible and skip gracefully.
//!
//! Keep this in signature lock-step with `chiplet_cloud::runtime::engine` —
//! that module is the sole consumer.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's `xla::Error` (stringly here).
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the XLA/PJRT backend is not available in this offline build \
         (the `xla` crate is stubbed; install the vendored bindings to serve models)"
    )))
}

/// A host-side literal (typed array). Stub: carries no data.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal::default()
    }

    /// Build a rank-0 literal from a scalar.
    pub fn scalar<T>(_value: T) -> Literal {
        Literal::default()
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Download the literal's data as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Deserialization support (`.npz` weight archives in the real crate).
pub trait FromRawBytes: Sized {
    /// Read the named arrays from an `.npz` archive.
    fn read_npz_by_name<P: AsRef<Path>, C>(path: P, ctx: &C, names: &[&str]) -> Result<Vec<Self>>;
}

impl FromRawBytes for Literal {
    fn read_npz_by_name<P: AsRef<Path>, C>(
        _path: P,
        _ctx: &C,
        _names: &[&str],
    ) -> Result<Vec<Literal>> {
        unavailable("Literal::read_npz_by_name")
    }
}

/// A parsed HLO module proto.
#[derive(Clone, Debug, Default)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
#[derive(Clone, Debug, Default)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a module proto as a computation (infallible in the real crate).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation::default()
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Synchronously download the buffer as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }

    /// Synchronously copy raw bytes into a host slice.
    pub fn copy_raw_to_host_sync<T>(&self, _dst: &mut [T], _offset: usize) -> Result<()> {
        unavailable("PjRtBuffer::copy_raw_to_host_sync")
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer arguments; returns per-device output rows.
    pub fn execute_b<T: Borrow<PjRtBuffer>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// A PJRT client (CPU platform in the runtime).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Start an asynchronous host→device upload of a literal.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }

    /// Platform name for logs.
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_operations_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"), "{err}");
    }
}
