//! Bench + regeneration of **Table 2**: TCO/Token-optimal Chiplet Cloud
//! systems for the eight case-study models.
//!
//! Set `CC_BENCH_FULL=1` for the paper-scale sweep (Table-1 ranges).

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::ModelSpec;
use chiplet_cloud::report::{self, Ctx};
use chiplet_cloud::util::bench::Bench;

fn space() -> ExploreSpace {
    if std::env::var("CC_BENCH_FULL").is_ok() {
        ExploreSpace::default()
    } else {
        ExploreSpace::coarse()
    }
}

fn main() {
    let mut b = Bench::new();
    // time phase 1 alone (the hardware exploration hot loop)
    b.run("phase1/hardware-exploration", || chiplet_cloud::explore::phase1(&space()));

    let ctx = Ctx::new(space());
    // time one full per-model optimization (phase 2 hot loop)
    let gpt3 = ModelSpec::gpt3();
    b.run("phase2/gpt3-grid-optimum", || {
        let grid = chiplet_cloud::config::Workload::study_grid(&gpt3);
        chiplet_cloud::evaluate::best_over_grid(&ctx.space, &ctx.servers, &grid)
    });

    // regenerate the table for all eight models
    let t = report::table2(&ctx, &ModelSpec::paper_models(), Some(std::path::Path::new("results")));
    print!("{}", t.render());
}
