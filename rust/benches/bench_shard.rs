//! Shard/merge overhead benchmark: what does distributing a sweep cost
//! versus just running it?
//!
//! Three configurations of the same gpt2 coarse sweep are timed —
//!
//! * `single`      — one `Engine::run` in this process;
//! * `in-process`  — `plan` into 3 shards, run each shard on a shared
//!   engine, `merge` the envelopes (the pure shard/merge algebra, no
//!   process spawns);
//! * `distributed` — the real orchestrator: 3 child worker processes with
//!   checkpoints under a temp run directory (skipped when the `ccloud`
//!   binary path is unavailable).
//!
//! All configurations must produce the identical outcome outside the
//! `"engine"` counters (asserted, bit-exact), and the timings are written
//! machine-readable to `BENCH_shard.json` (override the path with
//! `CC_BENCH_SHARD_JSON`). Pass `--quick` (the CI mode) to shrink the
//! measurement budget.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use chiplet_cloud::config::experiment::{EngineKnobs, Experiment, SpaceSpec, Task};
use chiplet_cloud::experiment::orchestrator::{self, OrchestratorConfig};
use chiplet_cloud::experiment::shard::{merge, plan, strip_engine, Envelope};
use chiplet_cloud::experiment::Engine;
use chiplet_cloud::util::json::Json;

const WORKERS: usize = 3;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn spec() -> Experiment {
    let models = vec!["gpt2".to_string()];
    Experiment {
        name: Experiment::default_name(Task::Sweep, &models),
        task: Task::Sweep,
        models,
        space: SpaceSpec::Coarse,
        workload: None,
        serve: None,
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let e = spec();
    let iters = if quick { 2 } else { 5 };

    // Shared engine: Phase 1 is swept once, so the timings isolate the
    // shard/merge overhead rather than re-measuring the hardware sweep.
    let mut engine = Engine::new();
    let golden = strip_engine(&engine.run(&e).expect("single run").to_json()).to_string();

    let t0 = Instant::now();
    for _ in 0..iters {
        engine.run(&e).expect("single run");
    }
    let single_s = t0.elapsed().as_secs_f64() / iters as f64;

    let shards = plan(&e, WORKERS, &mut engine).expect("plan");
    let t0 = Instant::now();
    let mut merged_inproc = String::new();
    for _ in 0..iters {
        let envs: Vec<Envelope> = shards
            .iter()
            .map(|s| Envelope::new(s.clone(), engine.run(s).expect("shard run").to_json()))
            .collect();
        let merged = merge(&envs).expect("merge");
        merged_inproc = strip_engine(&merged.outcome).to_string();
    }
    let inproc_s = t0.elapsed().as_secs_f64() / iters as f64;
    assert_eq!(merged_inproc, golden, "in-process shard/merge diverged from the single run");

    // Distributed: the real child-process orchestrator, once (spawn +
    // checkpoint IO dominate; repeating it buys no precision).
    let exe: Option<PathBuf> = option_env!("CARGO_BIN_EXE_ccloud").map(PathBuf::from);
    let distributed_s = match exe {
        Some(exe) if exe.exists() => {
            let run_dir =
                std::env::temp_dir().join(format!("cc-bench-shard-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&run_dir);
            let cfg = OrchestratorConfig {
                workers: WORKERS,
                timeout: Duration::from_secs(600),
                exe: Some(exe),
                ..OrchestratorConfig::default()
            };
            let t0 = Instant::now();
            let run = orchestrator::run_distributed(&e, &run_dir, false, &cfg)
                .expect("distributed run");
            let wall = t0.elapsed().as_secs_f64();
            assert!(run.merged.missing.is_empty(), "distributed run lost shards");
            assert_eq!(
                strip_engine(&run.merged.outcome).to_string(),
                golden,
                "distributed outcome diverged from the single run"
            );
            let _ = std::fs::remove_dir_all(&run_dir);
            Some(wall)
        }
        _ => {
            println!("distributed: skipped (ccloud binary path unavailable)");
            None
        }
    };

    println!(
        "shard overhead ({WORKERS} shards): single {single_s:.3}s | in-process {inproc_s:.3}s \
         ({:.2}x) | distributed {}",
        inproc_s / single_s.max(1e-9),
        match distributed_s {
            Some(d) => format!("{d:.3}s ({:.2}x)", d / single_s.max(1e-9)),
            None => "skipped".to_string(),
        }
    );
    println!("outcomes identical across single, in-process sharded, and distributed runs");

    let out = obj(vec![
        ("bench", Json::Str("bench_shard".into())),
        ("mode", Json::Str(if quick { "quick".into() } else { "full".into() })),
        ("workers", Json::Num(WORKERS as f64)),
        ("single_s", Json::Num(single_s)),
        ("inprocess_s", Json::Num(inproc_s)),
        ("inprocess_overhead", Json::Num(inproc_s / single_s.max(1e-9))),
        (
            "distributed_s",
            distributed_s.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "distributed_overhead",
            distributed_s.map(|d| Json::Num(d / single_s.max(1e-9))).unwrap_or(Json::Null),
        ),
        ("identical_outcomes", Json::Bool(true)),
    ]);
    let path = std::env::var("CC_BENCH_SHARD_JSON").unwrap_or_else(|_| "BENCH_shard.json".into());
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_shard.json");
    println!("wrote {path}");
}
