//! CC-MEM cycle-simulator benchmarks: simulation throughput plus the
//! architectural numbers the paper claims (crossbar saturation, sparse
//! decoder rates, conflict penalties).

use chiplet_cloud::ccmem::bank::BurstMode;
use chiplet_cloud::ccmem::decoder::Decoder;
use chiplet_cloud::ccmem::traffic::{run_gemm_stream, run_random};
use chiplet_cloud::ccmem::CcMemConfig;
use chiplet_cloud::sparse::SparseTile;
use chiplet_cloud::util::bench::Bench;
use chiplet_cloud::util::rng::Rng;
use chiplet_cloud::util::table::Table;

fn main() {
    let cfg = CcMemConfig::small();
    let mut b = Bench::new();

    let s = b.run("ccmem/gemm-stream-64KB-per-group", || {
        run_gemm_stream(&cfg, 64 << 10, BurstMode::Dense)
    });
    let r = run_gemm_stream(&cfg, 64 << 10, BurstMode::Dense);
    let sim_rate = (r.cycles as f64) / s.mean_s;
    println!("simulator speed: {:.1} M simulated cycles/s", sim_rate / 1e6);

    b.run("ccmem/random-5k-cycles", || run_random(&cfg, 5_000, 42));

    let mut rng = Rng::new(3);
    let dense: Vec<u16> =
        (0..256).map(|_| if rng.chance(0.6) { 0 } else { 1 + rng.below(65535) as u16 }).collect();
    let tile = SparseTile::encode(&dense);
    b.run("decoder/tile-trace-60pct", || {
        let mut d = Decoder::new();
        d.decode_tile_trace(&tile)
    });

    // Architectural results table (the numbers §3.1–3.2 claim).
    let mut t = Table::new(vec!["experiment", "result"])
        .with_title("CC-MEM architectural validation");
    let dense_r = run_gemm_stream(&cfg, 64 << 10, BurstMode::Dense);
    t.row(vec![
        "GEMM-stream core BW utilization".to_string(),
        format!("{:.1}% (claim: ~100%)", dense_r.core_bw_utilization * 100.0),
    ]);
    let s60 = run_gemm_stream(&cfg, 64 << 10, BurstMode::Sparse { nnz_per_tile: 102 });
    t.row(vec![
        "60%-sparse stream vs dense cycles".to_string(),
        format!("{}/{} (claim: equal)", s60.cycles, dense_r.cycles),
    ]);
    let s10 = run_gemm_stream(&cfg, 64 << 10, BurstMode::Sparse { nnz_per_tile: 230 });
    t.row(vec![
        "10%-sparse stream slowdown".to_string(),
        format!("{:.2}x (claim: input-limited)", s10.cycles as f64 / dense_r.cycles as f64),
    ]);
    let rnd = run_random(&cfg, 20_000, 7);
    t.row(vec![
        "random-traffic conflict rate".to_string(),
        format!("{:.1}%", rnd.conflict_rate * 100.0),
    ]);
    print!("{}", t.render());
}
