//! Coordinator hot-path benchmarks: batcher formation under load and the
//! end-to-end serve loop over the PJRT engine (queue → batch → prefill →
//! lockstep decode → responses).

use std::time::Duration;

use chiplet_cloud::coordinator::{Batcher, BatcherConfig, Coordinator, CoordinatorConfig, Request};
use chiplet_cloud::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    // Batcher formation micro-bench (allocation-sensitive hot path).
    let cfg = BatcherConfig {
        batch: 8,
        prompt_len: 32,
        max_wait: Duration::from_millis(0),
        pad_token: 0,
        kv: chiplet_cloud::sched::KvBudget::unlimited(),
    };
    b.run("coordinator/batch-formation-8x32", || {
        let batcher = Batcher::new(cfg.clone());
        for i in 0..8 {
            batcher.submit(Request::new(i, vec![1; 24], 8));
        }
        batcher.next_batch()
    });

    // Prompt fitting micro-bench.
    let batcher = Batcher::new(cfg);
    let long: Vec<i32> = (0..512).collect();
    b.run("coordinator/fit-prompt-512to32", || batcher.fit_prompt(&long));

    // End-to-end serve loop on the tiny artifact.
    let dir = "artifacts";
    if !std::path::Path::new(dir).join("cc-tiny.manifest.json").exists() {
        eprintln!("bench_coordinator: artifacts missing — run `make artifacts` first");
        return;
    }
    let mut e2e = Bench::new();
    e2e.max_iters = 3;
    e2e.run("coordinator/e2e-8req-4tok", || {
        let coord = Coordinator::start(
            dir,
            "cc-tiny",
            CoordinatorConfig { max_wait: Duration::from_millis(5), ..CoordinatorConfig::default() },
        )
        .unwrap();
        for i in 0..8 {
            coord.submit(vec![(i % 50) as i32 + 1; 12], 4);
        }
        coord.shutdown().unwrap()
    });
}
