//! Bench + regeneration of **Fig. 13 (sparsity sweep)**.
//!
//! Set `CC_BENCH_FULL=1` for the paper-scale sweep.

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::report::{self, Ctx};
use chiplet_cloud::util::bench::Bench;

fn main() {
    let space = if std::env::var("CC_BENCH_FULL").is_ok() {
        ExploreSpace::default()
    } else {
        ExploreSpace::coarse()
    };
    let ctx = Ctx::new(space);
    let mut b = Bench::new();
    b.max_iters = 3;
    let mut last = None;
    b.run("harness/fig13", || {
        last = Some(report::fig13(&ctx, Some(std::path::Path::new("results"))));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
