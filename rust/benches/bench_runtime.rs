//! PJRT runtime benchmarks: artifact load, prefill latency, decode-step
//! latency and tokens/s on the AOT-compiled model (the L3 hot path of the
//! serving stack). Uses `cc-tiny` by default; set `CC_BENCH_MODEL=cc-gpt-mini`
//! for the ~110M serving model.

use chiplet_cloud::runtime::ModelEngine;
use chiplet_cloud::util::bench::Bench;

fn main() {
    let model = std::env::var("CC_BENCH_MODEL").unwrap_or_else(|_| "cc-tiny".to_string());
    let dir = "artifacts";
    if !std::path::Path::new(dir).join(format!("{model}.manifest.json")).exists() {
        eprintln!("bench_runtime: artifacts missing — run `make artifacts` first");
        return;
    }
    let engine = ModelEngine::load(dir, &model).expect("load artifacts");
    println!(
        "loaded {model}: {} params tensors, batch={}, load {:.1}s",
        engine.manifest.params.len(),
        engine.manifest.batch,
        engine.load_time_s
    );
    let (prompt, _) = engine.manifest.load_fixture().expect("fixture");

    let mut b = Bench::new();
    b.max_iters = 50;
    b.run("runtime/prefill", || engine.prefill(&prompt).unwrap());

    let (tokens, state0) = engine.prefill(&prompt).unwrap();
    // decode step latency (re-prime state each iter to keep pos legal)
    let mut state = state0;
    let mut toks = tokens.clone();
    let s = b.run("runtime/decode-step", || {
        if state.pos + 1 >= engine.manifest.max_ctx {
            let (t2, s2) = engine.prefill(&prompt).unwrap();
            toks = t2;
            state = s2;
        }
        toks = engine.decode_step(&toks, &mut state).unwrap();
    });
    let batch = engine.manifest.batch as f64;
    println!(
        "decode throughput: {:.1} tokens/s (batch {} x {:.1} steps/s)",
        batch / s.mean_s,
        batch,
        1.0 / s.mean_s
    );
}
