//! Bench + regeneration of **Fig. 8**: optimal TCO/1K tokens vs batch size
//! across models and context lengths (MHA vs MQA/GQA KV-cache effect).
//!
//! Set `CC_BENCH_FULL=1` for the paper-scale sweep and full batch grid.

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::report::{self, Ctx};
use chiplet_cloud::util::bench::Bench;

fn main() {
    let full = std::env::var("CC_BENCH_FULL").is_ok();
    let space = if full { ExploreSpace::default() } else { ExploreSpace::coarse() };
    let ctx = Ctx::new(space);
    let ctxs: Vec<usize> = if full { vec![1024, 2048, 4096] } else { vec![2048] };
    let batches: Vec<usize> =
        if full { vec![1, 4, 16, 64, 256, 1024] } else { vec![1, 16, 256, 1024] };
    let mut b = Bench::new();
    b.max_iters = 3;
    let mut last = None;
    b.run("harness/fig8", || {
        last = Some(report::fig8(&ctx, &ctxs, &batches, Some(std::path::Path::new("results"))));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
