//! Bench + regeneration of **Fig. 15**: minimum TCO/Token improvement that
//! justifies the ASIC NRE, vs the incumbent workload's yearly TCO.

use chiplet_cloud::report;
use chiplet_cloud::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let mut last = None;
    b.run("harness/fig15", || {
        last = Some(report::fig15(Some(std::path::Path::new("results"))));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
