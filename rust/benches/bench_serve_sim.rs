//! Serving-simulator benchmarks: event-sim wall cost per simulated
//! request, the static vs continuous goodput comparison on one seeded
//! high-load trace (continuous must win — asserted, not just printed),
//! the chunked-prefill / multi-replica paths, and the decode fast-forward
//! core against the step-by-step reference (bit-identical — asserted —
//! and the speedup printed).

use std::time::Instant;

use chiplet_cloud::config::{SloSpec, TrafficSpec};
use chiplet_cloud::perf::events::{simulate_replicated, simulate_trace, IterCost, SimConfig};
use chiplet_cloud::sched::{ContinuousBatch, KvBudget, RoutePolicy, StaticBatch};
use chiplet_cloud::util::bench::{black_box, Bench};

fn cfg() -> SimConfig {
    SimConfig::new(
        8,
        KvBudget::unlimited(),
        IterCost { prefill_s_per_token: 0.0001, decode_step_s: 0.01, prefill_chunk: 0 },
        false,
    )
}

/// The paged + chunked serving model over a binding synthetic budget.
fn paged_cfg() -> SimConfig {
    let mut c = cfg();
    c.kv = KvBudget::tokens(512, 16);
    c.paged_kv = true;
    c.cost = c.cost.with_chunk(16);
    c
}

fn main() {
    let mut b = Bench::new();

    // High-load trace: ~68% of slot capacity for continuous batching,
    // past the batch-synchronous policy's effective capacity.
    let trace = TrafficSpec::poisson(30.0, 400, 16, 4, 32).with_seed(11);
    let slo = SloSpec::new(0.25, 0.015);

    b.run("serve_sim/static-400req", || {
        black_box(simulate_trace(&cfg(), &mut StaticBatch::new(0.05), &trace, &slo))
    });
    b.run("serve_sim/continuous-400req", || {
        black_box(simulate_trace(&cfg(), &mut ContinuousBatch, &trace, &slo))
    });
    b.run("serve_sim/paged-chunked-400req", || {
        black_box(simulate_trace(&paged_cfg(), &mut ContinuousBatch, &trace, &slo))
    });
    b.run("serve_sim/jsq-2replica-400req", || {
        black_box(simulate_replicated(
            &cfg(),
            2,
            RoutePolicy::Jsq,
            &ContinuousBatch,
            &trace,
            &slo,
        ))
    });

    // --- Decode fast-forward vs reference stepping --------------------
    // Long generations at moderate load: most virtual time is uniform
    // decode, which the fast path jumps between events.
    let decode_heavy = TrafficSpec::poisson(4.0, 200, 32, 128, 512).with_seed(23);
    let fast_cfg = cfg();
    let mut ref_cfg = cfg();
    ref_cfg.reference_step = true;
    let fast_stats = b.run("serve_sim/fastforward-200req-decode-heavy", || {
        black_box(simulate_trace(&fast_cfg, &mut ContinuousBatch, &decode_heavy, &slo))
    });
    let ref_stats = b.run("serve_sim/reference-200req-decode-heavy", || {
        black_box(simulate_trace(&ref_cfg, &mut ContinuousBatch, &decode_heavy, &slo))
    });
    let fast = simulate_trace(&fast_cfg, &mut ContinuousBatch, &decode_heavy, &slo);
    let reference = simulate_trace(&ref_cfg, &mut ContinuousBatch, &decode_heavy, &slo);
    assert_eq!(fast.completed, reference.completed, "fast-forward diverged: completed");
    assert_eq!(fast.iterations, reference.iterations, "fast-forward diverged: iterations");
    assert_eq!(
        fast.ttft_p99_s.to_bits(),
        reference.ttft_p99_s.to_bits(),
        "fast-forward diverged: p99 TTFT"
    );
    assert_eq!(
        fast.tpot_p99_s.to_bits(),
        reference.tpot_p99_s.to_bits(),
        "fast-forward diverged: p99 TPOT"
    );
    assert_eq!(
        fast.makespan_s.to_bits(),
        reference.makespan_s.to_bits(),
        "fast-forward diverged: makespan"
    );
    println!(
        "fast-forward vs reference (decode-heavy): {:.2}x on p50 wall time (bit-identical reports)",
        ref_stats.p50_s / fast_stats.p50_s.max(1e-12)
    );

    // Early abort on a hopeless SLO: the simulation must get strictly
    // cheaper, not just the report smaller.
    let hopeless = SloSpec::new(f64::INFINITY, 1e-6);
    let mut abort_cfg = fast_cfg;
    abort_cfg.early_abort = true;
    let t0 = Instant::now();
    let full = simulate_trace(&fast_cfg, &mut ContinuousBatch, &decode_heavy, &hopeless);
    let full_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let aborted = simulate_trace(&abort_cfg, &mut ContinuousBatch, &decode_heavy, &hopeless);
    let abort_s = t0.elapsed().as_secs_f64();
    assert!(aborted.aborted_early, "hopeless SLO must abort early");
    assert!(
        aborted.iterations < full.iterations,
        "early abort must cut iterations: {} vs {}",
        aborted.iterations,
        full.iterations
    );
    println!(
        "early abort (hopeless SLO): {} of {} iterations simulated ({:.2}x wall)",
        aborted.iterations,
        full.iterations,
        full_s / abort_s.max(1e-12)
    );

    let st = simulate_trace(&cfg(), &mut StaticBatch::new(0.05), &trace, &slo);
    let co = simulate_trace(&cfg(), &mut ContinuousBatch, &trace, &slo);
    println!(
        "static:     goodput {:7.1} tok/s  ttft p99 {:7.3}s  occupancy {:4.1}%  slo-met {:4.1}%",
        st.goodput_tokens_per_s,
        st.ttft_p99_s,
        st.occupancy * 100.0,
        st.slo_met_frac * 100.0
    );
    println!(
        "continuous: goodput {:7.1} tok/s  ttft p99 {:7.3}s  occupancy {:4.1}%  slo-met {:4.1}%",
        co.goodput_tokens_per_s,
        co.ttft_p99_s,
        co.occupancy * 100.0,
        co.slo_met_frac * 100.0
    );
    assert!(
        co.goodput_tokens_per_s > st.goodput_tokens_per_s,
        "continuous batching must out-goodput static at high load ({} vs {})",
        co.goodput_tokens_per_s,
        st.goodput_tokens_per_s
    );
    assert!(
        co.ttft_p99_s < st.ttft_p99_s,
        "continuous batching must cut the p99 TTFT at high load ({} vs {})",
        co.ttft_p99_s,
        st.ttft_p99_s
    );
    println!("OK — continuous batching wins goodput and p99 TTFT at high load");
}
