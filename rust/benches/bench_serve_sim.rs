//! Serving-simulator benchmarks: event-sim wall cost per simulated
//! request, the static vs continuous goodput comparison on one seeded
//! high-load trace (continuous must win — asserted, not just printed),
//! the chunked-prefill / multi-replica paths, the decode fast-forward
//! core against the step-by-step reference (bit-identical — asserted —
//! and the speedup printed), and the million-request scale: quantized
//! time vs fast-forward (tails within the documented epsilon — asserted)
//! plus a sketched-tail multi-replica fleet run and a failure-aware fleet
//! section (fault-free runs through the failure-aware entry point are
//! bit-identical to the default path — asserted — and a scripted mid-run
//! outage keeps request conservation — asserted), and an overcommit
//! section on a block-bound paged pool (expected-residency admission must
//! out-goodput max-footprint reservation — asserted — while the
//! overcommit-off run keeps the pre-overcommit report shape).
//!
//! Pass `--quick` (the CI mode) to shrink the million-request sections;
//! set `CC_BENCH_JSON` to merge a `serve_sim` section into the sweep
//! bench's machine-readable artifact (existing keys are preserved).

use std::collections::BTreeMap;
use std::time::Instant;

use chiplet_cloud::config::{FaultSpec, OvercommitSpec, SloSpec, TrafficSpec};
use chiplet_cloud::perf::events::{
    simulate_replicated, simulate_replicated_faults, simulate_trace, IterCost, SimConfig,
};
use chiplet_cloud::sched::{ContinuousBatch, KvBudget, RoutePolicy, StaticBatch};
use chiplet_cloud::util::bench::{black_box, Bench};
use chiplet_cloud::util::json::Json;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn cfg() -> SimConfig {
    SimConfig::new(
        8,
        KvBudget::unlimited(),
        IterCost { prefill_s_per_token: 0.0001, decode_step_s: 0.01, prefill_chunk: 0 },
        false,
    )
}

/// The paged + chunked serving model over a binding synthetic budget.
fn paged_cfg() -> SimConfig {
    let mut c = cfg();
    c.kv = KvBudget::tokens(512, 16);
    c.paged_kv = true;
    c.cost = c.cost.with_chunk(16);
    c
}

fn main() {
    let mut b = Bench::new();

    // High-load trace: ~68% of slot capacity for continuous batching,
    // past the batch-synchronous policy's effective capacity.
    let trace = TrafficSpec::poisson(30.0, 400, 16, 4, 32).with_seed(11);
    let slo = SloSpec::new(0.25, 0.015);

    b.run("serve_sim/static-400req", || {
        black_box(simulate_trace(&cfg(), &mut StaticBatch::new(0.05), &trace, &slo))
    });
    b.run("serve_sim/continuous-400req", || {
        black_box(simulate_trace(&cfg(), &mut ContinuousBatch, &trace, &slo))
    });
    b.run("serve_sim/paged-chunked-400req", || {
        black_box(simulate_trace(&paged_cfg(), &mut ContinuousBatch, &trace, &slo))
    });
    b.run("serve_sim/jsq-2replica-400req", || {
        black_box(simulate_replicated(
            &cfg(),
            2,
            RoutePolicy::Jsq,
            &ContinuousBatch,
            &trace,
            &slo,
        ))
    });

    // --- Decode fast-forward vs reference stepping --------------------
    // Long generations at moderate load: most virtual time is uniform
    // decode, which the fast path jumps between events.
    let decode_heavy = TrafficSpec::poisson(4.0, 200, 32, 128, 512).with_seed(23);
    let fast_cfg = cfg();
    let mut ref_cfg = cfg();
    ref_cfg.reference_step = true;
    let fast_stats = b.run("serve_sim/fastforward-200req-decode-heavy", || {
        black_box(simulate_trace(&fast_cfg, &mut ContinuousBatch, &decode_heavy, &slo))
    });
    let ref_stats = b.run("serve_sim/reference-200req-decode-heavy", || {
        black_box(simulate_trace(&ref_cfg, &mut ContinuousBatch, &decode_heavy, &slo))
    });
    let fast = simulate_trace(&fast_cfg, &mut ContinuousBatch, &decode_heavy, &slo);
    let reference = simulate_trace(&ref_cfg, &mut ContinuousBatch, &decode_heavy, &slo);
    assert_eq!(fast.completed, reference.completed, "fast-forward diverged: completed");
    assert_eq!(fast.iterations, reference.iterations, "fast-forward diverged: iterations");
    assert_eq!(
        fast.ttft_p99_s.to_bits(),
        reference.ttft_p99_s.to_bits(),
        "fast-forward diverged: p99 TTFT"
    );
    assert_eq!(
        fast.tpot_p99_s.to_bits(),
        reference.tpot_p99_s.to_bits(),
        "fast-forward diverged: p99 TPOT"
    );
    assert_eq!(
        fast.makespan_s.to_bits(),
        reference.makespan_s.to_bits(),
        "fast-forward diverged: makespan"
    );
    println!(
        "fast-forward vs reference (decode-heavy): {:.2}x on p50 wall time (bit-identical reports)",
        ref_stats.p50_s / fast_stats.p50_s.max(1e-12)
    );

    // Early abort on a hopeless SLO: the simulation must get strictly
    // cheaper, not just the report smaller.
    let hopeless = SloSpec::new(f64::INFINITY, 1e-6);
    let mut abort_cfg = fast_cfg;
    abort_cfg.early_abort = true;
    let t0 = Instant::now();
    let full = simulate_trace(&fast_cfg, &mut ContinuousBatch, &decode_heavy, &hopeless);
    let full_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let aborted = simulate_trace(&abort_cfg, &mut ContinuousBatch, &decode_heavy, &hopeless);
    let abort_s = t0.elapsed().as_secs_f64();
    assert!(aborted.aborted_early, "hopeless SLO must abort early");
    assert!(
        aborted.iterations < full.iterations,
        "early abort must cut iterations: {} vs {}",
        aborted.iterations,
        full.iterations
    );
    println!(
        "early abort (hopeless SLO): {} of {} iterations simulated ({:.2}x wall)",
        aborted.iterations,
        full.iterations,
        full_s / abort_s.max(1e-12)
    );

    // --- Million-request scale: quantized time vs fast-forward ---------
    // Decode-heavy, ~50% loaded open loop: long uniform stretches between
    // arrivals are where the closed-form clock jump beats the
    // per-iteration replay. 1M requests stay under the default tail cap,
    // so both runs keep exact percentiles and the comparison isolates
    // pure quantization error.
    let quick = std::env::args().any(|a| a == "--quick");
    let mode = if quick { "quick" } else { "full" };
    // The quick fleet still exceeds the default tail cap (1 << 20), so the
    // sketched-tails path is exercised in CI too.
    let (n_single, n_fleet) = if quick { (200_000, 2_000_000) } else { (1_000_000, 10_000_000) };
    let million = TrafficSpec::poisson(0.6, n_single, 32, 256, 1024).with_seed(77);
    let unconstrained = SloSpec::unconstrained();
    let t0 = Instant::now();
    let ff = simulate_trace(&cfg(), &mut ContinuousBatch, &million, &unconstrained);
    let ff_s = t0.elapsed().as_secs_f64();
    let mut quant_cfg = cfg();
    quant_cfg.quantum = 5.0; // up to 500 decode steps per clock jump
    let t0 = Instant::now();
    let quant = simulate_trace(&quant_cfg, &mut ContinuousBatch, &million, &unconstrained);
    let quant_s = t0.elapsed().as_secs_f64();
    assert_eq!(ff.completed, quant.completed, "quantized mode diverged: completed");
    assert_eq!(ff.tokens, quant.tokens, "quantized mode diverged: tokens");
    let step = quant_cfg.cost.decode_step_s;
    for (q, r, what) in [
        (quant.ttft_p50_s, ff.ttft_p50_s, "ttft p50"),
        (quant.ttft_p99_s, ff.ttft_p99_s, "ttft p99"),
        (quant.tpot_p50_s, ff.tpot_p50_s, "tpot p50"),
        (quant.tpot_p99_s, ff.tpot_p99_s, "tpot p99"),
    ] {
        assert!(
            (q - r).abs() <= 2.0 * step + 1e-6 * r.abs(),
            "quantized {what} {q} outside epsilon of reference {r}"
        );
    }
    let single_speedup = ff_s / quant_s.max(1e-12);
    println!(
        "quantized vs fast-forward ({n_single} requests): {ff_s:.2}s -> {quant_s:.2}s \
         ({single_speedup:.2}x, tails within 2*step + 1e-6)"
    );

    // The fleet run: 8 replicas, sketched tails (offered >> tail_cap), the
    // arrival stream generated lazily — memory stays O(1) in requests.
    let fleet_traffic = TrafficSpec::poisson(4.8, n_fleet, 32, 256, 1024).with_seed(78);
    let t0 = Instant::now();
    let fleet = simulate_replicated(
        &quant_cfg,
        8,
        RoutePolicy::RoundRobin,
        &ContinuousBatch,
        &fleet_traffic,
        &unconstrained,
    );
    let fleet_s = t0.elapsed().as_secs_f64();
    assert_eq!(fleet.completed, n_fleet, "fleet run must serve the whole trace");
    assert!(
        fleet.per_request.is_empty(),
        "a {n_fleet}-request run must use sketched tails, not per-request records"
    );
    assert!(fleet.ttft_p99_s.is_finite() && fleet.ttft_p99_s > 0.0);
    println!(
        "quantized fleet ({n_fleet} requests, 8 replicas, sketched tails): {fleet_s:.2}s \
         ({:.0} simulated requests/s)",
        n_fleet as f64 / fleet_s.max(1e-12)
    );

    // --- Failure-aware fleet: none-identity + scripted outage ----------
    // First the safety property the fault model is built on: running the
    // failure-aware entry point with `FaultSpec::none` must be
    // bit-identical to the default replicated path — the fault machinery
    // may not perturb a fault-free run at all.
    let n_fault = if quick { 100_000 } else { 1_000_000 };
    let fault_traffic = TrafficSpec::poisson(9.0, n_fault, 32, 64, 256).with_seed(79);
    let plain = simulate_replicated(
        &quant_cfg,
        4,
        RoutePolicy::Jsq,
        &ContinuousBatch,
        &fault_traffic,
        &unconstrained,
    );
    let none = simulate_replicated_faults(
        &quant_cfg,
        4,
        RoutePolicy::Jsq,
        &ContinuousBatch,
        &fault_traffic,
        &FaultSpec::none(),
        &unconstrained,
    );
    assert_eq!(
        plain.fingerprint(),
        none.fingerprint(),
        "FaultSpec::none must be bit-identical to the default replicated path"
    );
    // Then a scripted outage: one of four replicas down for the middle
    // half of the run. The virtual makespan is ~requests/rps, so the plan
    // is phrased as fractions of that span.
    let span = n_fault as f64 / 9.0;
    let plan = format!("fail:0@{:.3},recover:0@{:.3}", span * 0.25, span * 0.75);
    let faults = FaultSpec::scripted(FaultSpec::parse_plan(&plan).expect("plan parses"));
    let t0 = Instant::now();
    let faulted = simulate_replicated_faults(
        &quant_cfg,
        4,
        RoutePolicy::Jsq,
        &ContinuousBatch,
        &fault_traffic,
        &faults,
        &unconstrained,
    );
    let fault_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        faulted.completed + faulted.rejected + faulted.lost,
        faulted.offered,
        "request conservation broke under the scripted outage"
    );
    assert!(faulted.downtime_frac > 0.0, "the scripted outage must accrue downtime");
    println!(
        "faulted fleet ({n_fault} requests, 4 replicas, 1 down mid-run): {fault_s:.2}s, \
         {} re-dispatched, {} lost, downtime {:.1}%",
        faulted.redispatched,
        faulted.lost,
        faulted.downtime_frac * 100.0
    );

    // --- Overcommit: expected-residency vs reservation admission -------
    // A saturating trace over a block-bound paged pool (the shape the
    // simulator's own unit test validates, at bench scale): reservation
    // admits ~3.5 mean-footprint requests into the 32-block pool, lazy
    // allocation roughly doubles the admitted concurrency, and 16 slots
    // keep the slot count from binding first.
    let n_oc = if quick { 20_000 } else { 200_000 };
    let oc_traffic = TrafficSpec::poisson(1e4, n_oc, 8, 4, 120).with_seed(17);
    let mut reserved_cfg = cfg();
    reserved_cfg.max_slots = 16;
    reserved_cfg.kv = KvBudget::tokens(256, 8);
    reserved_cfg.paged_kv = true;
    let mut oc_cfg = reserved_cfg.clone();
    oc_cfg.overcommit = Some(OvercommitSpec::quantile(0.5));
    let t0 = Instant::now();
    let rs = simulate_trace(&reserved_cfg, &mut ContinuousBatch, &oc_traffic, &unconstrained);
    let rs_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let oc = simulate_trace(&oc_cfg, &mut ContinuousBatch, &oc_traffic, &unconstrained);
    let oc_s = t0.elapsed().as_secs_f64();
    // Overcommit off: no preemption state, and the report keeps the
    // pre-overcommit aggregate arity — the new machinery is invisible.
    assert_eq!(rs.preempted, 0, "reservation admission must never preempt");
    assert!(rs.tiers.is_empty() && rs.windows.is_empty());
    assert_eq!(rs.fingerprint().0.len(), 24, "off-path report shape drifted");
    // Overcommit on: preempted work still finishes, and lazy admission
    // strictly wins goodput on the block-bound pool.
    assert_eq!(oc.completed, oc.offered, "preempted work must still finish");
    assert_eq!(rs.completed, rs.offered);
    assert!(oc.preempted > 0, "the block-bound pool must force preemptions");
    let oc_gain = oc.goodput_tokens_per_s / rs.goodput_tokens_per_s.max(1e-12);
    assert!(
        oc_gain > 1.0,
        "overcommit must out-goodput reservation admission: {} vs {}",
        oc.goodput_tokens_per_s,
        rs.goodput_tokens_per_s
    );
    println!(
        "overcommit ({n_oc} requests, 32-block pool): goodput {:.0} -> {:.0} tok/s \
         ({oc_gain:.2}x, {} preempted; wall {rs_s:.2}s -> {oc_s:.2}s)",
        rs.goodput_tokens_per_s, oc.goodput_tokens_per_s, oc.preempted
    );

    // Merge the serve_sim section into the shared bench artifact without
    // clobbering what bench_sweep_engine wrote.
    if let Ok(path) = std::env::var("CC_BENCH_JSON") {
        let mut root = match std::fs::read_to_string(&path).ok().and_then(|t| Json::parse(&t).ok())
        {
            Some(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        };
        root.insert(
            "serve_sim".to_string(),
            obj(vec![
                ("mode", Json::Str(mode.to_string())),
                (
                    "single",
                    obj(vec![
                        ("requests", Json::Num(n_single as f64)),
                        ("fast_forward_s", Json::Num(ff_s)),
                        ("quantized_s", Json::Num(quant_s)),
                        ("speedup", Json::Num(single_speedup)),
                    ]),
                ),
                (
                    "fleet",
                    obj(vec![
                        ("requests", Json::Num(n_fleet as f64)),
                        ("replicas", Json::Num(8.0)),
                        ("quantized_s", Json::Num(fleet_s)),
                        ("sketched", Json::Bool(true)),
                    ]),
                ),
                (
                    "faults",
                    obj(vec![
                        ("requests", Json::Num(n_fault as f64)),
                        ("replicas", Json::Num(4.0)),
                        ("plan", Json::Str(plan.clone())),
                        ("wall_s", Json::Num(fault_s)),
                        ("redispatched", Json::Num(faulted.redispatched as f64)),
                        ("lost", Json::Num(faulted.lost as f64)),
                        ("downtime_frac", Json::Num(faulted.downtime_frac)),
                        ("fault_free_identical", Json::Bool(true)),
                    ]),
                ),
                (
                    "overcommit",
                    obj(vec![
                        ("requests", Json::Num(n_oc as f64)),
                        ("reserved_goodput_tok_s", Json::Num(rs.goodput_tokens_per_s)),
                        ("overcommit_goodput_tok_s", Json::Num(oc.goodput_tokens_per_s)),
                        ("goodput_gain", Json::Num(oc_gain)),
                        ("preempted", Json::Num(oc.preempted as f64)),
                        ("off_shape_identical", Json::Bool(true)),
                    ]),
                ),
                ("epsilon_ok", Json::Bool(true)),
            ]),
        );
        std::fs::write(&path, format!("{}\n", Json::Obj(root))).expect("write bench json");
        println!("merged serve_sim into {path}");
    }

    let st = simulate_trace(&cfg(), &mut StaticBatch::new(0.05), &trace, &slo);
    let co = simulate_trace(&cfg(), &mut ContinuousBatch, &trace, &slo);
    println!(
        "static:     goodput {:7.1} tok/s  ttft p99 {:7.3}s  occupancy {:4.1}%  slo-met {:4.1}%",
        st.goodput_tokens_per_s,
        st.ttft_p99_s,
        st.occupancy * 100.0,
        st.slo_met_frac * 100.0
    );
    println!(
        "continuous: goodput {:7.1} tok/s  ttft p99 {:7.3}s  occupancy {:4.1}%  slo-met {:4.1}%",
        co.goodput_tokens_per_s,
        co.ttft_p99_s,
        co.occupancy * 100.0,
        co.slo_met_frac * 100.0
    );
    assert!(
        co.goodput_tokens_per_s > st.goodput_tokens_per_s,
        "continuous batching must out-goodput static at high load ({} vs {})",
        co.goodput_tokens_per_s,
        st.goodput_tokens_per_s
    );
    assert!(
        co.ttft_p99_s < st.ttft_p99_s,
        "continuous batching must cut the p99 TTFT at high load ({} vs {})",
        co.ttft_p99_s,
        st.ttft_p99_s
    );
    println!("OK — continuous batching wins goodput and p99 TTFT at high load");
}
