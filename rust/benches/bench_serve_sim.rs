//! Serving-simulator benchmarks: event-sim wall cost per simulated
//! request, the static vs continuous goodput comparison on one seeded
//! high-load trace (continuous must win — asserted, not just printed),
//! and the chunked-prefill / multi-replica paths.

use chiplet_cloud::config::{SloSpec, TrafficSpec};
use chiplet_cloud::perf::events::{simulate_replicated, simulate_trace, IterCost, SimConfig};
use chiplet_cloud::sched::{ContinuousBatch, KvBudget, RoutePolicy, StaticBatch};
use chiplet_cloud::util::bench::{black_box, Bench};

fn cfg() -> SimConfig {
    SimConfig {
        max_slots: 8,
        kv: KvBudget::unlimited(),
        cost: IterCost { prefill_s_per_token: 0.0001, decode_step_s: 0.01, prefill_chunk: 0 },
        paged_kv: false,
    }
}

/// The paged + chunked serving model over a binding synthetic budget.
fn paged_cfg() -> SimConfig {
    let mut c = cfg();
    c.kv = KvBudget::tokens(512, 16);
    c.paged_kv = true;
    c.cost = c.cost.with_chunk(16);
    c
}

fn main() {
    let mut b = Bench::new();

    // High-load trace: ~68% of slot capacity for continuous batching,
    // past the batch-synchronous policy's effective capacity.
    let trace = TrafficSpec::poisson(30.0, 400, 16, 4, 32).with_seed(11);
    let slo = SloSpec::new(0.25, 0.015);

    b.run("serve_sim/static-400req", || {
        black_box(simulate_trace(&cfg(), &mut StaticBatch::new(0.05), &trace, &slo))
    });
    b.run("serve_sim/continuous-400req", || {
        black_box(simulate_trace(&cfg(), &mut ContinuousBatch, &trace, &slo))
    });
    b.run("serve_sim/paged-chunked-400req", || {
        black_box(simulate_trace(&paged_cfg(), &mut ContinuousBatch, &trace, &slo))
    });
    b.run("serve_sim/jsq-2replica-400req", || {
        black_box(simulate_replicated(
            &cfg(),
            2,
            RoutePolicy::Jsq,
            &ContinuousBatch,
            &trace,
            &slo,
        ))
    });

    let st = simulate_trace(&cfg(), &mut StaticBatch::new(0.05), &trace, &slo);
    let co = simulate_trace(&cfg(), &mut ContinuousBatch, &trace, &slo);
    println!(
        "static:     goodput {:7.1} tok/s  ttft p99 {:7.3}s  occupancy {:4.1}%  slo-met {:4.1}%",
        st.goodput_tokens_per_s,
        st.ttft_p99_s,
        st.occupancy * 100.0,
        st.slo_met_frac * 100.0
    );
    println!(
        "continuous: goodput {:7.1} tok/s  ttft p99 {:7.3}s  occupancy {:4.1}%  slo-met {:4.1}%",
        co.goodput_tokens_per_s,
        co.ttft_p99_s,
        co.occupancy * 100.0,
        co.slo_met_frac * 100.0
    );
    assert!(
        co.goodput_tokens_per_s > st.goodput_tokens_per_s,
        "continuous batching must out-goodput static at high load ({} vs {})",
        co.goodput_tokens_per_s,
        st.goodput_tokens_per_s
    );
    assert!(
        co.ttft_p99_s < st.ttft_p99_s,
        "continuous batching must cut the p99 TTFT at high load ({} vs {})",
        co.ttft_p99_s,
        st.ttft_p99_s
    );
    println!("OK — continuous batching wins goodput and p99 TTFT at high load");
}
