//! Bench of the declarative experiment API: Phase-1 context sharing across
//! a campaign vs cold per-spec engines, plus the spec-layer codec itself.
//!
//! Asserts the API contracts the numbers rest on: a shared-engine campaign
//! produces bit-identical outcomes to cold runs (sharing is a pure
//! wall-clock optimization), and the strict JSON codec round-trips.

use chiplet_cloud::config::experiment::{EngineKnobs, Experiment, SpaceSpec, Task, WorkloadPoint};
use chiplet_cloud::config::{ServeSpec, SloSpec, TrafficSpec};
use chiplet_cloud::experiment::{self, Engine, Outcome};
use chiplet_cloud::util::bench::Bench;
use chiplet_cloud::util::json::Json;

fn serve_spec(name: &str, seed: u64) -> Experiment {
    Experiment {
        name: name.into(),
        task: Task::ServeSim,
        models: vec!["gpt2".into()],
        space: SpaceSpec::Coarse,
        workload: Some(WorkloadPoint { ctx: 1024, batch: 32 }),
        serve: Some(ServeSpec::new(
            TrafficSpec::poisson(4.0, 60, 16, 4, 16).with_seed(seed),
            SloSpec::unconstrained(),
        )),
        load: 0.8,
        engine: EngineKnobs::default(),
        shard: None,
    }
}

fn main() {
    let mut b = Bench::new();
    let specs = [serve_spec("a", 1), serve_spec("b", 2), serve_spec("c", 3)];

    // Codec throughput (parse ∘ serialize on a fully-populated spec).
    let text = specs[0].to_json_string();
    b.run("experiment/spec-json-round-trip", || {
        Experiment::from_json_str(&text).expect("round trip")
    });

    // Campaign with one shared engine (Phase 1 swept once)...
    let shared = b.run("experiment/campaign-3-specs-shared-engine", || {
        let mut engine = Engine::new();
        engine.run_campaign(&specs)
    });
    // ...vs cold engines per spec (Phase 1 re-swept every time).
    let cold = b.run("experiment/campaign-3-specs-cold-engines", || {
        specs.iter().map(|e| experiment::run(e).expect("runs")).collect::<Vec<_>>()
    });
    println!(
        "shared-engine campaign mean {} vs cold {} ({:.2}x)",
        chiplet_cloud::util::fmt_secs(shared.mean_s),
        chiplet_cloud::util::fmt_secs(cold.mean_s),
        cold.mean_s / shared.mean_s.max(1e-12),
    );
    // Small timing-noise allowance: sharing does strictly less work (two
    // fewer Phase-1 sweeps), but single-core CI boxes jitter.
    assert!(
        shared.min_s <= cold.min_s * 1.10,
        "sharing the Phase-1 context must not be slower: shared {} vs cold {}",
        shared.min_s,
        cold.min_s
    );

    // Sharing is answer-preserving: shared vs cold outcomes, bit for bit
    // (compared through the canonical JSON rendering).
    let mut engine = Engine::new();
    let shared_outcomes = engine.run_campaign(&specs);
    assert_eq!(engine.contexts(), 1, "one coarse space ⇒ one Phase-1 sweep");
    for (e, (name, outcome)) in specs.iter().zip(&shared_outcomes) {
        let cold_outcome = experiment::run(e).expect("runs");
        assert_eq!(name, &e.name);
        assert_eq!(
            outcome.to_json().to_string(),
            cold_outcome.to_json().to_string(),
            "context sharing changed the outcome of {name}"
        );
        assert!(matches!(outcome, Outcome::Serve(s) if s.feasible));
        Json::parse(&outcome.to_json().to_string()).expect("valid JSON");
    }
    println!("campaign outcomes identical across shared and cold engines");
}
