//! End-to-end sweep-engine benchmark: the GPT-3 Table-2 grid (3 contexts ×
//! batch 1..1024) through three configurations of the co-design search —
//!
//! * `sequential` — the seed behaviour: single thread, exhaustive;
//! * `parallel`   — fork-join only (no pruning, no Pareto ordering);
//! * `engine`     — parallel + branch-and-bound pruning + Pareto-first
//!   ordering (the default `SweepEngine`).
//!
//! All three must return the **identical** optimum (asserted, bit-exact);
//! the engine targets ≥ 5× end-to-end over sequential on 8 cores. Phase 1
//! is also timed sequential vs parallel.
//!
//! The SLO-constrained stage 2 is then timed fast (decode fast-forward +
//! early abort + speculative parallel waves) against the sequential
//! reference scan — identical selection asserted — and everything is
//! written machine-readable to `BENCH_sweep.json` (override the path with
//! `CC_BENCH_JSON`) so the repo's perf trajectory is tracked run over run.
//!
//! Set `CC_BENCH_FULL=1` for the paper-scale Table-1 space; pass `--quick`
//! (the CI mode) for a shorter SLO validation trace.

use std::collections::BTreeMap;
use std::time::Instant;

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, ServeSpec, SloSpec, TrafficSpec, Workload};
use chiplet_cloud::evaluate::SweepEngine;
use chiplet_cloud::explore::{self, pareto};
use chiplet_cloud::util::json::Json;

fn space() -> ExploreSpace {
    if std::env::var("CC_BENCH_FULL").is_ok() {
        ExploreSpace::default()
    } else {
        ExploreSpace::coarse()
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let space = space();
    let threads = chiplet_cloud::util::parallel::num_threads();
    let mode = if quick { "quick" } else { "full" };
    println!("sweep engine bench: {threads} worker threads ({mode} mode)");

    // --- Phase 1: hardware exploration -------------------------------
    let t0 = Instant::now();
    let (servers_seq, _) = explore::phase1_seq(&space);
    let p1_seq = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let (servers, _) = explore::phase1(&space);
    let p1_par = t0.elapsed().as_secs_f64();
    assert_eq!(servers, servers_seq, "parallel phase 1 diverged");
    println!(
        "phase1: {} feasible servers | sequential {:.3}s, parallel {:.3}s ({:.2}x)",
        servers.len(),
        p1_seq,
        p1_par,
        p1_seq / p1_par.max(1e-9)
    );
    let frontier = pareto::frontier_indices(&servers).len();
    println!("pareto frontier: {} of {} servers", frontier, servers.len());

    // --- Phase 2: GPT-3 over the Table-2 grid -------------------------
    let grid = Workload::study_grid(&ModelSpec::gpt3());

    let t0 = Instant::now();
    let seq = SweepEngine::sequential().best_over_grid(&space, &servers, &grid);
    let t_seq = t0.elapsed().as_secs_f64();

    let par_only = SweepEngine { threads: 0, prune: false, pareto_order: false, fast_sim: true };
    let t0 = Instant::now();
    let par = par_only.best_over_grid(&space, &servers, &grid);
    let t_par = t0.elapsed().as_secs_f64();

    let engine = SweepEngine { threads: 0, prune: true, pareto_order: true, fast_sim: true };
    let t0 = Instant::now();
    let (full, stats) = engine.best_over_grid_stats(&space, &servers, &grid);
    let t_full = t0.elapsed().as_secs_f64();

    // Byte-identical optima across all three configurations.
    let (w_seq, p_seq) = seq.expect("sequential optimum");
    for (label, result) in [("parallel", par), ("engine", full)] {
        let (w, p) = result.expect("optimum");
        assert_eq!((w.ctx, w.batch), (w_seq.ctx, w_seq.batch), "{label}: workload diverged");
        assert_eq!(p.mapping, p_seq.mapping, "{label}: mapping diverged");
        assert_eq!(p.server, p_seq.server, "{label}: server diverged");
        assert_eq!(p.n_servers, p_seq.n_servers, "{label}: server count diverged");
        assert_eq!(
            p.tco_per_token.to_bits(),
            p_seq.tco_per_token.to_bits(),
            "{label}: TCO/Token diverged"
        );
    }

    println!(
        "phase2 (gpt3 x {} workloads): sequential {:.2}s | parallel {:.2}s ({:.2}x) | \
         engine {:.2}s ({:.2}x)",
        grid.len(),
        t_seq,
        t_par,
        t_seq / t_par.max(1e-9),
        t_full,
        t_seq / t_full.max(1e-9)
    );
    println!(
        "engine counters: {} pairs ({} bound-skipped), {} candidates, {} simulated, {} pruned",
        stats.servers,
        stats.servers_pruned,
        stats.candidates,
        stats.simulated,
        stats.mappings_pruned
    );
    println!(
        "optimum: ${:.3}/1M tok @ ctx {} batch {} (tp={} pp={} ub={}) — identical across configs",
        p_seq.tco_per_mtok(),
        w_seq.ctx,
        w_seq.batch,
        p_seq.mapping.tp,
        p_seq.mapping.pp,
        p_seq.mapping.microbatch
    );

    let phase2_speedup = t_seq / t_full.max(1e-9);

    // --- Stage 2: SLO-constrained validation --------------------------
    // Two regimes over a saturating, decode-heavy closed loop: a *tight*
    // TPOT target (queueing pushes most bound-feasible candidates over —
    // early abort and the speculative waves carry the run) and a *mid*
    // target (the cheapest candidates pass — decode fast-forward carries
    // the single confirming simulation). Byte-identical selections are
    // asserted in both; the headline speedup is over the combined wall.
    let w = Workload::new(ModelSpec::megatron(), 1024, 64);
    let fastest = SweepEngine::sequential()
        .sweep(&space, &servers, &w)
        .iter()
        .map(|p| p.perf.token_period)
        .fold(f64::INFINITY, f64::min);
    assert!(fastest.is_finite(), "no feasible design for the SLO bench workload");
    let requests = if quick { 60 } else { 400 };
    let traffic = TrafficSpec::closed_loop(16, 0.0, requests, 32, 64, 256).with_seed(17);
    let reference_engine = SweepEngine::sequential();
    let fast_engine = SweepEngine { threads: 0, prune: true, pareto_order: true, fast_sim: true };

    let (mut t_ref, mut t_fast) = (0.0f64, 0.0f64);
    let (mut validated_fast, mut aborted_fast, mut validated_ref) = (0usize, 0usize, 0usize);
    let mut scenarios_json: Vec<(&str, Json)> = Vec::new();
    for (regime, factor) in [("tight", 1.1), ("mid", 4.0)] {
        let slo = SloSpec::new(f64::INFINITY, fastest * factor);
        let spec = ServeSpec::new(traffic, slo);

        let t0 = Instant::now();
        let reference = reference_engine.best_point_slo(&space, &servers, &w, &spec);
        let r_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let fast = fast_engine.best_point_slo(&space, &servers, &w, &spec);
        let f_s = t0.elapsed().as_secs_f64();
        t_ref += r_s;
        t_fast += f_s;

        let (vf, af, vr, selected) = match (&reference, &fast) {
            (Some(r), Some(f)) => {
                assert_eq!(f.point.mapping, r.point.mapping, "{regime}: mapping diverged");
                assert_eq!(f.point.server, r.point.server, "{regime}: server diverged");
                assert_eq!(
                    f.point.tco_per_token.to_bits(),
                    r.point.tco_per_token.to_bits(),
                    "{regime}: TCO/Token diverged"
                );
                assert_eq!(
                    f.report.makespan_s.to_bits(),
                    r.report.makespan_s.to_bits(),
                    "{regime}: winner report diverged"
                );
                let sel = obj(vec![
                    ("die_mm2", Json::Num(f.point.server.chiplet.die_mm2)),
                    ("tp", Json::Num(f.point.mapping.tp as f64)),
                    ("pp", Json::Num(f.point.mapping.pp as f64)),
                    ("microbatch", Json::Num(f.point.mapping.microbatch as f64)),
                    ("tco_per_mtok", Json::Num(f.point.tco_per_mtok())),
                ]);
                (f.validated, f.aborted_early, r.validated, sel)
            }
            (None, None) => (0, 0, 0, Json::Null),
            _ => panic!("{regime}: stage-2 engines disagree on feasibility"),
        };
        let feasible = selected != Json::Null;
        validated_fast += vf;
        aborted_fast += af;
        validated_ref += vr;
        println!(
            "stage2 [{regime}] (tpot {:.1}x period, {requests} requests): reference {r_s:.2}s | \
             fast {f_s:.2}s ({:.2}x) — {vf} validated ({af} aborted early) vs {vr} sequential{}",
            factor,
            r_s / f_s.max(1e-9),
            if feasible { "" } else { " [no feasible design]" }
        );
        scenarios_json.push((
            regime,
            obj(vec![
                ("tpot_factor", Json::Num(factor)),
                ("reference_s", Json::Num(r_s)),
                ("fast_s", Json::Num(f_s)),
                ("speedup", Json::Num(r_s / f_s.max(1e-9))),
                ("validated_fast", Json::Num(vf as f64)),
                ("aborted_early_fast", Json::Num(af as f64)),
                ("validated_reference", Json::Num(vr as f64)),
                ("feasible", Json::Bool(feasible)),
                ("selected", selected),
            ]),
        ));
    }

    let stage2_speedup = t_ref / t_fast.max(1e-9);
    println!(
        "stage2 combined: reference {t_ref:.2}s | fast {t_fast:.2}s ({stage2_speedup:.2}x) — \
         {validated_fast} validated ({aborted_fast} aborted early) vs {validated_ref} sequential"
    );

    // --- Machine-readable trajectory ----------------------------------
    let out = obj(vec![
        ("bench", Json::Str("bench_sweep_engine".into())),
        ("mode", Json::Str(if quick { "quick".into() } else { "full".into() })),
        ("threads", Json::Num(threads as f64)),
        (
            "phase1",
            obj(vec![
                ("sequential_s", Json::Num(p1_seq)),
                ("parallel_s", Json::Num(p1_par)),
                ("speedup", Json::Num(p1_seq / p1_par.max(1e-9))),
            ]),
        ),
        (
            "phase2",
            obj(vec![
                ("sequential_s", Json::Num(t_seq)),
                ("parallel_s", Json::Num(t_par)),
                ("engine_s", Json::Num(t_full)),
                ("speedup", Json::Num(phase2_speedup)),
            ]),
        ),
        (
            "slo_stage2",
            obj(vec![
                ("requests", Json::Num(requests as f64)),
                ("reference_s", Json::Num(t_ref)),
                ("fast_s", Json::Num(t_fast)),
                ("speedup", Json::Num(stage2_speedup)),
                ("validated_fast", Json::Num(validated_fast as f64)),
                ("aborted_early_fast", Json::Num(aborted_fast as f64)),
                ("validated_reference", Json::Num(validated_ref as f64)),
                ("identical_selection", Json::Bool(true)),
                ("scenarios", obj(scenarios_json)),
            ]),
        ),
    ]);
    let path = std::env::var("CC_BENCH_JSON").unwrap_or_else(|_| "BENCH_sweep.json".into());
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_sweep.json");
    println!("wrote {path}");

    let target = 5.0;
    for (label, speedup) in [("phase2 engine", phase2_speedup), ("slo stage-2", stage2_speedup)] {
        if speedup >= target {
            println!("PASS: {label} speedup {speedup:.2}x >= {target}x");
        } else {
            println!(
                "NOTE: {label} speedup {speedup:.2}x < {target}x on this machine \
                 ({threads} threads; the {target}x target assumes 8 cores)"
            );
        }
    }
}
