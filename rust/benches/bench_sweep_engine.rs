//! End-to-end sweep-engine benchmark: the GPT-3 Table-2 grid (3 contexts ×
//! batch 1..1024) through three configurations of the co-design search —
//!
//! * `sequential` — the seed behaviour: single thread, exhaustive;
//! * `parallel`   — fork-join only (no pruning, no Pareto ordering);
//! * `engine`     — parallel + branch-and-bound pruning + Pareto-first
//!   ordering (the default `SweepEngine`).
//!
//! All three must return the **identical** optimum (asserted, bit-exact);
//! the engine targets ≥ 5× end-to-end over sequential on 8 cores. Phase 1
//! is also timed sequential vs parallel.
//!
//! Set `CC_BENCH_FULL=1` for the paper-scale Table-1 space.

use std::time::Instant;

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::{ModelSpec, Workload};
use chiplet_cloud::evaluate::SweepEngine;
use chiplet_cloud::explore::{self, pareto};

fn space() -> ExploreSpace {
    if std::env::var("CC_BENCH_FULL").is_ok() {
        ExploreSpace::default()
    } else {
        ExploreSpace::coarse()
    }
}

fn main() {
    let space = space();
    let threads = chiplet_cloud::util::parallel::num_threads();
    println!("sweep engine bench: {} worker threads", threads);

    // --- Phase 1: hardware exploration -------------------------------
    let t0 = Instant::now();
    let (servers_seq, _) = explore::phase1_seq(&space);
    let p1_seq = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let (servers, _) = explore::phase1(&space);
    let p1_par = t0.elapsed().as_secs_f64();
    assert_eq!(servers, servers_seq, "parallel phase 1 diverged");
    println!(
        "phase1: {} feasible servers | sequential {:.3}s, parallel {:.3}s ({:.2}x)",
        servers.len(),
        p1_seq,
        p1_par,
        p1_seq / p1_par.max(1e-9)
    );
    let frontier = pareto::frontier_indices(&servers).len();
    println!("pareto frontier: {} of {} servers", frontier, servers.len());

    // --- Phase 2: GPT-3 over the Table-2 grid -------------------------
    let grid = Workload::study_grid(&ModelSpec::gpt3());

    let t0 = Instant::now();
    let seq = SweepEngine::sequential().best_over_grid(&space, &servers, &grid);
    let t_seq = t0.elapsed().as_secs_f64();

    let par_only = SweepEngine { threads: 0, prune: false, pareto_order: false };
    let t0 = Instant::now();
    let par = par_only.best_over_grid(&space, &servers, &grid);
    let t_par = t0.elapsed().as_secs_f64();

    let engine = SweepEngine { threads: 0, prune: true, pareto_order: true };
    let t0 = Instant::now();
    let (full, stats) = engine.best_over_grid_stats(&space, &servers, &grid);
    let t_full = t0.elapsed().as_secs_f64();

    // Byte-identical optima across all three configurations.
    let (w_seq, p_seq) = seq.expect("sequential optimum");
    for (label, result) in [("parallel", par), ("engine", full)] {
        let (w, p) = result.expect("optimum");
        assert_eq!((w.ctx, w.batch), (w_seq.ctx, w_seq.batch), "{label}: workload diverged");
        assert_eq!(p.mapping, p_seq.mapping, "{label}: mapping diverged");
        assert_eq!(p.server, p_seq.server, "{label}: server diverged");
        assert_eq!(p.n_servers, p_seq.n_servers, "{label}: server count diverged");
        assert_eq!(
            p.tco_per_token.to_bits(),
            p_seq.tco_per_token.to_bits(),
            "{label}: TCO/Token diverged"
        );
    }

    println!(
        "phase2 (gpt3 x {} workloads): sequential {:.2}s | parallel {:.2}s ({:.2}x) | \
         engine {:.2}s ({:.2}x)",
        grid.len(),
        t_seq,
        t_par,
        t_seq / t_par.max(1e-9),
        t_full,
        t_seq / t_full.max(1e-9)
    );
    println!(
        "engine counters: {} pairs ({} bound-skipped), {} candidates, {} simulated, {} pruned",
        stats.servers,
        stats.servers_pruned,
        stats.candidates,
        stats.simulated,
        stats.mappings_pruned
    );
    println!(
        "optimum: ${:.3}/1M tok @ ctx {} batch {} (tp={} pp={} ub={}) — identical across configs",
        p_seq.tco_per_mtok(),
        w_seq.ctx,
        w_seq.batch,
        p_seq.mapping.tp,
        p_seq.mapping.pp,
        p_seq.mapping.microbatch
    );

    let speedup = t_seq / t_full.max(1e-9);
    let target = 5.0;
    if speedup >= target {
        println!("PASS: engine speedup {speedup:.2}x >= {target}x");
    } else {
        println!(
            "NOTE: engine speedup {speedup:.2}x < {target}x on this machine \
             ({threads} threads; the 5x target assumes 8 cores)"
        );
    }
}
