//! Summary statistics used across the DSE engine, benches and reports.

/// Arithmetic mean; 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; used by the multi-model chip objective (Fig. 14).
/// Inputs must be strictly positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation.
///
/// Hardened for the tail-latency reporting paths: empty input returns 0.0,
/// a single element is its own percentile for every `q`, and `q` is
/// clamped into [0, 100] (a NaN `q` reads as 0) — out-of-range quantiles
/// used to index past the end of the sorted vector.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_of_sorted(&v, q)
}

/// Batch percentiles: sort `xs` **in place** once and read every quantile
/// off the sorted slice. Returns one value per entry of `qs`, each
/// bit-identical to `percentile(xs, q)` on the same data — the sort
/// comparator and the interpolation are shared — without re-sorting per
/// quantile (the serving-report aggregation reads p50 *and* p99 of three
/// metric vectors per run, which used to cost six clones and six sorts).
pub fn percentiles(xs: &mut [f64], qs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; qs.len()];
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter().map(|&q| percentile_of_sorted(xs, q)).collect()
}

/// One quantile of an already-sorted (ascending) non-empty slice, with the
/// same clamping and linear interpolation as [`percentile`].
fn percentile_of_sorted(v: &[f64], q: f64) -> f64 {
    if v.len() == 1 {
        return v[0];
    }
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 100.0) };
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// The early-abort budget of a latency SLO: the minimum number of samples
/// **strictly above** a target that force `percentile(xs, q)` above that
/// target for *any* completed sample set of at most `n` values.
///
/// Derivation: over `m` sorted values the interpolated quantile reads
/// indices `floor(pos)`/`ceil(pos)` with `pos = q/100 · (m-1)`, so it
/// exceeds the target as soon as `x[floor(pos)]` does — i.e. when at least
/// `m - floor(pos)` values are violators. That bound is non-decreasing in
/// `m` (as `m` grows by one, `floor(pos)` grows by at most one), so the
/// budget computed at the *offered* request count `n` is valid for every
/// possible completion count `m <= n`: once a running simulation has
/// accumulated this many violators, the final percentile provably exceeds
/// the target no matter how the remaining requests fare.
pub fn quantile_violation_budget(n: usize, q: f64) -> usize {
    if n == 0 {
        return 1;
    }
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 100.0) };
    let pos = (q / 100.0) * (n - 1) as f64;
    n - pos.floor() as usize
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max helpers tolerant of NaN-free inputs.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Index of the minimum value (first occurrence). None on empty input.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

/// Index of the maximum value (first occurrence). None on empty input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
        // geomean of identical values is the value
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn arg_extrema() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 200.0), 0.0);
    }

    #[test]
    fn single_element_is_every_percentile() {
        for q in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], q), 7.5);
        }
    }

    #[test]
    fn batch_percentiles_match_single_calls_bitwise() {
        // Awkward sizes and values (ties, tiny gaps) where a different
        // sort or interpolation would show.
        for n in [1usize, 2, 3, 7, 99, 100, 101] {
            let xs: Vec<f64> = (0..n).map(|i| ((i * 7919) % 97) as f64 / 3.0).collect();
            let qs = [0.0, 17.3, 50.0, 99.0, 100.0];
            let singles: Vec<f64> = qs.iter().map(|&q| percentile(&xs, q)).collect();
            let mut sorted = xs.clone();
            let batch = percentiles(&mut sorted, &qs);
            for (s, b) in singles.iter().zip(&batch) {
                assert_eq!(s.to_bits(), b.to_bits(), "n={n}");
            }
        }
        assert_eq!(percentiles(&mut [], &[50.0, 99.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn violation_budget_forces_the_percentile_over() {
        let target = 10.0;
        for n in [1usize, 2, 5, 50, 100, 101, 102, 250, 400] {
            let budget = quantile_violation_budget(n, 99.0);
            assert!(budget >= 1 && budget <= n);
            // `budget` violators among any m in [budget, n] completions
            // force p99 over the target...
            for m in [budget, (budget + n) / 2, n] {
                let mut xs: Vec<f64> = vec![0.0; m - budget];
                xs.resize(m, target + 1.0);
                assert!(
                    percentile(&xs, 99.0) > target,
                    "n={n} m={m} budget={budget} must prove a violation"
                );
            }
            // ...while budget-1 violators leave a passing outcome possible
            // (barely-over violators diluted by on-target passes), so
            // aborting one violator earlier would be unsound.
            if budget > 1 {
                let mut xs: Vec<f64> = vec![0.0; n - (budget - 1)];
                xs.resize(n, target * 1.001);
                assert!(
                    percentile(&xs, 99.0) <= target,
                    "n={n} budget={budget}: one fewer violator must stay unprovable"
                );
            }
        }
    }

    #[test]
    fn violation_budget_is_monotone_in_n() {
        // The soundness of aborting on the *offered* count relies on the
        // budget never shrinking as the sample grows.
        let mut prev = 0;
        for n in 1..=2000 {
            let b = quantile_violation_budget(n, 99.0);
            assert!(b >= prev, "budget regressed at n={n}: {b} < {prev}");
            prev = b;
        }
    }

    #[test]
    fn out_of_range_quantiles_clamp() {
        let xs: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        // These used to index past the sorted vector (panic) or saturate
        // a negative position to 0 silently.
        assert_eq!(percentile(&xs, 150.0), 10.0);
        assert_eq!(percentile(&xs, -20.0), 1.0);
        assert_eq!(percentile(&xs, f64::NAN), 1.0);
    }
}
