//! Summary statistics used across the DSE engine, benches and reports,
//! plus the bounded-memory quantile sketch the serving simulator folds
//! million-request tails into ([`QuantileSketch`]).

use std::collections::BTreeMap;

/// The approved total-order comparator for `f64` sorts (`ccloud lint`
/// rule `no-float-eq` bans `partial_cmp(..).unwrap()`, which panics on
/// NaN mid-sort). IEEE-754 `totalOrder`: `-NaN < -inf < ... < -0.0 <
/// +0.0 < ... < +inf < +NaN` — so a stray (positive) NaN sorts **last**
/// instead of aborting the run, and percentile reads below 100 stay
/// NaN-free. Signature matches `sort_by`'s comparator directly:
/// `v.sort_by(total_cmp_f64)`.
pub fn total_cmp_f64(a: &f64, b: &f64) -> std::cmp::Ordering {
    a.total_cmp(b)
}

/// Arithmetic mean; 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; used by the multi-model chip objective (Fig. 14).
/// Inputs must be strictly positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation.
///
/// Hardened for the tail-latency reporting paths: empty input returns 0.0,
/// a single element is its own percentile for every `q`, and `q` is
/// clamped into [0, 100] (a NaN `q` reads as 0) — out-of-range quantiles
/// used to index past the end of the sorted vector. NaN **samples** sort
/// last ([`total_cmp_f64`]) instead of panicking mid-sort: quantiles below
/// the NaN fraction stay finite and p100 of a NaN-containing input is NaN.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(total_cmp_f64);
    percentile_of_sorted(&v, q)
}

/// Batch percentiles: sort `xs` **in place** once and read every quantile
/// off the sorted slice. Returns one value per entry of `qs`, each
/// bit-identical to `percentile(xs, q)` on the same data — the sort
/// comparator and the interpolation are shared — without re-sorting per
/// quantile (the serving-report aggregation reads p50 *and* p99 of three
/// metric vectors per run, which used to cost six clones and six sorts).
pub fn percentiles(xs: &mut [f64], qs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; qs.len()];
    }
    xs.sort_by(total_cmp_f64);
    qs.iter().map(|&q| percentile_of_sorted(xs, q)).collect()
}

/// One quantile of an already-sorted (ascending) non-empty slice, with the
/// same clamping and linear interpolation as [`percentile`].
fn percentile_of_sorted(v: &[f64], q: f64) -> f64 {
    if v.len() == 1 {
        return v[0];
    }
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 100.0) };
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// The early-abort budget of a latency SLO: the minimum number of samples
/// **strictly above** a target that force `percentile(xs, q)` above that
/// target for *any* completed sample set of at most `n` values.
///
/// Derivation: over `m` sorted values the interpolated quantile reads
/// indices `floor(pos)`/`ceil(pos)` with `pos = q/100 · (m-1)`, so it
/// exceeds the target as soon as `x[floor(pos)]` does — i.e. when at least
/// `m - floor(pos)` values are violators. That bound is non-decreasing in
/// `m` (as `m` grows by one, `floor(pos)` grows by at most one), so the
/// budget computed at the *offered* request count `n` is valid for every
/// possible completion count `m <= n`: once a running simulation has
/// accumulated this many violators, the final percentile provably exceeds
/// the target no matter how the remaining requests fare.
pub fn quantile_violation_budget(n: usize, q: f64) -> usize {
    if n == 0 {
        return 1;
    }
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 100.0) };
    let pos = (q / 100.0) * (n - 1) as f64;
    n - pos.floor() as usize
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max helpers tolerant of NaN-free inputs.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Index of the minimum value (first occurrence). None on empty input.
/// NaN entries rank last ([`total_cmp_f64`]), so they are never the
/// argmin unless every entry is NaN.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter().enumerate().min_by(|a, b| total_cmp_f64(a.1, b.1)).map(|(i, _)| i)
}

/// Index of the maximum value (last occurrence among exact ties). NaN
/// entries rank above +inf in the total order, so an input containing NaN
/// reports a NaN index — callers that must ignore NaN should filter first.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter().enumerate().max_by(|a, b| total_cmp_f64(a.1, b.1)).map(|(i, _)| i)
}

/// Default relative accuracy of the serving-tail sketches: quantiles are
/// reported within ±1% of the true order statistic.
pub const SKETCH_DEFAULT_ALPHA: f64 = 0.01;

/// Mergeable, bounded-memory quantile sketch (DDSketch-style logarithmic
/// histogram, dependency-free).
///
/// Positive samples land in geometric buckets `i = ceil(ln x / ln γ)` with
/// `γ = (1+α)/(1-α)`; every value in bucket `i` lies in `(γ^(i-1), γ^i]`,
/// so reporting the midpoint-ish estimate `2γ^i/(γ+1)` guarantees
/// **relative error ≤ α** against the exact order statistic. Non-positive
/// samples collapse into a zero bucket (serving latencies are
/// non-negative; a TTFT of exactly 0 stays exact).
///
/// Memory is O(number of occupied buckets) — for latencies spanning
/// microseconds to days at α = 1% that is a few thousand `(i64, u64)`
/// entries, independent of the sample count. Two sketches built with the
/// same `α` merge *exactly* (bucket counts add), so per-replica tails
/// combine into a fleet tail without concatenating sample vectors:
/// `merge` then `quantile` equals building one sketch over the union.
///
/// `quantile(q)` reads the floor-rank order statistic (`rank =
/// floor(q/100 · (n-1))`, the lower of the two indices the interpolated
/// [`percentile`] blends), so versus the interpolated exact value the
/// total error is bounded by α plus the gap between adjacent order
/// statistics at that rank.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    alpha: f64,
    ln_gamma: f64,
    buckets: BTreeMap<i32, u64>,
    zeros: u64,
    count: u64,
    min: f64,
    max: f64,
}

impl QuantileSketch {
    /// Sketch with relative accuracy `alpha` (clamped into [1e-6, 0.5]).
    pub fn new(alpha: f64) -> QuantileSketch {
        let alpha = if alpha.is_finite() {
            alpha.clamp(1e-6, 0.5)
        } else {
            SKETCH_DEFAULT_ALPHA
        };
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        QuantileSketch {
            alpha,
            ln_gamma: gamma.ln(),
            buckets: BTreeMap::new(),
            zeros: 0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Sketch at [`SKETCH_DEFAULT_ALPHA`].
    pub fn default_accuracy() -> QuantileSketch {
        QuantileSketch::new(SKETCH_DEFAULT_ALPHA)
    }

    /// The relative accuracy this sketch was built with.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True if no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Record one sample. NaN is dropped; non-positive values fold into
    /// the exact zero bucket (reported as 0.0 at read time, or `min` if
    /// negatives were recorded).
    pub fn record(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x <= 0.0 {
            self.zeros += 1;
            return;
        }
        // Float→int casts saturate in Rust, so +INF degrades to the top
        // bucket instead of wrapping.
        let i = (x.ln() / self.ln_gamma).ceil();
        let i = i.clamp(i32::MIN as f64, i32::MAX as f64) as i32;
        *self.buckets.entry(i).or_insert(0) += 1;
    }

    /// Fold `other` into `self`. Exact: quantiles of the merged sketch
    /// equal those of a single sketch over the union of samples. Both
    /// sketches must share the same `alpha` (the bucket boundaries differ
    /// otherwise and the error bound would be void).
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert_eq!(
            self.alpha.to_bits(),
            other.alpha.to_bits(),
            "QuantileSketch::merge requires identical accuracy"
        );
        for (&i, &c) in &other.buckets {
            *self.buckets.entry(i).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The `q`-th percentile estimate (floor-rank order statistic, within
    /// relative `alpha`). Empty sketch reads 0.0; `q` is clamped into
    /// [0, 100] like [`percentile`].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 100.0) };
        let rank = ((q / 100.0) * (self.count - 1) as f64).floor() as u64;
        if rank < self.zeros {
            // Non-positive region: exact for the all-zeros case, `min`
            // if genuine negatives were folded in.
            return self.min.min(0.0);
        }
        let mut cum = self.zeros;
        for (&i, &c) in &self.buckets {
            cum += c;
            if cum > rank {
                let gamma = self.ln_gamma.exp();
                let est = (i as f64 * self.ln_gamma).exp() * 2.0 / (gamma + 1.0);
                // Observed extrema only ever tighten the bucket bound.
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
        // geomean of identical values is the value
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolation() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn arg_extrema() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 200.0), 0.0);
    }

    #[test]
    fn single_element_is_every_percentile() {
        for q in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], q), 7.5);
        }
    }

    #[test]
    fn batch_percentiles_match_single_calls_bitwise() {
        // Awkward sizes and values (ties, tiny gaps) where a different
        // sort or interpolation would show.
        for n in [1usize, 2, 3, 7, 99, 100, 101] {
            let xs: Vec<f64> = (0..n).map(|i| ((i * 7919) % 97) as f64 / 3.0).collect();
            let qs = [0.0, 17.3, 50.0, 99.0, 100.0];
            let singles: Vec<f64> = qs.iter().map(|&q| percentile(&xs, q)).collect();
            let mut sorted = xs.clone();
            let batch = percentiles(&mut sorted, &qs);
            for (s, b) in singles.iter().zip(&batch) {
                assert_eq!(s.to_bits(), b.to_bits(), "n={n}");
            }
        }
        assert_eq!(percentiles(&mut [], &[50.0, 99.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn violation_budget_forces_the_percentile_over() {
        let target = 10.0;
        for n in [1usize, 2, 5, 50, 100, 101, 102, 250, 400] {
            let budget = quantile_violation_budget(n, 99.0);
            assert!(budget >= 1 && budget <= n);
            // `budget` violators among any m in [budget, n] completions
            // force p99 over the target...
            for m in [budget, (budget + n) / 2, n] {
                let mut xs: Vec<f64> = vec![0.0; m - budget];
                xs.resize(m, target + 1.0);
                assert!(
                    percentile(&xs, 99.0) > target,
                    "n={n} m={m} budget={budget} must prove a violation"
                );
            }
            // ...while budget-1 violators leave a passing outcome possible
            // (barely-over violators diluted by on-target passes), so
            // aborting one violator earlier would be unsound.
            if budget > 1 {
                let mut xs: Vec<f64> = vec![0.0; n - (budget - 1)];
                xs.resize(n, target * 1.001);
                assert!(
                    percentile(&xs, 99.0) <= target,
                    "n={n} budget={budget}: one fewer violator must stay unprovable"
                );
            }
        }
    }

    #[test]
    fn violation_budget_is_monotone_in_n() {
        // The soundness of aborting on the *offered* count relies on the
        // budget never shrinking as the sample grows.
        let mut prev = 0;
        for n in 1..=2000 {
            let b = quantile_violation_budget(n, 99.0);
            assert!(b >= prev, "budget regressed at n={n}: {b} < {prev}");
            prev = b;
        }
    }

    #[test]
    fn nan_samples_sort_last_instead_of_panicking() {
        // Documented policy (see total_cmp_f64): a stray NaN must never
        // abort a report run. It ranks above every finite sample, so only
        // the very top of the distribution reads as NaN.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
        let mut batch = xs;
        let got = percentiles(&mut batch, &[0.0, 50.0, 100.0]);
        assert_eq!(got[0], 1.0);
        assert_eq!(got[1], 2.5);
        assert!(got[2].is_nan());
        // argmin ignores NaN; argmax reports it (callers filter).
        assert_eq!(argmin(&xs), Some(2));
        assert_eq!(argmax(&xs), Some(1));
        assert_eq!(argmin(&[f64::NAN]), Some(0));
    }

    #[test]
    fn sketch_tolerates_nan_by_dropping_it() {
        // Documented policy: the sketch rejects NaN at record time, so
        // fleet tails stay finite even when a replica misbehaves.
        let mut sk = QuantileSketch::default_accuracy();
        sk.record(1.0);
        sk.record(f64::NAN);
        sk.record(3.0);
        assert_eq!(sk.count(), 2);
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert!(sk.quantile(q).is_finite(), "q={q}");
        }
        assert!(sk.quantile(100.0) <= 3.0 * (1.0 + sk.alpha()));
    }

    #[test]
    fn total_cmp_orders_nan_and_signed_zero() {
        use std::cmp::Ordering;
        assert_eq!(total_cmp_f64(&1.0, &f64::NAN), Ordering::Less);
        assert_eq!(total_cmp_f64(&f64::INFINITY, &f64::NAN), Ordering::Less);
        assert_eq!(total_cmp_f64(&-0.0, &0.0), Ordering::Less);
        let mut v = [f64::NAN, 2.0, f64::NEG_INFINITY, -0.0];
        v.sort_by(total_cmp_f64);
        assert_eq!(v[0], f64::NEG_INFINITY);
        assert!(v[3].is_nan());
    }

    #[test]
    fn out_of_range_quantiles_clamp() {
        let xs: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        // These used to index past the sorted vector (panic) or saturate
        // a negative position to 0 silently.
        assert_eq!(percentile(&xs, 150.0), 10.0);
        assert_eq!(percentile(&xs, -20.0), 1.0);
        assert_eq!(percentile(&xs, f64::NAN), 1.0);
    }

    /// Heavy-tailed seeded corpora for the sketch properties: exponential,
    /// Pareto (infinite variance at shape 1.5) and lognormal-ish tails.
    fn heavy_tailed(seed: u64, n: usize, kind: usize) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n)
            .map(|_| {
                let u = rng.f64().clamp(1e-12, 1.0 - 1e-12);
                match kind {
                    0 => -(1.0 - u).ln(),                  // exponential(1)
                    1 => (1.0 - u).powf(-1.0 / 1.5),       // Pareto(1.5)
                    _ => (-(1.0 - u).ln() * 2.0 - 1.0).exp(), // lognormal-ish
                }
            })
            .collect()
    }

    #[test]
    fn sketch_quantiles_are_within_alpha_of_exact_order_stats() {
        for seed in [1u64, 42, 9001] {
            for kind in 0..3 {
                let xs = heavy_tailed(seed, 50_000, kind);
                let mut sk = QuantileSketch::default_accuracy();
                for &x in &xs {
                    sk.record(x);
                }
                let a = sk.alpha();
                let mut sorted = xs.clone();
                sorted.sort_by(total_cmp_f64);
                for q in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
                    let s = sk.quantile(q);
                    // Tight documented bound: within relative alpha of the
                    // floor-rank order statistic.
                    let rank = ((q / 100.0) * (xs.len() - 1) as f64).floor() as usize;
                    let exact = sorted[rank];
                    assert!(
                        (s - exact).abs() <= a * exact + 1e-12,
                        "seed={seed} kind={kind} q={q}: sketch {s} vs order stat {exact}"
                    );
                    // And therefore bracketed by the adjacent order stats
                    // around the interpolated `percentiles` read.
                    let hi = sorted[((q / 100.0) * (xs.len() - 1) as f64).ceil() as usize];
                    assert!(s >= exact * (1.0 - a) - 1e-12 && s <= hi * (1.0 + a) + 1e-12);
                }
            }
        }
    }

    #[test]
    fn sketch_merge_equals_fleet_sketch_bitwise() {
        let xs = heavy_tailed(7, 40_000, 1);
        // Four "replica" sketches, round-robin sharded...
        let mut shards: Vec<QuantileSketch> =
            (0..4).map(|_| QuantileSketch::default_accuracy()).collect();
        for (i, &x) in xs.iter().enumerate() {
            shards[i % 4].record(x);
        }
        // ...versus one fleet-level sketch over every sample.
        let mut fleet = QuantileSketch::default_accuracy();
        for &x in &xs {
            fleet.record(x);
        }
        let mut merged = shards[0].clone();
        for s in &shards[1..] {
            merged.merge(s);
        }
        assert_eq!(merged.count(), fleet.count());
        for q in [0.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(
                merged.quantile(q).to_bits(),
                fleet.quantile(q).to_bits(),
                "merge must be exact at q={q}"
            );
        }
        // The merged sketch also stays within bound of the exact tail.
        let mut sorted = xs.clone();
        sorted.sort_by(total_cmp_f64);
        let rank = ((99.0 / 100.0) * (xs.len() - 1) as f64).floor() as usize;
        let exact = sorted[rank];
        let s = merged.quantile(99.0);
        assert!((s - exact).abs() <= merged.alpha() * exact + 1e-12);
    }

    #[test]
    fn sketch_edge_cases() {
        let mut sk = QuantileSketch::default_accuracy();
        assert!(sk.is_empty());
        assert_eq!(sk.quantile(50.0), 0.0);
        sk.record(f64::NAN); // dropped
        assert_eq!(sk.count(), 0);
        sk.record(3.25);
        // A single sample is every quantile, exactly (min==max clamp).
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(sk.quantile(q), 3.25);
        }
        let mut zeros = QuantileSketch::default_accuracy();
        for _ in 0..10 {
            zeros.record(0.0);
        }
        // Zero latencies stay exact, not "within alpha of zero".
        assert_eq!(zeros.quantile(99.0), 0.0);
        assert_eq!(zeros.count(), 10);
    }

    #[test]
    #[should_panic(expected = "identical accuracy")]
    fn sketch_merge_rejects_mismatched_accuracy() {
        let mut a = QuantileSketch::new(0.01);
        let b = QuantileSketch::new(0.02);
        a.merge(&b);
    }
}
