//! Summary statistics used across the DSE engine, benches and reports.

/// Arithmetic mean; 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; used by the multi-model chip objective (Fig. 14).
/// Inputs must be strictly positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max helpers tolerant of NaN-free inputs.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Index of the minimum value (first occurrence). None on empty input.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

/// Index of the maximum value (first occurrence). None on empty input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
        // geomean of identical values is the value
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn arg_extrema() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
