//! Process-supervision helpers for the distributed campaign orchestrator:
//! atomic file replacement (checkpoint writes that are either complete or
//! absent, never truncated) and deterministic retry backoff.

use std::io;
use std::path::Path;
use std::time::Duration;

/// Atomically replace `path` with `bytes`: write a temp file in the same
/// directory, then `rename` over the target (atomic on POSIX). A reader —
/// or a resumed orchestrator scanning checkpoints — can never observe a
/// half-written file; a crash mid-write leaves only the temp file behind.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    std::fs::create_dir_all(dir)?;
    let stem = path.file_name().and_then(|n| n.to_str()).unwrap_or("out");
    let tmp = dir.join(format!(".{stem}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

/// Deterministic exponential backoff: `base << attempt`, saturating, capped
/// at `cap`. Attempt 0 (the first retry) waits `base`; there is no jitter —
/// reproducibility of the whole failure/retry schedule matters more here
/// than thundering-herd avoidance between a handful of local children.
pub fn backoff_delay(base: Duration, attempt: u32, cap: Duration) -> Duration {
    let mult = 1u32.checked_shl(attempt.min(31)).unwrap_or(u32::MAX);
    base.checked_mul(mult).map(|d| d.min(cap)).unwrap_or(cap)
}

/// Kill a child process and reap it (best-effort; a child that already
/// exited is fine). `wait` after `kill` is required to avoid zombies.
pub fn kill_and_reap(child: &mut std::process::Child) {
    let _ = child.kill();
    let _ = child.wait();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let base = Duration::from_millis(250);
        let cap = Duration::from_secs(30);
        assert_eq!(backoff_delay(base, 0, cap), Duration::from_millis(250));
        assert_eq!(backoff_delay(base, 1, cap), Duration::from_millis(500));
        assert_eq!(backoff_delay(base, 2, cap), Duration::from_millis(1000));
        assert_eq!(backoff_delay(base, 10, cap), cap);
        // Saturates instead of overflowing at absurd attempt counts.
        assert_eq!(backoff_delay(base, 63, cap), cap);
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("cc-proc-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("out.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
