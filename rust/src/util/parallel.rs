//! Deterministic fork-join parallelism for the sweep engine.
//!
//! The default implementation chunks the input across `std::thread::scope`
//! workers and reassembles results **in input order**, so parallel sweeps
//! are bit-identical to sequential ones. With the optional `rayon` feature
//! the same API routes through the rayon pool (also order-preserving).
//!
//! Thread count resolution: an explicit per-call count wins, otherwise the
//! `CC_SWEEP_THREADS` environment variable, otherwise
//! `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Default worker count: `CC_SWEEP_THREADS` or the machine's parallelism.
pub fn num_threads() -> usize {
    std::env::var("CC_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Resolve a requested worker count: 0 = auto ([`num_threads`]), anything
/// else verbatim. The sweep engine's speculative stage-2 uses this to size
/// its validation waves to the workers that will actually run them.
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        num_threads()
    } else {
        threads
    }
}

/// Resolve a requested thread count (0 = auto) against the input length.
fn effective_threads(threads: usize, len: usize) -> usize {
    resolve(threads).min(len.max(1))
}

/// Apply `f` to every item, in parallel, returning results in input order.
///
/// `threads == 0` selects the auto thread count; `threads == 1` runs inline
/// (the exact sequential path). Results are deterministic regardless of the
/// worker count: output index `i` is always `f(&items[i])`.
#[cfg(not(feature = "rayon"))]
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync + Send,
{
    let threads = effective_threads(threads, items.len());
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(|x| f(x)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let f = &f;
    let mut parts: Vec<Vec<R>> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| s.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
            .collect();
        for h in handles {
            // A worker panic (e.g. a failed property assertion running
            // under par_map) is re-raised on the caller's thread with its
            // original payload instead of a second, less informative panic.
            match h.join() {
                Ok(part) => parts.push(part),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    parts.into_iter().flatten().collect()
}

/// Apply `f` to every item, in parallel, returning results in input order
/// (rayon-pool variant; identical semantics to the scoped-thread default).
#[cfg(feature = "rayon")]
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync + Send,
{
    use rayon::prelude::*;
    if effective_threads(threads, items.len()) <= 1 || items.len() <= 1 {
        return items.iter().map(|x| f(x)).collect();
    }
    items.par_iter().map(f).collect()
}

/// An `f64` with atomic load / fetch-min, used as the shared branch-and-bound
/// incumbent ("best TCO/Token seen so far") across sweep workers.
///
/// Correctness of the sweep does not depend on the freshness of this value:
/// a stale (larger) incumbent only causes fewer candidates to be pruned,
/// never a wrong result.
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    /// New atomic holding `v`.
    pub fn new(v: f64) -> Self {
        AtomicF64(AtomicU64::new(v.to_bits()))
    }

    /// Current value.
    pub fn load(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Lower the stored value to `v` if `v` is smaller; returns the value
    /// observed before the update. NaN inputs are ignored.
    pub fn fetch_min(&self, v: f64) -> f64 {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let curf = f64::from_bits(cur);
            if !(v < curf) {
                return curf;
            }
            match self.0.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return curf,
                Err(actual) => cur = actual,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let seq: Vec<usize> = xs.iter().map(|x| x * 3 + 1).collect();
        for threads in [0, 1, 2, 3, 7] {
            assert_eq!(par_map(&xs, threads, |x| x * 3 + 1), seq, "threads={threads}");
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(&empty, 0, |x| *x).is_empty());
        assert_eq!(par_map(&[41u32], 0, |x| x + 1), vec![42]);
    }

    #[test]
    fn par_map_more_threads_than_items() {
        let xs = [1u64, 2, 3];
        assert_eq!(par_map(&xs, 64, |x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn atomic_f64_fetch_min() {
        let a = AtomicF64::new(f64::INFINITY);
        assert_eq!(a.load(), f64::INFINITY);
        a.fetch_min(2.5);
        assert_eq!(a.load(), 2.5);
        a.fetch_min(3.0); // larger: no-op
        assert_eq!(a.load(), 2.5);
        a.fetch_min(1.0);
        assert_eq!(a.load(), 1.0);
        a.fetch_min(f64::NAN); // ignored
        assert_eq!(a.load(), 1.0);
    }

    #[test]
    fn atomic_f64_concurrent_min() {
        let a = AtomicF64::new(f64::INFINITY);
        std::thread::scope(|s| {
            for t in 0..8 {
                let a = &a;
                s.spawn(move || {
                    for i in 0..1000 {
                        a.fetch_min((t * 1000 + i) as f64 / 7.0 + 1.0);
                    }
                });
            }
        });
        assert_eq!(a.load(), 1.0);
    }
}
