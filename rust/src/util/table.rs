//! ASCII table rendering for paper-style tables and figures-as-rows.

/// A simple column-aligned ASCII table builder.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new(), title: None }
    }

    /// Attach a title printed above the table.
    pub fn with_title<S: Into<String>>(mut self, title: S) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Append a row; panics if the arity differs from the header.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the table to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (header + rows), for `results/*.csv` dumps.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&crate::util::csv::csv_line(&self.header));
        for row in &self.rows {
            out.push_str(&crate::util::csv::csv_line(row));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["model", "tco"]);
        t.row(vec!["GPT-3", "0.161"]);
        t.row(vec!["PaLM", "0.245"]);
        let s = t.render();
        assert!(s.contains("| model | tco   |"));
        assert!(s.contains("| GPT-3 | 0.161 |"));
        // all lines same width
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }
}
