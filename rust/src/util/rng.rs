//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! Used by the property-test harness, workload generators and the serving
//! simulator. Deterministic by construction: every consumer takes an
//! explicit seed so tests and benches are reproducible.

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without rejection; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Approximately normal draw (sum of 12 uniforms, µ=0, σ=1).
    pub fn normal(&mut self) -> f64 {
        (0..12).map(|_| self.f64()).sum::<f64>() - 6.0
    }

    /// Exponential draw with rate `lambda` (inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
