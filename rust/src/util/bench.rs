//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! [`Bench`] for warmup + timed iterations with mean/p50/p99 reporting, and
//! then prints the paper table/figure rows it regenerates.

use std::time::{Duration, Instant};

/// Result summary of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Mean wall time per iteration (seconds).
    pub mean_s: f64,
    /// Median wall time (seconds).
    pub p50_s: f64,
    /// 99th percentile wall time (seconds).
    pub p99_s: f64,
    /// Min wall time (seconds).
    pub min_s: f64,
}

impl BenchStats {
    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} iters={:<6} mean={:<10} p50={:<10} p99={:<10} min={}",
            self.name,
            self.iters,
            crate::util::fmt_secs(self.mean_s),
            crate::util::fmt_secs(self.p50_s),
            crate::util::fmt_secs(self.p99_s),
            crate::util::fmt_secs(self.min_s),
        )
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bench {
    /// Warmup duration before timing starts.
    pub warmup: Duration,
    /// Measurement budget.
    pub measure: Duration,
    /// Upper bound on timed iterations (keeps huge-per-iter benches sane).
    pub max_iters: u64,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        // Fast-mode default so `cargo bench` over 13 targets stays tractable
        // on the single-core CI box; override via CC_BENCH_SECS.
        let secs: f64 = std::env::var("CC_BENCH_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0);
        Bench {
            warmup: Duration::from_secs_f64(secs * 0.25),
            measure: Duration::from_secs_f64(secs),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// Create with defaults (see [`Default`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, preventing the result from being optimized away.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && (samples.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let stats = BenchStats {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_s: crate::util::stats::mean(&samples),
            p50_s: crate::util::stats::percentile(&samples, 50.0),
            p99_s: crate::util::stats::percentile(&samples, 99.0),
            min_s: crate::util::stats::min(&samples),
        };
        println!("{}", stats.report());
        self.results.push(stats.clone());
        stats
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_something() {
        std::env::set_var("CC_BENCH_SECS", "0.05");
        let mut b = Bench::new();
        let s = b.run("noop-sum", || (0..100u64).sum::<u64>());
        assert!(s.iters > 0);
        assert!(s.mean_s >= 0.0);
        assert!(s.p99_s >= s.p50_s * 0.5);
    }
}
