//! Poison-recovering wrappers around `std::sync` locking.
//!
//! The serving coordinator shares request queues and metrics between the
//! submit path and the batcher thread. `Mutex::lock().unwrap()` turns one
//! panicked holder into a cascade: every later lock attempt panics on the
//! poison flag, taking down threads that could have carried on. These
//! wrappers recover the guard from a poisoned lock instead — the protected
//! data in this crate is always in a consistent state between operations
//! (plain queues/counters mutated by short critical sections, no
//! multi-step invariants held across a panic point), so continuing with
//! the inner value is sound and keeps shutdown/drain paths reachable.
//! They are also the `no-panic`-clean spelling `ccloud lint` expects
//! library code to use.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait`, recovering the re-acquired guard on poison.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout`, recovering the re-acquired guard on poison.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    d: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, d).unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // Poison the mutex by panicking while holding it.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison on purpose");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn wait_timeout_returns_on_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_unpoisoned(&m);
        let (_g, res) = wait_timeout_unpoisoned(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
