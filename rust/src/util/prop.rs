//! Tiny property-based testing harness (proptest is unavailable offline).
//!
//! A property runs against `cases` random inputs drawn from a seeded
//! [`Rng`](crate::util::rng::Rng). On failure the harness re-runs with a
//! simple halving shrink over the generator's size parameter and reports the
//! seed so the failure is reproducible:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath on this image)
//! use chiplet_cloud::util::prop::check;
//! check("addition commutes", 100, |r| {
//!     let (a, b) = (r.below(1000) as i64, r.below(1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Run `f` against `cases` seeded random inputs; panic with the failing seed
/// on the first failure.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` instead of
/// panicking, for properties that want to accumulate context.
pub fn check_result<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("below is below", 200, |r| {
            let n = 1 + r.below(100);
            assert!(r.below(n) < n);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| panic!("nope"));
    }

    #[test]
    fn result_property() {
        check_result("ok", 10, |r| {
            if r.f64() <= 1.0 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }
}
