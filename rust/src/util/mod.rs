//! Small self-contained utilities.
//!
//! The offline build environment only ships the `xla` crate's dependency
//! closure, so facilities normally pulled from crates.io (CLI parsing,
//! property testing, bench harness, JSON) are implemented here.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod parallel;
pub mod proc;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;

/// FNV-1a 64-bit hash. Stable across platforms and runs (unlike
/// `DefaultHasher`), so it is safe to persist — the experiment layer uses
/// it to fingerprint specs for shard/checkpoint identity.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Format a dollar amount with engineering suffixes for table output.
pub fn fmt_dollars(x: f64) -> String {
    let (v, suffix) = if x.abs() >= 1e9 {
        (x / 1e9, "B")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "K")
    } else {
        (x, "")
    };
    if suffix.is_empty() && x.abs() < 10.0 {
        format!("${v:.3}")
    } else {
        format!("${v:.2}{suffix}")
    }
}

/// Format a count with engineering suffixes (1.2K, 3.4M, ...).
pub fn fmt_count(x: f64) -> String {
    if x.abs() >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if x.abs() >= 1e9 {
        format!("{:.2}B", x / 1e9)
    } else if x.abs() >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x.abs() >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dollars_suffixes() {
        assert_eq!(fmt_dollars(35e6), "$35.00M");
        assert_eq!(fmt_dollars(1.5e9), "$1.50B");
        assert_eq!(fmt_dollars(450.0), "$450.00");
        assert_eq!(fmt_dollars(0.161), "$0.161");
    }

    #[test]
    fn counts() {
        assert_eq!(fmt_count(2_726_000.0), "2.73M");
        assert_eq!(fmt_count(99_000.0), "99.00K");
    }

    #[test]
    fn secs() {
        assert_eq!(fmt_secs(5e-6), "5.00µs");
        assert_eq!(fmt_secs(0.25), "250.00ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
    }

    #[test]
    fn fnv1a64_reference_vectors() {
        // Published FNV-1a test vectors — pins the hash across refactors
        // (persisted fingerprints must never silently change meaning).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
