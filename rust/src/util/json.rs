//! Minimal JSON reader/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/*.manifest.json`, written
//! by `python/compile/aot.py`) and for machine-readable result dumps. This
//! is a full JSON parser (objects, arrays, strings with escapes, numbers,
//! bools, null) — small because the workloads are small, not because the
//! grammar is truncated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        // cc-lint: allow(no-float-eq) fract()==0.0 is the exact IEEE-754 integrality test (fract of an integer-valued double is exactly +0.0, never an epsilon)
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as usize) } else { None })
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a full UTF-8 sequence
                    let len = utf8_len(c);
                    let chunk = self.b.get(self.i..self.i + len).ok_or("bad utf8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                // Canonical number form: integer-valued doubles print
                // without a fractional part so outcome documents diff and
                // hash stably across writers. Exactness argument for the
                // allowlisted comparison below: `fract()` of an
                // integer-valued double is exactly +0.0 (no rounding is
                // involved — the fractional bits are literally zero), and
                // the `|x| < 1e15 < 2^53` guard keeps the `as i64` cast
                // inside the range where every integer is representable,
                // so the printed digits equal the stored value bit-for-bit.
                // -0.0 canonicalizes to "0" by design (its fract is -0.0,
                // which compares equal to 0.0).
                // cc-lint: allow(no-float-eq) exact integrality test, see the canonicalization note above
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"args":[{"name":"w","shape":[2,3],"dtype":"float32"}],"n":3,"ok":true,"note":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let args = v.get("args").unwrap().as_arr().unwrap();
        assert_eq!(args[0].get("name").unwrap().as_str(), Some("w"));
        assert_eq!(args[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
        // re-parse the rendering
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn number_canonicalization_boundaries() {
        // The 1e15 guard: the last integer below it prints via the i64
        // path, 1e15 itself takes the float path — both must re-parse to
        // the identical double (the suppression in Display is earned by
        // this round-trip staying bit-exact).
        let below = 1e15 - 1.0;
        assert_eq!(Json::Num(below).to_string(), "999999999999999");
        let back = Json::parse(&Json::Num(below).to_string()).unwrap();
        assert_eq!(back.as_f64().unwrap().to_bits(), below.to_bits());
        let at = Json::parse(&Json::Num(1e15).to_string()).unwrap();
        assert_eq!(at.as_f64().unwrap().to_bits(), 1e15_f64.to_bits());
    }

    #[test]
    fn negative_zero_canonicalizes_to_zero() {
        // Documented policy: -0.0 prints as "0" (fract(-0.0) is -0.0,
        // which == 0.0 exactly). The sign bit is deliberately dropped —
        // outcome hashing wants one spelling for the one numeric value.
        assert_eq!(Json::Num(-0.0).to_string(), "0");
        let back = Json::parse("0").unwrap();
        assert_eq!(back.as_f64(), Some(0.0));
    }

    #[test]
    fn subnormals_roundtrip_exactly() {
        for x in [5e-324_f64, 2.2250738585072009e-308, 4.9406564584124654e-321] {
            assert!(x.is_subnormal() || x > 0.0);
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap();
            assert_eq!(back.as_f64().unwrap().to_bits(), x.to_bits(), "{s}");
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }
}
