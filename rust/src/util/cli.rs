//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is `--name` present (as flag or option)?
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        self.get(name)
            .ok_or_else(|| format!("missing required option --{name}"))?
            .parse()
            .map_err(|_| format!("option --{name} has invalid value"))
    }

    /// Error when any of the given *boolean* flags swallowed a value. The
    /// `--key value` grammar makes `--seq a.json b.json` parse as
    /// `seq = "a.json"` — silently dropping `a.json` from the positional
    /// list — so commands that mix positional file lists with boolean
    /// flags call this to turn the silent drop into a loud error.
    pub fn reject_valued_flags(&self, flags: &[&str]) -> Result<(), String> {
        for f in flags {
            if let Some(v) = self.options.get(*f) {
                return Err(format!(
                    "--{f} takes no value but got '{v}' — put boolean flags after the \
                     positional arguments (or use --{f} last)"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = args(&["table2", "--model", "gpt3", "--ctx=2048", "--verbose"]);
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.get("model"), Some("gpt3"));
        assert_eq!(a.get_or::<usize>("ctx", 0), 2048);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn flag_before_positional() {
        // `--flag` followed by a positional: the positional is consumed as
        // the flag's value (documented --key value behaviour).
        let a = args(&["--out", "results", "fig7"]);
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.positional, vec!["fig7"]);
    }

    #[test]
    fn reject_valued_flags_catches_swallowed_positionals() {
        // `--seq a.json b.json`: a.json is consumed as seq's value and
        // vanishes from positional — must be rejected, not silently run.
        let a = args(&["run", "--seq", "a.json", "b.json"]);
        assert_eq!(a.positional, vec!["run", "b.json"]);
        let err = a.reject_valued_flags(&["seq", "json"]).unwrap_err();
        assert!(err.contains("--seq") && err.contains("a.json"), "{err}");
        // Flags in trailing position stay plain flags and pass the check.
        let a = args(&["run", "a.json", "b.json", "--seq", "--json"]);
        assert_eq!(a.positional, vec!["run", "a.json", "b.json"]);
        a.reject_valued_flags(&["seq", "json"]).unwrap();
    }

    #[test]
    fn require_errors() {
        let a = args(&[]);
        assert!(a.require::<usize>("batch").is_err());
        let a = args(&["--batch", "abc"]);
        assert!(a.require::<usize>("batch").is_err());
        let a = args(&["--batch", "8"]);
        assert_eq!(a.require::<usize>("batch").unwrap(), 8);
    }
}
