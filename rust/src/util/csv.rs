//! Minimal CSV writing (RFC-4180 quoting) for `results/*.csv` dumps.

use std::io::Write;
use std::path::Path;

/// Quote a single CSV field if needed.
pub fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Render one CSV line (with trailing newline).
pub fn csv_line<S: AsRef<str>>(fields: &[S]) -> String {
    let mut out = fields.iter().map(|f| csv_field(f.as_ref())).collect::<Vec<_>>().join(",");
    out.push('\n');
    out
}

/// Write rows (first row = header) to a CSV file, creating parent dirs.
pub fn write_csv<P: AsRef<Path>>(path: P, rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    for row in rows {
        f.write_all(csv_line(row).as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quoting() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn line() {
        assert_eq!(csv_line(&["a", "b,c"]), "a,\"b,c\"\n");
    }
}
