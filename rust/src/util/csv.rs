//! Minimal CSV writing (RFC-4180 quoting) for `results/*.csv` dumps, and
//! a streaming line parser ([`CsvReader`]) for trace ingestion — reads
//! records one line at a time with located errors, never materializing
//! the file.

use std::io::Write;
use std::path::Path;

/// Quote a single CSV field if needed.
pub fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Render one CSV line (with trailing newline).
pub fn csv_line<S: AsRef<str>>(fields: &[S]) -> String {
    let mut out = fields.iter().map(|f| csv_field(f.as_ref())).collect::<Vec<_>>().join(",");
    out.push('\n');
    out
}

/// Write rows (first row = header) to a CSV file, creating parent dirs.
pub fn write_csv<P: AsRef<Path>>(path: P, rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    for row in rows {
        f.write_all(csv_line(row).as_bytes())?;
    }
    Ok(())
}

/// A CSV parse error located by 1-based line number.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CsvError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

/// Split one CSV record (no trailing newline) into fields: RFC-4180
/// quoted fields with `""` escapes; malformed rows (unterminated quote,
/// text after a closing quote, a bare quote mid-field) error with the
/// given `line` number attached.
pub fn split_csv_line(s: &str, line: usize) -> Result<Vec<String>, CsvError> {
    let mut fields = Vec::new();
    let mut it = s.chars().peekable();
    'fields: loop {
        let mut field = String::new();
        if it.peek() == Some(&'"') {
            it.next();
            loop {
                match it.next() {
                    None => {
                        return Err(CsvError { line, msg: "unterminated quoted field".into() })
                    }
                    Some('"') => {
                        if it.peek() == Some(&'"') {
                            it.next();
                            field.push('"');
                        } else {
                            break;
                        }
                    }
                    Some(c) => field.push(c),
                }
            }
            fields.push(field);
            match it.next() {
                None => return Ok(fields),
                Some(',') => continue 'fields,
                Some(c) => {
                    return Err(CsvError {
                        line,
                        msg: format!("unexpected '{c}' after a closing quote"),
                    })
                }
            }
        }
        loop {
            match it.next() {
                None => {
                    fields.push(field);
                    return Ok(fields);
                }
                Some(',') => {
                    fields.push(field);
                    continue 'fields;
                }
                Some('"') => {
                    return Err(CsvError { line, msg: "'\"' inside an unquoted field".into() })
                }
                Some(c) => field.push(c),
            }
        }
    }
}

/// Streaming CSV reader: yields `(line_number, fields)` per record,
/// skipping blank lines, holding one line in memory at a time. Records
/// are one physical line each (quoted fields may not span lines — the
/// trace format never needs embedded newlines). I/O and parse errors are
/// located by 1-based line number.
pub struct CsvReader<R: std::io::BufRead> {
    inner: R,
    line: usize,
    buf: String,
}

impl<R: std::io::BufRead> CsvReader<R> {
    pub fn new(inner: R) -> CsvReader<R> {
        CsvReader { inner, line: 0, buf: String::new() }
    }
}

impl<R: std::io::BufRead> Iterator for CsvReader<R> {
    type Item = Result<(usize, Vec<String>), CsvError>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            self.buf.clear();
            match self.inner.read_line(&mut self.buf) {
                Ok(0) => return None,
                Ok(_) => {}
                Err(e) => {
                    self.line += 1;
                    return Some(Err(CsvError {
                        line: self.line,
                        msg: format!("read failed: {e}"),
                    }));
                }
            }
            self.line += 1;
            let s = self.buf.trim_end_matches(['\n', '\r']);
            if s.is_empty() {
                continue;
            }
            return Some(split_csv_line(s, self.line).map(|f| (self.line, f)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quoting() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn line() {
        assert_eq!(csv_line(&["a", "b,c"]), "a,\"b,c\"\n");
    }

    #[test]
    fn split_plain_and_quoted() {
        assert_eq!(split_csv_line("a,b,c", 1).unwrap(), vec!["a", "b", "c"]);
        assert_eq!(split_csv_line("", 1).unwrap(), vec![""]);
        assert_eq!(split_csv_line("a,,c", 1).unwrap(), vec!["a", "", "c"]);
        assert_eq!(split_csv_line("a,b,", 1).unwrap(), vec!["a", "b", ""]);
        assert_eq!(split_csv_line("\"a,b\",c", 1).unwrap(), vec!["a,b", "c"]);
        assert_eq!(split_csv_line("\"say \"\"hi\"\"\",x", 1).unwrap(), vec!["say \"hi\"", "x"]);
        assert_eq!(split_csv_line("\"\",\"\"", 1).unwrap(), vec!["", ""]);
    }

    #[test]
    fn split_round_trips_the_writer() {
        let fields = ["plain", "a,b", "say \"hi\"", "", "tail\nnewline"];
        let line = csv_line(&fields);
        let parsed = split_csv_line(line.trim_end_matches('\n'), 1).unwrap();
        // The embedded-newline field survives quoting; the record itself
        // stays one parser line because we trimmed only the trailing \n.
        assert_eq!(parsed, fields);
    }

    #[test]
    fn split_errors_are_located() {
        let e = split_csv_line("\"open", 7).unwrap_err();
        assert_eq!(e.line, 7);
        assert!(e.msg.contains("unterminated"), "{e}");
        let e = split_csv_line("\"a\"b,c", 3).unwrap_err();
        assert!(e.msg.contains("after a closing quote"), "{e}");
        let e = split_csv_line("a\"b", 9).unwrap_err();
        assert!(e.msg.contains("unquoted"), "{e}");
        assert_eq!(format!("{e}"), "line 9: '\"' inside an unquoted field");
    }

    #[test]
    fn reader_streams_with_line_numbers() {
        let data = "h1,h2\n1,2\n\n\"x,y\",3\r\nlast,4";
        let rows: Vec<_> = CsvReader::new(data.as_bytes()).collect::<Result<Vec<_>, _>>().unwrap();
        assert_eq!(
            rows,
            vec![
                (1, vec!["h1".to_string(), "h2".to_string()]),
                (2, vec!["1".to_string(), "2".to_string()]),
                (4, vec!["x,y".to_string(), "3".to_string()]),
                (5, vec!["last".to_string(), "4".to_string()]),
            ]
        );
    }

    #[test]
    fn reader_surfaces_malformed_rows() {
        let data = "ok,row\n\"bad\nok,again\n";
        let mut r = CsvReader::new(data.as_bytes());
        assert!(r.next().unwrap().is_ok());
        let err = r.next().unwrap().unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.msg.contains("unterminated"));
        // The reader is line-oriented, so it recovers on the next line.
        assert_eq!(r.next().unwrap().unwrap().0, 3);
    }
}
