//! Discrete-event serving simulator: synthetic arrival traces driven
//! through a [`crate::sched::Policy`] with the analytic per-iteration
//! latencies of [`crate::perf::simulator`].
//!
//! The steady-state simulator answers "what throughput does a saturated
//! lockstep batch sustain"; this module answers the paper's *serving*
//! question — what TTFT/TPOT tails, batch occupancy and goodput does a
//! design deliver under real traffic, where requests queue, batches run
//! partially full, and slots free at different times. Virtual time only:
//! every iteration's duration comes from the analytic model
//! ([`IterCost`]), so runs are deterministic, seeded, and fast enough to
//! validate sweep candidates ([`crate::evaluate::SweepEngine::best_point_slo`]).
//!
//! Iteration model (matching the AOT runtime's shape): an *admission*
//! iteration prefixes the newcomers' prompt processing to the incumbents'
//! decode step — newcomers receive their first token from the prefill, so
//! TTFT is measured at the end of the admitting iteration; a *decode*
//! iteration advances every live slot by one token in lockstep at the
//! pipeline's token period, regardless of occupancy (static shapes: padded
//! slots are computed anyway, which is exactly why occupancy is worth
//! measuring).

use std::collections::VecDeque;

use crate::config::workload::{ArrivalProcess, SloSpec, TrafficSpec};
use crate::config::Workload;
use crate::perf::DecodePerf;
use crate::sched::{sanitize, Action, KvBudget, Policy, SchedView};
use crate::util::rng::Rng;
use crate::util::stats;

/// One request arrival in a trace.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// Request id (ascending with arrival order).
    pub id: u64,
    /// Arrival time, seconds since trace start.
    pub at_s: f64,
    /// Prompt tokens to prefill.
    pub prompt_tokens: usize,
    /// Tokens to generate (>= 1; the first comes from the prefill).
    pub new_tokens: usize,
}

/// Generate the open-loop arrival list for a traffic spec. Closed-loop
/// specs return an empty list — their arrivals are produced *during* the
/// simulation (each completion schedules the client's next request).
pub fn open_loop_trace(t: &TrafficSpec) -> Vec<Arrival> {
    let mut rng = Rng::new(t.seed);
    let mut out = Vec::with_capacity(t.requests);
    let mut now = 0.0f64;
    match t.arrival {
        ArrivalProcess::Poisson { rps } => {
            for id in 0..t.requests {
                now += rng.exponential(rps.max(1e-12));
                out.push(arrival(&mut rng, t, id as u64, now));
            }
        }
        ArrivalProcess::Bursty { rps, burst } => {
            let burst = burst.max(1);
            // Exponential gaps between bursts with mean burst/rps keep the
            // long-run rate at `rps` while arrivals clump.
            let mut id = 0u64;
            while (id as usize) < t.requests {
                now += rng.exponential((rps / burst as f64).max(1e-12));
                for _ in 0..burst.min(t.requests - id as usize) {
                    out.push(arrival(&mut rng, t, id, now));
                    id += 1;
                }
            }
        }
        ArrivalProcess::ClosedLoop { .. } => {}
    }
    out
}

fn arrival(rng: &mut Rng, t: &TrafficSpec, id: u64, at_s: f64) -> Arrival {
    let (lo, hi) = (t.new_tokens_lo.max(1), t.new_tokens_hi.max(t.new_tokens_lo).max(1));
    Arrival { id, at_s, prompt_tokens: t.prompt_tokens, new_tokens: rng.range(lo, hi) }
}

/// Analytic per-iteration costs driving the simulator's virtual clock.
#[derive(Clone, Copy, Debug)]
pub struct IterCost {
    /// Prefill seconds per *prompt token* of one admitted sequence.
    pub prefill_s_per_token: f64,
    /// One lockstep decode iteration over the batch, s (the pipeline's
    /// token period).
    pub decode_step_s: f64,
}

impl IterCost {
    /// Derive the costs from a steady-state simulation of the workload:
    /// decode iterations run at the pipeline token period; prefill charges
    /// each sequence its per-token share of the whole-batch prefill.
    pub fn from_perf(perf: &DecodePerf, w: &Workload) -> IterCost {
        let prompt_tokens = (w.batch.max(1) * w.prompt_len.max(1)) as f64;
        IterCost {
            prefill_s_per_token: perf.prefill_latency / prompt_tokens,
            decode_step_s: perf.token_period,
        }
    }
}

/// Simulator configuration: engine shape, KV budget and iteration costs.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Compiled batch slots.
    pub max_slots: usize,
    /// KV-capacity admission budget.
    pub kv: KvBudget,
    /// Iteration cost model.
    pub cost: IterCost,
}

/// Per-request outcome record.
#[derive(Clone, Copy, Debug)]
pub struct ReqStats {
    /// Request id.
    pub id: u64,
    /// Arrival time, s.
    pub arrival_s: f64,
    /// First-token completion time, s.
    pub first_token_s: f64,
    /// Final-token completion time, s.
    pub finish_s: f64,
    /// Tokens generated.
    pub tokens: usize,
}

impl ReqStats {
    /// Time to first token.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// Time per output token after the first (0 for single-token requests).
    pub fn tpot_s(&self) -> f64 {
        if self.tokens > 1 {
            (self.finish_s - self.first_token_s) / (self.tokens - 1) as f64
        } else {
            0.0
        }
    }

    /// End-to-end latency.
    pub fn total_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Did this request meet both per-request latency targets?
    pub fn meets(&self, slo: &SloSpec) -> bool {
        self.ttft_s() <= slo.ttft_p99_s && self.tpot_s() <= slo.tpot_p99_s
    }
}

/// Aggregate report of one simulated trace.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Policy that produced the schedule.
    pub policy: String,
    /// Requests the trace offered.
    pub offered: usize,
    /// Requests completed.
    pub completed: usize,
    /// Tokens generated.
    pub tokens: usize,
    /// Virtual time from first arrival to last completion, s.
    pub makespan_s: f64,
    /// Tokens per second of wall (virtual) time.
    pub tokens_per_s: f64,
    /// Tokens per second counting only SLO-compliant requests.
    pub goodput_tokens_per_s: f64,
    /// Fraction of requests meeting the SLO.
    pub slo_met_frac: f64,
    /// TTFT p50, s.
    pub ttft_p50_s: f64,
    /// TTFT p99, s.
    pub ttft_p99_s: f64,
    /// TPOT p50, s.
    pub tpot_p50_s: f64,
    /// TPOT p99, s.
    pub tpot_p99_s: f64,
    /// End-to-end latency p50, s.
    pub total_p50_s: f64,
    /// End-to-end latency p99, s.
    pub total_p99_s: f64,
    /// Time-weighted decode-slot occupancy (1.0 = every iteration full).
    pub occupancy: f64,
    /// Engine iterations executed.
    pub iterations: u64,
    /// Peak concurrently-live sequences (must respect the KV budget).
    pub peak_live: usize,
    /// Per-request records (arrival order).
    pub per_request: Vec<ReqStats>,
}

impl ServeReport {
    /// Does the simulated run meet the SLO? Requires every offered request
    /// to have completed — percentiles over a partial (or empty) set of
    /// completions would otherwise declare a run that served nothing
    /// SLO-compliant (e.g. a zero KV budget admits no one and produces
    /// all-zero tails).
    pub fn meets(&self, slo: &SloSpec) -> bool {
        self.completed == self.offered
            && self.ttft_p99_s <= slo.ttft_p99_s
            && self.tpot_p99_s <= slo.tpot_p99_s
    }
}

/// A live decode slot.
#[derive(Clone, Copy, Debug)]
struct Slot {
    id: u64,
    arrival_s: f64,
    first_token_s: f64,
    tokens: usize,
    remaining: usize,
    /// Closed-loop client that owns the request, if any.
    client: Option<usize>,
}

/// Closed-loop arrival state: each client resubmits `think_s` after its
/// previous request completes, until the request budget is spent.
struct ClosedLoop {
    /// Per-client next-submit time; `INFINITY` while a request is in flight.
    ready: Vec<f64>,
    think_s: f64,
    budget: usize,
}

impl ClosedLoop {
    /// Earliest future client submit time, if any client has budget left.
    fn next_ready(&self) -> Option<f64> {
        if self.budget == 0 {
            return None;
        }
        self.ready.iter().copied().filter(|r| r.is_finite()).reduce(f64::min)
    }
}

/// Drive a policy over a traffic spec and report the serving tails.
///
/// Deterministic in `(cfg, policy, traffic, slo)`: the virtual clock only
/// advances by analytic iteration costs and seeded arrival draws.
pub fn simulate_trace(
    cfg: &SimConfig,
    policy: &mut dyn Policy,
    traffic: &TrafficSpec,
    slo: &SloSpec,
) -> ServeReport {
    let mut rng = Rng::new(traffic.seed ^ 0x5EED_CAFE);
    let mut pending: VecDeque<Arrival> = open_loop_trace(traffic).into();
    let mut closed: Option<ClosedLoop> = match traffic.arrival {
        ArrivalProcess::ClosedLoop { clients, think_s } => Some(ClosedLoop {
            ready: vec![0.0; clients.max(1)],
            think_s: think_s.max(0.0),
            budget: traffic.requests,
        }),
        _ => None,
    };
    let mut next_id = 0u64;

    let kv_slots = cfg.kv.concurrency(cfg.max_slots);
    let mut queue: VecDeque<(Arrival, Option<usize>)> = VecDeque::new();
    let mut slots: Vec<Option<Slot>> = vec![None; cfg.max_slots];
    let mut done: Vec<ReqStats> = Vec::new();

    let mut now = 0.0f64;
    let mut first_arrival: Option<f64> = None;
    let mut last_finish = 0.0f64;
    let mut busy_slot_time = 0.0f64;
    let mut busy_time = 0.0f64;
    let mut iterations = 0u64;
    let mut peak_live = 0usize;

    loop {
        // Materialize every arrival with `at_s <= now` into the queue.
        while pending.front().map(|a| a.at_s <= now).unwrap_or(false) {
            let a = pending.pop_front().unwrap();
            first_arrival.get_or_insert(a.at_s);
            queue.push_back((a, None));
        }
        if let Some(cl) = closed.as_mut() {
            for c in 0..cl.ready.len() {
                if cl.budget == 0 {
                    break;
                }
                let r = cl.ready[c];
                if r.is_finite() && r <= now {
                    let a = arrival(&mut rng, traffic, next_id, r);
                    next_id += 1;
                    cl.budget -= 1;
                    cl.ready[c] = f64::INFINITY; // in flight until completion
                    first_arrival.get_or_insert(a.at_s);
                    queue.push_back((a, Some(c)));
                }
            }
        }

        let live = slots.iter().filter(|s| s.is_some()).count();
        // Next future arrival instant, for Wait actions.
        let next_arrival = {
            let open = pending.front().map(|a| a.at_s);
            let cl = closed.as_ref().and_then(ClosedLoop::next_ready);
            match (open, cl) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            }
        };

        if queue.is_empty() && live == 0 && next_arrival.is_none() {
            break;
        }

        let view = SchedView {
            now_s: now,
            queued: queue.len(),
            oldest_arrival_s: queue.front().map(|(a, _)| a.at_s).unwrap_or(now),
            live,
            max_slots: cfg.max_slots,
            kv_slots,
            refill_mid_iteration: true,
        };
        match sanitize(policy.decide(&view), &view) {
            Action::Admit(n) => {
                // Interleaved iteration: newcomers prefill (first token),
                // incumbents take one decode step.
                let mut t_iter = if live > 0 { cfg.cost.decode_step_s } else { 0.0 };
                let mut admitted: Vec<(Arrival, Option<usize>)> = Vec::with_capacity(n);
                for _ in 0..n {
                    let (a, c) = queue.pop_front().unwrap();
                    t_iter += a.prompt_tokens as f64 * cfg.cost.prefill_s_per_token;
                    admitted.push((a, c));
                }
                now += t_iter;
                iterations += 1;
                busy_slot_time += (live + admitted.len()) as f64 * t_iter;
                busy_time += t_iter;
                step_live_slots(&mut slots, now, &mut done, &mut closed, &mut last_finish);
                for (a, c) in admitted {
                    let slot = Slot {
                        id: a.id,
                        arrival_s: a.at_s,
                        first_token_s: now,
                        tokens: 1,
                        remaining: a.new_tokens - 1,
                        client: c,
                    };
                    if slot.remaining == 0 {
                        finish_slot(&slot, now, &mut done, &mut closed, &mut last_finish);
                    } else {
                        let free = slots.iter().position(|s| s.is_none()).expect("free slot");
                        slots[free] = Some(slot);
                    }
                }
                peak_live = peak_live.max(slots.iter().filter(|s| s.is_some()).count());
            }
            Action::Decode => {
                now += cfg.cost.decode_step_s;
                iterations += 1;
                busy_slot_time += live as f64 * cfg.cost.decode_step_s;
                busy_time += cfg.cost.decode_step_s;
                step_live_slots(&mut slots, now, &mut done, &mut closed, &mut last_finish);
            }
            Action::Wait(deadline) => {
                let target = match (next_arrival, deadline) {
                    (Some(a), Some(d)) => Some(a.min(d).max(now)),
                    (Some(a), None) => Some(a.max(now)),
                    (None, Some(d)) if live > 0 || !queue.is_empty() => Some(d.max(now)),
                    _ => None,
                };
                match target {
                    Some(t) if t > now => now = t,
                    Some(_) => {
                        // Deadline already passed but the policy keeps
                        // waiting with work available — nudge time to the
                        // next arrival to guarantee progress.
                        match next_arrival {
                            Some(a) if a > now => now = a,
                            _ => break,
                        }
                    }
                    None => break,
                }
            }
        }
    }

    // --- aggregate --------------------------------------------------------
    done.sort_by_key(|r| r.id);
    let ttfts: Vec<f64> = done.iter().map(|r| r.ttft_s()).collect();
    let tpots: Vec<f64> = done.iter().filter(|r| r.tokens > 1).map(|r| r.tpot_s()).collect();
    let totals: Vec<f64> = done.iter().map(|r| r.total_s()).collect();
    let tokens: usize = done.iter().map(|r| r.tokens).sum();
    let good_tokens: usize = done.iter().filter(|r| r.meets(slo)).map(|r| r.tokens).sum();
    let met = done.iter().filter(|r| r.meets(slo)).count();
    let makespan = (last_finish - first_arrival.unwrap_or(0.0)).max(0.0);
    ServeReport {
        policy: policy.name().to_string(),
        offered: traffic.requests,
        completed: done.len(),
        tokens,
        makespan_s: makespan,
        tokens_per_s: if makespan > 0.0 { tokens as f64 / makespan } else { 0.0 },
        goodput_tokens_per_s: if makespan > 0.0 { good_tokens as f64 / makespan } else { 0.0 },
        slo_met_frac: if done.is_empty() { 0.0 } else { met as f64 / done.len() as f64 },
        ttft_p50_s: stats::percentile(&ttfts, 50.0),
        ttft_p99_s: stats::percentile(&ttfts, 99.0),
        tpot_p50_s: stats::percentile(&tpots, 50.0),
        tpot_p99_s: stats::percentile(&tpots, 99.0),
        total_p50_s: stats::percentile(&totals, 50.0),
        total_p99_s: stats::percentile(&totals, 99.0),
        occupancy: if busy_time > 0.0 {
            busy_slot_time / (busy_time * cfg.max_slots as f64)
        } else {
            0.0
        },
        iterations,
        peak_live,
        per_request: done,
    }
}

/// Advance every live slot by one token at time `now`; free finished ones.
fn step_live_slots(
    slots: &mut [Option<Slot>],
    now: f64,
    done: &mut Vec<ReqStats>,
    closed: &mut Option<ClosedLoop>,
    last_finish: &mut f64,
) {
    for s in slots.iter_mut() {
        let Some(slot) = s else { continue };
        slot.tokens += 1;
        slot.remaining -= 1;
        if slot.remaining == 0 {
            let finished = *slot;
            *s = None;
            finish_slot(&finished, now, done, closed, last_finish);
        }
    }
}

/// Record a completed request; a closed-loop client starts thinking.
fn finish_slot(
    slot: &Slot,
    now: f64,
    done: &mut Vec<ReqStats>,
    closed: &mut Option<ClosedLoop>,
    last_finish: &mut f64,
) {
    done.push(ReqStats {
        id: slot.id,
        arrival_s: slot.arrival_s,
        first_token_s: slot.first_token_s,
        finish_s: now,
        tokens: slot.tokens,
    });
    *last_finish = last_finish.max(now);
    if let (Some(cl), Some(c)) = (closed.as_mut(), slot.client) {
        cl.ready[c] = now + cl.think_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{ContinuousBatch, StaticBatch};

    fn cost() -> IterCost {
        IterCost { prefill_s_per_token: 0.001, decode_step_s: 0.01 }
    }

    fn cfg(slots: usize) -> SimConfig {
        SimConfig { max_slots: slots, kv: KvBudget::unlimited(), cost: cost() }
    }

    #[test]
    fn poisson_trace_is_seeded_and_sorted() {
        let t = TrafficSpec::poisson(100.0, 50, 16, 4, 8);
        let a = open_loop_trace(&t);
        let b = open_loop_trace(&t);
        assert_eq!(a.len(), 50);
        assert!(a.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.new_tokens, y.new_tokens);
            assert!((4..=8).contains(&x.new_tokens));
        }
        let c = open_loop_trace(&t.with_seed(7));
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_s != y.at_s));
    }

    #[test]
    fn bursty_trace_clumps_arrivals() {
        let t = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 100.0, burst: 5 },
            ..TrafficSpec::poisson(100.0, 20, 16, 4, 8)
        };
        let a = open_loop_trace(&t);
        assert_eq!(a.len(), 20);
        // within a burst, arrivals share a timestamp
        assert_eq!(a[0].at_s.to_bits(), a[4].at_s.to_bits());
        assert!(a[5].at_s > a[4].at_s);
    }

    /// Hand-traceable single-request run: one arrival at t=0, prompt 10,
    /// 3 new tokens. Admission iteration costs 10 × 1 ms (first token at
    /// 10 ms), then two decode steps of 10 ms each finish it at 30 ms.
    #[test]
    fn single_request_timeline_is_exact() {
        let t = TrafficSpec::poisson(1e9, 1, 10, 3, 3);
        let rep = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.tokens, 3);
        let r = rep.per_request[0];
        assert!((r.ttft_s() - 0.010).abs() < 1e-12, "ttft={}", r.ttft_s());
        assert!((r.finish_s - r.first_token_s - 0.020).abs() < 1e-12);
        assert!((r.tpot_s() - 0.010).abs() < 1e-12);
        assert_eq!(rep.iterations, 3);
    }

    #[test]
    fn simulation_is_deterministic() {
        let t = TrafficSpec::poisson(40.0, 200, 16, 4, 32).with_seed(123);
        let run = || {
            let rep = simulate_trace(&cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
            (rep.tokens, rep.iterations, rep.ttft_p99_s.to_bits(), rep.makespan_s.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn every_request_completes_with_its_budget() {
        let t = TrafficSpec::poisson(50.0, 300, 8, 1, 16).with_seed(9);
        let mut st = StaticBatch::new(0.02);
        let mut co = ContinuousBatch;
        let policies: [&mut dyn Policy; 2] = [&mut st, &mut co];
        for policy in policies {
            let rep = simulate_trace(&cfg(8), policy, &t, &SloSpec::unconstrained());
            assert_eq!(rep.completed, 300, "{}", rep.policy);
            let trace = open_loop_trace(&t);
            for (r, a) in rep.per_request.iter().zip(&trace) {
                assert_eq!(r.id, a.id);
                assert_eq!(r.tokens, a.new_tokens);
                assert!(r.first_token_s >= a.at_s);
                assert!(r.finish_s >= r.first_token_s);
            }
        }
    }

    #[test]
    fn closed_loop_generates_exactly_the_request_budget() {
        let t = TrafficSpec::closed_loop(4, 0.005, 40, 8, 4, 8).with_seed(3);
        let rep = simulate_trace(&cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 40);
        // at most `clients` requests are ever in flight
        assert!(rep.peak_live <= 4, "peak={}", rep.peak_live);
    }

    #[test]
    fn kv_budget_caps_concurrency() {
        let mut c = cfg(8);
        c.kv = KvBudget::seqs(3);
        let t = TrafficSpec::poisson(1000.0, 60, 8, 8, 8);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 60);
        assert!(rep.peak_live <= 3, "peak={}", rep.peak_live);
    }

    #[test]
    fn static_batching_runs_batch_synchronous() {
        // 8 simultaneous arrivals, 4 slots: two sequential full batches.
        let t = TrafficSpec::poisson(1e9, 8, 10, 5, 5);
        let rep = simulate_trace(&cfg(4), &mut StaticBatch::new(0.001), &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 8);
        // batch 2 must start after batch 1 fully drains
        let b1_finish = rep.per_request[..4].iter().map(|r| r.finish_s).fold(0.0, f64::max);
        let b2_first = rep.per_request[4..].iter().map(|r| r.first_token_s).fold(f64::MAX, f64::min);
        assert!(b2_first >= b1_finish - 1e-12);
        assert!((rep.occupancy - 1.0).abs() < 1e-9);
    }
}
