//! Discrete-event serving simulator: synthetic arrival traces driven
//! through a [`crate::sched::Policy`] with the analytic per-iteration
//! latencies of [`crate::perf::simulator`].
//!
//! The steady-state simulator answers "what throughput does a saturated
//! lockstep batch sustain"; this module answers the paper's *serving*
//! question — what TTFT/TPOT tails, batch occupancy and goodput does a
//! design deliver under real traffic, where requests queue, batches run
//! partially full, and slots free at different times. Virtual time only:
//! every iteration's duration comes from the analytic model
//! ([`IterCost`]), so runs are deterministic, seeded, and fast enough to
//! validate sweep candidates ([`crate::evaluate::SweepEngine::best_point_slo`]).
//!
//! Iteration model (matching the AOT runtime's shape): an *admission*
//! iteration starts the newcomers' prompt processing alongside the
//! incumbents' decode step; a *decode* iteration advances every decoding
//! slot by one token in lockstep at the pipeline's token period,
//! regardless of occupancy (static shapes: padded slots are computed
//! anyway, which is exactly why occupancy is worth measuring).
//!
//! Two refinements over the seed model, both off by default so the legacy
//! golden traces replay bit-identically:
//!
//! * **Chunked prefill** ([`IterCost::prefill_chunk`] > 0): a newcomer's
//!   prompt is processed at most `prefill_chunk` tokens per iteration
//!   instead of stalling the whole batch for the full prompt, so resident
//!   decoders' inter-token gap during admissions is bounded by one chunk —
//!   the Sarathi/DeepSpeed-FastGen schedule at the cost model's
//!   granularity. The first token (and TTFT) lands when the last chunk
//!   completes.
//! * **Paged KV accounting** ([`SimConfig::paged_kv`]): admission charges
//!   each request's *actual* maximum footprint (prompt + token budget,
//!   block-granular) against a [`KvLedger`] and residency grows per token,
//!   instead of reserving `w.ctx` full-context KV per slot. Requests whose
//!   footprint exceeds the total capacity can never be admitted and are
//!   reported as incomplete rather than silently dropped.
//!
//! [`simulate_replicated`] runs N independent replicas of the same design
//! behind a [`RoutePolicy`] (round-robin, join-shortest-queue, or
//! token-weighted join-shortest-queue) so the simulator can answer
//! fleet-level questions, not just single-server ones. The `*_on` variants
//! ([`simulate_trace_on`], [`simulate_replicated_on`]) accept a
//! pre-materialized [`open_loop_trace`] so callers validating many designs
//! against the same traffic share one trace instead of re-drawing it.
//!
//! ## Simulator throughput: decode fast-forward and early abort
//!
//! The SLO-constrained sweep ([`crate::evaluate::SweepEngine::best_point_slo`])
//! runs one full trace per stage-2 candidate, so simulator wall-clock
//! bounds how much of the design space can actually be validated. Two
//! accelerations, neither of which changes any report a caller keeps:
//!
//! * **Decode fast-forward** (default): between scheduling events — the
//!   next arrival, the next slot completion, the horizon — a decode-only
//!   batch is *uniform*: every iteration decodes the same slots at the
//!   same cost and the policy's decision cannot change
//!   ([`crate::sched::Policy::decode_stable`]). The simulator advances
//!   those stretches in bulk: the clock and busy-time accumulators replay
//!   the reference path's exact per-iteration additions (three float adds
//!   per skipped iteration, so the result is **bit-identical** — a closed
//!   form `now + k·step` would round differently), while slot token
//!   counts and the paged residency ledger jump in O(live slots) per
//!   stretch instead of per iteration. All per-iteration policy calls,
//!   queue scans and slot walks disappear. [`SimConfig::reference_step`]
//!   forces the step-by-step reference path, which the property tests
//!   hold the fast path bit-identical against.
//! * **Early abort** ([`SimConfig::early_abort`], off by default): the
//!   simulator counts completed requests whose TTFT/TPOT exceed the SLO
//!   targets; once the count reaches the quantile violation budget
//!   ([`crate::util::stats::quantile_violation_budget`] at the offered
//!   request count) the final p99 provably exceeds the target no matter
//!   how the rest of the trace fares, and the run stops with
//!   [`ServeReport::aborted_early`] set (a paged-KV rejection aborts
//!   immediately — the completed-all requirement of [`ServeReport::meets`]
//!   is already unmeetable). A run that *passes* its SLO never crosses the
//!   budget, so a passing report is bit-identical with or without the
//!   flag; only provably-failing runs return early. With a constrained
//!   TTFT target the abort also counts requests *still queued* that have
//!   already waited past the target — their first token cannot precede
//!   the current clock, so they are provable violators before they finish
//!   (see [`SimConfig::early_abort`]).
//!
//! ## Million-request scale: quantized time, streaming, sketched tails
//!
//! Three further mechanisms let [`simulate_replicated`] hold 10M-request
//! traces on 8 replicas in seconds of wall clock and O(1) memory:
//!
//! * **Quantized-time decode stretches** ([`SimConfig::quantum`] > 0):
//!   fast-forward replays the reference clock's per-iteration float adds
//!   (bit-identical, but O(iterations) inside a stretch); quantized mode
//!   computes the iteration count `k` to the next event in closed form
//!   and advances the clock by one fused `k·step` multiply — O(1) per
//!   stretch regardless of its length, with at most `quantum` seconds of
//!   virtual time per jump. **Epsilon contract**: the closed form lands
//!   every scheduling event within one iteration of the reference
//!   schedule (float rounding of `k·step` versus `k` repeated adds can
//!   shift an event boundary by ±1 iteration), so per-request TTFT and
//!   end-to-end latencies differ from the reference path by at most one
//!   decode step plus O(k·ulp) float reconstruction error, and TPOT by at
//!   most two steps divided by the token count; aggregate p50/p99 tails
//!   inherit the same bound. The property suite asserts
//!   `|quantized − reference| ≤ 2·decode_step + 1e-6·|reference|` on
//!   TTFT/TPOT/total tails across the corpus. Default 0.0 keeps the
//!   bit-identical fast-forward, so existing goldens do not move.
//! * **Streaming ingestion** ([`simulate_trace_stream`],
//!   [`simulate_replicated_stream`]): the simulator pulls arrivals from
//!   any `(at_s, id)`-ordered iterator — the synthetic generators behind
//!   [`open_loop_iter`] or a [`crate::perf::trace::TraceFile`] replay —
//!   merged lazily with the event loop, so a trace is never materialized.
//!   The slice-based `*_on` entry points delegate to the stream versions
//!   (byte-identical by construction).
//! * **Sketched tails** ([`SimConfig::tail_cap`]): past the cap, finished
//!   requests fold into mergeable [`crate::util::stats::QuantileSketch`]es
//!   (relative error ≤ 1%) instead of accumulating `per_request` records;
//!   replica sketches merge exactly into fleet tails without
//!   concatenating sample vectors, keeping memory O(1) in requests.
//!   `per_request` is empty in a sketched report.
//!
//! ## Failure-aware fleets
//!
//! At cloud scale failures are the steady state, so the replicated
//! simulator can run under a [`FaultSpec`]: each replica carries a fault
//! clock — either a seeded MTBF/MTTR alternating-renewal process
//! (exponential up/down dwells drawn from a per-replica stream of
//! `faults.seed`) or, when the spec ships a scripted plan
//! (`fail:<replica>@<t>` / `recover:<replica>@<t>`), exactly that
//! schedule (a non-empty plan overrides the stochastic process). On a
//! failure the replica *crashes*: every resident request loses its KV
//! state, every queued request its place, and all of them are
//! re-dispatched to the surviving fleet for a recompute-from-scratch
//! retry (the original arrival stamp is kept, so the detour shows up in
//! the request's TTFT). Each request gets
//! [`FaultSpec::max_redispatch`] retries; past the budget — or stranded
//! with the whole fleet down at the end of a scripted plan — it counts
//! as [`ServeReport::lost`]. Routing is health-aware: down replicas are
//! excluded, JSQ variants rank only live replicas, round-robin skips
//! ahead to the next live index, and the deterministic `(time, id)`
//! order with lowest-index tie-breaks is preserved — faulted runs replay
//! bit-identically for a fixed spec. Failures take effect at iteration
//! boundaries (an iteration straddling the fault instant completes
//! first), and early abort is disabled under faults: re-dispatched
//! arrivals carry old timestamps, which breaks the sorted-queue proof
//! the in-flight TTFT bound rests on, so faulted runs are always
//! simulated in full. Conservation holds on every faulted run:
//! `completed + rejected + lost == offered`. `FaultSpec::none` delegates
//! to the fault-free entry points and is **byte-identical** to them by
//! construction.
//!
//! ## Overcommit, priority tiers and windowed goodput
//!
//! Three serving-side mechanisms, all off by default and byte-identical
//! to the legacy paths when disabled:
//!
//! * **Overcommit admission** ([`SimConfig::overcommit`], requires
//!   `paged_kv`): instead of reserving every request's *maximum* KV
//!   footprint up front, admission charges only the **expected** residency
//!   — prompt plus a configurable quantile of the token-budget
//!   distribution, or the observed running mean of released requests
//!   ([`crate::config::OvercommitSpec`]) — against an
//!   [`OvercommitLedger`] that allocates blocks lazily as tokens are
//!   generated (the vLLM discipline). When a decode step needs a block
//!   and none is free, the engine **preempts** the lowest-priority,
//!   most-recently-admitted resident sequence: its blocks are freed, its
//!   request re-queues at the head with its original arrival stamp and
//!   full token budget, and it recomputes from scratch on re-admission
//!   (the same recompute penalty a crash pays). Preemptions are counted
//!   in [`ServeReport::preempted`] and conserve requests — nothing is
//!   ever dropped by a preemption, so
//!   `completed + rejected + lost == offered` still holds.
//!   [`Replica::reject_unservable`] keeps rejecting requests whose *max*
//!   footprint exceeds the whole capacity, which guarantees a lone
//!   resident sequence always fits — so a preemption victim provably
//!   exists whenever an append fails, and thrash is bounded.
//! * **Priority tiers** ([`crate::config::TierSpec`] on the traffic
//!   spec): arrivals carry a tier tag (0 = interactive, 1 = batch) drawn
//!   from the spec's interactive share, with per-tier token-budget
//!   ranges. Admission consults a [`TierSelector`] — interactive first,
//!   with a bounded batch-starvation fairness knob — and the report
//!   grows per-tier tails ([`ServeReport::tiers`]); each request's SLO
//!   verdict uses its own tier's targets. Preemption victims are chosen
//!   batch-first, so the interactive tier's tail is what overcommit
//!   protects.
//! * **Windowed goodput** ([`SimConfig::window_s`] > 0): completions
//!   fold into fixed-width virtual-time buckets
//!   ([`ServeReport::windows`]) — completed/token/good-token counts per
//!   window, merged across replicas by bucket — giving a throughput
//!   time-series without per-request records even in sketched mode.
//!
//! Early abort is disabled whenever overcommit or tiers are active:
//! preemption re-queues requests out of arrival order, which breaks the
//! sorted-queue proof behind the in-flight TTFT bound.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::config::workload::{
    ArrivalProcess, FaultEvent, FaultSpec, OvercommitSpec, ResidencyEstimate, SloSpec, TokenDist,
    TrafficSpec,
};
use crate::config::Workload;
use crate::perf::DecodePerf;
use crate::sched::{
    sanitize, Action, KvBudget, KvLedger, OvercommitLedger, Policy, RoutePolicy, SchedView,
    TierSelector,
};
use crate::util::rng::Rng;
use crate::util::stats;

/// One request arrival in a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Request id (ascending with arrival order).
    pub id: u64,
    /// Arrival time, seconds since trace start.
    pub at_s: f64,
    /// Prompt tokens to prefill.
    pub prompt_tokens: usize,
    /// Tokens to generate (>= 1; the first comes from the prefill).
    pub new_tokens: usize,
    /// Priority tier (0 = interactive, 1 = batch). Always 0 when the
    /// traffic spec carries no [`crate::config::TierSpec`].
    pub tier: u8,
}

/// Generate the open-loop arrival list for a traffic spec, in `(at_s, id)`
/// order — the id tie-break makes bursty traces (which emit equal
/// timestamps by construction) a *total* order, so every consumer replays
/// them identically regardless of float comparison quirks. Closed-loop
/// specs return an empty list — their arrivals are produced *during* the
/// simulation (each completion schedules the client's next request).
pub fn open_loop_trace(t: &TrafficSpec) -> Vec<Arrival> {
    let mut out: Vec<Arrival> = open_loop_iter(t).collect();
    // Generation is already time-ordered (the clock only advances), but the
    // tie-break by id is the contract consumers rely on — make it explicit.
    out.sort_by(|a, b| {
        crate::util::stats::total_cmp_f64(&a.at_s, &b.at_s).then(a.id.cmp(&b.id))
    });
    out
}

/// Lazily generate the open-loop arrivals of a traffic spec, yielding the
/// *same draws in the same `(at_s, id)` order* as [`open_loop_trace`]
/// materializes — the generators only ever move the clock forward, so
/// generation order already is the sorted order (a property test holds
/// the two bit-identical). This is the synthetic-traffic producer behind
/// the streaming entry points ([`simulate_trace_stream`],
/// [`simulate_replicated_stream`]); trace files provide the other
/// producer ([`crate::perf::trace::TraceFile::arrivals`]) behind the same
/// iterator interface. Closed-loop specs yield nothing, as with
/// [`open_loop_trace`].
pub fn open_loop_iter(t: &TrafficSpec) -> OpenLoopIter {
    OpenLoopIter { traffic: *t, rng: Rng::new(t.seed), now: 0.0, next_id: 0, burst_left: 0 }
}

/// Iterator state of [`open_loop_iter`].
pub struct OpenLoopIter {
    traffic: TrafficSpec,
    rng: Rng,
    now: f64,
    next_id: u64,
    burst_left: usize,
}

impl Iterator for OpenLoopIter {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        if self.next_id as usize >= self.traffic.requests {
            return None;
        }
        match self.traffic.arrival {
            ArrivalProcess::Poisson { rps } => {
                self.now += self.rng.exponential(rps.max(1e-12));
            }
            ArrivalProcess::Bursty { rps, burst } => {
                // Exponential gaps between bursts with mean burst/rps keep
                // the long-run rate at `rps` while arrivals clump; draws
                // interleave exactly as the materializing loop's did (one
                // gap draw, then one size draw per burst member).
                let burst = burst.max(1);
                if self.burst_left == 0 {
                    self.now += self.rng.exponential((rps / burst as f64).max(1e-12));
                    self.burst_left = burst;
                }
                self.burst_left -= 1;
            }
            ArrivalProcess::ClosedLoop { .. } => return None,
        }
        let traffic = self.traffic;
        let a = arrival(&mut self.rng, &traffic, self.next_id, self.now);
        self.next_id += 1;
        Some(a)
    }
}

/// One base-distribution token-budget draw. The Uniform arm keeps the
/// historical `rng.range` path (one `next_u64` per draw) so legacy
/// streams replay byte-identically; Pareto inverts one unit draw.
fn draw_new_tokens(rng: &mut Rng, t: &TrafficSpec, lo: usize, hi: usize) -> usize {
    match t.new_tokens_dist {
        TokenDist::Uniform => rng.range(lo, hi),
        dist @ TokenDist::Pareto { .. } => dist.sample_unit(rng.f64(), lo, hi),
    }
}

fn arrival(rng: &mut Rng, t: &TrafficSpec, id: u64, at_s: f64) -> Arrival {
    let (lo, hi) = (t.new_tokens_lo.max(1), t.new_tokens_hi.max(t.new_tokens_lo).max(1));
    let (tier, new_tokens) = match t.tiers {
        Some(ts) => {
            // The tier coin flips first, then the tier's own budget draw
            // — tiered streams need not match untiered ones (only the
            // tiers-off path carries a byte-identity contract).
            if rng.chance(ts.interactive_share) {
                let ilo = ts.interactive_new_tokens_lo.max(1);
                let ihi = ts.interactive_new_tokens_hi.max(ilo);
                (0u8, rng.range(ilo, ihi))
            } else {
                (1u8, draw_new_tokens(rng, t, lo, hi))
            }
        }
        None => (0u8, draw_new_tokens(rng, t, lo, hi)),
    };
    Arrival { id, at_s, prompt_tokens: t.prompt_tokens, new_tokens, tier }
}

/// Analytic per-iteration costs driving the simulator's virtual clock.
#[derive(Clone, Copy, Debug)]
pub struct IterCost {
    /// Prefill seconds per *prompt token* of one admitted sequence.
    pub prefill_s_per_token: f64,
    /// One lockstep decode iteration over the batch, s (the pipeline's
    /// token period).
    pub decode_step_s: f64,
    /// Max prompt tokens prefilled per prefilling slot per iteration;
    /// 0 = the whole prompt in its admission iteration (the seed's
    /// stall-the-batch model).
    pub prefill_chunk: usize,
}

impl IterCost {
    /// Derive the costs from a steady-state simulation of the workload:
    /// decode iterations run at the pipeline token period; prefill charges
    /// each sequence its per-token share of the whole-batch prefill.
    ///
    /// Degenerate inputs must not *silently* poison the model: a
    /// zero-token prompt (`w.ctx == 0` makes `prompt_len` 0) is clamped
    /// out of the divisor, and a NaN or negative upstream latency — which
    /// would otherwise flow NaN into every TTFT percentile, where all
    /// comparisons are false and a broken design can slip through — is
    /// pinned to `INFINITY` instead. Infinite cost fails every SLO
    /// comparison *conservatively*: the event sim terminates immediately
    /// (any horizon is reached) with requests incomplete, so
    /// [`ServeReport::meets`] rejects the design rather than crowning it.
    pub fn from_perf(perf: &DecodePerf, w: &Workload) -> IterCost {
        let prompt_tokens = (w.batch.max(1) as f64) * (w.prompt_len.max(1) as f64);
        let sane = |v: f64| if v.is_nan() || v < 0.0 { f64::INFINITY } else { v };
        IterCost {
            prefill_s_per_token: sane(perf.prefill_latency / prompt_tokens),
            decode_step_s: sane(perf.token_period),
            prefill_chunk: 0,
        }
    }

    /// Same costs with chunked prefill at `chunk` tokens per iteration
    /// (0 restores whole-prompt admission).
    pub fn with_chunk(mut self, chunk: usize) -> IterCost {
        self.prefill_chunk = chunk;
        self
    }
}

/// Simulator configuration: engine shape, KV budget, iteration costs and
/// the KV accounting model.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Compiled batch slots.
    pub max_slots: usize,
    /// KV-capacity admission budget.
    pub kv: KvBudget,
    /// Iteration cost model.
    pub cost: IterCost,
    /// Per-slot paged accounting (block-granular [`KvLedger`] over
    /// `kv.capacity_tokens`) instead of the legacy full-context-per-slot
    /// reservation (`kv.max_seqs`).
    pub paged_kv: bool,
    /// Step every iteration through the full per-iteration path instead of
    /// fast-forwarding uniform decode stretches. The reference behaviour
    /// for regression tests and the fast-forward benchmarks; results are
    /// bit-identical either way (asserted by the property suite), so
    /// leave this off outside of testing.
    pub reference_step: bool,
    /// Stop simulating as soon as the SLO outcome is provably negative
    /// (see the module docs). The returned report carries
    /// [`ServeReport::aborted_early`] and fails [`ServeReport::meets`];
    /// its tails describe a *partial* run, so enable this only where the
    /// report is consumed as a feasibility verdict (stage-2 sweep
    /// validation), not where it is shown to a reader. With a finite TTFT
    /// target the proof also counts requests still queued that have
    /// already out-waited the target (their eventual TTFT is provably
    /// over), so overloaded runs abort long before requests complete.
    pub early_abort: bool,
    /// Quantized-time decode stretches: when `> 0`, uniform decode
    /// stretches advance as an integer iteration count times the decode
    /// step in O(1) — one fused multiply instead of replaying per-
    /// iteration float adds — jumping at most `quantum` seconds of
    /// virtual time at a time. Reports are reconstructed at stretch
    /// boundaries within the documented epsilon of the bit-exact
    /// reference path (module docs, "Million-request scale"). `0.0`
    /// (default) keeps the bit-identical fast-forward. Use a large
    /// finite value (e.g. `1e9`) for maximum speed.
    pub quantum: f64,
    /// Completed-sample cap above which tails are tracked in mergeable
    /// quantile sketches (relative error ≤ 1%) instead of per-request
    /// records: runs offering more than `tail_cap` requests keep memory
    /// O(1) and return an empty [`ServeReport::per_request`]. Runs at or
    /// under the cap are unaffected (exact, bit-identical tails).
    pub tail_cap: usize,
    /// Expected-residency overcommit admission with exhaustion-driven
    /// preemption (module docs, "Overcommit, priority tiers and windowed
    /// goodput"). Requires `paged_kv`; `None` (default) keeps the
    /// reserve-the-maximum ledger byte-identically.
    pub overcommit: Option<OvercommitSpec>,
    /// Goodput window width, seconds of virtual time: when `> 0`,
    /// completions fold into fixed-width buckets reported as
    /// [`ServeReport::windows`]. `0.0` (default) disables windowed rows.
    pub window_s: f64,
}

/// Default [`SimConfig::tail_cap`]: exact per-request tails up to ~1M
/// completions, sketched beyond.
pub const DEFAULT_TAIL_CAP: usize = 1 << 20;

impl SimConfig {
    /// Config with the default execution knobs: fast-forward on
    /// (`reference_step: false`), early abort off, quantized time off,
    /// exact tails up to [`DEFAULT_TAIL_CAP`] samples.
    pub fn new(max_slots: usize, kv: KvBudget, cost: IterCost, paged_kv: bool) -> SimConfig {
        SimConfig {
            max_slots,
            kv,
            cost,
            paged_kv,
            reference_step: false,
            early_abort: false,
            quantum: 0.0,
            tail_cap: DEFAULT_TAIL_CAP,
            overcommit: None,
            window_s: 0.0,
        }
    }
}

/// Per-request outcome record.
#[derive(Clone, Copy, Debug)]
pub struct ReqStats {
    /// Request id.
    pub id: u64,
    /// Arrival time, s.
    pub arrival_s: f64,
    /// First-token completion time, s.
    pub first_token_s: f64,
    /// Final-token completion time, s.
    pub finish_s: f64,
    /// Tokens generated.
    pub tokens: usize,
    /// Priority tier the request arrived with (0 when tiers are off).
    pub tier: u8,
}

impl ReqStats {
    /// Time to first token.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// Time per output token after the first (0 for single-token requests).
    pub fn tpot_s(&self) -> f64 {
        if self.tokens > 1 {
            (self.finish_s - self.first_token_s) / (self.tokens - 1) as f64
        } else {
            0.0
        }
    }

    /// End-to-end latency.
    pub fn total_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Did this request meet both per-request latency targets?
    pub fn meets(&self, slo: &SloSpec) -> bool {
        self.ttft_s() <= slo.ttft_p99_s && self.tpot_s() <= slo.tpot_p99_s
    }
}

/// Aggregate report of one simulated trace.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Policy that produced the schedule.
    pub policy: String,
    /// Serving replicas simulated (1 for [`simulate_trace`]).
    pub replicas: usize,
    /// Requests the trace offered.
    pub offered: usize,
    /// Requests completed.
    pub completed: usize,
    /// Tokens generated.
    pub tokens: usize,
    /// Virtual time from first arrival to last completion, s.
    pub makespan_s: f64,
    /// Tokens per second of wall (virtual) time.
    pub tokens_per_s: f64,
    /// Tokens per second counting only SLO-compliant requests.
    pub goodput_tokens_per_s: f64,
    /// Fraction of requests meeting the SLO.
    pub slo_met_frac: f64,
    /// TTFT p50, s.
    pub ttft_p50_s: f64,
    /// TTFT p99, s.
    pub ttft_p99_s: f64,
    /// TPOT p50, s.
    pub tpot_p50_s: f64,
    /// TPOT p99, s.
    pub tpot_p99_s: f64,
    /// End-to-end latency p50, s.
    pub total_p50_s: f64,
    /// End-to-end latency p99, s.
    pub total_p99_s: f64,
    /// Time-weighted decode-slot occupancy (1.0 = every iteration full).
    pub occupancy: f64,
    /// Engine iterations executed (summed across replicas).
    pub iterations: u64,
    /// Peak concurrently-live sequences on any one replica (must respect
    /// the KV budget).
    pub peak_live: usize,
    /// Peak resident KV tokens on any one replica's paged ledger (0 when
    /// `paged_kv` is off).
    pub peak_kv_tokens: usize,
    /// Requests rejected because their footprint exceeds the paged KV
    /// capacity outright (they count against `offered` but never
    /// complete, so [`ServeReport::meets`] stays conservative).
    pub rejected: usize,
    /// The run stopped before serving the whole trace because the SLO
    /// outcome was already provably negative ([`SimConfig::early_abort`]).
    /// Tails then describe the partial run; `meets` is necessarily false.
    pub aborted_early: bool,
    /// Re-dispatch events: a replica failure crashed a request off its
    /// queue or slots and the fleet retried it from scratch. One request
    /// can count several times (bounded per request by
    /// [`FaultSpec::max_redispatch`]). 0 on fault-free runs.
    pub redispatched: usize,
    /// Requests dropped after exhausting the re-dispatch budget, or
    /// stranded with the whole fleet down at the end of a scripted fault
    /// plan. Conservation on any faulted run:
    /// `completed + rejected + lost == offered`. 0 on fault-free runs.
    pub lost: usize,
    /// Fraction of fleet capacity lost to downtime: down replica-seconds
    /// over `replicas ×` the run's span. 0.0 on fault-free runs.
    pub downtime_frac: f64,
    /// Preemption events under overcommit admission: a resident sequence
    /// lost its KV blocks to an exhausted pool and re-queued for a
    /// recompute-from-scratch retry. One request can count several
    /// times. 0 when overcommit is off. Preemptions conserve requests:
    /// `completed + rejected + lost == offered` still holds.
    pub preempted: usize,
    /// Per-tier tails and goodput (tier 0 = interactive, tier 1 =
    /// batch), present only when the traffic spec carries tiers.
    pub tiers: Vec<TierReport>,
    /// Fixed-width virtual-time goodput buckets, present only when
    /// [`SimConfig::window_s`] > 0; merged across replicas by bucket.
    pub windows: Vec<WindowRow>,
    /// Per-request records, sorted by request id.
    pub per_request: Vec<ReqStats>,
}

/// One priority tier's slice of a [`ServeReport`].
#[derive(Clone, Copy, Debug)]
pub struct TierReport {
    /// Tier tag (0 = interactive, 1 = batch).
    pub tier: u8,
    /// Requests of this tier completed.
    pub completed: usize,
    /// Tokens generated for this tier.
    pub tokens: usize,
    /// Fraction of this tier's completions meeting *its own* SLO.
    pub slo_met_frac: f64,
    /// TTFT p50, s.
    pub ttft_p50_s: f64,
    /// TTFT p99, s.
    pub ttft_p99_s: f64,
    /// TPOT p50, s.
    pub tpot_p50_s: f64,
    /// TPOT p99, s.
    pub tpot_p99_s: f64,
    /// Tokens per second of SLO-compliant requests of this tier, over
    /// the run's makespan.
    pub goodput_tokens_per_s: f64,
    /// Preemption events whose victim belonged to this tier.
    pub preempted: usize,
}

/// One fixed-width goodput window of a [`ServeReport`] (completions
/// bucketed by finish time).
#[derive(Clone, Copy, Debug)]
pub struct WindowRow {
    /// Window start, seconds of virtual time (width = `window_s`).
    pub start_s: f64,
    /// Requests finished inside the window.
    pub completed: usize,
    /// Tokens those completions generated.
    pub tokens: usize,
    /// Tokens of the SLO-compliant subset.
    pub good_tokens: usize,
}

/// A [`ServeReport`] flattened to bit-exact integers: every aggregate
/// field (floats by `to_bits`) plus every per-request record. See
/// [`ServeReport::fingerprint`].
pub type ReportFingerprint = (Vec<u64>, Vec<(u64, [u64; 3], usize)>);

impl ServeReport {
    /// Does the simulated run meet the SLO? Requires every offered request
    /// to have completed — percentiles over a partial (or empty) set of
    /// completions would otherwise declare a run that served nothing
    /// SLO-compliant (e.g. a zero KV budget admits no one and produces
    /// all-zero tails).
    pub fn meets(&self, slo: &SloSpec) -> bool {
        self.completed == self.offered
            && self.ttft_p99_s <= slo.ttft_p99_s
            && self.tpot_p99_s <= slo.tpot_p99_s
    }

    /// The SLO verdict under faults: `meets`'s every-request completion
    /// requirement is unachievable once a replica can die mid-run, so the
    /// availability-constrained selection asks instead that the completed
    /// fraction reach `availability` (lost *and* rejected requests both
    /// count against it) while the latency tails still hold. With
    /// `availability >= 1.0` this is at least as strict as [`meets`]
    /// (`ServeReport::meets`); an aborted run never qualifies.
    pub fn meets_available(&self, slo: &SloSpec, availability: f64) -> bool {
        if self.offered == 0 || self.aborted_early {
            return false;
        }
        self.completed as f64 / self.offered as f64 >= availability
            && self.ttft_p99_s <= slo.ttft_p99_s
            && self.tpot_p99_s <= slo.tpot_p99_s
    }

    /// The SLO verdict for one priority tier: every offered request still
    /// has to complete (preempted requests recompute and finish, so
    /// overcommit does not relax this), but only the named tier's tails
    /// are held to the targets — the tiered design selection validates
    /// the interactive tier while batch absorbs the preemption penalty.
    /// Falls back to [`ServeReport::meets`] when the run carried no
    /// tiers.
    pub fn meets_tier(&self, tier: u8, slo: &SloSpec) -> bool {
        match self.tiers.iter().find(|t| t.tier == tier) {
            Some(t) => {
                self.completed == self.offered
                    && t.ttft_p99_s <= slo.ttft_p99_s
                    && t.tpot_p99_s <= slo.tpot_p99_s
            }
            None => self.meets(slo),
        }
    }

    /// [`ServeReport::meets_tier`] under faults: the completion term
    /// relaxes to the availability fraction (as in
    /// [`ServeReport::meets_available`]) while only the named tier's tails
    /// are held to the targets. Falls back to `meets_available` when the
    /// run carried no tiers.
    pub fn meets_tier_available(&self, tier: u8, slo: &SloSpec, availability: f64) -> bool {
        if self.offered == 0 || self.aborted_early {
            return false;
        }
        match self.tiers.iter().find(|t| t.tier == tier) {
            Some(t) => {
                self.completed as f64 / self.offered as f64 >= availability
                    && t.ttft_p99_s <= slo.ttft_p99_s
                    && t.tpot_p99_s <= slo.tpot_p99_s
            }
            None => self.meets_available(slo, availability),
        }
    }

    /// Everything a bit-identity assertion between two runs must compare,
    /// as exact integers: two reports fingerprint equal iff every count,
    /// every float (to the bit) and every per-request record match. The
    /// single shared definition the fast-forward/reference property tests
    /// and benches assert on — one place to extend when a field is added,
    /// so no suite's assertion can silently fall behind. The `policy`
    /// label is deliberately excluded (compared runs share it by
    /// construction).
    pub fn fingerprint(&self) -> ReportFingerprint {
        let mut agg = vec![
            self.replicas as u64,
            self.offered as u64,
            self.completed as u64,
            self.tokens as u64,
            self.makespan_s.to_bits(),
            self.tokens_per_s.to_bits(),
            self.goodput_tokens_per_s.to_bits(),
            self.slo_met_frac.to_bits(),
            self.ttft_p50_s.to_bits(),
            self.ttft_p99_s.to_bits(),
            self.tpot_p50_s.to_bits(),
            self.tpot_p99_s.to_bits(),
            self.total_p50_s.to_bits(),
            self.total_p99_s.to_bits(),
            self.occupancy.to_bits(),
            self.iterations,
            self.peak_live as u64,
            self.peak_kv_tokens as u64,
            self.rejected as u64,
            u64::from(self.aborted_early),
            self.redispatched as u64,
            self.lost as u64,
            self.downtime_frac.to_bits(),
            self.preempted as u64,
        ];
        for t in &self.tiers {
            agg.extend([
                t.tier as u64,
                t.completed as u64,
                t.tokens as u64,
                t.slo_met_frac.to_bits(),
                t.ttft_p50_s.to_bits(),
                t.ttft_p99_s.to_bits(),
                t.tpot_p50_s.to_bits(),
                t.tpot_p99_s.to_bits(),
                t.goodput_tokens_per_s.to_bits(),
                t.preempted as u64,
            ]);
        }
        for w in &self.windows {
            agg.extend([
                w.start_s.to_bits(),
                w.completed as u64,
                w.tokens as u64,
                w.good_tokens as u64,
            ]);
        }
        let per = self
            .per_request
            .iter()
            .map(|q| {
                let times =
                    [q.arrival_s.to_bits(), q.first_token_s.to_bits(), q.finish_s.to_bits()];
                (q.id, times, q.tokens)
            })
            .collect();
        (agg, per)
    }
}

/// A live slot: prefilling while `prefill_remaining > 0` (tokens == 0),
/// decoding afterwards.
#[derive(Clone, Copy, Debug)]
struct Slot {
    id: u64,
    arrival_s: f64,
    first_token_s: f64,
    /// Tokens generated so far (0 while prefilling).
    tokens: usize,
    /// Tokens still to generate.
    remaining: usize,
    /// Prompt tokens still to prefill.
    prefill_remaining: usize,
    /// The request's *original* prompt length — `prefill_remaining`
    /// shrinks as chunks land, but a crashed request recomputes the whole
    /// prompt from scratch on its next replica.
    prompt_tokens: usize,
    /// Priority tier the request arrived with (0 when tiers are off).
    tier: u8,
    /// Closed-loop client that owns the request, if any.
    client: Option<usize>,
}

/// Closed-loop arrival state: each client resubmits `think_s` after its
/// previous request completes, until the request budget is spent.
struct ClosedLoop {
    /// Per-client next-submit time; `INFINITY` while a request is in flight.
    ready: Vec<f64>,
    /// Per-client token-budget streams: each client draws its own request
    /// sizes, so the order in which *other* clients' requests complete
    /// cannot relabel which request gets which budget — the property the
    /// closed-loop quantized-time epsilon contract rests on (a
    /// one-iteration completion shift reorders resubmits, but every
    /// client's k-th request still draws the same size).
    rngs: Vec<Rng>,
    think_s: f64,
    budget: usize,
}

impl ClosedLoop {
    /// Earliest future client submit time, if any client has budget left.
    fn next_ready(&self) -> Option<f64> {
        if self.budget == 0 {
            return None;
        }
        self.ready.iter().copied().filter(|r| r.is_finite()).reduce(f64::min)
    }
}

/// The early-abort rule of one run: latency targets plus the violation
/// budget at the offered request count (see
/// [`crate::util::stats::quantile_violation_budget`] for why the budget at
/// the *offered* count is sound for every possible completion count).
#[derive(Clone, Copy, Debug)]
struct AbortRule {
    /// p99 TTFT target, s.
    ttft_s: f64,
    /// p99 TPOT target, s.
    tpot_s: f64,
    /// Violators of either target that prove the final p99 over it.
    budget: usize,
}

impl AbortRule {
    /// The rule for a run, if early abort is on and a target binds.
    fn new(cfg: &SimConfig, offered: usize, slo: &SloSpec) -> Option<AbortRule> {
        if !cfg.early_abort || slo.is_unconstrained() {
            return None;
        }
        Some(AbortRule {
            ttft_s: slo.ttft_p99_s,
            tpot_s: slo.tpot_p99_s,
            budget: stats::quantile_violation_budget(offered, 99.0).max(1),
        })
    }
}

/// Bounded-memory tail accounting of one replica (or, merged, one
/// fleet): three mergeable sketches plus the scalar aggregates that the
/// exact path would have derived from `done`. Engaged when a run offers
/// more than [`SimConfig::tail_cap`] requests.
struct TailTally {
    ttft: stats::QuantileSketch,
    tpot: stats::QuantileSketch,
    total: stats::QuantileSketch,
    completed: usize,
    tokens: usize,
    good_tokens: usize,
    met: usize,
}

impl TailTally {
    fn new() -> TailTally {
        TailTally {
            ttft: stats::QuantileSketch::default_accuracy(),
            tpot: stats::QuantileSketch::default_accuracy(),
            total: stats::QuantileSketch::default_accuracy(),
            completed: 0,
            tokens: 0,
            good_tokens: 0,
            met: 0,
        }
    }

    /// Fold one finished request in — the online mirror of what the exact
    /// aggregate computes from `done` after the run.
    fn record(&mut self, r: &ReqStats, slo: &SloSpec) {
        self.completed += 1;
        self.tokens += r.tokens;
        if r.meets(slo) {
            self.met += 1;
            self.good_tokens += r.tokens;
        }
        self.ttft.record(r.ttft_s());
        if r.tokens > 1 {
            // The exact path excludes single-token requests from the TPOT
            // vector (their TPOT is identically 0); mirror that.
            self.tpot.record(r.tpot_s());
        }
        self.total.record(r.total_s());
    }

    fn merge(&mut self, other: &TailTally) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.total.merge(&other.total);
        self.completed += other.completed;
        self.tokens += other.tokens;
        self.good_tokens += other.good_tokens;
        self.met += other.met;
    }
}

/// One goodput window's running counters (see [`WindowRow`]).
#[derive(Clone, Copy, Debug, Default)]
struct WindowAcc {
    completed: usize,
    tokens: usize,
    good_tokens: usize,
}

/// One engine replica's full simulation state: queue, slots, paged ledger
/// and virtual clock. [`simulate_trace`] drives a single replica to
/// completion; [`simulate_replicated`] interleaves several in global time
/// order so arrivals can be routed on the fleet state at their instant.
/// The lifetime is the arrival source's: replicas stream their own
/// arrivals through a one-item lookahead (`pending`) instead of owning a
/// materialized queue.
struct Replica<'a> {
    cfg: SimConfig,
    /// Slot-count concurrency cap presented to the policy.
    kv_slots: usize,
    ledger: Option<KvLedger>,
    /// Lazy (time, id)-ordered arrival source owned by this replica
    /// (empty for externally-routed replicas).
    source: Box<dyn Iterator<Item = Arrival> + 'a>,
    /// One-item lookahead over `source` — the head the reference
    /// `pending.front()` peeks gave, without the materialized deque.
    pending: Option<Arrival>,
    /// Closed-loop synthesis state (None for open-loop replicas).
    closed: Option<ClosedLoop>,
    traffic: TrafficSpec,
    /// Next closed-loop request id (offset per replica so merged reports
    /// keep unique ids).
    next_id: u64,
    queue: VecDeque<(Arrival, Option<usize>)>,
    slots: Vec<Option<Slot>>,
    /// Free slot indices as a min-heap, so admission fills the lowest free
    /// index in O(log slots) — the same order the reference
    /// `position(is_none)` scan picked, which per-iteration ledger
    /// interleaving (and thus `peak_kv_tokens`) depends on.
    free_list: BinaryHeap<Reverse<usize>>,
    /// Occupied slots, maintained incrementally (the per-iteration
    /// `filter(is_some).count()` scan this replaces was O(slots) on the
    /// hottest path).
    live_count: usize,
    /// Live slots still mid-prefill; decode fast-forward requires 0.
    prefilling: usize,
    /// Early-abort rule, when validation wants provably-failing runs cut.
    abort: Option<AbortRule>,
    /// Completed requests whose TTFT exceeded the abort rule's target.
    ttft_violations: usize,
    /// Completed multi-token requests whose TPOT exceeded the target.
    tpot_violations: usize,
    /// Set once the run is provably SLO-infeasible; the drive loop exits.
    aborted: bool,
    /// The run's SLO, for online goodput accounting in sketched mode.
    slo: SloSpec,
    /// Bounded-memory tail accounting, engaged when the run offers more
    /// than [`SimConfig::tail_cap`] requests; `done` stays empty then.
    tally: Option<TailTally>,
    /// Per-tier sketched tails, engaged only when sketched *and* tiered
    /// (index = tier tag; the overall `tally` keeps recording too).
    tier_tallies: Option<Vec<TailTally>>,
    /// Expected-residency ledger; Some when overcommit is on (it then
    /// replaces the reservation `ledger`).
    oc: Option<OvercommitLedger>,
    /// Tier-ordered admission state; Some when the traffic carries tiers.
    selector: Option<TierSelector>,
    /// Preemption events on this replica.
    preempted: usize,
    /// Preemption events by victim tier (index = tier tag, capped at 1).
    preempted_by_tier: [usize; 2],
    /// Windowed goodput buckets (bucket index -> accumulators), engaged
    /// when `cfg.window_s > 0`.
    windows: BTreeMap<u64, WindowAcc>,
    done: Vec<ReqStats>,
    now: f64,
    first_arrival: Option<f64>,
    last_finish: f64,
    busy_slot_time: f64,
    busy_time: f64,
    iterations: u64,
    peak_live: usize,
    peak_kv_tokens: usize,
    rejected: usize,
}

impl<'a> Replica<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        cfg: &SimConfig,
        traffic: &TrafficSpec,
        mut source: Box<dyn Iterator<Item = Arrival> + 'a>,
        closed: Option<ClosedLoop>,
        id_base: u64,
        abort: Option<AbortRule>,
        slo: &SloSpec,
        sketched: bool,
    ) -> Replica<'a> {
        let pending = source.next();
        // Overcommit replaces the reserve-the-maximum ledger with the
        // lazily-allocating one (validate() requires paged_kv with it).
        let oc_on = cfg.overcommit.is_some() && cfg.paged_kv;
        Replica {
            cfg: *cfg,
            kv_slots: if cfg.paged_kv {
                cfg.max_slots
            } else {
                cfg.kv.concurrency(cfg.max_slots)
            },
            ledger: (cfg.paged_kv && !oc_on).then(|| cfg.kv.ledger()),
            oc: oc_on
                .then(|| OvercommitLedger::new(cfg.kv.capacity_tokens, cfg.kv.block_tokens)),
            selector: traffic.tiers.map(|t| TierSelector::new(t.max_consecutive_interactive)),
            preempted: 0,
            preempted_by_tier: [0, 0],
            windows: BTreeMap::new(),
            source,
            pending,
            closed,
            traffic: *traffic,
            next_id: id_base,
            queue: VecDeque::new(),
            slots: vec![None; cfg.max_slots],
            free_list: (0..cfg.max_slots).map(Reverse).collect(),
            live_count: 0,
            prefilling: 0,
            abort,
            ttft_violations: 0,
            tpot_violations: 0,
            aborted: false,
            slo: *slo,
            tally: sketched.then(TailTally::new),
            tier_tallies: (sketched && traffic.tiers.is_some())
                .then(|| vec![TailTally::new(), TailTally::new()]),
            done: Vec::new(),
            now: 0.0,
            first_arrival: None,
            last_finish: 0.0,
            busy_slot_time: 0.0,
            busy_time: 0.0,
            iterations: 0,
            peak_live: 0,
            peak_kv_tokens: 0,
            rejected: 0,
        }
    }

    /// Externally-routed arrival (the replicated simulator's path).
    fn enqueue(&mut self, a: Arrival) {
        self.first_arrival.get_or_insert(a.at_s);
        self.queue.push_back((a, None));
    }

    fn occupied(&self) -> usize {
        self.live_count
    }

    /// Queued + resident requests — the join-shortest-queue load signal.
    fn outstanding(&self) -> usize {
        self.queue.len() + self.occupied()
    }

    /// Outstanding token *work*: prompt + generation tokens still to be
    /// processed across the queue and the live slots — the token-weighted
    /// [`RoutePolicy::JsqTokens`] load signal. Under heavy-tailed token
    /// budgets a queue-length count treats a 4-token request and a
    /// 1000-token request as equal load; summed remaining work does not.
    fn outstanding_tokens(&self) -> usize {
        let queued: usize =
            self.queue.iter().map(|(a, _)| a.prompt_tokens + a.new_tokens).sum();
        let live: usize =
            self.slots.iter().flatten().map(|s| s.prefill_remaining + s.remaining).sum();
        queued + live
    }

    /// Move every self-generated arrival with `at_s <= now` into the queue,
    /// pulling lazily from the source through the one-item lookahead.
    fn materialize(&mut self) {
        while let Some(a) = self.pending {
            if a.at_s > self.now {
                break;
            }
            self.pending = self.source.next();
            self.first_arrival.get_or_insert(a.at_s);
            self.queue.push_back((a, None));
        }
        if let Some(cl) = self.closed.as_mut() {
            for c in 0..cl.ready.len() {
                if cl.budget == 0 {
                    break;
                }
                let r = cl.ready[c];
                if r.is_finite() && r <= self.now {
                    let a = arrival(&mut cl.rngs[c], &self.traffic, self.next_id, r);
                    self.next_id += 1;
                    cl.budget -= 1;
                    cl.ready[c] = f64::INFINITY; // in flight until completion
                    self.first_arrival.get_or_insert(a.at_s);
                    self.queue.push_back((a, Some(c)));
                }
            }
        }
    }

    /// Next future self-generated arrival instant, if any.
    fn next_internal_arrival(&self) -> Option<f64> {
        let open = self.pending.map(|a| a.at_s);
        let cl = self.closed.as_ref().and_then(ClosedLoop::next_ready);
        match (open, cl) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// The KV tokens one request is charged at admission. Reservation
    /// mode charges the maximum footprint; overcommit charges prompt +
    /// the expected generation length — a distribution quantile, or the
    /// observed running mean of released requests (max footprint until
    /// the first release seeds the mean) — clamped to the request's own
    /// budget so no one is charged more than it could ever hold.
    fn expected_charge(&self, a: &Arrival) -> usize {
        let Some(spec) = self.cfg.overcommit else {
            return a.prompt_tokens + a.new_tokens;
        };
        let expect = match spec.estimate {
            ResidencyEstimate::Quantile(q) => self.traffic.quantile_new_tokens(a.tier, q),
            ResidencyEstimate::RunningMean => {
                match self.oc.as_ref().and_then(OvercommitLedger::observed_mean) {
                    Some(m) => m,
                    None => return a.prompt_tokens + a.new_tokens,
                }
            }
        };
        let expect = if expect.is_finite() { expect.round() as usize } else { a.new_tokens };
        a.prompt_tokens + expect.clamp(1, a.new_tokens.max(1))
    }

    /// Head-of-line requests the paged ledger accepts right now.
    fn kv_admissible(&self) -> usize {
        if let Some(l) = &self.oc {
            return l.admissible(self.queue.iter().map(|(a, _)| self.expected_charge(a)));
        }
        match &self.ledger {
            Some(l) => {
                l.admissible(self.queue.iter().map(|(a, _)| a.prompt_tokens + a.new_tokens))
            }
            None => usize::MAX,
        }
    }

    /// Reject queue-head requests whose footprint exceeds the paged
    /// capacity *outright* — they could never be admitted, and FIFO
    /// admission would otherwise starve every fitting request behind them
    /// (the serving tail would read as a dead design instead of one that
    /// cannot hold a single outlier). Rejected requests stay un-completed
    /// in the report, so SLO validation still fails conservatively; a
    /// closed-loop client whose request is rejected goes back to thinking.
    fn reject_unservable(&mut self) {
        // Under overcommit the same max-footprint test runs against the
        // overcommit ledger's geometry: a request that could never fit
        // even alone must be shed, which is also what guarantees every
        // failing append has a preemption victim (a lone sequence fits).
        let (block_tokens, capacity) = if let Some(l) = &self.ledger {
            (l.block_tokens(), l.capacity_blocks())
        } else if let Some(l) = &self.oc {
            (l.block_tokens(), l.capacity_blocks())
        } else {
            return;
        };
        let fits = |a: &Arrival| {
            (a.prompt_tokens + a.new_tokens).div_ceil(block_tokens).max(1) <= capacity
        };
        if self.selector.is_some() {
            // Tiered admission picks anywhere in the queue, so oversized
            // requests must be shed wherever they sit — an unservable
            // batch request mid-queue would otherwise be picked, admitted
            // on its (fitting) expected charge, and then preempt forever.
            let mut i = 0;
            while i < self.queue.len() {
                let (a, c) = self.queue[i];
                if fits(&a) {
                    i += 1;
                    continue;
                }
                let _ = self.queue.remove(i);
                self.rejected += 1;
                if let (Some(cl), Some(c)) = (self.closed.as_mut(), c) {
                    cl.ready[c] = self.now + cl.think_s;
                }
            }
            return;
        }
        while let Some((a, c)) = self.queue.front().copied() {
            if fits(&a) {
                break;
            }
            self.queue.pop_front();
            self.rejected += 1;
            if self.abort.is_some() {
                // A rejected request can never complete, so
                // `completed == offered` — and hence `meets` — is already
                // lost; stop paying for the rest of the trace.
                self.aborted = true;
            }
            if let (Some(cl), Some(c)) = (self.closed.as_mut(), c) {
                cl.ready[c] = self.now + cl.think_s;
            }
        }
    }

    /// Record a completed request out of slot `idx`; a closed-loop client
    /// starts thinking, the slot returns to the free list, and the
    /// early-abort violation counters advance.
    fn finish(&mut self, idx: usize, slot: Slot) {
        let stats = ReqStats {
            id: slot.id,
            arrival_s: slot.arrival_s,
            first_token_s: slot.first_token_s,
            finish_s: self.now,
            tokens: slot.tokens,
            tier: slot.tier,
        };
        if let Some(a) = self.abort {
            // Strictly-above mirrors the percentile proof: p99 > target
            // needs values > target, and `ReqStats::meets` uses `<=`.
            if stats.ttft_s() > a.ttft_s {
                self.ttft_violations += 1;
            }
            if stats.tokens > 1 && stats.tpot_s() > a.tpot_s {
                self.tpot_violations += 1;
            }
            if self.ttft_violations >= a.budget || self.tpot_violations >= a.budget {
                self.aborted = true;
            }
        }
        // Each request is judged against its own tier's SLO when tiers
        // are on; the run SLO otherwise (identical when tiers are off).
        let slo = match self.traffic.tiers {
            Some(ts) => ts.slo_for(slot.tier),
            None => self.slo,
        };
        match self.tally.as_mut() {
            Some(t) => t.record(&stats, &slo),
            None => self.done.push(stats),
        }
        if let Some(tt) = self.tier_tallies.as_mut() {
            tt[usize::from(slot.tier.min(1))].record(&stats, &slo);
        }
        if self.cfg.window_s > 0.0 {
            let bucket = (self.now / self.cfg.window_s).floor().max(0.0) as u64;
            let w = self.windows.entry(bucket).or_default();
            w.completed += 1;
            w.tokens += stats.tokens;
            if stats.meets(&slo) {
                w.good_tokens += stats.tokens;
            }
        }
        self.last_finish = self.last_finish.max(self.now);
        self.free_list.push(Reverse(idx));
        self.live_count -= 1;
        if let Some(l) = self.ledger.as_mut() {
            l.release(slot.id);
        }
        if let Some(l) = self.oc.as_mut() {
            l.release(slot.id);
        }
        if let (Some(cl), Some(c)) = (self.closed.as_mut(), slot.client) {
            cl.ready[c] = self.now + cl.think_s;
        }
    }

    /// Preempt the lowest-priority, most-recently-admitted resident
    /// sequence other than `keep`: its blocks are freed (no residency
    /// observation — the run was cut short), its request re-queues at the
    /// head with its original arrival stamp and *full* token budget for a
    /// recompute-from-scratch retry. Returns false when no victim exists,
    /// which [`Replica::reject_unservable`]'s lone-sequence-fits guarantee
    /// makes unreachable in practice — callers then stop retrying instead
    /// of spinning.
    fn preempt_one(&mut self, keep: u64) -> bool {
        let Some(victim) = self.oc.as_ref().and_then(|l| l.preempt_candidate(keep)) else {
            return false;
        };
        let mut idx = None;
        for (i, slot) in self.slots.iter().enumerate() {
            if matches!(slot, Some(s) if s.id == victim) {
                idx = Some(i);
                break;
            }
        }
        let Some(i) = idx else { return false };
        let Some(s) = self.slots[i].take() else { return false };
        if s.prefill_remaining > 0 {
            self.prefilling -= 1;
        }
        self.free_list.push(Reverse(i));
        self.live_count -= 1;
        if let Some(l) = self.oc.as_mut() {
            l.preempt(victim);
        }
        self.preempted += 1;
        self.preempted_by_tier[usize::from(s.tier.min(1))] += 1;
        let retry = Arrival {
            id: s.id,
            at_s: s.arrival_s,
            prompt_tokens: s.prompt_tokens,
            // tokens + remaining is the original budget whether the slot
            // was mid-prefill or mid-decode.
            new_tokens: s.tokens + s.remaining,
            tier: s.tier,
        };
        self.queue.push_front((retry, s.client));
        true
    }

    /// Fail this replica at its current clock: every resident request
    /// loses its KV state and every queued request its place — both come
    /// back as fresh [`Arrival`]s (original arrival stamp, original
    /// prompt, full token budget: the recompute-from-scratch penalty) in
    /// deterministic `(at_s, id)` order for the fleet to re-dispatch. The
    /// engine state resets to empty; busy time and iteration counts are
    /// kept — the wasted work was really spent and must keep depressing
    /// occupancy. Only the faulted router calls this, so there is no
    /// closed-loop state to repair and no pending source to drop.
    fn crash(&mut self) -> Vec<Arrival> {
        let mut victims: Vec<Arrival> = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                victims.push(Arrival {
                    id: s.id,
                    at_s: s.arrival_s,
                    prompt_tokens: s.prompt_tokens,
                    // tokens + remaining is the original budget whether the
                    // slot was mid-prefill or mid-decode.
                    new_tokens: s.tokens + s.remaining,
                    tier: s.tier,
                });
            }
        }
        victims.extend(self.queue.drain(..).map(|(a, _)| a));
        self.free_list = (0..self.cfg.max_slots).map(Reverse).collect();
        self.live_count = 0;
        self.prefilling = 0;
        let oc_on = self.oc.is_some();
        self.ledger = (self.cfg.paged_kv && !oc_on).then(|| self.cfg.kv.ledger());
        self.oc = oc_on.then(|| {
            OvercommitLedger::new(self.cfg.kv.capacity_tokens, self.cfg.kv.block_tokens)
        });
        victims
            .sort_by(|a, b| stats::total_cmp_f64(&a.at_s, &b.at_s).then(a.id.cmp(&b.id)));
        victims
    }

    /// Execute one engine iteration: admit `n` newcomers (their prefill
    /// starts this iteration), advance every prefilling slot by one chunk
    /// and every decoding slot by one token.
    // index loops: completions mutate `self.slots[i]` *and* call
    // `self.finish(&mut self)`, which an iterator borrow cannot express
    #[allow(clippy::needless_range_loop)]
    fn run_iteration(&mut self, n: usize) {
        // Decoding slots are the ones past their prefill at iteration start.
        let decoding: Vec<usize> = (0..self.slots.len())
            .filter(|&i| matches!(self.slots[i], Some(s) if s.prefill_remaining == 0))
            .collect();
        let mut t = if decoding.is_empty() { 0.0 } else { self.cfg.cost.decode_step_s };
        for _ in 0..n {
            // `n` comes from sanitize(), which never exceeds the queue
            // length — an empty queue here means the admission plan is
            // stale, and admitting nothing is the benign degradation.
            // Tiered admission picks by priority (bounded batch
            // starvation); FIFO otherwise.
            let picked = match self.selector.as_mut() {
                Some(sel) => {
                    match sel.pick(self.queue.iter().map(|(a, _)| a.tier)) {
                        Some(i) => i,
                        None => break,
                    }
                }
                None => 0,
            };
            let entry = if picked == 0 {
                self.queue.pop_front()
            } else {
                self.queue.remove(picked)
            };
            let Some((a, c)) = entry else { break };
            if self.oc.is_some() {
                let charge = self.expected_charge(&a);
                let ok = match self.oc.as_mut() {
                    Some(l) => l.admit(a.id, a.prompt_tokens, charge, a.tier),
                    None => false,
                };
                if !ok {
                    // The sanitize() admissibility count was a FIFO-prefix
                    // estimate; an out-of-order pick (or a mean that moved)
                    // can overshoot. Put the request back and stop
                    // admitting this iteration.
                    self.queue.insert(picked.min(self.queue.len()), (a, c));
                    break;
                }
            } else if let Some(l) = self.ledger.as_mut() {
                let ok = l.admit(a.id, a.prompt_tokens, a.prompt_tokens + a.new_tokens);
                if self.selector.is_none() {
                    debug_assert!(ok, "sanitize admitted past the paged KV capacity");
                } else if !ok {
                    // Out-of-order picks void the FIFO-prefix proof; put
                    // the request back rather than corrupting the ledger.
                    self.queue.insert(picked.min(self.queue.len()), (a, c));
                    break;
                }
            }
            // Lowest free index, as the reference `position(is_none)` scan
            // picked — slot order decides per-iteration processing order.
            // cc-lint: allow(no-panic) sanitize() caps admissions at the free-slot count; silently dropping an admitted request here would corrupt the ledger, so a desync must abort
            let Reverse(free) = self.free_list.pop().expect("free slot");
            debug_assert!(self.slots[free].is_none(), "free list desynced");
            self.slots[free] = Some(Slot {
                id: a.id,
                arrival_s: a.at_s,
                first_token_s: f64::NAN,
                tokens: 0,
                remaining: a.new_tokens,
                prefill_remaining: a.prompt_tokens,
                prompt_tokens: a.prompt_tokens,
                tier: a.tier,
                client: c,
            });
            self.live_count += 1;
            if a.prompt_tokens > 0 {
                self.prefilling += 1;
            }
        }
        // One prefill chunk per prefilling slot (admitted or resident).
        let mut prefills_done = 0usize;
        for s in self.slots.iter_mut().flatten() {
            if s.prefill_remaining > 0 {
                let step = if self.cfg.cost.prefill_chunk == 0 {
                    s.prefill_remaining
                } else {
                    s.prefill_remaining.min(self.cfg.cost.prefill_chunk)
                };
                t += step as f64 * self.cfg.cost.prefill_s_per_token;
                s.prefill_remaining -= step;
                if s.prefill_remaining == 0 {
                    prefills_done += 1;
                }
            }
        }
        self.prefilling -= prefills_done;
        let occ = self.occupied();
        self.now += t;
        self.iterations += 1;
        self.busy_time += t;
        self.busy_slot_time += occ as f64 * t;
        self.peak_live = self.peak_live.max(occ);
        // Decode completions for the slots decoding at iteration start.
        // A preemption can vacate a later-decoding slot mid-loop; the
        // `let Some` guard already tolerates vacated slots.
        for i in decoding {
            // Selected as occupied at iteration start; nothing in between
            // vacates slots, so a None here simply has no work to do.
            let Some(s) = self.slots[i].as_mut() else { continue };
            s.tokens += 1;
            s.remaining -= 1;
            let (id, finished) = (s.id, s.remaining == 0);
            if let Some(l) = self.ledger.as_mut() {
                l.append(id);
            }
            self.oc_append(id);
            if finished {
                if let Some(slot) = self.slots[i].take() {
                    self.finish(i, slot);
                }
            }
        }
        // Prefill completions: the first token emerges with the last chunk.
        for i in 0..self.slots.len() {
            let Some(s) = self.slots[i].as_mut() else { continue };
            if s.tokens == 0 && s.prefill_remaining == 0 {
                s.first_token_s = self.now;
                s.tokens = 1;
                s.remaining -= 1;
                let (id, finished) = (s.id, s.remaining == 0);
                if let Some(l) = self.ledger.as_mut() {
                    l.append(id);
                }
                self.oc_append(id);
                if finished {
                    if let Some(slot) = self.slots[i].take() {
                        self.finish(i, slot);
                    }
                }
            }
        }
        if let Some(l) = &self.ledger {
            self.peak_kv_tokens = self.peak_kv_tokens.max(l.peak_resident_tokens());
        }
        if let Some(l) = &self.oc {
            self.peak_kv_tokens = self.peak_kv_tokens.max(l.peak_resident_tokens());
        }
    }

    /// Record one generated token against the overcommit ledger,
    /// preempting victims until the block fits (no-op when overcommit is
    /// off). See [`Replica::preempt_one`] for why a victim always exists
    /// while the pool is exhausted.
    fn oc_append(&mut self, id: u64) {
        if self.oc.is_none() {
            return;
        }
        loop {
            let appended = match self.oc.as_mut() {
                Some(l) => l.append(id),
                None => true,
            };
            if appended || !self.preempt_one(id) {
                return;
            }
        }
    }

    /// Bulk-advance a uniform decode stretch: as many pure decode
    /// iterations (no admissions, no prefill work, no completions) as
    /// provably precede the next scheduling event — the earliest slot
    /// completion, the next self-generated arrival, or the horizon. The
    /// caller sits at a validated `Decode` decision point with no
    /// prefilling slots and a [`Policy::decode_stable`] policy, so every
    /// iteration in the stretch is identical and the policy need not be
    /// consulted again until the event.
    ///
    /// Returns the number of iterations advanced (0 = nothing uniform to
    /// skip; the caller runs the normal per-iteration path). The
    /// completion iteration itself — and any iteration where an arrival or
    /// the horizon may change the decision — is deliberately left to
    /// [`Replica::run_iteration`], which is the single place completions,
    /// admissions and ledger releases interleave.
    ///
    /// Bit-exactness: the clock and the busy-time accumulators replay the
    /// reference path's per-iteration float additions (`now += step`, one
    /// at a time) — a closed-form `now + k·step` would round differently.
    /// The iteration *count* to the next event bounds the loop in closed
    /// form; everything else (slot token counts, the paged residency
    /// ledger, peaks) is caught up in O(live) after the loop, which is
    /// exact because residency grows monotonically across the stretch.
    fn fast_forward(&mut self, horizon: f64) -> usize {
        // Stop one short of the earliest completion: that iteration
        // releases slots/ledger blocks and must run through the full path.
        let max_k = match self.slots.iter().flatten().map(|s| s.remaining).min() {
            Some(r) if r > 1 => r - 1,
            _ => return 0,
        };
        // Under overcommit, additionally stop before any bulk append
        // could outgrow the free block pool: a stretch capped this way
        // provably needs no preemption, so skipping it is exact. Cap 0
        // falls back to per-iteration stepping, which preempts.
        let max_k = match &self.oc {
            Some(l) => {
                let cap = l.bulk_append_cap();
                if cap == 0 {
                    return 0;
                }
                max_k.min(cap)
            }
            None => max_k,
        };
        let step = self.cfg.cost.decode_step_s;
        if !step.is_finite() || step <= 0.0 {
            // Degenerate costs (pinned-to-INFINITY guards, zero periods)
            // keep the reference path's exact termination behaviour.
            return 0;
        }
        let next_arrival = self.next_internal_arrival().unwrap_or(f64::INFINITY);
        let occ_step = self.live_count as f64 * step;
        let mut k = 0usize;
        loop {
            // The first iteration's guards (now < horizon, no arrival due)
            // were just checked by the caller's decision point; each
            // further iteration re-checks them on the advanced clock,
            // exactly as the reference loop's decision points would.
            self.now += step;
            self.busy_time += step;
            self.busy_slot_time += occ_step;
            k += 1;
            if k >= max_k || self.now >= horizon || next_arrival <= self.now {
                break;
            }
        }
        self.iterations += k as u64;
        self.peak_live = self.peak_live.max(self.live_count);
        for s in self.slots.iter_mut().flatten() {
            s.tokens += k;
            s.remaining -= k;
            if let Some(l) = self.ledger.as_mut() {
                l.append_n(s.id, k);
            }
            if let Some(l) = self.oc.as_mut() {
                l.append_n(s.id, k);
            }
        }
        if let Some(l) = &self.ledger {
            self.peak_kv_tokens = self.peak_kv_tokens.max(l.peak_resident_tokens());
        }
        if let Some(l) = &self.oc {
            self.peak_kv_tokens = self.peak_kv_tokens.max(l.peak_resident_tokens());
        }
        k
    }

    /// Quantized-time sibling of [`Replica::fast_forward`]: the same
    /// uniform-stretch preconditions and the same event bounds, but the
    /// iteration count `k` to the next event is computed in closed form
    /// and the clock advances by one fused `k·step` add — O(1) in the
    /// stretch length instead of O(k). At most
    /// [`SimConfig::quantum`] seconds of virtual time advance per jump.
    ///
    /// Epsilon contract (property-tested, see the module docs): ceil
    /// division lands each event within one iteration of where the
    /// reference path's repeated adds put it, so per-request latencies
    /// differ by at most one decode step plus the float error of `k·step`
    /// versus `k` sequential adds (O(k) ulps). An undershoot caused by
    /// that rounding only costs another (shorter) jump at the next
    /// decision point — progress is guaranteed because `k >= 1` and the
    /// clock strictly advances by at least one step.
    fn quantized_forward(&mut self, horizon: f64) -> usize {
        // Stop one short of the earliest completion, as fast_forward does:
        // the completion iteration itself runs the full path.
        let max_k = match self.slots.iter().flatten().map(|s| s.remaining).min() {
            Some(r) if r > 1 => r - 1,
            _ => return 0,
        };
        // Overcommit: same preemption-free stretch cap as fast_forward.
        let max_k = match &self.oc {
            Some(l) => {
                let cap = l.bulk_append_cap();
                if cap == 0 {
                    return 0;
                }
                max_k.min(cap)
            }
            None => max_k,
        };
        let step = self.cfg.cost.decode_step_s;
        if !step.is_finite() || step <= 0.0 {
            return 0;
        }
        // Iterations until the clock reaches `target` (>= 1: the caller's
        // decision point already cleared the current instant).
        let now = self.now;
        let until = |target: f64| -> usize {
            if !target.is_finite() {
                return usize::MAX;
            }
            let d = target - now;
            if d <= 0.0 {
                return 1;
            }
            let k = (d / step).ceil();
            if k >= usize::MAX as f64 {
                usize::MAX
            } else {
                (k as usize).max(1)
            }
        };
        let next_arrival = self.next_internal_arrival().unwrap_or(f64::INFINITY);
        let per_jump = if self.cfg.quantum.is_finite() {
            let cap = (self.cfg.quantum / step).floor();
            if cap >= usize::MAX as f64 {
                usize::MAX
            } else {
                (cap as usize).max(1)
            }
        } else {
            usize::MAX
        };
        let k = max_k.min(until(horizon)).min(until(next_arrival)).min(per_jump);
        let dt = k as f64 * step;
        self.now += dt;
        self.busy_time += dt;
        self.busy_slot_time += self.live_count as f64 * dt;
        self.iterations += k as u64;
        self.peak_live = self.peak_live.max(self.live_count);
        for s in self.slots.iter_mut().flatten() {
            s.tokens += k;
            s.remaining -= k;
            if let Some(l) = self.ledger.as_mut() {
                l.append_n(s.id, k);
            }
            if let Some(l) = self.oc.as_mut() {
                l.append_n(s.id, k);
            }
        }
        if let Some(l) = &self.ledger {
            self.peak_kv_tokens = self.peak_kv_tokens.max(l.peak_resident_tokens());
        }
        if let Some(l) = &self.oc {
            self.peak_kv_tokens = self.peak_kv_tokens.max(l.peak_resident_tokens());
        }
        k
    }

    /// Queued requests that have *already* out-waited a finite TTFT
    /// target: their first token cannot precede `now`, so their final
    /// TTFT provably exceeds the target before they complete — a sound
    /// lower bound on eventual violators, disjoint from the completed
    /// counters (queued means not completed). Open-loop queues are
    /// `(at_s, id)`-ordered, so the violators are a queue prefix found by
    /// binary search; closed-loop queues interleave client ready times
    /// out of order and fall back to completed-violator counting only
    /// (their queue depth is bounded by the client count anyway).
    fn queued_ttft_violators(&self, ttft_s: f64) -> usize {
        if self.closed.is_some() || !ttft_s.is_finite() || self.queue.is_empty() {
            return 0;
        }
        // `now - at_s > target` is computed directly (not rearranged) so
        // float rounding cannot overcount; it is monotone non-increasing
        // along the sorted queue, so violators form a prefix.
        let (mut lo, mut hi) = (0usize, self.queue.len());
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.now - self.queue[mid].0.at_s > ttft_s {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Local early-abort check on completed + in-flight TTFT violators.
    /// A run that meets its SLO never trips this (the lower bound is at
    /// most the final violator count, which stays under the budget), so
    /// passing reports are unchanged by the in-flight extension.
    fn ttft_wait_infeasible(&self) -> bool {
        match self.abort {
            Some(rule) => {
                self.ttft_violations + self.queued_ttft_violators(rule.ttft_s) >= rule.budget
            }
            None => false,
        }
    }

    /// Drive this replica's policy loop, running every iteration that
    /// starts strictly before `horizon` (`INFINITY` = drain to
    /// completion). Returns when blocked on arrivals the replica does not
    /// generate itself (the replicated router's cue to feed it more), or
    /// as soon as the run is provably SLO-infeasible under an early-abort
    /// rule.
    fn advance(&mut self, policy: &mut dyn Policy, horizon: f64) {
        loop {
            if self.aborted {
                return;
            }
            self.materialize();
            self.reject_unservable();
            if self.aborted {
                return;
            }
            if self.ttft_wait_infeasible() {
                self.aborted = true;
                return;
            }
            let live = self.occupied();
            if live == 0 && self.queue.is_empty() {
                // Idle: jump to the next self-generated arrival, if any.
                match self.next_internal_arrival() {
                    Some(t) if t < horizon => {
                        self.now = self.now.max(t);
                        continue;
                    }
                    _ => return,
                }
            }
            if live == 0 {
                // Externally-routed arrivals (the replicated path) can be
                // stamped later than an idle replica's local clock; an
                // admission must not start before its request arrives.
                if let Some(&(a, _)) = self.queue.front() {
                    if a.at_s > self.now {
                        self.now = a.at_s;
                    }
                }
            }
            if self.now >= horizon {
                return;
            }
            let view = SchedView {
                now_s: self.now,
                queued: self.queue.len(),
                oldest_arrival_s: self.queue.front().map(|(a, _)| a.at_s).unwrap_or(self.now),
                live,
                max_slots: self.cfg.max_slots,
                kv_slots: self.kv_slots,
                kv_admissible: self.kv_admissible(),
                refill_mid_iteration: true,
            };
            match sanitize(policy.decide(&view), &view) {
                Action::Admit(n) => self.run_iteration(n),
                Action::Decode => {
                    // A decode decision with nothing mid-prefill opens a
                    // uniform stretch: fast-forward to the next event and
                    // re-decide there (the event may admit, complete, or
                    // end the horizon), unless the reference stepping was
                    // requested or the policy gives no stability contract.
                    // Quantized mode takes the O(1) closed-form jump
                    // instead of the bit-exact O(k) replay.
                    if !self.cfg.reference_step && self.prefilling == 0 && policy.decode_stable()
                    {
                        let jumped = if self.cfg.quantum > 0.0 {
                            self.quantized_forward(horizon)
                        } else {
                            self.fast_forward(horizon)
                        };
                        if jumped > 0 {
                            continue;
                        }
                    }
                    self.run_iteration(0)
                }
                Action::Wait(deadline) => {
                    // live == 0 here: sanitize coerces waits to decodes
                    // whenever sequences are in flight.
                    let next = self.next_internal_arrival();
                    let target = match (next, deadline) {
                        (Some(a), Some(d)) => Some(a.min(d)),
                        (Some(a), None) => Some(a),
                        (None, Some(d)) if !self.queue.is_empty() => Some(d),
                        _ => None,
                    };
                    match target {
                        Some(t) if t >= horizon => return,
                        Some(t) if t > self.now => self.now = t,
                        Some(_) => {
                            // Deadline already passed but the policy keeps
                            // waiting with work queued — nudge time to the
                            // next arrival to guarantee progress.
                            match next {
                                Some(a) if a > self.now && a < horizon => self.now = a,
                                _ => return,
                            }
                        }
                        None => return,
                    }
                }
            }
        }
    }
}

/// The run's early-abort rule, with the overcommit/tiers suppression:
/// preemption and tier-ordered admission re-queue and reorder requests,
/// breaking the sorted-queue proof behind the in-flight TTFT bound (the
/// same reason the faulted router never arms the rule), so those runs are
/// always simulated in full.
fn abort_rule(
    cfg: &SimConfig,
    traffic: &TrafficSpec,
    offered: usize,
    slo: &SloSpec,
) -> Option<AbortRule> {
    if cfg.overcommit.is_some() || traffic.tiers.is_some() {
        return None;
    }
    AbortRule::new(cfg, offered, slo)
}

/// Fleet-wide early-abort check: some replica already aborted locally, or
/// the *summed* violation counters prove the final p99 over the target
/// even though no single replica's share crosses the budget on its own.
/// TTFT sums include each replica's in-flight lower bound
/// ([`Replica::queued_ttft_violators`]) — queued requests that have
/// already out-waited the target at their replica's clock.
fn fleet_infeasible(reps: &[Replica<'_>], rule: &AbortRule) -> bool {
    reps.iter().any(|r| r.aborted)
        || reps
            .iter()
            .map(|r| r.ttft_violations + r.queued_ttft_violators(rule.ttft_s))
            .sum::<usize>()
            >= rule.budget
        || reps.iter().map(|r| r.tpot_violations).sum::<usize>() >= rule.budget
}

/// One replica's failure/repair process: either the scripted plan's
/// events for this replica (a non-empty [`FaultSpec::plan`] overrides the
/// stochastic process fleet-wide) or a seeded alternating-renewal process
/// with exponential dwells of mean `mtbf_s` up and `mttr_s` down.
struct FaultClock {
    /// This replica's scripted transitions, in `at_s` order.
    script: VecDeque<FaultEvent>,
    /// Dwell-time stream of the stochastic process (None when scripted).
    rng: Option<Rng>,
    mtbf_s: f64,
    mttr_s: f64,
    up: bool,
    /// Next stochastic transition instant (INFINITY when scripted or
    /// exhausted).
    next_stochastic: f64,
    /// Clock reading when the current down spell began (meaningful while
    /// `!up`).
    down_since: f64,
    /// Accumulated down replica-seconds.
    down_total: f64,
}

impl FaultClock {
    fn new(faults: &FaultSpec, replica: usize) -> FaultClock {
        let mut script: Vec<FaultEvent> =
            faults.plan.iter().filter(|e| e.replica == replica).copied().collect();
        script.sort_by(|a, b| stats::total_cmp_f64(&a.at_s, &b.at_s));
        let stochastic = faults.plan.is_empty() && faults.mtbf_s > 0.0;
        let mut rng =
            stochastic.then(|| Rng::new(faults.seed ^ 0xFA11_C10C ^ replica as u64));
        let next_stochastic = match rng.as_mut() {
            Some(r) => r.exponential(1.0 / faults.mtbf_s),
            None => f64::INFINITY,
        };
        FaultClock {
            script: script.into(),
            rng,
            mtbf_s: faults.mtbf_s,
            mttr_s: faults.mttr_s,
            up: true,
            next_stochastic,
            down_since: 0.0,
            down_total: 0.0,
        }
    }

    /// Next transition instant (INFINITY when the process is exhausted).
    fn next_at(&self) -> f64 {
        match self.script.front() {
            Some(e) => e.at_s,
            None => self.next_stochastic,
        }
    }

    /// Fire the transition due at `t`, updating up/down state and the
    /// downtime accumulator. Scripted no-op transitions (failing a down
    /// replica, recovering an up one) are legal and change nothing.
    fn fire(&mut self, t: f64) {
        let target_up = match self.script.pop_front() {
            Some(e) => e.up,
            None => {
                let toggled = !self.up;
                if let Some(r) = self.rng.as_mut() {
                    // Dwell until the *next* transition: up spells last
                    // mtbf_s on average, down spells mttr_s.
                    let mean = if toggled { self.mtbf_s } else { self.mttr_s };
                    self.next_stochastic = t + r.exponential(1.0 / mean.max(1e-12));
                }
                toggled
            }
        };
        if self.up && !target_up {
            self.down_since = t;
            self.up = false;
        } else if !self.up && target_up {
            self.down_total += (t - self.down_since).max(0.0);
            self.up = true;
        }
    }
}

/// Fleet-level failure bookkeeping for the faulted router: per-replica
/// fault clocks, the all-down parking lot, per-request retry counts and
/// the re-dispatch/lost tallies.
struct FleetFaults {
    clocks: Vec<FaultClock>,
    route: RoutePolicy,
    rr_next: usize,
    /// Arrivals (fresh or crashed-off) that found the whole fleet down;
    /// drained through the router at the next recovery.
    parked: VecDeque<Arrival>,
    /// Crash count per request id (BTreeMap: deterministic iteration is a
    /// serialization-adjacent invariant this module holds everywhere).
    tries: BTreeMap<u64, usize>,
    max_redispatch: usize,
    redispatched: usize,
    lost: usize,
}

impl FleetFaults {
    fn new(faults: &FaultSpec, n: usize, route: RoutePolicy) -> FleetFaults {
        FleetFaults {
            clocks: (0..n).map(|j| FaultClock::new(faults, j)).collect(),
            route,
            rr_next: 0,
            parked: VecDeque::new(),
            tries: BTreeMap::new(),
            max_redispatch: faults.max_redispatch,
            redispatched: 0,
            lost: 0,
        }
    }

    /// Earliest pending transition `(instant, replica)` across the fleet;
    /// ties break to the lowest replica index (strict `<` keeps the first
    /// minimum). `(INFINITY, MAX)` when every process is exhausted.
    fn next_transition(&self) -> (f64, usize) {
        let mut best = (f64::INFINITY, usize::MAX);
        for (j, c) in self.clocks.iter().enumerate() {
            let t = c.next_at();
            if t < best.0 {
                best = (t, j);
            }
        }
        best
    }

    /// Route one arrival to a live replica — the fault-free policies with
    /// down replicas excluded (round-robin skips ahead to the next live
    /// index without losing its rotation; JSQ variants rank only live
    /// replicas, lowest-index tie-breaks intact). With the whole fleet
    /// down the arrival parks until the next recovery. `now` is the fleet
    /// instant of the dispatch: a re-dispatched victim keeps its original
    /// `at_s` for the stats (its TTFT must absorb the detour), so an
    /// *idle* target's lagging local clock is bumped to `now` to keep it
    /// from serving the request before the dispatch happened (busy
    /// targets are already at or past `now` after the fleet advance).
    fn dispatch(&mut self, reps: &mut [Replica<'_>], a: Arrival, now: f64) {
        let n = reps.len();
        if !self.clocks.iter().any(|c| c.up) {
            self.parked.push_back(a);
            return;
        }
        let target = match self.route {
            RoutePolicy::RoundRobin => {
                let mut t = self.rr_next;
                while !self.clocks[t % n].up {
                    t += 1;
                }
                self.rr_next = t + 1;
                t % n
            }
            RoutePolicy::Jsq => (0..n)
                .filter(|&i| self.clocks[i].up)
                .min_by_key(|&i| (reps[i].outstanding(), i))
                .unwrap_or(0),
            RoutePolicy::JsqTokens => (0..n)
                .filter(|&i| self.clocks[i].up)
                .min_by_key(|&i| (reps[i].outstanding_tokens(), i))
                .unwrap_or(0),
        };
        if reps[target].occupied() == 0 && reps[target].queue.is_empty() {
            reps[target].now = reps[target].now.max(now);
        }
        reps[target].enqueue(a);
    }

    /// Retry-dispatch one crash victim, or count it lost once it has been
    /// crashed off more than `max_redispatch` times. Queued victims burn
    /// the budget too: a replica that dies the instant work reaches it
    /// could otherwise cycle the same request forever.
    fn redispatch(&mut self, reps: &mut [Replica<'_>], a: Arrival, now: f64) {
        let t = self.tries.entry(a.id).or_insert(0);
        *t += 1;
        if *t > self.max_redispatch {
            self.lost += 1;
        } else {
            self.redispatched += 1;
            self.dispatch(reps, a, now);
        }
    }

    /// Fire the transition due on replica `j` at instant `t`: a failure
    /// crashes the replica and re-dispatches its victims to the
    /// survivors; a recovery re-opens it and drains the parking lot.
    fn fire(&mut self, reps: &mut [Replica<'_>], j: usize, t: f64) {
        let was_up = self.clocks[j].up;
        self.clocks[j].fire(t);
        let is_up = self.clocks[j].up;
        if was_up && !is_up {
            for a in reps[j].crash() {
                self.redispatch(reps, a, t);
            }
        } else if !was_up && is_up {
            // Parked requests already burned their retry when crashed off
            // (or never crashed at all): dispatch, don't re-count.
            while let Some(a) = self.parked.pop_front() {
                self.dispatch(reps, a, t);
            }
        }
    }

    /// Total down replica-seconds with still-down clocks closed out at
    /// `end`. Call once, after the run.
    fn downtime_total(&mut self, end: f64) -> f64 {
        let mut sum = 0.0;
        for c in self.clocks.iter_mut() {
            if !c.up {
                c.down_total += (end - c.down_since).max(0.0);
                c.up = true; // closed out — a second call must not double-count
            }
            sum += c.down_total;
        }
        sum
    }
}

/// Merge per-replica outcomes into one report. `fleet_aborted` marks an
/// early abort the *router* decided on fleet-wide violation counts (a
/// replica-local abort is carried by the replica itself). Sketched
/// replicas merge their tail tallies (exactly — bucket counts add)
/// instead of concatenating per-request vectors.
fn aggregate(
    replicas: Vec<Replica<'_>>,
    policy: &str,
    offered: usize,
    slo: &SloSpec,
    fleet_aborted: bool,
) -> ServeReport {
    let n = replicas.len().max(1);
    let max_slots = replicas.first().map(|r| r.cfg.max_slots).unwrap_or(1);
    let tiers_spec = replicas.first().and_then(|r| r.traffic.tiers);
    let window_s = replicas.first().map(|r| r.cfg.window_s).unwrap_or(0.0);
    let mut done: Vec<ReqStats> = Vec::new();
    let mut tally: Option<TailTally> = None;
    let mut tier_tallies: Option<Vec<TailTally>> = None;
    let mut window_accs: BTreeMap<u64, WindowAcc> = BTreeMap::new();
    let mut first_arrival: Option<f64> = None;
    let mut last_finish = 0.0f64;
    let (mut busy_slot_time, mut busy_time) = (0.0f64, 0.0f64);
    let mut iterations = 0u64;
    let (mut peak_live, mut peak_kv) = (0usize, 0usize);
    let mut rejected = 0usize;
    let mut preempted = 0usize;
    let mut preempted_by_tier = [0usize; 2];
    let mut aborted_early = fleet_aborted;
    for r in replicas {
        rejected += r.rejected;
        preempted += r.preempted;
        preempted_by_tier[0] += r.preempted_by_tier[0];
        preempted_by_tier[1] += r.preempted_by_tier[1];
        aborted_early |= r.aborted;
        done.extend(r.done);
        if let Some(t) = r.tally {
            match tally.as_mut() {
                Some(m) => m.merge(&t),
                None => tally = Some(t),
            }
        }
        if let Some(tt) = r.tier_tallies {
            match tier_tallies.as_mut() {
                Some(m) => {
                    for (a, b) in m.iter_mut().zip(&tt) {
                        a.merge(b);
                    }
                }
                None => tier_tallies = Some(tt),
            }
        }
        for (b, w) in r.windows {
            let e = window_accs.entry(b).or_default();
            e.completed += w.completed;
            e.tokens += w.tokens;
            e.good_tokens += w.good_tokens;
        }
        first_arrival = match (first_arrival, r.first_arrival) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        last_finish = last_finish.max(r.last_finish);
        busy_slot_time += r.busy_slot_time;
        busy_time += r.busy_time;
        iterations += r.iterations;
        peak_live = peak_live.max(r.peak_live);
        peak_kv = peak_kv.max(r.peak_kv_tokens);
    }
    let windows: Vec<WindowRow> = window_accs
        .into_iter()
        .map(|(b, w)| WindowRow {
            start_s: b as f64 * window_s,
            completed: w.completed,
            tokens: w.tokens,
            good_tokens: w.good_tokens,
        })
        .collect();
    if let Some(t) = tally {
        // Bounded-memory path: tails from the merged fleet sketch, no
        // per-request records (entry points engage the tally on every
        // replica of a run or none, so `done` is empty here).
        debug_assert!(done.is_empty(), "mixed exact/sketched replicas in one run");
        let makespan = (last_finish - first_arrival.unwrap_or(0.0)).max(0.0);
        let tiers: Vec<TierReport> = match (&tiers_spec, &tier_tallies) {
            (Some(_), Some(tt)) => tt
                .iter()
                .enumerate()
                .map(|(i, t)| TierReport {
                    tier: i as u8,
                    completed: t.completed,
                    tokens: t.tokens,
                    slo_met_frac: if t.completed == 0 {
                        0.0
                    } else {
                        t.met as f64 / t.completed as f64
                    },
                    ttft_p50_s: t.ttft.quantile(50.0),
                    ttft_p99_s: t.ttft.quantile(99.0),
                    tpot_p50_s: t.tpot.quantile(50.0),
                    tpot_p99_s: t.tpot.quantile(99.0),
                    goodput_tokens_per_s: if makespan > 0.0 {
                        t.good_tokens as f64 / makespan
                    } else {
                        0.0
                    },
                    preempted: preempted_by_tier[i.min(1)],
                })
                .collect(),
            _ => Vec::new(),
        };
        return ServeReport {
            policy: policy.to_string(),
            replicas: n,
            offered,
            completed: t.completed,
            tokens: t.tokens,
            makespan_s: makespan,
            tokens_per_s: if makespan > 0.0 { t.tokens as f64 / makespan } else { 0.0 },
            goodput_tokens_per_s: if makespan > 0.0 {
                t.good_tokens as f64 / makespan
            } else {
                0.0
            },
            slo_met_frac: if t.completed == 0 {
                0.0
            } else {
                t.met as f64 / t.completed as f64
            },
            ttft_p50_s: t.ttft.quantile(50.0),
            ttft_p99_s: t.ttft.quantile(99.0),
            tpot_p50_s: t.tpot.quantile(50.0),
            tpot_p99_s: t.tpot.quantile(99.0),
            total_p50_s: t.total.quantile(50.0),
            total_p99_s: t.total.quantile(99.0),
            occupancy: if busy_time > 0.0 {
                busy_slot_time / (busy_time * max_slots as f64)
            } else {
                0.0
            },
            iterations,
            peak_live,
            peak_kv_tokens: peak_kv,
            rejected,
            aborted_early,
            redispatched: 0,
            lost: 0,
            downtime_frac: 0.0,
            preempted,
            tiers,
            windows,
            per_request: Vec::new(),
        };
    }
    done.sort_by_key(|r| r.id);
    // One sort per metric vector (the batch API), not one per quantile.
    let mut ttfts: Vec<f64> = done.iter().map(|r| r.ttft_s()).collect();
    let mut tpots: Vec<f64> = done.iter().filter(|r| r.tokens > 1).map(|r| r.tpot_s()).collect();
    let mut totals: Vec<f64> = done.iter().map(|r| r.total_s()).collect();
    let ttft_p = stats::percentiles(&mut ttfts, &[50.0, 99.0]);
    let tpot_p = stats::percentiles(&mut tpots, &[50.0, 99.0]);
    let total_p = stats::percentiles(&mut totals, &[50.0, 99.0]);
    let tokens: usize = done.iter().map(|r| r.tokens).sum();
    // Each request is judged against its own tier's SLO; without tiers
    // this is exactly the run-wide SLO (the pre-tier behaviour).
    let slo_of = |r: &ReqStats| match tiers_spec {
        Some(ts) => ts.slo_for(r.tier),
        None => *slo,
    };
    let good_tokens: usize = done.iter().filter(|r| r.meets(&slo_of(r))).map(|r| r.tokens).sum();
    let met = done.iter().filter(|r| r.meets(&slo_of(r))).count();
    let makespan = (last_finish - first_arrival.unwrap_or(0.0)).max(0.0);
    let tiers: Vec<TierReport> = match tiers_spec {
        Some(ts) => (0u8..2)
            .map(|tier| {
                let tslo = ts.slo_for(tier);
                let sub: Vec<&ReqStats> = done.iter().filter(|r| r.tier == tier).collect();
                let mut ttfts: Vec<f64> = sub.iter().map(|r| r.ttft_s()).collect();
                let mut tpots: Vec<f64> =
                    sub.iter().filter(|r| r.tokens > 1).map(|r| r.tpot_s()).collect();
                let ttft_p = stats::percentiles(&mut ttfts, &[50.0, 99.0]);
                let tpot_p = stats::percentiles(&mut tpots, &[50.0, 99.0]);
                let t_tokens: usize = sub.iter().map(|r| r.tokens).sum();
                let t_good: usize =
                    sub.iter().filter(|r| r.meets(&tslo)).map(|r| r.tokens).sum();
                let t_met = sub.iter().filter(|r| r.meets(&tslo)).count();
                TierReport {
                    tier,
                    completed: sub.len(),
                    tokens: t_tokens,
                    slo_met_frac: if sub.is_empty() {
                        0.0
                    } else {
                        t_met as f64 / sub.len() as f64
                    },
                    ttft_p50_s: ttft_p[0],
                    ttft_p99_s: ttft_p[1],
                    tpot_p50_s: tpot_p[0],
                    tpot_p99_s: tpot_p[1],
                    goodput_tokens_per_s: if makespan > 0.0 {
                        t_good as f64 / makespan
                    } else {
                        0.0
                    },
                    preempted: preempted_by_tier[usize::from(tier.min(1))],
                }
            })
            .collect(),
        None => Vec::new(),
    };
    ServeReport {
        policy: policy.to_string(),
        replicas: n,
        offered,
        completed: done.len(),
        tokens,
        makespan_s: makespan,
        tokens_per_s: if makespan > 0.0 { tokens as f64 / makespan } else { 0.0 },
        goodput_tokens_per_s: if makespan > 0.0 { good_tokens as f64 / makespan } else { 0.0 },
        slo_met_frac: if done.is_empty() { 0.0 } else { met as f64 / done.len() as f64 },
        ttft_p50_s: ttft_p[0],
        ttft_p99_s: ttft_p[1],
        tpot_p50_s: tpot_p[0],
        tpot_p99_s: tpot_p[1],
        total_p50_s: total_p[0],
        total_p99_s: total_p[1],
        occupancy: if busy_time > 0.0 {
            busy_slot_time / (busy_time * max_slots as f64)
        } else {
            0.0
        },
        iterations,
        peak_live,
        peak_kv_tokens: peak_kv,
        rejected,
        aborted_early,
        redispatched: 0,
        lost: 0,
        downtime_frac: 0.0,
        preempted,
        tiers,
        windows,
        per_request: done,
    }
}

/// Closed-loop state over exactly `clients` clients — zero is legal (an
/// inert replica in a partition wider than the client count). Each client
/// seeds its own token-budget stream from `(traffic.seed, id_base, c)`,
/// so replicas and clients never share draws.
fn closed_loop_state(
    traffic: &TrafficSpec,
    clients: usize,
    budget: usize,
    id_base: u64,
) -> ClosedLoop {
    match traffic.arrival {
        ArrivalProcess::ClosedLoop { think_s, .. } => ClosedLoop {
            ready: vec![0.0; clients],
            rngs: (0..clients)
                .map(|c| Rng::new(traffic.seed ^ 0xC11E_4275 ^ (id_base | c as u64)))
                .collect(),
            think_s: think_s.max(0.0),
            budget,
        },
        _ => unreachable!("closed_loop_state on an open-loop spec"),
    }
}

/// Drive a policy over a traffic spec and report the serving tails.
///
/// Deterministic in `(cfg, policy, traffic, slo)`: the virtual clock only
/// advances by analytic iteration costs and seeded arrival draws. The
/// arrivals stream from [`open_loop_iter`] — which yields exactly the
/// [`open_loop_trace`] order — so the trace is never materialized.
pub fn simulate_trace(
    cfg: &SimConfig,
    policy: &mut dyn Policy,
    traffic: &TrafficSpec,
    slo: &SloSpec,
) -> ServeReport {
    simulate_trace_stream(cfg, policy, traffic, traffic.requests, open_loop_iter(traffic), slo)
}

/// [`simulate_trace`] over a pre-materialized open-loop arrival list — the
/// cross-candidate warm start: callers validating many designs against the
/// *same* traffic ([`crate::evaluate::SweepEngine::best_point_slo`])
/// materialize [`open_loop_trace`] once and share it, instead of re-drawing
/// the identical seeded trace per validation. Passing exactly
/// `open_loop_trace(traffic)` makes this byte-identical to
/// [`simulate_trace`] by construction; passing anything else is on the
/// caller (the hand-built-trace tests use that deliberately). Closed-loop
/// specs ignore `trace` (their arrivals are synthesized during the run —
/// pass `&[]`).
pub fn simulate_trace_on(
    cfg: &SimConfig,
    policy: &mut dyn Policy,
    traffic: &TrafficSpec,
    trace: &[Arrival],
    slo: &SloSpec,
) -> ServeReport {
    simulate_trace_stream(cfg, policy, traffic, traffic.requests, trace.iter().copied(), slo)
}

/// Streaming variant of [`simulate_trace_on`]: drives one replica off any
/// `(at_s, id)`-ordered arrival iterator, merged lazily with the event
/// loop through a one-item lookahead — the source is pulled only as
/// virtual time reaches each arrival and is never materialized. `offered`
/// is the total request count the source will yield (synthetic specs know
/// it from `traffic.requests`; trace files from their validation pass) —
/// the early-abort budget and completion accounting need it up front.
/// Closed-loop specs ignore the source, as with [`simulate_trace_on`].
pub fn simulate_trace_stream<I>(
    cfg: &SimConfig,
    policy: &mut dyn Policy,
    traffic: &TrafficSpec,
    offered: usize,
    source: I,
    slo: &SloSpec,
) -> ServeReport
where
    I: IntoIterator<Item = Arrival>,
{
    let closed = match traffic.arrival {
        ArrivalProcess::ClosedLoop { clients, .. } => {
            Some(closed_loop_state(traffic, clients.max(1), offered, 0))
        }
        _ => None,
    };
    let abort = abort_rule(cfg, traffic, offered, slo);
    let sketched = offered > cfg.tail_cap;
    let mut replica = Replica::new(
        cfg,
        traffic,
        Box::new(source.into_iter()),
        closed,
        0,
        abort,
        slo,
        sketched,
    );
    replica.advance(policy, f64::INFINITY);
    let name = policy.name().to_string();
    aggregate(vec![replica], &name, offered, slo, false)
}

/// Simulate `replicas` independent copies of the same design behind a
/// routing policy, each replica running its own clone of `policy`.
///
/// Open-loop arrivals are routed **at their arrival instant** on the fleet
/// state at that instant (every replica is first advanced to the arrival
/// time), so join-shortest-queue sees real queue depths, not a static
/// split. Arrivals are processed in `(time, id)` order and JSQ ties break
/// to the lowest replica index — the schedule is bit-reproducible.
///
/// Closed-loop traffic is self-routing by nature — a client resubmits to
/// the replica serving it — so clients and the request budget are
/// partitioned round-robin across replicas up front and each replica runs
/// its loop independently (the routing policy is moot there).
pub fn simulate_replicated<P: Policy + Clone>(
    cfg: &SimConfig,
    replicas: usize,
    route: RoutePolicy,
    policy: &P,
    traffic: &TrafficSpec,
    slo: &SloSpec,
) -> ServeReport {
    simulate_replicated_stream(
        cfg,
        replicas,
        route,
        policy,
        traffic,
        traffic.requests,
        open_loop_iter(traffic),
        slo,
    )
}

/// [`simulate_replicated`] over a pre-materialized open-loop arrival list
/// (see [`simulate_trace_on`] for the warm-start contract). The routed
/// schedule depends only on the arrival list and the fleet state, so a
/// shared trace replays bit-identically to a per-call regeneration.
pub fn simulate_replicated_on<P: Policy + Clone>(
    cfg: &SimConfig,
    replicas: usize,
    route: RoutePolicy,
    policy: &P,
    traffic: &TrafficSpec,
    trace: &[Arrival],
    slo: &SloSpec,
) -> ServeReport {
    simulate_replicated_stream(
        cfg,
        replicas,
        route,
        policy,
        traffic,
        traffic.requests,
        trace.iter().copied(),
        slo,
    )
}

/// Streaming variant of [`simulate_replicated_on`]: the router pulls
/// arrivals one at a time from any `(at_s, id)`-ordered iterator —
/// synthetic ([`open_loop_iter`]) or a trace-file replay — so fleet-scale
/// traces cost O(1) memory. `offered` is the total count the source will
/// yield (see [`simulate_trace_stream`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_replicated_stream<P, I>(
    cfg: &SimConfig,
    replicas: usize,
    route: RoutePolicy,
    policy: &P,
    traffic: &TrafficSpec,
    offered: usize,
    source: I,
    slo: &SloSpec,
) -> ServeReport
where
    P: Policy + Clone,
    I: IntoIterator<Item = Arrival>,
{
    let n = replicas.max(1);
    if n == 1 {
        let mut p = policy.clone();
        return simulate_trace_stream(cfg, &mut p, traffic, offered, source, slo);
    }
    // Every replica carries the *fleet-wide* violation budget — its own
    // violators alone crossing it is sufficient (the fleet total can only
    // be larger), so replica-local aborts stay sound; the router below
    // additionally aborts on the fleet total between arrivals.
    let abort = abort_rule(cfg, traffic, offered, slo);
    let sketched = offered > cfg.tail_cap;
    let mut pols: Vec<P> = (0..n).map(|_| policy.clone()).collect();
    let mut reps: Vec<Replica> = Vec::with_capacity(n);
    let label = |p: &P| format!("{} x{} {}", p.name(), n, route.name());

    if let ArrivalProcess::ClosedLoop { clients, .. } = traffic.arrival {
        // Fewer clients than replicas leaves the surplus replicas inert —
        // a 1-client spec must model 1 client's concurrency no matter how
        // many replicas stand by — and the request budget is split only
        // among the replicas that actually own clients.
        let clients = clients.max(1);
        let active = clients.min(n);
        for r in 0..n {
            let clients_r = clients / n + usize::from(r < clients % n);
            let budget_r = if r < active {
                offered / active + usize::from(r < offered % active)
            } else {
                0
            };
            let id_base = (r as u64) << 32;
            let closed = closed_loop_state(traffic, clients_r, budget_r, id_base);
            reps.push(Replica::new(
                cfg,
                traffic,
                Box::new(std::iter::empty()),
                Some(closed),
                id_base,
                abort,
                slo,
                sketched,
            ));
        }
        // Each replica runs its whole partition in one drain, so check the
        // fleet counters between drains: once one replica's run (or the
        // sum so far) proves infeasibility, the remaining partitions need
        // not be simulated at all.
        let mut fleet_aborted = false;
        for i in 0..reps.len() {
            if let Some(rule) = &abort {
                if fleet_infeasible(&reps, rule) {
                    fleet_aborted = true;
                    break;
                }
            }
            reps[i].advance(&mut pols[i], f64::INFINITY);
        }
        let name = label(policy);
        return aggregate(reps, &name, offered, slo, fleet_aborted);
    }

    for _ in 0..n {
        reps.push(Replica::new(
            cfg,
            traffic,
            Box::new(std::iter::empty()),
            None,
            0,
            abort,
            slo,
            sketched,
        ));
    }
    let mut rr_next = 0usize;
    let mut fleet_aborted = false;
    for a in source {
        // Bring the whole fleet up to the arrival instant so the router
        // sees each replica's queue as of `a.at_s`.
        for (rep, pol) in reps.iter_mut().zip(pols.iter_mut()) {
            rep.advance(pol, a.at_s);
        }
        if let Some(rule) = &abort {
            // Fleet-wide early abort: replica-local counters may each sit
            // under the budget while their sum already proves the final
            // p99 over the target.
            if fleet_infeasible(&reps, rule) {
                fleet_aborted = true;
                break;
            }
        }
        let target = match route {
            RoutePolicy::RoundRobin => {
                let t = rr_next % n;
                rr_next += 1;
                t
            }
            RoutePolicy::Jsq => {
                (0..n).min_by_key(|&i| (reps[i].outstanding(), i)).unwrap_or(0)
            }
            RoutePolicy::JsqTokens => {
                (0..n).min_by_key(|&i| (reps[i].outstanding_tokens(), i)).unwrap_or(0)
            }
        };
        reps[target].enqueue(a);
    }
    if !fleet_aborted {
        // The decode tails drained here can dwarf the routed portion;
        // re-check the fleet counters before each replica's drain so a
        // proof of infeasibility reached mid-drain spares the rest.
        for i in 0..reps.len() {
            if let Some(rule) = &abort {
                if fleet_infeasible(&reps, rule) {
                    fleet_aborted = true;
                    break;
                }
            }
            reps[i].advance(&mut pols[i], f64::INFINITY);
        }
    }
    let name = label(policy);
    aggregate(reps, &name, offered, slo, fleet_aborted)
}

/// [`simulate_replicated`] under a failure model (module docs,
/// "Failure-aware fleets"): replicas fail and recover on their
/// [`FaultSpec`] clocks, in-flight work is crashed off and re-dispatched
/// with a recompute-from-scratch penalty, and the router only targets
/// live replicas. `FaultSpec::none` delegates to the fault-free path and
/// is byte-identical to it.
pub fn simulate_replicated_faults<P: Policy + Clone>(
    cfg: &SimConfig,
    replicas: usize,
    route: RoutePolicy,
    policy: &P,
    traffic: &TrafficSpec,
    faults: &FaultSpec,
    slo: &SloSpec,
) -> ServeReport {
    simulate_replicated_stream_faults(
        cfg,
        replicas,
        route,
        policy,
        traffic,
        traffic.requests,
        open_loop_iter(traffic),
        faults,
        slo,
    )
}

/// Streaming variant of [`simulate_replicated_faults`] (see
/// [`simulate_replicated_stream`] for the source/`offered` contract).
///
/// The event loop merges arrivals with fault transitions in global time
/// order (a transition tied with an arrival fires first), advancing the
/// whole fleet to each instant so crashes hit exactly the work that was
/// in flight then. Early abort is never armed here — re-dispatched
/// arrivals carry their original (old) timestamps, which breaks the
/// sorted-queue proof behind the in-flight TTFT bound — so faulted runs
/// are always simulated in full; closed-loop traffic (whose clients are
/// partitioned per replica and cannot fail over — `validate()` rejects
/// the combination) degrades to the fault-free path rather than
/// guessing at fail-over semantics.
#[allow(clippy::too_many_arguments)]
pub fn simulate_replicated_stream_faults<P, I>(
    cfg: &SimConfig,
    replicas: usize,
    route: RoutePolicy,
    policy: &P,
    traffic: &TrafficSpec,
    offered: usize,
    source: I,
    faults: &FaultSpec,
    slo: &SloSpec,
) -> ServeReport
where
    P: Policy + Clone,
    I: IntoIterator<Item = Arrival>,
{
    if faults.is_none() || matches!(traffic.arrival, ArrivalProcess::ClosedLoop { .. }) {
        return simulate_replicated_stream(
            cfg, replicas, route, policy, traffic, offered, source, slo,
        );
    }
    // No n == 1 short-circuit: a single replica still fails and recovers
    // (its crashed work parks until the recovery, then recomputes).
    let n = replicas.max(1);
    let sketched = offered > cfg.tail_cap;
    let mut pols: Vec<P> = (0..n).map(|_| policy.clone()).collect();
    let mut reps: Vec<Replica> = (0..n)
        .map(|_| {
            Replica::new(cfg, traffic, Box::new(std::iter::empty()), None, 0, None, slo, sketched)
        })
        .collect();
    let mut ff = FleetFaults::new(faults, n, route);
    let mut src = source.into_iter();
    let mut next_a = src.next();
    while let Some(a) = next_a {
        let (tf, j) = ff.next_transition();
        if tf <= a.at_s {
            for (rep, pol) in reps.iter_mut().zip(pols.iter_mut()) {
                rep.advance(pol, tf);
            }
            ff.fire(&mut reps, j, tf);
            continue;
        }
        next_a = src.next();
        for (rep, pol) in reps.iter_mut().zip(pols.iter_mut()) {
            rep.advance(pol, a.at_s);
        }
        ff.dispatch(&mut reps, a, a.at_s);
    }
    // Drain: keep interleaving work with fault transitions until nothing
    // is queued, resident, or parked. Termination: dwell draws strictly
    // advance the fault clocks, completions drain between transitions,
    // and any request that keeps getting crashed off exhausts its retry
    // budget and is counted lost.
    loop {
        let work = !ff.parked.is_empty()
            || reps.iter().any(|r| r.occupied() > 0 || !r.queue.is_empty());
        if !work {
            break;
        }
        let (tf, j) = ff.next_transition();
        if tf.is_finite() {
            for (rep, pol) in reps.iter_mut().zip(pols.iter_mut()) {
                rep.advance(pol, tf);
            }
            ff.fire(&mut reps, j, tf);
        } else {
            for (rep, pol) in reps.iter_mut().zip(pols.iter_mut()) {
                rep.advance(pol, f64::INFINITY);
            }
            // A scripted schedule that ends with the whole fleet down
            // strands the parking lot: those requests can never run.
            ff.lost += ff.parked.len();
            ff.parked.clear();
        }
    }
    let end = reps.iter().map(|r| r.now.max(r.last_finish)).fold(0.0f64, f64::max);
    let down = ff.downtime_total(end);
    let name = format!("{} x{} {} +faults", policy.name(), n, route.name());
    let mut report = aggregate(reps, &name, offered, slo, false);
    report.redispatched = ff.redispatched;
    report.lost = ff.lost;
    report.downtime_frac = if end > 0.0 { down / (n as f64 * end) } else { 0.0 };
    debug_assert_eq!(
        report.completed + report.rejected + report.lost,
        report.offered,
        "faulted-run conservation broke"
    );
    report
}

/// A report for a run that could not happen (e.g. a validated trace file
/// that became unreadable before simulation): zero completions out of
/// `offered`, so [`ServeReport::meets`] is false — the conservative
/// verdict.
pub(crate) fn unserved_report(policy: &str, replicas: usize, offered: usize) -> ServeReport {
    let mut r = aggregate(Vec::new(), policy, offered, &SloSpec::unconstrained(), false);
    r.replicas = replicas.max(1);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::TierSpec;
    use crate::sched::{ContinuousBatch, StaticBatch};

    fn cost() -> IterCost {
        IterCost { prefill_s_per_token: 0.001, decode_step_s: 0.01, prefill_chunk: 0 }
    }

    fn cfg(slots: usize) -> SimConfig {
        SimConfig::new(slots, KvBudget::unlimited(), cost(), false)
    }

    #[test]
    fn poisson_trace_is_seeded_and_sorted() {
        let t = TrafficSpec::poisson(100.0, 50, 16, 4, 8);
        let a = open_loop_trace(&t);
        let b = open_loop_trace(&t);
        assert_eq!(a.len(), 50);
        assert!(a.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.new_tokens, y.new_tokens);
            assert!((4..=8).contains(&x.new_tokens));
        }
        let c = open_loop_trace(&t.with_seed(7));
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_s != y.at_s));
    }

    #[test]
    fn bursty_trace_clumps_arrivals_in_id_order() {
        let t = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 100.0, burst: 5 },
            ..TrafficSpec::poisson(100.0, 20, 16, 4, 8)
        };
        let a = open_loop_trace(&t);
        assert_eq!(a.len(), 20);
        // within a burst, arrivals share a timestamp but keep id order —
        // the (time, id) total order bursty replay depends on
        assert_eq!(a[0].at_s.to_bits(), a[4].at_s.to_bits());
        assert!(a[5].at_s > a[4].at_s);
        assert!(a.windows(2).all(|w| (w[0].at_s, w[0].id) < (w[1].at_s, w[1].id)));
    }

    /// Hand-traceable single-request run: one arrival at t=0, prompt 10,
    /// 3 new tokens. Admission iteration costs 10 × 1 ms (first token at
    /// 10 ms), then two decode steps of 10 ms each finish it at 30 ms.
    #[test]
    fn single_request_timeline_is_exact() {
        let t = TrafficSpec::poisson(1e9, 1, 10, 3, 3);
        let rep = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.tokens, 3);
        let r = rep.per_request[0];
        assert!((r.ttft_s() - 0.010).abs() < 1e-12, "ttft={}", r.ttft_s());
        assert!((r.finish_s - r.first_token_s - 0.020).abs() < 1e-12);
        assert!((r.tpot_s() - 0.010).abs() < 1e-12);
        assert_eq!(rep.iterations, 3);
    }

    /// The same single request under chunked prefill (chunk 4): three
    /// prefill iterations of 4+4+2 tokens — TTFT unchanged at 10 ms
    /// because no decoder shares the batch — then two decode steps.
    #[test]
    fn single_request_chunked_timeline_is_exact() {
        let t = TrafficSpec::poisson(1e9, 1, 10, 3, 3);
        let mut c = cfg(4);
        c.cost = c.cost.with_chunk(4);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 1);
        let r = rep.per_request[0];
        assert!((r.ttft_s() - 0.010).abs() < 1e-12, "ttft={}", r.ttft_s());
        assert!((r.tpot_s() - 0.010).abs() < 1e-12);
        // 3 prefill iterations + 2 decode iterations
        assert_eq!(rep.iterations, 5);
    }

    /// Chunked prefill bounds the stall resident decoders eat during an
    /// admission: under the stall-the-batch model a short request alive
    /// across one 512-token admission pays the whole 0.512 s as a single
    /// inter-token gap; with chunk 64 the gap is one chunk + one decode
    /// step, so the per-request TPOT tail drops strictly.
    #[test]
    fn chunked_prefill_improves_tpot_tail() {
        let t = TrafficSpec::poisson(12.0, 120, 512, 4, 32).with_seed(7);
        let run = |chunk: usize| {
            let mut c = cfg(8);
            c.cost = c.cost.with_chunk(chunk);
            simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained())
        };
        let stall = run(0);
        let chunked = run(64);
        assert_eq!(stall.completed, 120);
        assert_eq!(chunked.completed, 120);
        assert!(
            chunked.tpot_p99_s < stall.tpot_p99_s,
            "chunked p99 TPOT {} must beat stall-the-batch {}",
            chunked.tpot_p99_s,
            stall.tpot_p99_s
        );
    }

    #[test]
    fn simulation_is_deterministic() {
        let t = TrafficSpec::poisson(40.0, 200, 16, 4, 32).with_seed(123);
        let run = || {
            let rep = simulate_trace(&cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
            (rep.tokens, rep.iterations, rep.ttft_p99_s.to_bits(), rep.makespan_s.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn every_request_completes_with_its_budget() {
        let t = TrafficSpec::poisson(50.0, 300, 8, 1, 16).with_seed(9);
        let mut st = StaticBatch::new(0.02);
        let mut co = ContinuousBatch;
        let policies: [&mut dyn Policy; 2] = [&mut st, &mut co];
        for policy in policies {
            let rep = simulate_trace(&cfg(8), policy, &t, &SloSpec::unconstrained());
            assert_eq!(rep.completed, 300, "{}", rep.policy);
            let trace = open_loop_trace(&t);
            for (r, a) in rep.per_request.iter().zip(&trace) {
                assert_eq!(r.id, a.id);
                assert_eq!(r.tokens, a.new_tokens);
                assert!(r.first_token_s >= a.at_s);
                assert!(r.finish_s >= r.first_token_s);
            }
        }
    }

    #[test]
    fn closed_loop_generates_exactly_the_request_budget() {
        let t = TrafficSpec::closed_loop(4, 0.005, 40, 8, 4, 8).with_seed(3);
        let rep = simulate_trace(&cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 40);
        // at most `clients` requests are ever in flight
        assert!(rep.peak_live <= 4, "peak={}", rep.peak_live);
    }

    #[test]
    fn kv_budget_caps_concurrency() {
        let mut c = cfg(8);
        c.kv = KvBudget::seqs(3);
        let t = TrafficSpec::poisson(1000.0, 60, 8, 8, 8);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 60);
        assert!(rep.peak_live <= 3, "peak={}", rep.peak_live);
    }

    #[test]
    fn paged_ledger_caps_resident_tokens() {
        // Capacity of 64 tokens in 8-token blocks; every request needs
        // 8 + 8 = 16 tokens = 2 blocks, so at most 4 resident at once.
        let mut c = cfg(8);
        c.kv = KvBudget::tokens(64, 8);
        c.paged_kv = true;
        let t = TrafficSpec::poisson(1000.0, 60, 8, 8, 8);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 60);
        assert!(rep.peak_live <= 4, "peak={}", rep.peak_live);
        assert!(rep.peak_kv_tokens <= 64, "peak kv={}", rep.peak_kv_tokens);
    }

    #[test]
    fn paged_admits_more_than_full_reservation() {
        // Full-context reservation at ctx 64 admits 2 sequences into 128
        // tokens of KV; the actual footprint is 8+8=16 tokens, so paged
        // accounting fits 8 — strictly more concurrency from the same SRAM.
        let t = TrafficSpec::poisson(1000.0, 60, 8, 8, 8);
        let mut legacy = cfg(8);
        legacy.kv = KvBudget { max_seqs: 2, capacity_tokens: 128, block_tokens: 8 };
        let l = simulate_trace(&legacy, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let mut paged = legacy;
        paged.paged_kv = true;
        let p = simulate_trace(&paged, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(l.peak_live <= 2);
        assert!(p.peak_live > l.peak_live, "paged {} vs legacy {}", p.peak_live, l.peak_live);
        assert!(p.makespan_s < l.makespan_s, "more concurrency must finish sooner");
    }

    #[test]
    fn oversized_request_reports_incomplete_not_hang() {
        // Requests whose footprint (40 tokens) exceeds the whole paged
        // capacity (32) can never be admitted; the sim must terminate and
        // report them rejected instead of spinning.
        let mut c = cfg(4);
        c.kv = KvBudget::tokens(32, 8);
        c.paged_kv = true;
        let t = TrafficSpec::poisson(1e9, 3, 32, 8, 8);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 0, "nothing fits, nothing completes");
        assert_eq!(rep.rejected, 3);
        assert!(!rep.meets(&SloSpec::unconstrained()));
    }

    /// Never-fitting outliers must not starve the fitting traffic queued
    /// behind them: they are rejected at the queue head and everything
    /// else serves. Footprint = 8 prompt + new tokens against a 3-block
    /// (24-token) capacity: new <= 16 fits, new >= 17 can never fit.
    #[test]
    fn oversized_outliers_do_not_starve_the_tail() {
        let mut c = cfg(4);
        c.kv = KvBudget::tokens(24, 8);
        c.paged_kv = true;
        let t = TrafficSpec::poisson(200.0, 60, 8, 4, 32).with_seed(5);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(rep.rejected > 0, "the wide token range must sample outliers");
        assert!(rep.completed > 0, "fitting requests must be served");
        assert_eq!(rep.completed + rep.rejected, 60, "every request is served or rejected");
        assert!(rep.peak_kv_tokens <= 24);
    }

    #[test]
    fn static_batching_runs_batch_synchronous() {
        // 8 simultaneous arrivals, 4 slots: two sequential full batches.
        let t = TrafficSpec::poisson(1e9, 8, 10, 5, 5);
        let rep =
            simulate_trace(&cfg(4), &mut StaticBatch::new(0.001), &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, 8);
        // batch 2 must start after batch 1 fully drains
        let b1_finish = rep.per_request[..4].iter().map(|r| r.finish_s).fold(0.0, f64::max);
        let b2_first =
            rep.per_request[4..].iter().map(|r| r.first_token_s).fold(f64::MAX, f64::min);
        assert!(b2_first >= b1_finish - 1e-12);
        assert!((rep.occupancy - 1.0).abs() < 1e-9);
    }

    #[test]
    fn iter_cost_guards_degenerate_inputs() {
        // A NaN prefill latency (e.g. an upstream 0/0) on a zero-token
        // prompt must not poison the cost model with NaN — it pins to
        // INFINITY, which fails SLO validation conservatively instead of
        // letting a broken design pass with all-zero tails.
        let perf = DecodePerf {
            stage_latency: 0.0,
            microbatch_latency: 0.0,
            token_period: f64::NAN,
            tokens_per_s: 0.0,
            tokens_per_s_chip: 0.0,
            prefill_latency: f64::NAN,
            compute_util: 0.0,
            mem_util: 0.0,
            comm_frac: 0.0,
            n_chips: 1,
        };
        let mut w = Workload::new(crate::config::ModelSpec::gpt2(), 1024, 4);
        w.prompt_len = 0; // the degenerate zero-token prompt
        let c = IterCost::from_perf(&perf, &w);
        assert!(!c.prefill_s_per_token.is_nan());
        assert!(!c.decode_step_s.is_nan());
        assert_eq!(c.prefill_s_per_token, f64::INFINITY);
        assert_eq!(c.decode_step_s, f64::INFINITY);
        // The sim must terminate on infinite costs and reject, not hang or
        // trivially pass.
        let cfg = SimConfig::new(4, KvBudget::unlimited(), c, false);
        let t = TrafficSpec::poisson(100.0, 5, 8, 2, 4);
        let rep = simulate_trace(&cfg, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(rep.completed < rep.offered);
        assert!(!rep.meets(&SloSpec::unconstrained()));
        // A healthy zero-token-prompt workload stays finite and harmless.
        let healthy = DecodePerf { token_period: 0.01, prefill_latency: 0.0, ..perf };
        let c = IterCost::from_perf(&healthy, &w);
        assert_eq!(c.prefill_s_per_token, 0.0);
        assert_eq!(c.decode_step_s, 0.01);
    }

    /// The fast-forward core against the step-by-step reference on a
    /// decode-heavy trace: same iteration count, same clock, same tails —
    /// to the bit (the broader property sweep lives in the integration
    /// suite; this is the quick in-module guard).
    #[test]
    fn fast_forward_is_bit_identical_to_reference_step() {
        // Long generations and sparse arrivals maximize the uniform decode
        // stretches the fast path jumps.
        let t = TrafficSpec::poisson(3.0, 60, 32, 64, 256).with_seed(7);
        let mut reference = cfg(8);
        reference.reference_step = true;
        let fast = cfg(8);
        for policy_static in [false, true] {
            let run = |c: &SimConfig| {
                if policy_static {
                    simulate_trace(c, &mut StaticBatch::new(0.02), &t, &SloSpec::unconstrained())
                } else {
                    simulate_trace(c, &mut ContinuousBatch, &t, &SloSpec::unconstrained())
                }
            };
            let a = run(&reference);
            let b = run(&fast);
            assert_eq!(a.completed, 60);
            assert_eq!(a.fingerprint(), b.fingerprint(), "static={policy_static}");
        }
    }

    /// Paged accounting through the fast path: residency bulk-advance and
    /// peak tracking must replay the per-iteration ledger exactly.
    #[test]
    fn fast_forward_matches_reference_under_paged_kv() {
        let t = TrafficSpec::poisson(50.0, 80, 16, 32, 128).with_seed(9);
        let mut c = cfg(6);
        c.kv = KvBudget::tokens(1024, 16);
        c.paged_kv = true;
        c.cost = c.cost.with_chunk(8);
        let mut reference = c;
        reference.reference_step = true;
        let a = simulate_trace(&reference, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let b = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(a.peak_kv_tokens > 0);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// Early abort: a provably-failing run stops early (fewer iterations,
    /// `aborted_early`, `meets` false), a passing run is untouched to the
    /// bit, and the verdict always matches the full simulation's.
    #[test]
    fn early_abort_is_sound_and_cheaper() {
        let t = TrafficSpec::poisson(30.0, 200, 16, 16, 64).with_seed(3);
        // Hopeless TPOT target: every multi-token request violates 1 µs.
        let hopeless = SloSpec::new(f64::INFINITY, 1e-6);
        let full = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &hopeless);
        let mut abort_cfg = cfg(4);
        abort_cfg.early_abort = true;
        let aborted = simulate_trace(&abort_cfg, &mut ContinuousBatch, &t, &hopeless);
        assert!(!full.meets(&hopeless));
        assert!(!aborted.meets(&hopeless));
        assert!(aborted.aborted_early);
        assert!(!full.aborted_early);
        assert!(
            aborted.iterations < full.iterations,
            "abort must cut the trace short: {} vs {}",
            aborted.iterations,
            full.iterations
        );
        assert!(aborted.completed < aborted.offered);
        // A comfortably-met SLO: abort never fires and the report is the
        // full one, bit for bit.
        let loose = SloSpec::new(1e6, 1e6);
        let a = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &loose);
        let b = simulate_trace(&abort_cfg, &mut ContinuousBatch, &t, &loose);
        assert!(a.meets(&loose) && b.meets(&loose));
        assert!(!b.aborted_early);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// Fleet-wide early abort on the replicated open-loop path: with
    /// round-robin spreading violators evenly, the per-replica counters
    /// stay under the budget while their *sum* crosses it — the router's
    /// summed check must abort, and the verdict must match the full run.
    #[test]
    fn early_abort_sums_violations_across_replicas() {
        // 200 offered => budget 3: the fleet aborts at 3 total violators,
        // when each replica holds at most 2 (< 3) — only the summed check
        // can fire. Hopeless TPOT target: every multi-token completion
        // violates.
        let t = TrafficSpec::poisson(30.0, 200, 16, 16, 64).with_seed(13);
        let hopeless = SloSpec::new(f64::INFINITY, 1e-6);
        let run = |early_abort: bool| {
            let mut c = cfg(4);
            c.early_abort = early_abort;
            simulate_replicated(&c, 2, RoutePolicy::RoundRobin, &ContinuousBatch, &t, &hopeless)
        };
        let full = run(false);
        let aborted = run(true);
        assert!(!full.meets(&hopeless) && !aborted.meets(&hopeless));
        assert!(aborted.aborted_early, "the fleet-sum check must fire");
        assert!(!full.aborted_early);
        assert!(
            aborted.iterations < full.iterations,
            "fleet abort must cut simulated work: {} vs {}",
            aborted.iterations,
            full.iterations
        );
        // A loose target across the same fleet never aborts and replays
        // the full run bit for bit.
        let loose = SloSpec::new(1e6, 1e6);
        let run_loose = |early_abort: bool| {
            let mut c = cfg(4);
            c.early_abort = early_abort;
            simulate_replicated(&c, 2, RoutePolicy::RoundRobin, &ContinuousBatch, &t, &loose)
        };
        let a = run_loose(false);
        let b = run_loose(true);
        assert!(a.meets(&loose) && b.meets(&loose) && !b.aborted_early);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// Closed-loop replicas drain their whole partition in one advance;
    /// once one partition's run proves infeasibility, the remaining
    /// partitions are skipped entirely.
    #[test]
    fn early_abort_skips_remaining_closed_loop_partitions() {
        let t = TrafficSpec::closed_loop(4, 0.0, 120, 16, 16, 64).with_seed(19);
        let hopeless = SloSpec::new(f64::INFINITY, 1e-6);
        let run = |early_abort: bool| {
            let mut c = cfg(4);
            c.early_abort = early_abort;
            simulate_replicated(&c, 2, RoutePolicy::RoundRobin, &ContinuousBatch, &t, &hopeless)
        };
        let full = run(false);
        let aborted = run(true);
        assert!(aborted.aborted_early);
        assert!(!full.meets(&hopeless) && !aborted.meets(&hopeless));
        assert!(
            aborted.iterations * 2 < full.iterations,
            "skipping a whole partition must save at least half the work: {} vs {}",
            aborted.iterations,
            full.iterations
        );
    }

    /// A paged-KV rejection under early abort stops the run immediately —
    /// completed-all is already unmeetable.
    #[test]
    fn early_abort_fires_on_rejection() {
        let mut c = cfg(4);
        c.kv = KvBudget::tokens(32, 8);
        c.paged_kv = true;
        c.early_abort = true;
        // First request's footprint (40) exceeds the whole capacity (32).
        let t = TrafficSpec::poisson(1e9, 10, 32, 8, 8);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::new(1.0, 1.0));
        assert!(rep.aborted_early);
        assert!(rep.rejected >= 1);
        assert!(!rep.meets(&SloSpec::new(1.0, 1.0)));
    }

    #[test]
    fn replicated_single_matches_simulate_trace() {
        let t = TrafficSpec::poisson(40.0, 100, 16, 4, 16).with_seed(5);
        let a = simulate_trace(&cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let b = simulate_replicated(
            &cfg(8),
            1,
            RoutePolicy::Jsq,
            &ContinuousBatch,
            &t,
            &SloSpec::unconstrained(),
        );
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.ttft_p99_s.to_bits(), b.ttft_p99_s.to_bits());
    }

    #[test]
    fn two_replicas_complete_everything_and_split_load() {
        let t = TrafficSpec::poisson(60.0, 200, 16, 4, 16).with_seed(21);
        for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq] {
            let rep = simulate_replicated(
                &cfg(4),
                2,
                route,
                &ContinuousBatch,
                &t,
                &SloSpec::unconstrained(),
            );
            assert_eq!(rep.completed, 200, "{route:?}");
            assert_eq!(rep.replicas, 2);
            // two replicas halve the per-replica load: faster than one
            let single =
                simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
            assert!(rep.makespan_s <= single.makespan_s + 1e-9);
        }
    }

    #[test]
    fn replicated_runs_are_bit_reproducible_on_tied_arrivals() {
        // Bursty traces emit equal timestamps; the (time, id) order and
        // lowest-index JSQ tie-break must make replay exact.
        let t = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 80.0, burst: 8 },
            ..TrafficSpec::poisson(80.0, 160, 16, 4, 24)
        }
        .with_seed(99);
        let run = || {
            let rep = simulate_replicated(
                &cfg(4),
                3,
                RoutePolicy::Jsq,
                &ContinuousBatch,
                &t,
                &SloSpec::unconstrained(),
            );
            (rep.completed, rep.iterations, rep.ttft_p99_s.to_bits(), rep.makespan_s.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn closed_loop_replicas_partition_clients() {
        let t = TrafficSpec::closed_loop(6, 0.001, 60, 8, 4, 8).with_seed(17);
        let rep = simulate_replicated(
            &cfg(8),
            2,
            RoutePolicy::RoundRobin,
            &ContinuousBatch,
            &t,
            &SloSpec::unconstrained(),
        );
        assert_eq!(rep.completed, 60);
        // 3 clients per replica bound per-replica concurrency
        assert!(rep.peak_live <= 3, "peak={}", rep.peak_live);
    }

    /// The warm-start entry points over exactly `open_loop_trace(t)` must
    /// replay the self-generating paths to the bit — the contract the
    /// sweep's cross-candidate trace sharing rests on.
    #[test]
    fn warm_trace_entry_points_are_bit_identical() {
        let t = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 60.0, burst: 4 },
            ..TrafficSpec::poisson(60.0, 120, 16, 4, 32)
        }
        .with_seed(31);
        let trace = open_loop_trace(&t);
        let slo = SloSpec::unconstrained();
        let a = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &slo);
        let b = simulate_trace_on(&cfg(4), &mut ContinuousBatch, &t, &trace, &slo);
        assert_eq!(a.fingerprint(), b.fingerprint());
        for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::JsqTokens] {
            let a = simulate_replicated(&cfg(4), 2, route, &ContinuousBatch, &t, &slo);
            let b =
                simulate_replicated_on(&cfg(4), 2, route, &ContinuousBatch, &t, &trace, &slo);
            assert_eq!(a.fingerprint(), b.fingerprint(), "{route:?}");
        }
    }

    /// Hand-built trace where count-based JSQ and token-weighted JSQ must
    /// disagree: a 1000-token request parks on replica 0, and the third
    /// arrival sees outstanding *counts* tied (1 vs 1) but outstanding
    /// *work* wildly skewed (~1000 vs ~4 tokens). Count-JSQ ties to the
    /// lowest index and strands the newcomer behind the long job;
    /// token-JSQ routes it to the nearly-idle replica.
    #[test]
    fn jsq_tokens_routes_on_outstanding_work_not_count() {
        let t = TrafficSpec::poisson(1.0, 3, 1, 1, 1000);
        let trace = vec![
            Arrival { id: 0, at_s: 0.0, prompt_tokens: 1, new_tokens: 1000, tier: 0 },
            Arrival { id: 1, at_s: 0.001, prompt_tokens: 1, new_tokens: 4, tier: 0 },
            Arrival { id: 2, at_s: 0.002, prompt_tokens: 1, new_tokens: 4, tier: 0 },
        ];
        let run = |route: RoutePolicy| {
            simulate_replicated_on(
                &cfg(1),
                2,
                route,
                &ContinuousBatch,
                &t,
                &trace,
                &SloSpec::unconstrained(),
            )
        };
        let by_count = run(RoutePolicy::Jsq);
        let by_tokens = run(RoutePolicy::JsqTokens);
        assert_eq!(by_count.completed, 3);
        assert_eq!(by_tokens.completed, 3);
        let ttft = |r: &ServeReport| r.per_request[2].ttft_s();
        assert!(
            ttft(&by_count) > 1.0,
            "count-JSQ must strand request 2 behind the 1000-token job (ttft {})",
            ttft(&by_count)
        );
        assert!(
            ttft(&by_tokens) < 0.5,
            "token-JSQ must route request 2 to the short queue (ttft {})",
            ttft(&by_tokens)
        );
    }

    /// Token-weighted routing under heavy-tailed budgets: everything still
    /// completes, replay is bit-reproducible, and across seeds the
    /// aggregate p99 TTFT is no worse than load-oblivious round-robin.
    #[test]
    fn jsq_tokens_beats_round_robin_under_heavy_tails() {
        let mk = |seed: u64| {
            TrafficSpec {
                arrival: ArrivalProcess::Bursty { rps: 5.0, burst: 6 },
                ..TrafficSpec::poisson(5.0, 150, 16, 1, 256)
            }
            .with_seed(seed)
        };
        let run = |t: &TrafficSpec, route: RoutePolicy| {
            simulate_replicated(&cfg(4), 2, route, &ContinuousBatch, t, &SloSpec::unconstrained())
        };
        let (mut rr_sum, mut jsqt_sum) = (0.0f64, 0.0f64);
        for seed in [3u64, 7, 11] {
            let t = mk(seed);
            let rr = run(&t, RoutePolicy::RoundRobin);
            let jsqt = run(&t, RoutePolicy::JsqTokens);
            assert_eq!(rr.completed, 150);
            assert_eq!(jsqt.completed, 150);
            let again = run(&t, RoutePolicy::JsqTokens);
            assert_eq!(jsqt.fingerprint(), again.fingerprint(), "seed {seed}");
            rr_sum += rr.ttft_p99_s;
            jsqt_sum += jsqt.ttft_p99_s;
        }
        assert!(
            jsqt_sum <= rr_sum,
            "token-weighted JSQ p99 TTFT (sum {jsqt_sum}) must not lose to round-robin \
             (sum {rr_sum}) under heavy-tailed bursts"
        );
    }

    #[test]
    fn closed_loop_fewer_clients_than_replicas_stays_honest() {
        // A 1-client spec across 3 replicas must model exactly one
        // in-flight request fleet-wide — no phantom clients — and still
        // serve the whole budget.
        let t = TrafficSpec::closed_loop(1, 0.0, 20, 8, 2, 4).with_seed(8);
        let rep = simulate_replicated(
            &cfg(4),
            3,
            RoutePolicy::Jsq,
            &ContinuousBatch,
            &t,
            &SloSpec::unconstrained(),
        );
        assert_eq!(rep.completed, 20);
        assert_eq!(rep.peak_live, 1, "one client => one in-flight request");
    }

    /// The lazy generator must yield exactly the materialized trace, bit
    /// for bit and in the same (time, id) order, for both open-loop
    /// processes — the streaming entry points rest on this identity.
    #[test]
    fn open_loop_iter_matches_collected_trace() {
        let specs = [
            TrafficSpec::poisson(80.0, 200, 16, 4, 32).with_seed(11),
            TrafficSpec {
                arrival: ArrivalProcess::Bursty { rps: 80.0, burst: 7 },
                ..TrafficSpec::poisson(80.0, 200, 16, 4, 32)
            }
            .with_seed(11),
        ];
        for t in &specs {
            let eager = open_loop_trace(t);
            let lazy: Vec<Arrival> = open_loop_iter(t).collect();
            assert_eq!(eager.len(), lazy.len());
            for (a, b) in eager.iter().zip(&lazy) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.at_s.to_bits(), b.at_s.to_bits());
                assert_eq!(a.prompt_tokens, b.prompt_tokens);
                assert_eq!(a.new_tokens, b.new_tokens);
            }
        }
        // Closed loops self-generate inside the replica: the iterator is
        // empty by contract, like `open_loop_trace`.
        let closed = TrafficSpec::closed_loop(4, 0.01, 50, 8, 4, 8);
        assert_eq!(open_loop_iter(&closed).count(), 0);
        assert!(open_loop_trace(&closed).is_empty());
    }

    /// The streaming entry points fed the materialized trace must replay
    /// the slice entry points to the bit.
    #[test]
    fn stream_entry_points_match_slice_entry_points() {
        let t = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 60.0, burst: 5 },
            ..TrafficSpec::poisson(60.0, 150, 16, 4, 32)
        }
        .with_seed(41);
        let trace = open_loop_trace(&t);
        let slo = SloSpec::unconstrained();
        let a = simulate_trace_on(&cfg(4), &mut ContinuousBatch, &t, &trace, &slo);
        let b = simulate_trace_stream(
            &cfg(4),
            &mut ContinuousBatch,
            &t,
            t.requests,
            trace.iter().copied(),
            &slo,
        );
        assert_eq!(a.fingerprint(), b.fingerprint());
        let a = simulate_replicated_on(
            &cfg(4),
            2,
            RoutePolicy::JsqTokens,
            &ContinuousBatch,
            &t,
            &trace,
            &slo,
        );
        let b = simulate_replicated_stream(
            &cfg(4),
            2,
            RoutePolicy::JsqTokens,
            &ContinuousBatch,
            &t,
            t.requests,
            trace.iter().copied(),
            &slo,
        );
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// Quantized-time mode against the bit-exact default across arrival
    /// processes, KV accounting modes, and replica counts: identical
    /// completion/token/rejection counts, and every latency tail within
    /// the documented bound `2·decode_step + 1e-6·|reference|`.
    #[test]
    fn quantized_mode_stays_within_the_documented_epsilon() {
        let close = |q: f64, r: f64, step: f64, what: &str| {
            assert!(
                (q - r).abs() <= 2.0 * step + 1e-6 * r.abs(),
                "{what}: quantized {q} vs reference {r} (step {step})"
            );
        };
        let specs = [
            TrafficSpec::poisson(20.0, 150, 16, 8, 64).with_seed(5),
            TrafficSpec {
                arrival: ArrivalProcess::Bursty { rps: 20.0, burst: 6 },
                ..TrafficSpec::poisson(20.0, 150, 16, 8, 64)
            }
            .with_seed(5),
            TrafficSpec::closed_loop(6, 0.002, 120, 16, 8, 64).with_seed(5),
        ];
        for t in &specs {
            for paged in [false, true] {
                for replicas in [1usize, 2] {
                    let mut exact = cfg(4);
                    if paged {
                        exact.kv = KvBudget::tokens(4096, 16);
                        exact.paged_kv = true;
                    }
                    let mut quant = exact;
                    quant.quantum = 0.05; // 5 decode steps per jump
                    let run = |c: &SimConfig| {
                        simulate_replicated(
                            c,
                            replicas,
                            RoutePolicy::RoundRobin,
                            &ContinuousBatch,
                            t,
                            &SloSpec::unconstrained(),
                        )
                    };
                    let r = run(&exact);
                    let q = run(&quant);
                    let tag = format!("paged={paged} replicas={replicas} {:?}", t.arrival);
                    assert_eq!(r.completed, q.completed, "{tag}");
                    assert_eq!(r.tokens, q.tokens, "{tag}");
                    assert_eq!(r.rejected, q.rejected, "{tag}");
                    // Closed loops feed completions back into their own
                    // arrivals, so a one-iteration completion shift
                    // reorders resubmits — but per-client RNG streams pin
                    // every client's k-th token budget regardless of that
                    // order, so the full epsilon contract now binds for
                    // closed-loop tails too (this used to assert only the
                    // count exactness above).
                    let step = exact.cost.decode_step_s;
                    close(q.ttft_p50_s, r.ttft_p50_s, step, &tag);
                    close(q.ttft_p99_s, r.ttft_p99_s, step, &tag);
                    close(q.tpot_p50_s, r.tpot_p50_s, step, &tag);
                    close(q.tpot_p99_s, r.tpot_p99_s, step, &tag);
                    close(q.total_p99_s, r.total_p99_s, step, &tag);
                    close(q.makespan_s, r.makespan_s, step, &tag);
                }
            }
        }
    }

    /// A quantum so large it never splits a stretch takes the same jumps
    /// as fast-forward up to float rounding (`k·step` fused vs `k`
    /// sequential adds): identical completion and token counts, and the
    /// clock within the documented epsilon.
    #[test]
    fn oversized_quantum_degenerates_to_fast_forward_jumps() {
        let t = TrafficSpec::poisson(10.0, 100, 16, 8, 64).with_seed(23);
        let mut quant = cfg(4);
        quant.quantum = 1e9;
        let a = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let b = simulate_trace(&quant, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.tokens, b.tokens);
        let step = quant.cost.decode_step_s;
        assert!(
            (a.makespan_s - b.makespan_s).abs() <= 2.0 * step + 1e-6 * a.makespan_s.abs(),
            "makespan {} vs {}",
            a.makespan_s,
            b.makespan_s
        );
    }

    /// Dropping `tail_cap` below the offered count flips aggregation to
    /// the sketch: counts and throughput stay exact, per-request records
    /// are dropped, and every tail lands within the sketch's relative
    /// accuracy of the exact order statistic.
    #[test]
    fn sketched_tails_track_exact_percentiles() {
        let t = TrafficSpec::poisson(40.0, 400, 16, 1, 256).with_seed(29);
        let exact = simulate_trace(&cfg(8), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let mut c = cfg(8);
        c.tail_cap = 100; // offered 400 > cap => sketched
        let sk = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(sk.completed, exact.completed);
        assert_eq!(sk.tokens, exact.tokens);
        assert_eq!(sk.offered, exact.offered);
        assert!(sk.per_request.is_empty(), "sketched mode must not hold samples");
        assert!(!exact.per_request.is_empty());
        let alpha = crate::util::stats::SKETCH_DEFAULT_ALPHA;
        for (q, r, what) in [
            (sk.ttft_p50_s, exact.ttft_p50_s, "ttft p50"),
            (sk.ttft_p99_s, exact.ttft_p99_s, "ttft p99"),
            (sk.tpot_p50_s, exact.tpot_p50_s, "tpot p50"),
            (sk.tpot_p99_s, exact.tpot_p99_s, "tpot p99"),
            (sk.total_p50_s, exact.total_p50_s, "total p50"),
            (sk.total_p99_s, exact.total_p99_s, "total p99"),
        ] {
            assert!(
                (q - r).abs() <= 2.0 * alpha * r.abs() + 1e-12,
                "{what}: sketch {q} vs exact {r}"
            );
        }
        // The replicated merge path: per-replica sketches folded together
        // must agree with the fleet-exact tails to the same bound.
        let fleet_exact = simulate_replicated(
            &cfg(8),
            2,
            RoutePolicy::RoundRobin,
            &ContinuousBatch,
            &t,
            &SloSpec::unconstrained(),
        );
        let fleet_sk = simulate_replicated(
            &c,
            2,
            RoutePolicy::RoundRobin,
            &ContinuousBatch,
            &t,
            &SloSpec::unconstrained(),
        );
        assert_eq!(fleet_sk.completed, fleet_exact.completed);
        assert_eq!(fleet_sk.tokens, fleet_exact.tokens);
        assert!(
            (fleet_sk.ttft_p99_s - fleet_exact.ttft_p99_s).abs()
                <= 2.0 * alpha * fleet_exact.ttft_p99_s.abs() + 1e-12,
            "merged fleet sketch p99 {} vs exact {}",
            fleet_sk.ttft_p99_s,
            fleet_exact.ttft_p99_s
        );
    }

    /// In-flight TTFT lower bound: requests already waiting past the
    /// target count against the violation budget *before* they are served,
    /// so a one-slot replica drowning in queue aborts long before it
    /// grinds through every stranded request — and a generous target
    /// still replays the full run bit for bit.
    #[test]
    fn in_flight_ttft_wait_aborts_hopeless_queues() {
        // One slot, one enormous resident request: everyone behind it
        // waits ~20 virtual seconds against a 0.5 s TTFT target.
        let t = TrafficSpec::poisson(1e6, 50, 8, 2000, 2000).with_seed(2);
        let tight = SloSpec::new(0.5, f64::INFINITY);
        let full = simulate_trace(&cfg(1), &mut ContinuousBatch, &t, &tight);
        let mut c = cfg(1);
        c.early_abort = true;
        let aborted = simulate_trace(&c, &mut ContinuousBatch, &t, &tight);
        assert!(!full.meets(&tight) && !aborted.meets(&tight), "verdicts must agree");
        assert!(aborted.aborted_early);
        assert!(
            aborted.iterations < full.iterations,
            "queue-wait bound must abort early: {} vs {}",
            aborted.iterations,
            full.iterations
        );
        assert!(aborted.completed < aborted.offered);
        // A target no queued request can violate never trips the bound.
        let loose = SloSpec::new(1e6, f64::INFINITY);
        let a = simulate_trace(&cfg(1), &mut ContinuousBatch, &t, &loose);
        let b = simulate_trace(&c, &mut ContinuousBatch, &t, &loose);
        assert!(!b.aborted_early);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// `FaultSpec::none` must be byte-identical to the fault-free
    /// replicated path — the delegation the "existing goldens hold"
    /// guarantee rests on — with the new accounting fields pinned to 0.
    #[test]
    fn faultspec_none_is_fingerprint_identical() {
        let t = TrafficSpec::poisson(60.0, 150, 16, 4, 16).with_seed(11);
        let slo = SloSpec::unconstrained();
        for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::JsqTokens] {
            for replicas in [1usize, 3] {
                let a =
                    simulate_replicated(&cfg(4), replicas, route, &ContinuousBatch, &t, &slo);
                let b = simulate_replicated_faults(
                    &cfg(4),
                    replicas,
                    route,
                    &ContinuousBatch,
                    &t,
                    &FaultSpec::none(),
                    &slo,
                );
                assert_eq!(a.fingerprint(), b.fingerprint(), "{route:?} x{replicas}");
                assert_eq!(b.redispatched, 0);
                assert_eq!(b.lost, 0);
                assert_eq!(b.downtime_frac.to_bits(), 0.0f64.to_bits());
            }
        }
    }

    /// Scripted mid-run kill of 1 of 3 replicas: its in-flight work
    /// re-dispatches (recompute from scratch), the p99 TTFT strictly
    /// degrades versus the fault-free fleet, downtime registers, replay
    /// is bit-reproducible, and conservation holds.
    #[test]
    fn scripted_kill_redispatches_and_degrades_ttft() {
        let t = TrafficSpec::poisson(40.0, 200, 16, 8, 32).with_seed(5);
        let slo = SloSpec::unconstrained();
        let clean =
            simulate_replicated(&cfg(4), 3, RoutePolicy::Jsq, &ContinuousBatch, &t, &slo);
        let faults =
            FaultSpec::scripted(FaultSpec::parse_plan("fail:0@1.0,recover:0@3.0").unwrap());
        let run = || {
            simulate_replicated_faults(
                &cfg(4),
                3,
                RoutePolicy::Jsq,
                &ContinuousBatch,
                &t,
                &faults,
                &slo,
            )
        };
        let f = run();
        assert_eq!(f.fingerprint(), run().fingerprint(), "faulted replay must be exact");
        assert_eq!(f.completed + f.rejected + f.lost, f.offered);
        assert!(f.redispatched > 0, "the kill must catch work in flight");
        assert!(f.downtime_frac > 0.0, "2 s of 1-of-3 down must register");
        assert!(
            f.ttft_p99_s > clean.ttft_p99_s,
            "the recompute detour must show in the tail: {} vs clean {}",
            f.ttft_p99_s,
            clean.ttft_p99_s
        );
    }

    /// A scripted blackout that never recovers strands everything still
    /// unserved: counted lost (never hung), conservation intact, and the
    /// availability verdict fails.
    #[test]
    fn whole_fleet_down_forever_loses_the_tail() {
        let t = TrafficSpec::poisson(40.0, 100, 16, 4, 8).with_seed(3);
        let faults =
            FaultSpec::scripted(FaultSpec::parse_plan("fail:0@0.5,fail:1@0.5").unwrap());
        let slo = SloSpec::unconstrained();
        let f = simulate_replicated_faults(
            &cfg(4),
            2,
            RoutePolicy::RoundRobin,
            &ContinuousBatch,
            &t,
            &faults,
            &slo,
        );
        assert!(f.lost > 0, "arrivals after the blackout can never be served");
        assert!(f.completed < f.offered);
        assert_eq!(f.completed + f.rejected + f.lost, f.offered);
        assert!(!f.meets_available(&slo, 0.99));
    }

    /// Stochastic MTBF/MTTR faults: bit-reproducible for a fixed seed,
    /// and conservation holds under every seeded schedule.
    #[test]
    fn stochastic_faults_conserve_and_replay() {
        let t = TrafficSpec::poisson(50.0, 300, 16, 4, 16).with_seed(9);
        let slo = SloSpec::unconstrained();
        for seed in [1u64, 2, 3] {
            let faults = FaultSpec::mtbf(2.0, 0.5, seed);
            let run = || {
                simulate_replicated_faults(
                    &cfg(4),
                    3,
                    RoutePolicy::JsqTokens,
                    &ContinuousBatch,
                    &t,
                    &faults,
                    &slo,
                )
            };
            let a = run();
            assert_eq!(a.fingerprint(), run().fingerprint(), "seed {seed}");
            assert_eq!(a.completed + a.rejected + a.lost, a.offered, "seed {seed}");
            assert!(a.downtime_frac > 0.0 && a.downtime_frac < 1.0, "seed {seed}");
        }
    }

    /// Overcommit on a pool far smaller than the aggregate footprint must
    /// preempt (the optimism was real), conserve every request (preempted
    /// work recomputes and finishes), stay within the physical capacity,
    /// and replay bit-identically.
    #[test]
    fn overcommit_preempts_conserves_and_replays() {
        let mut c = cfg(8);
        c.kv = KvBudget::tokens(64, 8);
        c.paged_kv = true;
        c.overcommit = Some(OvercommitSpec::quantile(0.5));
        // Footprint 8 + U[4,48] <= 56 tokens: everything fits alone, so
        // nothing is shed and conservation reads completed == offered.
        let t = TrafficSpec::poisson(1000.0, 60, 8, 4, 48).with_seed(11);
        let slo = SloSpec::unconstrained();
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &slo);
        assert!(rep.preempted > 0, "a 64-token pool under ~34-token charges must preempt");
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.completed, rep.offered, "preempted requests recompute and finish");
        assert!(rep.peak_kv_tokens <= 64, "peak kv={}", rep.peak_kv_tokens);
        let again = simulate_trace(&c, &mut ContinuousBatch, &t, &slo);
        assert_eq!(rep.fingerprint(), again.fingerprint());
    }

    /// With overcommit and tiers off, the report carries none of the new
    /// state: no preemptions, no tier rows, no windows — the shape the
    /// off-path byte-identity property rests on.
    #[test]
    fn plain_runs_carry_no_overcommit_state() {
        let t = TrafficSpec::poisson(100.0, 40, 16, 4, 16);
        let rep = simulate_trace(&cfg(4), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.preempted, 0);
        assert!(rep.tiers.is_empty());
        assert!(rep.windows.is_empty());
    }

    /// Tier-ordered admission must buy the interactive tier a tighter TTFT
    /// tail than batch under overload, while the fairness bound keeps
    /// batch completing (bounded starvation).
    #[test]
    fn tiers_favor_interactive_ttft_without_starving_batch() {
        let islo = SloSpec::new(0.5, 0.05);
        let bslo = SloSpec::unconstrained();
        let tiers = TierSpec::new(0.5, 2, 8, islo, bslo).with_fairness(4);
        // Batch budgets 32..64 on 2 slots at 50 req/s: heavy overload, so
        // queue order decides TTFT.
        let t = TrafficSpec::poisson(50.0, 80, 16, 32, 64).with_seed(13).with_tiers(tiers);
        let rep = simulate_trace(&cfg(2), &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert_eq!(rep.completed, rep.offered);
        assert_eq!(rep.tiers.len(), 2);
        let (i, b) = (&rep.tiers[0], &rep.tiers[1]);
        assert_eq!((i.tier, b.tier), (0, 1));
        assert!(i.completed > 0 && b.completed > 0, "both tiers must be sampled and served");
        assert!(
            i.ttft_p99_s < b.ttft_p99_s,
            "priority admission must show in the tails: interactive {} vs batch {}",
            i.ttft_p99_s,
            b.ttft_p99_s
        );
        assert_eq!(i.completed + b.completed, rep.completed);
        assert_eq!(i.tokens + b.tokens, rep.tokens);
    }

    /// Windowed goodput rows partition the run: bucket sums reproduce the
    /// aggregate counters exactly and buckets come out time-ordered.
    #[test]
    fn goodput_windows_partition_the_run() {
        let mut c = cfg(4);
        c.window_s = 0.5;
        let t = TrafficSpec::poisson(50.0, 60, 8, 4, 8).with_seed(3);
        let rep = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(!rep.windows.is_empty());
        assert!(rep.windows.windows(2).all(|w| w[0].start_s < w[1].start_s));
        assert_eq!(rep.windows.iter().map(|w| w.completed).sum::<usize>(), rep.completed);
        assert_eq!(rep.windows.iter().map(|w| w.tokens).sum::<usize>(), rep.tokens);
        assert!(rep.windows.iter().all(|w| w.good_tokens <= w.tokens));
    }

    /// The decode fast-forward must stay bit-identical to per-iteration
    /// stepping with the overcommit ledger in the loop: the bulk-append
    /// cap provably excludes preemption inside a jumped stretch.
    #[test]
    fn fast_forward_matches_reference_under_overcommit() {
        let mut c = cfg(8);
        c.kv = KvBudget::tokens(768, 16);
        c.paged_kv = true;
        c.overcommit = Some(OvercommitSpec::quantile(0.5));
        let t = TrafficSpec::poisson(3.0, 40, 16, 32, 128).with_seed(7);
        let mut reference = c;
        reference.reference_step = true;
        let a = simulate_trace(&reference, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        let b = simulate_trace(&c, &mut ContinuousBatch, &t, &SloSpec::unconstrained());
        assert!(a.peak_kv_tokens > 0);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// The overcommit payoff when the block pool (not the slot count)
    /// bounds concurrency: reservation pins a request's whole footprint
    /// for its whole residency, while lazy allocation holds only the
    /// grown prefix — roughly half the block-time — so the same trace
    /// runs ~2x the concurrency and finishes sooner even paying the
    /// recompute penalty for preemptions.
    #[test]
    fn overcommit_outserves_reservation_on_a_block_bound_pool() {
        let mut reserved = cfg(16);
        reserved.kv = KvBudget::tokens(256, 8);
        reserved.paged_kv = true;
        let mut oc = reserved;
        oc.overcommit = Some(OvercommitSpec::quantile(0.5));
        // Mean footprint 8 + 62 = 70 tokens (9 blocks): the 32-block pool
        // sustains ~3.5 reserved requests but ~6.5 lazily-grown ones, and
        // 16 slots keep the slot count from binding first.
        let t = TrafficSpec::poisson(1e4, 300, 8, 4, 120).with_seed(17);
        let slo = SloSpec::unconstrained();
        let r = simulate_trace(&reserved, &mut ContinuousBatch, &t, &slo);
        let o = simulate_trace(&oc, &mut ContinuousBatch, &t, &slo);
        assert_eq!(r.completed, r.offered);
        assert_eq!(o.completed, o.offered, "preempted work must still finish");
        assert_eq!(o.tokens, r.tokens, "same seeded budgets either way");
        assert!(o.peak_live > r.peak_live, "lazy allocation must admit more concurrency");
        assert!(
            o.makespan_s < r.makespan_s,
            "overcommit concurrency must finish sooner: {} vs {}",
            o.makespan_s,
            r.makespan_s
        );
        assert!(o.goodput_tokens_per_s > r.goodput_tokens_per_s);
    }

    /// Overcommit + tiers across a replicated fleet: conservation holds,
    /// preemption lands on batch first, and the run replays bit-identically.
    #[test]
    fn replicated_overcommit_tiers_conserve_and_replay() {
        let mut c = cfg(4);
        c.kv = KvBudget::tokens(96, 8);
        c.paged_kv = true;
        c.overcommit = Some(OvercommitSpec::quantile(0.5));
        let tiers =
            TierSpec::new(0.4, 2, 8, SloSpec::new(0.5, 0.05), SloSpec::unconstrained())
                .with_fairness(4);
        let t = TrafficSpec::poisson(200.0, 120, 8, 16, 48).with_seed(29).with_tiers(tiers);
        let slo = SloSpec::unconstrained();
        let run = || {
            simulate_replicated(&c, 2, RoutePolicy::JsqTokens, &ContinuousBatch, &t, &slo)
        };
        let rep = run();
        assert_eq!(rep.completed + rep.rejected, rep.offered);
        assert_eq!(rep.completed, rep.offered, "nothing here exceeds the pool alone");
        assert!(rep.preempted > 0);
        assert_eq!(
            rep.tiers.iter().map(|t| t.preempted).sum::<usize>(),
            rep.preempted,
            "per-tier preemption counts must partition the total"
        );
        assert_eq!(rep.fingerprint(), run().fingerprint());
    }
}
