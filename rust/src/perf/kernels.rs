//! Roofline kernel latencies on one chiplet.
//!
//! A decode-step GEMM with the weights resident in CC-MEM is limited by
//! `max(flops / peak_flops, bytes / mem_bw)`; the CC-MEM burst engine keeps
//! the port at near-peak rate for the highly structured GEMM access pattern
//! (validated by [`crate::ccmem::traffic`]), so no extra derating is applied
//! to the memory term. Elementwise work (norms, activations, embeddings)
//! rides the SIMD cores and is folded into a small epilogue factor.

use crate::arch::ChipletDesign;

/// Fraction of peak MACs achievable on the GEMM body (systolic/SIMD
/// efficiency at decode tile shapes).
pub const MAC_EFFICIENCY: f64 = 0.9;

/// Epilogue overhead factor for elementwise ops (layernorm, activation,
/// residual) relative to the GEMM time.
pub const EPILOGUE_FACTOR: f64 = 1.03;

/// Latency (s) of a kernel with the given FLOPs and CC-MEM traffic on one
/// chip. Compute and memory streams overlap (double-buffered bursts), so
/// the kernel sits on the roofline.
pub fn kernel_latency(chip: &ChipletDesign, flops: f64, bytes: f64) -> f64 {
    let t_compute = flops / (chip.tflops * 1e12 * MAC_EFFICIENCY);
    let t_memory = bytes / (chip.mem_bw_gbps * 1e9);
    t_compute.max(t_memory) * EPILOGUE_FACTOR
}

/// Compute-side utilization implied by a kernel (1.0 = compute-bound).
pub fn kernel_compute_util(chip: &ChipletDesign, flops: f64, bytes: f64) -> f64 {
    let t = kernel_latency(chip, flops, bytes);
    (flops / (chip.tflops * 1e12)) / t
}

/// Memo table for [`kernel_latency`] within one mapping search.
///
/// The roofline inputs of the per-layer kernel depend only on the
/// (tensor-parallel width, micro-batch) pair — not on the pipeline depth or
/// the server-count scale — so a search over hundreds of candidate mappings
/// touches only a handful of distinct kernels. Keyed by `(tp, microbatch)`;
/// **must not** be shared across different (chip, workload) pairs.
#[derive(Clone, Debug, Default)]
pub struct KernelCache {
    entries: Vec<(usize, usize, f64)>,
}

impl KernelCache {
    /// Cached latency for `(tp, microbatch)`, computing it via `f` on miss.
    pub fn latency(&mut self, tp: usize, microbatch: usize, f: impl FnOnce() -> f64) -> f64 {
        if let Some(&(_, _, v)) =
            self.entries.iter().find(|&&(a, b, _)| a == tp && b == microbatch)
        {
            return v;
        }
        let v = f();
        self.entries.push((tp, microbatch, v));
        v
    }

    /// Number of distinct kernels memoized.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been memoized yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The micro-batch at which a chip's FC kernels transition from
/// memory-bound to compute-bound: `µb* = bytes_per_param · F / (2B)`.
pub fn balanced_microbatch(chip: &ChipletDesign, bytes_per_param: f64) -> f64 {
    bytes_per_param * chip.tflops * 1e12 / (2.0 * chip.mem_bw_gbps * 1e9 / MAC_EFFICIENCY)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chip() -> ChipletDesign {
        ChipletDesign {
            die_mm2: 140.0,
            sram_mb: 225.8,
            tflops: 5.5,
            mem_bw_gbps: 2750.0,
            n_bank_groups: 172,
            io_link_gbps: 25.0,
            io_links: 4,
            tdp_w: 14.1,
        }
    }

    #[test]
    fn memory_bound_small_microbatch() {
        let c = chip();
        // µb=1 FC shard: OI = 1 FLOP/byte < balance 2 ⇒ memory-bound
        let bytes = 26.6e6; // ~weights of one GPT-3 layer / 136 chips
        let flops = bytes; // 2·µb·P/tp with µb=1, fp16
        let t = kernel_latency(&c, flops, bytes);
        assert!((t - bytes / 2.75e12 * EPILOGUE_FACTOR).abs() / t < 1e-9);
        assert!(kernel_compute_util(&c, flops, bytes) < 0.6);
    }

    #[test]
    fn compute_bound_large_microbatch() {
        let c = chip();
        let bytes = 26.6e6;
        let flops = bytes * 32.0; // µb = 32
        let util = kernel_compute_util(&c, flops, bytes);
        assert!(util > 0.85, "util={util}");
    }

    #[test]
    fn balance_point_matches_table2_intuition() {
        // bw_ratio 0.5 B/FLOP chip with fp16 weights balances near µb=2
        let ub = balanced_microbatch(&chip(), 2.0);
        assert!((1.5..=2.5).contains(&ub), "ub={ub}");
    }

    #[test]
    fn cache_memoizes_by_tp_and_microbatch() {
        let c = chip();
        let mut cache = KernelCache::default();
        let mut calls = 0usize;
        let mut get = |tp, ub, flops, bytes| {
            cache.latency(tp, ub, || {
                calls += 1;
                kernel_latency(&c, flops, bytes)
            })
        };
        let a = get(136, 2, 5.3e7, 2.7e7);
        let b = get(136, 2, 5.3e7, 2.7e7); // hit
        let d = get(68, 2, 1.06e8, 5.4e7); // different tp: miss
        assert_eq!(a, b);
        assert_eq!(calls, 2);
        assert_ne!(a, d);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn latency_monotone() {
        let c = chip();
        assert!(kernel_latency(&c, 2e9, 1e6) > kernel_latency(&c, 1e9, 1e6));
        assert!(kernel_latency(&c, 1e6, 2e9) > kernel_latency(&c, 1e6, 1e9));
    }
}
