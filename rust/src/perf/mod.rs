//! Analytical inference simulation (paper §4.2 "Inference Simulation").
//!
//! * [`kernels`] — roofline latency of the per-chip compute/memory kernels.
//! * [`allreduce`] — collective latency `T = (N−1)·(D/N)/B + T_init`, with
//!   the 2D weight-stationary `O(1/√n)` communication scaling [37].
//! * [`pipeline`] — the pipeline/micro-batch schedule
//!   `l_all = l_prefill + (t−1)·max(l_mb, n·l_s)` (paper Fig. 6).
//! * [`simulator`] — end-to-end: per-token latency, throughput, utilization
//!   for a (server, workload, mapping) triple.
//! * [`events`] — discrete-event *serving* simulation: synthetic arrival
//!   traces through a [`crate::sched::Policy`] at the analytic iteration
//!   latencies, reporting TTFT/TPOT tails, occupancy and goodput.
//! * [`trace`] — streaming ingestion of real request traces
//!   (`serve-sim --trace-file`), validated once, replayed lazily.

pub mod allreduce;
pub mod events;
pub mod kernels;
pub mod pipeline;
pub mod simulator;
pub mod trace;

pub use events::{simulate_trace, IterCost, ServeReport, SimConfig};
pub use simulator::{simulate, simulate_cached, DecodePerf};
