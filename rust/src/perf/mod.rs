//! Analytical inference simulation (paper §4.2 "Inference Simulation").
//!
//! * [`kernels`] — roofline latency of the per-chip compute/memory kernels.
//! * [`allreduce`] — collective latency `T = (N−1)·(D/N)/B + T_init`, with
//!   the 2D weight-stationary `O(1/√n)` communication scaling [37].
//! * [`pipeline`] — the pipeline/micro-batch schedule
//!   `l_all = l_prefill + (t−1)·max(l_mb, n·l_s)` (paper Fig. 6).
//! * [`simulator`] — end-to-end: per-token latency, throughput, utilization
//!   for a (server, workload, mapping) triple.

pub mod allreduce;
pub mod kernels;
pub mod pipeline;
pub mod simulator;

pub use simulator::{simulate, simulate_cached, DecodePerf};
