//! End-to-end inference simulation for a (server, workload, mapping)
//! triple (paper §4.2).
//!
//! Per layer and micro-batch, one chip runs its FC shard (roofline kernel),
//! streams its KV shard for attention, and participates in two all-reduces
//! (post-attention and post-FFN) under the 2D weight-stationary layout.
//! Stages exchange boundary activations over the on-PCB torus. The pipeline
//! schedule then yields the token period, throughput and utilization.

use crate::arch::ServerDesign;
use crate::config::Workload;
use crate::mapping::{partition, Mapping};
use crate::perf::{allreduce, kernels, pipeline};

/// Simulated decode-phase performance of a full system.
#[derive(Clone, Debug)]
pub struct DecodePerf {
    /// One pipeline stage's latency for one micro-batch, s.
    pub stage_latency: f64,
    /// One micro-batch through all stages, s.
    pub microbatch_latency: f64,
    /// Steady-state per-token period, s.
    pub token_period: f64,
    /// Sustained generation throughput, tokens/s (whole system).
    pub tokens_per_s: f64,
    /// Tokens/s per chip (Table 2's metric).
    pub tokens_per_s_chip: f64,
    /// Prefill latency for the workload's prompt, s.
    pub prefill_latency: f64,
    /// Compute utilization during decode (0..1).
    pub compute_util: f64,
    /// CC-MEM bandwidth utilization during decode (0..1).
    pub mem_util: f64,
    /// Share of the token period spent in communication.
    pub comm_frac: f64,
    /// Chips actually used by the mapping.
    pub n_chips: usize,
}

/// Simulate decode-phase serving. Returns `None` when the mapping does not
/// fit chip memory or violates basic shape constraints.
pub fn simulate(server: &ServerDesign, w: &Workload, mapping: &Mapping) -> Option<DecodePerf> {
    simulate_cached(server, w, mapping, &mut kernels::KernelCache::default())
}

/// [`simulate`] with an external kernel-latency memo table.
///
/// A mapping search evaluates hundreds of candidates whose per-layer
/// roofline kernel depends only on `(tp, microbatch)`; passing one
/// [`kernels::KernelCache`] for the whole search skips the recomputation.
/// The cache is keyed by `(tp, microbatch)` only, so it **must** be scoped
/// to a single (server, workload) pair. Results are bit-identical to the
/// uncached path.
pub fn simulate_cached(
    server: &ServerDesign,
    w: &Workload,
    mapping: &Mapping,
    cache: &mut kernels::KernelCache,
) -> Option<DecodePerf> {
    let m = &w.model;
    if mapping.pp > m.n_layers || mapping.tp == 0 || mapping.microbatch == 0 {
        return None;
    }
    if mapping.microbatch > w.batch {
        return None;
    }
    let chip = &server.chiplet;
    let prof = partition::profile(w, mapping);
    if !prof.fits(chip.sram_mb) {
        return None;
    }

    // --- one layer, one micro-batch, on one chip ---------------------
    let bytes_layer = prof.weight_read_per_layer_ub + prof.kv_read_per_layer_ub;
    let t_kernel = cache.latency(mapping.tp, mapping.microbatch, || {
        kernels::kernel_latency(chip, prof.flops_per_layer_ub, bytes_layer)
    });
    // two all-reduces per layer (attention output, FFN output)
    let act_bytes = mapping.microbatch as f64 * m.d_model as f64 * m.bytes_per_param;
    let t_ar = if w.comm_1d {
        2.0 * allreduce::allreduce_latency(act_bytes, mapping.tp, chip.io_link_gbps)
    } else {
        2.0 * allreduce::allreduce_2d_ws(act_bytes, mapping.tp, chip.io_link_gbps)
    };
    let t_layer = t_kernel + t_ar;

    // --- stage latency: resident layers + boundary activation hop ----
    let t_hop = if mapping.pp > 1 {
        act_bytes / (chip.io_link_gbps * 1e9) + allreduce::T_INIT
    } else {
        0.0
    };
    let l_s = prof.layers_per_stage as f64 * t_layer + t_hop;
    let l_mb = mapping.pp as f64 * l_s;

    // --- pipeline schedule -------------------------------------------
    let n_micro = mapping.n_micro(w.batch);
    let period = pipeline::token_period(l_mb, l_s, n_micro);
    let tokens_per_s = w.batch as f64 / period;
    let n_chips = mapping.n_chips();

    // --- utilization ---------------------------------------------------
    // Total FLOPs per generated-token round: every chip runs each of its
    // resident layers once per micro-batch.
    let flops_round = prof.flops_per_layer_ub
        * prof.layers_per_stage as f64
        * n_micro as f64
        * mapping.pp as f64
        * mapping.tp as f64;
    let peak = n_chips as f64 * chip.tflops * 1e12;
    let compute_util = (flops_round / period) / peak;
    let bytes_round = bytes_layer
        * prof.layers_per_stage as f64
        * n_micro as f64
        * mapping.pp as f64
        * mapping.tp as f64;
    let mem_util = (bytes_round / period) / (n_chips as f64 * chip.mem_bw_gbps * 1e9);

    // --- prefill (reported, excluded from the throughput metric) -----
    let prefill_flops =
        2.0 * m.n_params() * (w.prompt_len * w.batch) as f64;
    let prefill_latency =
        prefill_flops / (peak * kernels::MAC_EFFICIENCY * 0.7); // 70% prefill efficiency

    Some(DecodePerf {
        stage_latency: l_s,
        microbatch_latency: l_mb,
        token_period: period,
        tokens_per_s,
        tokens_per_s_chip: tokens_per_s / n_chips as f64,
        prefill_latency,
        compute_util: compute_util.min(1.0),
        mem_util: mem_util.min(1.0),
        comm_frac: (t_ar * prof.layers_per_stage as f64 + t_hop) / l_s,
        n_chips,
    })
}

/// Max context length supportable at a batch size on a system of `n_chips`
/// chips with `sram_mb` each (Table 2's "Max Context Length" row).
pub fn max_context(w: &Workload, n_chips: usize, sram_mb: f64) -> usize {
    let m = &w.model;
    let total = n_chips as f64 * sram_mb * 1e6 * partition::SRAM_USABLE_FRAC;
    let spare = total - m.weight_bytes();
    if spare <= 0.0 {
        return 0;
    }
    let kv_per_tok =
        2.0 * m.n_layers as f64 * (m.kv_heads() * m.d_head) as f64 * m.bytes_per_param;
    (spare / (kv_per_tok * w.batch as f64)) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipletDesign;
    use crate::config::ModelSpec;

    fn gpt3_server() -> ServerDesign {
        ServerDesign {
            chiplet: ChipletDesign {
                die_mm2: 140.0,
                sram_mb: 225.8,
                tflops: 5.5,
                mem_bw_gbps: 2750.0,
                n_bank_groups: 172,
                io_link_gbps: 25.0,
                io_links: 4,
                tdp_w: 14.1,
            },
            chips_per_lane: 17,
            lanes: 8,
            server_power_w: 2020.0,
            server_capex: 5300.0,
        }
    }

    /// Table 2 GPT-3 row: 8.1 tokens/s/chip at tp=136, pp=96, batch 256,
    /// µb=2. Our simulator must land within ~1.5× (the paper's own model
    /// has unpublished constants).
    #[test]
    fn table2_gpt3_tokens_per_chip() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let mapping = Mapping { tp: 136, pp: 96, microbatch: 2 };
        let p = simulate(&gpt3_server(), &w, &mapping).expect("fits");
        assert!(
            (5.4..=12.2).contains(&p.tokens_per_s_chip),
            "tokens/s/chip = {}",
            p.tokens_per_s_chip
        );
        // decode utilization should be substantial at batch 256
        assert!(p.compute_util > 0.3, "util={}", p.compute_util);
    }

    #[test]
    fn cached_simulation_is_bit_identical() {
        let s = gpt3_server();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let mut cache = crate::perf::kernels::KernelCache::default();
        // vary pp at fixed (tp, µb): the kernel memo must be reused and the
        // results must match the uncached path exactly.
        for pp in [96usize, 48, 32] {
            let m = Mapping { tp: 136, pp, microbatch: 2 };
            let plain = simulate(&s, &w, &m).unwrap();
            let cached = simulate_cached(&s, &w, &m, &mut cache).unwrap();
            assert_eq!(plain.token_period.to_bits(), cached.token_period.to_bits());
            assert_eq!(plain.tokens_per_s.to_bits(), cached.tokens_per_s.to_bits());
            assert_eq!(plain.compute_util.to_bits(), cached.compute_util.to_bits());
        }
        assert_eq!(cache.len(), 1, "one distinct (tp, µb) kernel expected");
    }

    #[test]
    fn too_small_memory_rejects() {
        let mut s = gpt3_server();
        s.chiplet.sram_mb = 10.0;
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        assert!(simulate(&s, &w, &Mapping { tp: 136, pp: 96, microbatch: 2 }).is_none());
    }

    #[test]
    fn throughput_grows_with_batch_when_pipelined() {
        let s = gpt3_server();
        let m = Mapping { tp: 136, pp: 96, microbatch: 2 };
        let t64 = simulate(&s, &Workload::new(ModelSpec::gpt3(), 1024, 64), &m).unwrap();
        let t256 = simulate(&s, &Workload::new(ModelSpec::gpt3(), 1024, 256), &m).unwrap();
        assert!(t256.tokens_per_s > t64.tokens_per_s);
    }

    /// Fig. 9's mechanism: at fixed batch, throughput peaks when pp ≈ batch
    /// (with µb=1) and degrades for very small pp.
    #[test]
    fn pipeline_depth_sweet_spot() {
        let s = gpt3_server();
        let w = Workload::new(ModelSpec::gpt3(), 1024, 32);
        // use enough chips that memory fits in all cases: fix total 13056
        let thr = |pp: usize| {
            let tp = 13056 / pp;
            simulate(&s, &w, &Mapping { tp, pp, microbatch: 1 })
                .map(|p| p.tokens_per_s)
                .unwrap_or(0.0)
        };
        let t2 = thr(2);
        let t32 = thr(32);
        assert!(t32 > t2, "pp=32 {} should beat pp=2 {}", t32, t2);
    }

    #[test]
    fn microbatch_balances_roofline() {
        let s = gpt3_server();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let ub1 = simulate(&s, &w, &Mapping { tp: 136, pp: 96, microbatch: 1 }).unwrap();
        let ub2 = simulate(&s, &w, &Mapping { tp: 136, pp: 96, microbatch: 2 }).unwrap();
        // µb=2 matches the chip's 0.5 B/FLOP provisioning: better throughput
        assert!(ub2.tokens_per_s > ub1.tokens_per_s);
    }

    #[test]
    fn max_context_shrinks_with_batch() {
        let w64 = Workload::new(ModelSpec::gpt3(), 2048, 64);
        let w512 = Workload::new(ModelSpec::gpt3(), 2048, 512);
        let c64 = max_context(&w64, 13056, 225.8);
        let c512 = max_context(&w512, 13056, 225.8);
        assert!(c64 > c512);
        assert!(c64 > 2048, "Table 2 reports 8K max ctx at batch 256");
    }

    #[test]
    fn comm_fraction_reported() {
        let s = gpt3_server();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let p = simulate(&s, &w, &Mapping { tp: 136, pp: 96, microbatch: 2 }).unwrap();
        assert!(p.comm_frac > 0.0 && p.comm_frac < 0.6, "comm={}", p.comm_frac);
    }
}
