//! Collective communication model (paper §4.2).
//!
//! An all-reduce of `D` bytes across `N` nodes decomposes into one
//! reduce-scatter plus one all-gather, each costing
//! `T = (N−1)·(D/N)/B + T_init` where `B` is the bandwidth of the slowest
//! link (ring algorithm — the reason board-level organic-substrate links
//! suffice: the in-package fast links would not help the slowest hop,
//! §3.3).
//!
//! For the feed-forward layers the 2D weight-stationary layout [37] reduces
//! the communicated activation volume to `O(1/√N)` of the 1D layout.

/// Link initialization/synchronization latency, s (on-PCB torus hop).
pub const T_INIT: f64 = 1.0e-7;

/// One reduce-scatter or all-gather of `d_bytes` across `n` nodes at
/// `link_gbps` per link.
pub fn phase_latency(d_bytes: f64, n: usize, link_gbps: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    (nf - 1.0) * (d_bytes / nf) / (link_gbps * 1e9) + T_INIT
}

/// Full all-reduce (reduce-scatter + all-gather).
pub fn allreduce_latency(d_bytes: f64, n: usize, link_gbps: f64) -> f64 {
    2.0 * phase_latency(d_bytes, n, link_gbps)
}

/// All-reduce under the 2D weight-stationary layout: the activation volume
/// each ring carries shrinks by √N versus 1D tensor parallelism.
pub fn allreduce_2d_ws(d_bytes: f64, n: usize, link_gbps: f64) -> f64 {
    allreduce_latency(d_bytes / (n as f64).sqrt(), n, link_gbps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_is_free() {
        assert_eq!(allreduce_latency(1e6, 1, 25.0), 0.0);
    }

    #[test]
    fn matches_paper_formula() {
        // T_rs = (N-1) * (D/N) / B + T_init, N=4, D=1 MB, B=25 GB/s
        let t = phase_latency(1e6, 4, 25.0);
        let expect = 3.0 * 0.25e6 / 25e9 + T_INIT;
        assert!((t - expect).abs() < 1e-15);
    }

    /// §2.3.2: with 2D weight-stationary, FFN communication scales
    /// O(1/√n) — quadrupling the chips halves the time (for bandwidth-
    /// dominated sizes).
    #[test]
    fn two_d_ws_scaling() {
        let d = 64e6; // large buffer so T_init is negligible
        let t4 = allreduce_2d_ws(d, 4, 25.0);
        let t16 = allreduce_2d_ws(d, 16, 25.0);
        // bandwidth term: (N-1)/N · D/√N / B ⇒ ratio ≈ (3/4·1/2) / (15/16·1/4) = 1.6
        let ratio = t4 / t16;
        assert!((1.4..=1.8).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn latency_floor_at_tiny_sizes() {
        // tiny messages are dominated by 2·T_init per phase pair
        let t = allreduce_latency(8.0, 64, 25.0);
        assert!(t >= 2.0 * T_INIT);
        assert!(t < 3.0 * T_INIT);
    }

    #[test]
    fn monotone_in_nodes_for_fixed_total() {
        // For fixed D the per-node share shrinks but (N-1) grows: the
        // bandwidth term approaches D/B asymptotically from below.
        let d = 1e6;
        let t2 = allreduce_latency(d, 2, 25.0);
        let t64 = allreduce_latency(d, 64, 25.0);
        assert!(t64 > t2);
        assert!(t64 < 2.0 * (d / 25e9) + 3.0 * T_INIT);
    }
}
