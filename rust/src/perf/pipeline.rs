//! Pipeline-parallel micro-batch schedule (paper §4.2, Fig. 6).
//!
//! With micro-batch latency `l_mb` (one micro-batch through all stages),
//! stage latency `l_s`, and `n` micro-batches per batch:
//!
//! * per-token generation latency = `max(l_mb, n·l_s)` — either the
//!   pipeline is deep enough that the batch refill dominates (`n·l_s`), or
//!   a single micro-batch's trip dominates (`l_mb`);
//! * `l_all = l_prefill + (t−1)·max(l_mb, n·l_s)` for `t` tokens;
//! * throughput ≈ `N / max(l_mb, n·l_s)`.

/// The per-generated-token period of the pipeline.
pub fn token_period(l_mb: f64, l_s: f64, n_micro: usize) -> f64 {
    l_mb.max(n_micro as f64 * l_s)
}

/// End-to-end latency to generate `t` tokens after a prefill.
pub fn total_latency(l_prefill: f64, l_mb: f64, l_s: f64, n_micro: usize, t: usize) -> f64 {
    l_prefill + (t.saturating_sub(1)) as f64 * token_period(l_mb, l_s, n_micro)
}

/// Sustained generation throughput (tokens/s) for batch size `batch`.
pub fn throughput(batch: usize, l_mb: f64, l_s: f64, n_micro: usize) -> f64 {
    batch as f64 / token_period(l_mb, l_s, n_micro)
}

/// Pipeline bubble fraction: how much of the steady-state period the
/// stages sit idle. Zero when `n·l_s ≥ l_mb` (the schedule of Fig. 6(b)).
pub fn bubble_fraction(l_mb: f64, l_s: f64, n_micro: usize) -> f64 {
    let period = token_period(l_mb, l_s, n_micro);
    let busy = (n_micro as f64 * l_s).min(period);
    1.0 - busy / period
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_is_max_of_both_constraints() {
        assert_eq!(token_period(1.0, 0.1, 4), 1.0); // l_mb-bound (Fig. 6a)
        assert_eq!(token_period(1.0, 0.1, 20), 2.0); // n·l_s-bound (Fig. 6b)
    }

    /// §4.2: with `l_s = l_mb / p`, throughput is maximized when both n and
    /// p grow; at n ≈ p the two constraints meet and utilization peaks —
    /// the Fig. 9 finding that stages ≈ batch is optimal.
    #[test]
    fn optimum_at_n_equal_p() {
        let l_unit = 1.0; // l_mb for p stages: l_mb = l_unit (independent of p)
        let batch = 64;
        let mut best_p = 0;
        let mut best_thr = 0.0;
        for p in [1usize, 2, 4, 8, 16, 32, 64] {
            let l_s = l_unit / p as f64;
            // µb = 1 ⇒ n = batch
            let thr = throughput(batch, l_unit, l_s, batch);
            if thr > best_thr {
                best_thr = thr;
                best_p = p;
            }
        }
        assert_eq!(best_p, 64, "pipeline depth should match batch");
    }

    #[test]
    fn no_bubbles_when_saturated() {
        assert_eq!(bubble_fraction(1.0, 0.1, 10), 0.0);
        assert!(bubble_fraction(1.0, 0.1, 2) > 0.7);
    }

    #[test]
    fn total_latency_includes_prefill_once() {
        let l = total_latency(3.0, 1.0, 0.2, 4, 11);
        assert!((l - (3.0 + 10.0 * 1.0)).abs() < 1e-12);
        // one token: prefill only
        assert_eq!(total_latency(3.0, 1.0, 0.2, 4, 1), 3.0);
    }
}
