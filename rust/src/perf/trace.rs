//! Streaming request-trace ingestion for the serving simulator
//! (`ccloud serve-sim --trace-file <csv>`).
//!
//! Trace format — CSV with a mandatory header, one row per request:
//!
//! ```csv
//! at_s,prompt_tokens,new_tokens
//! 0.000,128,64
//! 0.013,256,32
//! ```
//!
//! * `at_s` — arrival time in seconds, finite, `>= 0`, **non-decreasing**
//!   (the simulator merges the trace lazily with its event loop and never
//!   re-sorts it);
//! * `prompt_tokens` — prompt length in tokens (`>= 0`);
//! * `new_tokens` — tokens to generate (`>= 1`).
//!
//! Request ids are assigned by row order. Malformed rows (wrong field
//! count, bad numbers, time going backwards, CSV quoting errors) are
//! reported as `path: line N: message`.
//!
//! [`TraceFile::open`] makes one streaming validation pass that checks
//! every row and counts them — the simulator needs the offered request
//! count up front (early-abort budgets, completion accounting) but the
//! rows themselves are only pulled on demand: [`TraceFile::arrivals`]
//! re-reads the file lazily, so a 10M-request trace costs two sequential
//! scans and O(1) memory, never a materialized `Vec`.

use std::fs::File;
use std::io::BufReader;
use std::path::{Path, PathBuf};

use crate::perf::events::Arrival;
use crate::util::csv::CsvReader;

/// The mandatory header row of a trace file.
pub const TRACE_HEADER: [&str; 3] = ["at_s", "prompt_tokens", "new_tokens"];

/// A validated on-disk arrival trace: path plus the row count from the
/// validation pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceFile {
    path: PathBuf,
    requests: usize,
}

impl TraceFile {
    /// Open and fully validate a trace file in one streaming pass.
    /// Errors (missing file, bad header, malformed rows) are located
    /// strings suitable for `Error::Config`.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<TraceFile, String> {
        let path = path.as_ref().to_path_buf();
        let mut rows = Rows::new(&path)?;
        let mut requests = 0usize;
        for row in &mut rows {
            row?;
            requests += 1;
        }
        if requests == 0 {
            return Err(format!("{}: trace has a header but no request rows", path.display()));
        }
        Ok(TraceFile { path, requests })
    }

    /// Number of requests (rows) in the trace — the simulator's offered
    /// count.
    pub fn requests(&self) -> usize {
        self.requests
    }

    /// The underlying path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A fresh lazy pass over the arrivals. Rows were validated by
    /// [`TraceFile::open`]; if the file changed or vanished underneath,
    /// opening errors here, and a row that turned malformed mid-iteration
    /// ends the stream early — the run then completes fewer requests than
    /// offered and reports infeasible, which is the conservative outcome.
    pub fn arrivals(&self) -> Result<TraceArrivals, String> {
        Ok(TraceArrivals { rows: Rows::new(&self.path)? })
    }
}

/// Internal row-level iterator shared by the validation and replay passes.
struct Rows {
    reader: CsvReader<BufReader<File>>,
    path: PathBuf,
    last_at: f64,
    next_id: u64,
}

impl Rows {
    fn new(path: &Path) -> Result<Rows, String> {
        let f = File::open(path)
            .map_err(|e| format!("{}: cannot open trace file: {e}", path.display()))?;
        let mut reader = CsvReader::new(BufReader::new(f));
        match reader.next() {
            None => {
                return Err(format!(
                    "{}: empty trace file (expected header '{}')",
                    path.display(),
                    TRACE_HEADER.join(",")
                ))
            }
            Some(Err(e)) => return Err(format!("{}: {e}", path.display())),
            Some(Ok((line, fields))) => {
                if fields != TRACE_HEADER {
                    return Err(format!(
                        "{}: line {line}: expected header '{}' (got '{}')",
                        path.display(),
                        TRACE_HEADER.join(","),
                        fields.join(",")
                    ));
                }
            }
        }
        Ok(Rows { reader, path: path.to_path_buf(), last_at: f64::NEG_INFINITY, next_id: 0 })
    }

    fn row_err(&self, line: usize, msg: String) -> String {
        format!("{}: line {line}: {msg}", self.path.display())
    }

    fn parse(&mut self, line: usize, fields: &[String]) -> Result<Arrival, String> {
        if fields.len() != 3 {
            return Err(self.row_err(
                line,
                format!("expected 3 fields ({}), got {}", TRACE_HEADER.join(","), fields.len()),
            ));
        }
        let at_s: f64 = fields[0]
            .trim()
            .parse()
            .map_err(|_| self.row_err(line, format!("at_s '{}' is not a number", fields[0])))?;
        if !at_s.is_finite() || at_s < 0.0 {
            return Err(self.row_err(line, format!("at_s {at_s} must be finite and >= 0")));
        }
        if at_s < self.last_at {
            return Err(self.row_err(
                line,
                format!("at_s {at_s} goes backwards (previous row was {})", self.last_at),
            ));
        }
        let prompt_tokens: usize = fields[1].trim().parse().map_err(|_| {
            self.row_err(line, format!("prompt_tokens '{}' is not a non-negative integer", fields[1]))
        })?;
        let new_tokens: usize = fields[2].trim().parse().map_err(|_| {
            self.row_err(line, format!("new_tokens '{}' is not a non-negative integer", fields[2]))
        })?;
        if new_tokens == 0 {
            return Err(self.row_err(line, "new_tokens must be >= 1".into()));
        }
        self.last_at = at_s;
        let id = self.next_id;
        self.next_id += 1;
        Ok(Arrival { id, at_s, prompt_tokens, new_tokens, tier: 0 })
    }
}

impl Iterator for Rows {
    type Item = Result<Arrival, String>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.reader.next()? {
            Err(e) => Some(Err(format!("{}: {e}", self.path.display()))),
            Ok((line, fields)) => Some(self.parse(line, &fields)),
        }
    }
}

/// Lazy arrival stream over a validated trace file — the trace-file
/// producer behind the same iterator interface as
/// [`crate::perf::events::open_loop_iter`].
pub struct TraceArrivals {
    rows: Rows,
}

impl Iterator for TraceArrivals {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        // Validated at open(); a file mutated mid-run degrades to a short
        // (conservative) stream rather than a panic.
        self.rows.next()?.ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};

    static UNIQ: AtomicU64 = AtomicU64::new(0);

    fn write_temp(content: &str) -> PathBuf {
        let n = UNIQ.fetch_add(1, Ordering::SeqCst);
        let path = std::env::temp_dir()
            .join(format!("ccloud-trace-test-{}-{n}.csv", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn valid_trace_counts_and_streams() {
        let p = write_temp("at_s,prompt_tokens,new_tokens\n0.0,8,4\n0.5,16,1\n0.5,0,2\n");
        let tf = TraceFile::open(&p).unwrap();
        assert_eq!(tf.requests(), 3);
        let got: Vec<Arrival> = tf.arrivals().unwrap().collect();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], Arrival { id: 0, at_s: 0.0, prompt_tokens: 8, new_tokens: 4, tier: 0 });
        assert_eq!(got[1], Arrival { id: 1, at_s: 0.5, prompt_tokens: 16, new_tokens: 1, tier: 0 });
        // Equal timestamps are fine (ties keep row order), prompt may be 0.
        assert_eq!(got[2], Arrival { id: 2, at_s: 0.5, prompt_tokens: 0, new_tokens: 2, tier: 0 });
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn errors_are_located_by_path_and_line() {
        let missing = std::env::temp_dir().join("ccloud-trace-test-does-not-exist.csv");
        let e = TraceFile::open(&missing).unwrap_err();
        assert!(e.contains("cannot open trace file"), "{e}");
        assert!(e.contains("ccloud-trace-test-does-not-exist.csv"), "{e}");

        for (body, needle, line) in [
            ("", "empty trace file", 0),
            ("wrong,header,row\n0.0,1,1\n", "expected header", 1),
            ("at_s,prompt_tokens,new_tokens\n", "no request rows", 0),
            ("at_s,prompt_tokens,new_tokens\n0.0,8\n", "expected 3 fields", 2),
            ("at_s,prompt_tokens,new_tokens\noops,8,4\n", "is not a number", 2),
            ("at_s,prompt_tokens,new_tokens\n-1.0,8,4\n", "must be finite and >= 0", 2),
            ("at_s,prompt_tokens,new_tokens\n1.0,8,4\n0.5,8,4\n", "goes backwards", 3),
            ("at_s,prompt_tokens,new_tokens\n0.0,-3,4\n", "non-negative integer", 2),
            ("at_s,prompt_tokens,new_tokens\n0.0,8,0\n", "new_tokens must be >= 1", 2),
            ("at_s,prompt_tokens,new_tokens\n\"0.0,8,4\n", "unterminated", 2),
        ] {
            let p = write_temp(body);
            let e = TraceFile::open(&p).unwrap_err();
            assert!(e.contains(needle), "body {body:?}: {e}");
            if line > 0 {
                assert!(e.contains(&format!("line {line}")), "body {body:?}: {e}");
            }
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn blank_lines_and_quoted_fields_are_tolerated() {
        let p = write_temp("at_s,prompt_tokens,new_tokens\n\n\"0.25\",8,4\n\n");
        let tf = TraceFile::open(&p).unwrap();
        assert_eq!(tf.requests(), 1);
        let got: Vec<Arrival> = tf.arrivals().unwrap().collect();
        assert_eq!(got[0].at_s, 0.25);
        std::fs::remove_file(&p).ok();
    }
}
