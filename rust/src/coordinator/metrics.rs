//! Serving metrics: latency percentiles (including TTFT tails),
//! throughput over wall time, batch occupancy.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::request::Response;
use crate::util::stats;
use crate::util::sync::lock_unpoisoned;

/// Aggregated serving metrics (thread safe).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    responses: Vec<Response>,
    batches: u64,
    live_slots: u64,
    total_slots: u64,
    decode_steps: u64,
    decode_time_s: f64,
    /// Start of the first recorded batch (its record time minus its own
    /// duration) — the origin of the wall-clock throughput window.
    wall_start: Option<Instant>,
    /// End of the most recent recorded batch.
    wall_end: Option<Instant>,
}

/// A point-in-time summary of the metrics.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Requests completed.
    pub completed: usize,
    /// Generated tokens (all requests).
    pub tokens: usize,
    /// Tokens per second of decode time (lockstep decode rate).
    pub decode_tokens_per_s: f64,
    /// Tokens per second of wall time across all recorded batches —
    /// includes prefill and scheduling gaps, the rate a client actually
    /// observes.
    pub wall_tokens_per_s: f64,
    /// Mean per-token decode latency, s.
    pub per_token_mean_s: f64,
    /// p50 time-to-first-token, s.
    pub ttft_p50_s: f64,
    /// p99 time-to-first-token, s.
    pub ttft_p99_s: f64,
    /// p50 total request latency, s.
    pub total_p50_s: f64,
    /// p99 total request latency, s.
    pub total_p99_s: f64,
    /// Mean queueing delay, s.
    pub queue_mean_s: f64,
    /// Batch slot occupancy (1.0 = every batch full).
    pub occupancy: f64,
    /// Batches executed.
    pub batches: u64,
}

impl Metrics {
    /// New empty metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one executed batch: occupancy counters plus its prefill and
    /// decode wall time (which also advance the wall-clock window).
    pub fn record_batch(&self, live: usize, total: usize, steps: usize, prefill_s: f64, decode_s: f64) {
        let now = Instant::now();
        let wall = (prefill_s + decode_s).max(0.0);
        let mut m = lock_unpoisoned(&self.inner);
        m.batches += 1;
        m.live_slots += live as u64;
        m.total_slots += total as u64;
        m.decode_steps += steps as u64;
        m.decode_time_s += decode_s;
        // Window start is the earliest batch *start* seen so far — with
        // multiple replicas, a later-starting batch can record first, so
        // keep the minimum rather than the first.
        let start = now.checked_sub(Duration::from_secs_f64(wall)).unwrap_or(now);
        m.wall_start = Some(m.wall_start.map_or(start, |ws| ws.min(start)));
        m.wall_end = Some(now);
    }

    /// Record a completed response.
    pub fn record_response(&self, resp: Response) {
        lock_unpoisoned(&self.inner).responses.push(resp);
    }

    /// Summarize.
    pub fn summary(&self) -> Summary {
        let m = lock_unpoisoned(&self.inner);
        let totals: Vec<f64> = m.responses.iter().map(|r| r.total_s()).collect();
        let queues: Vec<f64> = m.responses.iter().map(|r| r.queue_s).collect();
        let ttfts: Vec<f64> = m.responses.iter().map(|r| r.ttft_s).collect();
        let per_tok: Vec<f64> = m.responses.iter().map(|r| r.per_token_s()).collect();
        let tokens: usize = m.responses.iter().map(|r| r.tokens.len()).sum();
        let wall_s = match (m.wall_start, m.wall_end) {
            (Some(a), Some(b)) => b.saturating_duration_since(a).as_secs_f64(),
            _ => 0.0,
        };
        Summary {
            completed: m.responses.len(),
            tokens,
            decode_tokens_per_s: if m.decode_time_s > 0.0 {
                tokens as f64 / m.decode_time_s
            } else {
                0.0
            },
            wall_tokens_per_s: if wall_s > 0.0 { tokens as f64 / wall_s } else { 0.0 },
            per_token_mean_s: stats::mean(&per_tok),
            ttft_p50_s: stats::percentile(&ttfts, 50.0),
            ttft_p99_s: stats::percentile(&ttfts, 99.0),
            total_p50_s: stats::percentile(&totals, 50.0),
            total_p99_s: stats::percentile(&totals, 99.0),
            queue_mean_s: stats::mean(&queues),
            occupancy: if m.total_slots > 0 {
                m.live_slots as f64 / m.total_slots as f64
            } else {
                0.0
            },
            batches: m.batches,
        }
    }
}

impl Summary {
    /// Render the summary as a small report.
    pub fn render(&self) -> String {
        format!(
            "requests={} tokens={} wall={:.1} tok/s decode={:.1} tok/s per-token={} ttft p50={} p99={} total p50={} p99={} queue={} occupancy={:.0}% batches={}",
            self.completed,
            self.tokens,
            self.wall_tokens_per_s,
            self.decode_tokens_per_s,
            crate::util::fmt_secs(self.per_token_mean_s),
            crate::util::fmt_secs(self.ttft_p50_s),
            crate::util::fmt_secs(self.ttft_p99_s),
            crate::util::fmt_secs(self.total_p50_s),
            crate::util::fmt_secs(self.total_p99_s),
            crate::util::fmt_secs(self.queue_mean_s),
            self.occupancy * 100.0,
            self.batches,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_aggregates() {
        let m = Metrics::new();
        m.record_batch(3, 4, 10, 0.5, 1.0);
        m.record_batch(4, 4, 10, 0.5, 1.0);
        for i in 0..3 {
            m.record_response(Response {
                id: i,
                tokens: vec![0; 10],
                queue_s: 0.1,
                prefill_s: 0.2,
                decode_s: 1.0,
                ttft_s: 0.3,
            });
        }
        let s = m.summary();
        assert_eq!(s.completed, 3);
        assert_eq!(s.tokens, 30);
        assert!((s.occupancy - 7.0 / 8.0).abs() < 1e-12);
        assert!((s.decode_tokens_per_s - 15.0).abs() < 1e-12);
        assert!(s.total_p99_s >= s.total_p50_s);
        // TTFT tails come from the recorded first-token timestamps.
        assert!((s.ttft_p50_s - 0.3).abs() < 1e-12);
        assert!(s.ttft_p99_s >= s.ttft_p50_s);
        // Wall throughput: the window spans at least the first batch's
        // claimed 1.5 s of wall (record times here are back-to-back), so
        // the rate is positive and cannot exceed 30 tokens / 1.5 s.
        assert!(s.wall_tokens_per_s > 0.0);
        assert!(s.wall_tokens_per_s <= 30.0 / 1.5 + 1e-9, "wall={}", s.wall_tokens_per_s);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Metrics::new().summary();
        assert_eq!(s.completed, 0);
        assert_eq!(s.decode_tokens_per_s, 0.0);
        assert_eq!(s.wall_tokens_per_s, 0.0);
        assert_eq!(s.ttft_p99_s, 0.0);
        assert_eq!(s.occupancy, 0.0);
    }
}
