//! Request/response types for the serving coordinator.

use std::time::Instant;

/// Monotonic request identifier.
pub type RequestId = u64;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Unique id.
    pub id: RequestId,
    /// Prompt token ids (padded/truncated to the artifact's prompt length
    /// by the batcher).
    pub prompt: Vec<i32>,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Arrival time (for queueing-latency metrics).
    pub arrived: Instant,
}

impl Request {
    /// New request arriving now.
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, arrived: Instant::now() }
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id.
    pub id: RequestId,
    /// Generated token ids.
    pub tokens: Vec<i32>,
    /// Queue wait before the batch started, seconds.
    pub queue_s: f64,
    /// Prefill latency, seconds.
    pub prefill_s: f64,
    /// Decode time, seconds.
    pub decode_s: f64,
    /// Time to first token: queue wait + prefill (the first token emerges
    /// from the prefill), seconds.
    pub ttft_s: f64,
}

impl Response {
    /// Total time from arrival to completion.
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }

    /// Per-generated-token decode latency.
    pub fn per_token_s(&self) -> f64 {
        if self.tokens.is_empty() {
            0.0
        } else {
            self.decode_s / self.tokens.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_metrics() {
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.8,
            ttft_s: 0.3,
        };
        assert!((r.total_s() - 1.1).abs() < 1e-12);
        assert!((r.per_token_s() - 0.2).abs() < 1e-12);
        assert!((r.ttft_s - (r.queue_s + r.prefill_s)).abs() < 1e-12);
    }
}
