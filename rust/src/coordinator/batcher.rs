//! Dynamic batcher: queue requests, emit fixed-size batches.
//!
//! The AOT artifact is compiled at a fixed batch size B and prompt length
//! P (static shapes are what make the HLO loadable ahead of time), so the
//! batcher forms batches of exactly B slots: it waits up to `max_wait` for
//! the queue to fill, then pads the remainder with idle slots. Prompts are
//! left-truncated / right-padded to P. This is the paper's batching model:
//! throughput comes from weight reuse across the batch, and the batch
//! decodes in lockstep.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::request::Request;

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Batch size (must equal the artifact's compiled batch).
    pub batch: usize,
    /// Prompt length (the artifact's compiled prompt length).
    pub prompt_len: usize,
    /// Max time to wait for a full batch before emitting a padded one.
    pub max_wait: Duration,
    /// Token id used for padding prompts and idle slots.
    pub pad_token: i32,
}

/// A formed batch: B prompt rows plus the requests occupying them
/// (None = idle padding slot).
#[derive(Debug)]
pub struct Batch {
    /// [B, P] prompt token matrix.
    pub prompts: Vec<Vec<i32>>,
    /// Slot occupancy.
    pub slots: Vec<Option<Request>>,
    /// When the batch was formed.
    pub formed: Instant,
}

impl Batch {
    /// Number of live (non-padding) slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Largest token budget among live slots (decode steps to run).
    pub fn max_new_tokens(&self) -> usize {
        self.slots.iter().flatten().map(|r| r.max_new_tokens).max().unwrap_or(0)
    }
}

/// Thread-safe request queue + batch former. Consumers block on a condvar
/// — no polling loops, so an idle serving leader burns no CPU.
pub struct Batcher {
    /// Configuration.
    pub cfg: BatcherConfig,
    queue: Mutex<VecDeque<Request>>,
    nonempty: Condvar,
    closed: AtomicBool,
}

impl Batcher {
    /// New empty batcher.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            nonempty: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) {
        self.queue.lock().unwrap().push_back(req);
        self.nonempty.notify_all();
    }

    /// Number of queued requests.
    pub fn queued(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Signal shutdown: `next_batch` returns None once drained.
    ///
    /// The flag is flipped while holding the queue lock: every waiter is
    /// either parked in a wait (and gets the notify) or still holds the
    /// lock (and re-checks the flag before parking), so no wakeup can be
    /// missed and the waits need no insurance timeouts.
    pub fn close(&self) {
        let _q = self.queue.lock().unwrap();
        self.closed.store(true, Ordering::SeqCst);
        self.nonempty.notify_all();
    }

    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Normalize a prompt to exactly P tokens (keep the most recent P,
    /// right-pad with `pad_token`).
    pub fn fit_prompt(&self, prompt: &[i32]) -> Vec<i32> {
        let p = self.cfg.prompt_len;
        let mut row: Vec<i32> = if prompt.len() > p {
            prompt[prompt.len() - p..].to_vec()
        } else {
            prompt.to_vec()
        };
        row.resize(p, self.cfg.pad_token);
        row
    }

    /// Block until a batch can be formed (or the batcher is closed and
    /// empty → None). Waits up to `max_wait` for a full batch, then emits
    /// a padded partial batch.
    ///
    /// Both waits park on the `nonempty` condvar — `submit`/`close` wake us
    /// — instead of the old 1 ms sleep-poll loop, which burned a core per
    /// idle replica and added up to 1 ms of needless latency per request.
    /// `close()` flips the shutdown flag under the queue lock, so neither
    /// wait can miss its wakeup (see [`Batcher::close`]) and an idle
    /// replica truly sleeps.
    pub fn next_batch(&self) -> Option<Batch> {
        let mut q = self.queue.lock().unwrap();
        // Wait for the first request (or shutdown).
        while q.is_empty() {
            if self.is_closed() {
                return None;
            }
            q = self.nonempty.wait(q).unwrap();
        }
        // Wait for a full batch, the deadline, or shutdown.
        let deadline = Instant::now() + self.cfg.max_wait;
        while q.len() < self.cfg.batch && !self.is_closed() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self.nonempty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
        let n = q.len().min(self.cfg.batch);
        let mut slots: Vec<Option<Request>> = Vec::with_capacity(self.cfg.batch);
        let mut prompts = Vec::with_capacity(self.cfg.batch);
        for _ in 0..n {
            let req = q.pop_front().unwrap();
            prompts.push(self.fit_prompt(&req.prompt));
            slots.push(Some(req));
        }
        for _ in n..self.cfg.batch {
            prompts.push(vec![self.cfg.pad_token; self.cfg.prompt_len]);
            slots.push(None);
        }
        Some(Batch { prompts, slots, formed: Instant::now() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BatcherConfig {
        BatcherConfig { batch: 4, prompt_len: 8, max_wait: Duration::from_millis(5), pad_token: 0 }
    }

    #[test]
    fn full_batch_when_queue_full() {
        let b = Batcher::new(cfg());
        for i in 0..4 {
            b.submit(Request::new(i, vec![1, 2, 3], 4));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 4);
        assert_eq!(batch.prompts.len(), 4);
        assert!(batch.prompts.iter().all(|p| p.len() == 8));
    }

    #[test]
    fn partial_batch_after_timeout() {
        let b = Batcher::new(cfg());
        b.submit(Request::new(1, vec![5; 3], 2));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 1);
        assert!(batch.slots[1].is_none());
        assert_eq!(batch.max_new_tokens(), 2);
    }

    #[test]
    fn prompt_fitting() {
        let b = Batcher::new(cfg());
        // short prompt: right-padded
        assert_eq!(b.fit_prompt(&[1, 2]), vec![1, 2, 0, 0, 0, 0, 0, 0]);
        // long prompt: keeps the last 8
        let long: Vec<i32> = (0..12).collect();
        assert_eq!(b.fit_prompt(&long), (4..12).collect::<Vec<i32>>());
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(cfg());
        b.submit(Request::new(1, vec![1], 1));
        b.close();
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_submits_wake_the_batch_wait() {
        // A filling batch must complete on the submit wakeup, not wait out
        // the deadline (generous margins: deadline 5 s, expect ≪ 1 s).
        let b = std::sync::Arc::new(Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(5),
            ..cfg()
        }));
        b.submit(Request::new(1, vec![1], 1));
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let batch = b2.next_batch().unwrap();
            (batch.live(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        for i in 2..=4 {
            b.submit(Request::new(i, vec![1], 1));
        }
        let (live, waited) = h.join().unwrap();
        assert_eq!(live, 4);
        assert!(waited < Duration::from_secs(2), "waited {waited:?} — condvar wakeup missing");
    }

    #[test]
    fn close_unblocks_waiters() {
        let b = std::sync::Arc::new(Batcher::new(cfg()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap());
    }
}
