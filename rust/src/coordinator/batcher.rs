//! Request queue + policy-driven batch former.
//!
//! The queueing machinery (submit, condvar waits, shutdown) lives here;
//! the *decision* of when a batch forms and how many slots it fills lives
//! in [`crate::sched`] — the same [`Policy`](crate::sched::Policy) trait
//! the discrete-event serving simulator drives. The AOT artifact is
//! compiled at a fixed batch size B and prompt length P (static shapes are
//! what make the HLO loadable ahead of time), so an emitted [`Batch`] has
//! exactly B slots: admitted requests first, idle padding slots after.
//! Prompts are left-truncated / right-padded to P.
//!
//! Because the artifact's prefill is whole-batch, the live executor cannot
//! refill slots mid-generation; the view it presents to the policy says so
//! (`refill_mid_iteration = false`, `live = 0` between batches) and
//! [`sanitize`](crate::sched::sanitize) guarantees no policy can emit an
//! empty (all-padding) batch — the seed happily ran a full prefill on one.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::request::Request;
use crate::sched::{sanitize, Action, KvBudget, Policy, SchedView, StaticBatch};
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Batch size (must equal the artifact's compiled batch).
    pub batch: usize,
    /// Prompt length (the artifact's compiled prompt length).
    pub prompt_len: usize,
    /// Max time to wait for a full batch before emitting a padded one —
    /// the [`StaticBatch`] policy's window, measured from the *head-of-line
    /// request's arrival* (an upper bound on its queueing delay). The seed
    /// measured from when batch forming began instead, which let a request
    /// that had already aged in the queue behind a running batch wait a
    /// second full window.
    pub max_wait: Duration,
    /// Token id used for padding prompts and idle slots.
    pub pad_token: i32,
    /// KV-capacity budget of the deployment the artifact is served on
    /// ([`KvBudget::unlimited`] when the engine shape is the only cap).
    /// The whole-batch AOT engine holds every admitted request's KV for
    /// the full batch, so admission charges each request's *actual*
    /// footprint — truncated prompt plus its token budget — against a
    /// fresh per-batch paged ledger rather than reserving full context
    /// per slot.
    pub kv: KvBudget,
}

/// A formed batch: B prompt rows plus the requests occupying them
/// (None = idle padding slot).
#[derive(Debug)]
pub struct Batch {
    /// [B, P] prompt token matrix.
    pub prompts: Vec<Vec<i32>>,
    /// Slot occupancy.
    pub slots: Vec<Option<Request>>,
    /// When the batch was formed.
    pub formed: Instant,
}

impl Batch {
    /// Number of live (non-padding) slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Largest token budget among live slots (decode steps to run).
    pub fn max_new_tokens(&self) -> usize {
        self.slots.iter().flatten().map(|r| r.max_new_tokens).max().unwrap_or(0)
    }

    /// True when every slot is padding — running prefill on such a batch
    /// is pure waste and the server skips it.
    pub fn is_idle(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }
}

/// Thread-safe request queue + batch former. Consumers block on a condvar
/// — no polling loops, so an idle serving leader burns no CPU.
pub struct Batcher {
    /// Configuration.
    pub cfg: BatcherConfig,
    /// Time origin for the policy's `now_s`/arrival clocks.
    epoch: Instant,
    queue: Mutex<VecDeque<Request>>,
    nonempty: Condvar,
    closed: AtomicBool,
}

impl Batcher {
    /// New empty batcher.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            epoch: Instant::now(),
            queue: Mutex::new(VecDeque::new()),
            nonempty: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    /// The default batch-forming policy: batch-synchronous with the
    /// configured window (the seed's behaviour).
    pub fn static_policy(&self) -> StaticBatch {
        StaticBatch::new(self.cfg.max_wait.as_secs_f64())
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) {
        lock_unpoisoned(&self.queue).push_back(req);
        self.nonempty.notify_all();
    }

    /// Number of queued requests.
    pub fn queued(&self) -> usize {
        lock_unpoisoned(&self.queue).len()
    }

    /// Signal shutdown: `next_batch` returns None once drained.
    ///
    /// The flag is flipped while holding the queue lock: every waiter is
    /// either parked in a wait (and gets the notify) or still holds the
    /// lock (and re-checks the flag before parking), so no wakeup can be
    /// missed and the waits need no insurance timeouts.
    pub fn close(&self) {
        let _q = lock_unpoisoned(&self.queue);
        self.closed.store(true, Ordering::SeqCst);
        self.nonempty.notify_all();
    }

    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Seconds since the batcher's epoch (the policy clock).
    fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// An instant on the policy clock (clamped to 0 before the epoch).
    fn instant_s(&self, t: Instant) -> f64 {
        t.saturating_duration_since(self.epoch).as_secs_f64()
    }

    /// Head-of-line requests the KV budget admits into one batch.
    ///
    /// Everything frees between whole batches, so a fresh ledger per
    /// decision sees each queued request's actual KV footprint (the prompt
    /// is truncated to the compiled length before prefill). A head request
    /// whose footprint exceeds the *entire* capacity could never be
    /// admitted by the ledger — since the per-batch ledger is always at
    /// full capacity here, "doesn't fit now" means "never fits" — and a
    /// live server must not deadlock on it (nor starve everything queued
    /// behind it): it is admitted alone, best effort, and the deployment
    /// model simply cannot hold its KV on-chip.
    fn kv_admissible(&self, q: &VecDeque<Request>) -> usize {
        if self.cfg.kv.capacity_tokens == usize::MAX {
            // Unlimited ledger (the default): everything queued fits —
            // skip the O(queue) footprint scan on every condvar wakeup.
            return q.len();
        }
        let n = self.cfg.kv.ledger().admissible(
            q.iter().map(|r| r.prompt.len().min(self.cfg.prompt_len) + r.max_new_tokens),
        );
        if n == 0 && !q.is_empty() {
            1
        } else {
            n
        }
    }

    /// Slot-count cap the view presents. The legacy full-context cap
    /// (`max_seqs`) binds as the *tighter* of the two accounting models,
    /// but — like the ledger path above — a `max_seqs == 0` deployment
    /// (spare CC-MEM below one full-context footprint, exactly the
    /// long-prompt case paged accounting exists for) must degrade to
    /// serving one request at a time, never to a parked-forever batcher.
    fn kv_slots(&self, queued: usize) -> usize {
        let n = self.cfg.kv.concurrency(self.cfg.batch);
        if n == 0 && queued > 0 {
            1
        } else {
            n
        }
    }

    /// Normalize a prompt to exactly P tokens (keep the most recent P,
    /// right-pad with `pad_token`).
    pub fn fit_prompt(&self, prompt: &[i32]) -> Vec<i32> {
        let p = self.cfg.prompt_len;
        let mut row: Vec<i32> = if prompt.len() > p {
            prompt[prompt.len() - p..].to_vec()
        } else {
            prompt.to_vec()
        };
        row.resize(p, self.cfg.pad_token);
        row
    }

    /// Pop `n` requests into a padded B-slot batch. `n >= 1` is guaranteed
    /// by the callers ([`sanitize`] never emits an empty admission).
    fn form_batch(&self, q: &mut MutexGuard<'_, VecDeque<Request>>, n: usize) -> Batch {
        let n = n.min(q.len()).min(self.cfg.batch);
        let mut slots: Vec<Option<Request>> = Vec::with_capacity(self.cfg.batch);
        let mut prompts = Vec::with_capacity(self.cfg.batch);
        for _ in 0..n {
            // `n` was clamped to the queue length above, so the queue
            // cannot run dry mid-batch; bail rather than panic if it does.
            let Some(req) = q.pop_front() else { break };
            prompts.push(self.fit_prompt(&req.prompt));
            slots.push(Some(req));
        }
        for _ in n..self.cfg.batch {
            prompts.push(vec![self.cfg.pad_token; self.cfg.prompt_len]);
            slots.push(None);
        }
        Batch { prompts, slots, formed: Instant::now() }
    }

    /// Block until the default batch-synchronous policy forms a batch (or
    /// the batcher is closed and empty → None).
    pub fn next_batch(&self) -> Option<Batch> {
        self.next_batch_policy(&mut self.static_policy())
    }

    /// Block until `policy` admits a batch (or the batcher is closed and
    /// empty → None). The policy sees the live-executor view — zero live
    /// slots between batches, no mid-iteration refill — and its decisions
    /// pass through [`sanitize`], so an admission is always 1..=B requests.
    ///
    /// Both waits park on the `nonempty` condvar — `submit`/`close` wake us
    /// — so an idle replica truly sleeps; `close()` flips the shutdown flag
    /// under the queue lock, so no wakeup can be missed (see
    /// [`Batcher::close`]).
    pub fn next_batch_policy(&self, policy: &mut dyn Policy) -> Option<Batch> {
        let mut q = lock_unpoisoned(&self.queue);
        loop {
            if self.is_closed() {
                if q.is_empty() {
                    return None;
                }
                // Drain: emit what is queued without waiting for more —
                // still KV-budgeted batch by batch (workers loop on
                // `next_batch_policy`, so the rest follows in later calls).
                let n = self.kv_admissible(&q).min(self.kv_slots(q.len())).max(1);
                return Some(self.form_batch(&mut q, n));
            }
            let now_s = self.now_s();
            let kv_admissible = self.kv_admissible(&q);
            let view = SchedView {
                now_s,
                queued: q.len(),
                oldest_arrival_s: q
                    .front()
                    .map(|r| self.instant_s(r.arrived))
                    .unwrap_or(now_s),
                live: 0,
                max_slots: self.cfg.batch,
                kv_slots: self.kv_slots(q.len()),
                kv_admissible,
                refill_mid_iteration: false,
            };
            match sanitize(policy.decide(&view), &view) {
                Action::Admit(n) => return Some(self.form_batch(&mut q, n)),
                Action::Wait(Some(deadline_s)) => {
                    if deadline_s <= now_s {
                        // The window already expired; re-decide immediately
                        // (the policy will admit on the next pass).
                        continue;
                    }
                    let (guard, _) = wait_timeout_unpoisoned(
                        &self.nonempty,
                        q,
                        Duration::from_secs_f64(deadline_s - now_s),
                    );
                    q = guard;
                }
                // `sanitize` never returns Decode when `live == 0`; treat it
                // like an open-ended wait if a custom policy insists.
                Action::Wait(None) | Action::Decode => {
                    q = wait_unpoisoned(&self.nonempty, q);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ContinuousBatch;

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            batch: 4,
            prompt_len: 8,
            max_wait: Duration::from_millis(5),
            pad_token: 0,
            kv: KvBudget::unlimited(),
        }
    }

    #[test]
    fn full_batch_when_queue_full() {
        let b = Batcher::new(cfg());
        for i in 0..4 {
            b.submit(Request::new(i, vec![1, 2, 3], 4));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 4);
        assert_eq!(batch.prompts.len(), 4);
        assert!(batch.prompts.iter().all(|p| p.len() == 8));
    }

    #[test]
    fn partial_batch_after_timeout() {
        let b = Batcher::new(cfg());
        b.submit(Request::new(1, vec![5; 3], 2));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 1);
        assert!(batch.slots[1].is_none());
        assert_eq!(batch.max_new_tokens(), 2);
        assert!(!batch.is_idle());
    }

    #[test]
    fn continuous_policy_skips_the_window() {
        // With the continuous policy a single queued request is admitted
        // immediately — no batch-forming wait even with a huge window.
        let b = Batcher::new(BatcherConfig { max_wait: Duration::from_secs(60), ..cfg() });
        b.submit(Request::new(1, vec![7; 3], 2));
        let t0 = Instant::now();
        let batch = b.next_batch_policy(&mut ContinuousBatch).unwrap();
        assert_eq!(batch.live(), 1);
        assert!(t0.elapsed() < Duration::from_secs(2), "continuous policy must not wait");
    }

    /// The paged KV budget caps live-path admission by *actual* request
    /// footprints (truncated prompt + token budget), not slot count.
    #[test]
    fn kv_budget_caps_live_admission() {
        // 40-token capacity in 8-token blocks; each request needs
        // 8 (truncated prompt) + 4 = 12 tokens = 2 blocks → 2 requests.
        let b = Batcher::new(BatcherConfig { kv: KvBudget::tokens(40, 8), ..cfg() });
        for i in 0..4 {
            b.submit(Request::new(i, vec![1; 16], 4));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 2, "ledger admits 2 of 4 despite 4 slots");
        // the remaining two fit a fresh per-batch ledger next time
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 2);
    }

    /// A request whose footprint exceeds the whole KV capacity must be
    /// served (alone, best effort), not deadlock the batcher and starve
    /// the queue behind it.
    #[test]
    fn oversized_request_is_served_alone_not_deadlocked() {
        let b = Batcher::new(BatcherConfig { kv: KvBudget::tokens(40, 8), ..cfg() });
        b.submit(Request::new(1, vec![1; 8], 100)); // 8 + 100 tokens >> 40
        b.submit(Request::new(2, vec![1; 8], 4)); // fits comfortably
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 1, "oversized head admitted alone");
        assert_eq!(batch.slots[0].as_ref().unwrap().id, 1);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.slots[0].as_ref().unwrap().id, 2);
    }

    /// The shutdown drain stays KV-budgeted: it emits admissible-sized
    /// batches until the queue empties rather than one over-budget flush.
    #[test]
    fn close_drain_respects_kv_budget() {
        let b = Batcher::new(BatcherConfig { kv: KvBudget::tokens(40, 8), ..cfg() });
        for i in 0..4 {
            b.submit(Request::new(i, vec![1; 16], 4)); // 12 tokens = 2 blocks each
        }
        b.close();
        let mut sizes = Vec::new();
        while let Some(batch) = b.next_batch() {
            sizes.push(batch.live());
        }
        assert_eq!(sizes, vec![2, 2], "5-block budget drains two 2-request batches");
    }

    /// The legacy sequence cap still binds through `kv_slots`.
    #[test]
    fn kv_seq_cap_limits_batch() {
        let b = Batcher::new(BatcherConfig { kv: KvBudget::seqs(3), ..cfg() });
        for i in 0..6 {
            b.submit(Request::new(i, vec![1; 4], 2));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.live(), 3);
    }

    /// A deployment whose spare CC-MEM is below one *full-context*
    /// footprint (`max_seqs == 0`) degrades to one request at a time —
    /// it must never park the batcher forever.
    #[test]
    fn zero_seq_budget_degrades_to_singles_not_deadlock() {
        let b = Batcher::new(BatcherConfig { kv: KvBudget::seqs(0), ..cfg() });
        for i in 0..3 {
            b.submit(Request::new(i, vec![1; 4], 2));
        }
        for expect in 0..3u64 {
            let batch = b.next_batch().expect("served, not deadlocked");
            assert_eq!(batch.live(), 1);
            assert_eq!(batch.slots[0].as_ref().unwrap().id, expect);
        }
    }

    #[test]
    fn prompt_fitting() {
        let b = Batcher::new(cfg());
        // short prompt: right-padded
        assert_eq!(b.fit_prompt(&[1, 2]), vec![1, 2, 0, 0, 0, 0, 0, 0]);
        // long prompt: keeps the last 8
        let long: Vec<i32> = (0..12).collect();
        assert_eq!(b.fit_prompt(&long), (4..12).collect::<Vec<i32>>());
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(cfg());
        b.submit(Request::new(1, vec![1], 1));
        b.close();
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_submits_wake_the_batch_wait() {
        // A filling batch must complete on the submit wakeup, not wait out
        // the deadline (generous margins: deadline 5 s, expect ≪ 1 s).
        let b = std::sync::Arc::new(Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(5),
            ..cfg()
        }));
        b.submit(Request::new(1, vec![1], 1));
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let batch = b2.next_batch().unwrap();
            (batch.live(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        for i in 2..=4 {
            b.submit(Request::new(i, vec![1], 1));
        }
        let (live, waited) = h.join().unwrap();
        assert_eq!(live, 4);
        assert!(waited < Duration::from_secs(2), "waited {waited:?} — condvar wakeup missing");
    }

    #[test]
    fn close_unblocks_waiters() {
        let b = std::sync::Arc::new(Batcher::new(cfg()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap());
    }

    /// Regression for the all-padding-batch bug: a policy that insists on
    /// admitting from an empty queue must never produce an idle batch —
    /// `sanitize` coerces it to a wait, and close() then yields None.
    #[test]
    fn empty_admission_never_forms_an_idle_batch() {
        struct AlwaysAdmit;
        impl Policy for AlwaysAdmit {
            fn name(&self) -> &'static str {
                "always-admit"
            }
            fn decide(&mut self, _: &SchedView) -> Action {
                Action::Admit(4)
            }
        }
        let b = std::sync::Arc::new(Batcher::new(cfg()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch_policy(&mut AlwaysAdmit));
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap().is_none(), "empty queue must yield None, not an idle batch");
    }
}
