//! The serving loop: replica worker threads drain the batcher under a
//! [`crate::sched::Policy`] — prefill once per admitted batch, then
//! lockstep decode steps until every live slot's budget is met.
//!
//! PJRT handles are not `Send` (the CPU client is thread-affine), so each
//! replica thread *owns* its `ModelEngine`; the shared [`Batcher`] queue is
//! the router: an idle replica pulls the next batch, which is exactly
//! least-loaded dispatch (work stealing). Per-replica batch counts are
//! tracked for balance reporting.
//!
//! The batch-formation *decision* is the policy's ([`BatchingMode`] picks
//! which): batch-synchronous static batching (the artifact's native
//! granularity) or the continuous policy, which admits greedily with no
//! forming window. The AOT engine's whole-batch prefill cannot refill
//! slots mid-generation, so continuous batching's iteration-level refill
//! is exercised by the discrete-event simulator
//! ([`crate::perf::events`]); live and simulated paths share the policy
//! code itself.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Request, RequestId, Response};
use crate::runtime::ModelEngine;
use crate::sched::{ContinuousBatch, KvBudget, Policy};
use crate::{Error, Result};

/// Which scheduling policy the replica workers run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchingMode {
    /// Batch-synchronous static batching with the configured wait window.
    #[default]
    Static,
    /// Continuous (greedy, iteration-level) batching — on the whole-batch
    /// AOT engine this admits without a forming window.
    Continuous,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max wait for a full batch (the static policy's window).
    pub max_wait: Duration,
    /// Engine replicas (one worker thread each).
    pub replicas: usize,
    /// Scheduling policy for batch formation.
    pub mode: BatchingMode,
    /// KV-capacity budget of the target deployment; admission charges each
    /// request's actual footprint against a per-batch paged ledger (see
    /// [`BatcherConfig::kv`](crate::coordinator::batcher::BatcherConfig)).
    /// Unlimited by default — the demo artifacts are tiny.
    pub kv: KvBudget,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_wait: Duration::from_millis(50),
            replicas: 1,
            mode: BatchingMode::Static,
            kv: KvBudget::unlimited(),
        }
    }
}

/// The serving coordinator: batcher + replica workers + metrics.
pub struct Coordinator {
    /// Request batcher (the shared work queue = the router).
    pub batcher: Arc<Batcher>,
    /// Serving metrics.
    pub metrics: Arc<Metrics>,
    /// Batches executed per replica (dispatch balance).
    pub replica_batches: Arc<Vec<AtomicU64>>,
    responses: Arc<Mutex<Vec<Response>>>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Load `cfg.replicas` copies of the artifact and start their worker
    /// threads. The manifest is read once up front to size the batcher.
    pub fn start(
        dir: impl AsRef<std::path::Path>,
        model: &str,
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = crate::runtime::Manifest::load(&dir, model)?;
        let batcher = Arc::new(Batcher::new(BatcherConfig {
            batch: manifest.batch,
            prompt_len: manifest.prompt_len,
            max_wait: cfg.max_wait,
            pad_token: 0,
            kv: cfg.kv,
        }));
        let metrics = Arc::new(Metrics::new());
        let responses = Arc::new(Mutex::new(Vec::new()));
        let replica_batches =
            Arc::new((0..cfg.replicas.max(1)).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let mut workers = Vec::new();
        for rid in 0..cfg.replicas.max(1) {
            let batcher = batcher.clone();
            let metrics = metrics.clone();
            let responses = responses.clone();
            let replica_batches = replica_batches.clone();
            let dir = dir.clone();
            let model = model.to_string();
            let mode = cfg.mode;
            workers.push(std::thread::spawn(move || -> Result<()> {
                // the engine lives and dies on this thread (PJRT affinity);
                // each replica owns its policy instance
                let engine = ModelEngine::load(&dir, &model)?;
                let mut static_policy = batcher.static_policy();
                let mut continuous_policy = ContinuousBatch;
                let policy: &mut dyn Policy = match mode {
                    BatchingMode::Static => &mut static_policy,
                    BatchingMode::Continuous => &mut continuous_policy,
                };
                while let Some(batch) = batcher.next_batch_policy(policy) {
                    let rs = run_batch(&engine, &metrics, batch)?;
                    replica_batches[rid].fetch_add(1, Ordering::Relaxed);
                    crate::util::sync::lock_unpoisoned(&responses).extend(rs);
                }
                Ok(())
            }));
        }
        Ok(Coordinator {
            batcher,
            metrics,
            replica_batches,
            responses,
            next_id: AtomicU64::new(1),
            workers,
        })
    }

    /// Submit a generation request; returns its id.
    pub fn submit(&self, prompt: Vec<i32>, max_new_tokens: usize) -> RequestId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.batcher.submit(Request::new(id, prompt, max_new_tokens));
        id
    }

    /// Stop accepting requests, drain the queue, join workers, and return
    /// all responses (sorted by request id).
    pub fn shutdown(mut self) -> Result<Vec<Response>> {
        self.batcher.close();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| Error::Runtime("worker panicked".into()))??;
        }
        let mut rs = std::mem::take(&mut *crate::util::sync::lock_unpoisoned(&self.responses));
        rs.sort_by_key(|r| r.id);
        Ok(rs)
    }
}

/// Execute one batch on this replica's engine.
///
/// Idle (all-padding) batches are skipped outright — running a full
/// prefill on pure padding was the seed's bug; `sanitize` already prevents
/// policies from forming such batches, and this guard keeps the invariant
/// local to the executor too.
fn run_batch(engine: &ModelEngine, metrics: &Metrics, batch: Batch) -> Result<Vec<Response>> {
    if batch.is_idle() {
        debug_assert!(batch.max_new_tokens() == 0);
        return Ok(Vec::new());
    }
    let t0 = Instant::now();
    let (mut tokens, mut state) = engine.prefill(&batch.prompts)?;
    let prefill_s = t0.elapsed().as_secs_f64();

    let steps = batch
        .max_new_tokens()
        .min(engine.manifest.max_ctx.saturating_sub(engine.manifest.prompt_len));
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); batch.slots.len()];
    let t1 = Instant::now();
    for _ in 0..steps {
        for (i, tok) in tokens.iter().enumerate() {
            generated[i].push(*tok);
        }
        tokens = engine.decode_step(&tokens, &mut state)?;
    }
    let decode_s = t1.elapsed().as_secs_f64();
    metrics.record_batch(batch.live(), batch.slots.len(), steps, prefill_s, decode_s);

    let mut out = Vec::new();
    for (i, slot) in batch.slots.iter().enumerate() {
        let Some(req) = slot else { continue };
        let n = req.max_new_tokens.min(steps);
        let queue_s = (batch.formed - req.arrived).as_secs_f64().max(0.0);
        let resp = Response {
            id: req.id,
            tokens: generated[i][..n].to_vec(),
            queue_s,
            prefill_s,
            decode_s: decode_s * n as f64 / steps.max(1) as f64,
            ttft_s: queue_s + prefill_s,
        };
        metrics.record_response(resp.clone());
        out.push(resp);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn serves_batches_end_to_end() {
        let dir = artifacts_dir();
        if !dir.join("cc-tiny.manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let coord = Coordinator::start(
            &dir,
            "cc-tiny",
            CoordinatorConfig {
                max_wait: Duration::from_millis(20),
                ..CoordinatorConfig::default()
            },
        )
        .unwrap();
        for i in 0..6 {
            coord.submit(vec![(i % 100) as i32 + 1; 10], 4);
        }
        let metrics = coord.metrics.clone();
        let responses = coord.shutdown().unwrap();
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens.len(), 4);
            assert!(r.total_s() > 0.0);
            assert!(r.ttft_s > 0.0 && r.ttft_s <= r.total_s());
        }
        let s = metrics.summary();
        assert!(s.ttft_p99_s >= s.ttft_p50_s);
        assert!(s.wall_tokens_per_s > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let dir = artifacts_dir();
        if !dir.join("cc-tiny.manifest.json").exists() {
            return;
        }
        let run = || {
            let coord = Coordinator::start(&dir, "cc-tiny", CoordinatorConfig::default()).unwrap();
            let id = coord.submit(vec![7, 8, 9], 5);
            let rs = coord.shutdown().unwrap();
            rs.into_iter().find(|r| r.id == id).unwrap().tokens
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn continuous_mode_serves_the_same_stream() {
        let dir = artifacts_dir();
        if !dir.join("cc-tiny.manifest.json").exists() {
            return;
        }
        let coord = Coordinator::start(
            &dir,
            "cc-tiny",
            CoordinatorConfig {
                max_wait: Duration::from_millis(20),
                replicas: 1,
                mode: BatchingMode::Continuous,
                ..CoordinatorConfig::default()
            },
        )
        .unwrap();
        for i in 0..5 {
            coord.submit(vec![(i % 50) as i32 + 1; 8], 3);
        }
        let responses = coord.shutdown().unwrap();
        assert_eq!(responses.len(), 5);
        assert!(responses.iter().all(|r| r.tokens.len() == 3));
    }

    #[test]
    fn two_replicas_share_the_queue() {
        let dir = artifacts_dir();
        if !dir.join("cc-tiny.manifest.json").exists() {
            return;
        }
        let coord = Coordinator::start(
            &dir,
            "cc-tiny",
            CoordinatorConfig {
                max_wait: Duration::from_millis(5),
                replicas: 2,
                ..CoordinatorConfig::default()
            },
        )
        .unwrap();
        // many small batches so both replicas get work
        for i in 0..12 {
            coord.submit(vec![i as i32 + 1; 4], 2);
            std::thread::sleep(Duration::from_millis(8));
        }
        let batches = coord.replica_batches.clone();
        let responses = coord.shutdown().unwrap();
        assert_eq!(responses.len(), 12);
        let loads: Vec<u64> = batches.iter().map(|x| x.load(Ordering::Relaxed)).collect();
        assert!(loads.iter().sum::<u64>() >= 1);
    }
}
