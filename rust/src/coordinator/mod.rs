//! L3 serving coordinator — the leader process of a Chiplet Cloud server
//! (paper Fig. 3(c): the controller "dispatches remote procedure calls
//! from the off-PCB interface to all chiplets").
//!
//! * [`request`] — request/response types and token budgets.
//! * [`batcher`] — dynamic batching to the artifact's compiled batch size
//!   (batch-synchronous generation, the granularity the paper's pipeline
//!   schedule assumes).
//! * [`server`] — replica workers: each thread owns a `ModelEngine`
//!   (PJRT handles are thread-affine) and pulls from the shared batcher,
//!   which is exactly least-loaded routing (work stealing).
//! * [`metrics`] — latency/throughput accounting for the end-to-end
//!   example and benches.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{Request, RequestId, Response};
pub use server::{Coordinator, CoordinatorConfig};
