//! L3 serving coordinator — the leader process of a Chiplet Cloud server
//! (paper Fig. 3(c): the controller "dispatches remote procedure calls
//! from the off-PCB interface to all chiplets").
//!
//! * [`request`] — request/response types and token budgets.
//! * [`batcher`] — request queue + batch former; the *formation decision*
//!   is a [`crate::sched::Policy`], the same trait the discrete-event
//!   serving simulator drives.
//! * [`server`] — replica workers: each thread owns a `ModelEngine`
//!   (PJRT handles are thread-affine) and pulls policy-formed batches from
//!   the shared batcher, which is exactly least-loaded routing (work
//!   stealing). [`server::BatchingMode`] selects static vs continuous
//!   batching.
//! * [`metrics`] — latency/throughput accounting (TTFT tails, wall-clock
//!   tokens/s, occupancy) for the end-to-end example and benches.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{Request, RequestId, Response};
pub use server::{BatchingMode, Coordinator, CoordinatorConfig};
