//! Software mapping: tensor parallelism × pipeline parallelism ×
//! micro-batching (paper §4.2 "Software Optimizer").

pub mod optimizer;
pub mod partition;

pub use optimizer::{candidate_mappings, optimize_mapping, optimize_mapping_bounded, SearchStats};
pub use partition::ChipProfile;

/// One parallel mapping of a model onto a chiplet system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mapping {
    /// Tensor-parallel width (chips per pipeline stage, 2D weight-stationary
    /// layout within the stage per Pope et al. [37]).
    pub tp: usize,
    /// Pipeline-parallel depth (number of stages).
    pub pp: usize,
    /// Micro-batch size.
    pub microbatch: usize,
}

impl Mapping {
    /// Total chips used by the mapping.
    pub fn n_chips(&self) -> usize {
        self.tp * self.pp
    }

    /// Number of in-flight micro-batches for a batch size.
    pub fn n_micro(&self, batch: usize) -> usize {
        (batch + self.microbatch - 1) / self.microbatch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_and_micro_counts() {
        let m = Mapping { tp: 136, pp: 96, microbatch: 2 };
        assert_eq!(m.n_chips(), 13_056); // Table 2 GPT-3 system
        assert_eq!(m.n_micro(256), 128);
        assert_eq!(m.n_micro(255), 128); // ceil
    }
}
