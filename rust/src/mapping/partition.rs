//! Model partitioning: what one chip holds and computes under a mapping
//! (the paper's "chiplet memory profile" and "chiplet compute profile").

use crate::config::{ModelSpec, Workload};
use crate::mapping::Mapping;

/// Fraction of CC-MEM usable for model state; the rest is reserved for
/// CSRs, index memory and scheduling slack. Shared by every capacity
/// check (profile fit, min chip count, max context, KV admission budget)
/// so they cannot drift apart.
pub const SRAM_USABLE_FRAC: f64 = 0.98;

/// Per-chip memory and compute profile for a (workload, mapping) pair.
#[derive(Clone, Debug)]
pub struct ChipProfile {
    /// Weight bytes resident on the chip.
    pub weight_bytes: f64,
    /// KV-cache bytes resident on the chip (full batch).
    pub kv_bytes: f64,
    /// Activation working-set bytes (double-buffered boundaries).
    pub act_bytes: f64,
    /// FLOPs this chip performs per layer per micro-batch decode step.
    pub flops_per_layer_ub: f64,
    /// Weight bytes this chip streams per layer per micro-batch step.
    pub weight_read_per_layer_ub: f64,
    /// KV bytes this chip streams per layer per micro-batch step.
    pub kv_read_per_layer_ub: f64,
    /// Layers per pipeline stage (ceil).
    pub layers_per_stage: usize,
}

/// Parameters per decoder layer (excluding embeddings).
pub fn params_per_layer(m: &ModelSpec) -> f64 {
    (m.n_params() - (m.vocab as f64) * m.d_model as f64) / m.n_layers as f64
}

/// Build the per-chip profile. The model's layers are split across `pp`
/// stages; within a stage, weights and KV heads are sharded across `tp`
/// chips (2D weight-stationary for the FC layers [37]).
pub fn profile(w: &Workload, mapping: &Mapping) -> ChipProfile {
    let m = &w.model;
    let n = mapping.n_chips() as f64;
    let layers_per_stage = m.n_layers.div_ceil(mapping.pp);
    let ub = mapping.microbatch as f64;

    let p_layer = params_per_layer(m);
    let weight_bytes = w.stored_weight_bytes() / n;
    let kv_bytes = w.kv_bytes() / n;
    // boundary activations: µb × d in and out, double buffered, per resident layer
    let act_bytes =
        4.0 * ub * m.d_model as f64 * m.bytes_per_param * layers_per_stage as f64;

    // Per layer, per micro-batch decode step, on ONE of the tp chips:
    let flops_fc = 2.0 * ub * p_layer / mapping.tp as f64;
    let kv_layer_per_seq =
        2.0 * w.ctx as f64 * (m.kv_heads() * m.d_head) as f64 * m.bytes_per_param;
    let flops_attn = 2.0 * ub * 2.0 * w.ctx as f64 * m.d_attn() as f64 / mapping.tp as f64;
    ChipProfile {
        weight_bytes,
        kv_bytes,
        act_bytes,
        flops_per_layer_ub: flops_fc + flops_attn,
        weight_read_per_layer_ub: p_layer * m.bytes_per_param * w.weight_read_scale
            / mapping.tp as f64,
        kv_read_per_layer_ub: ub * kv_layer_per_seq / mapping.tp as f64,
        layers_per_stage,
    }
}

impl ChipProfile {
    /// Total resident bytes on the chip.
    pub fn resident_bytes(&self) -> f64 {
        self.weight_bytes + self.kv_bytes + self.act_bytes
    }

    /// Does the profile fit a chip with `sram_mb` of CC-MEM? A small margin
    /// is reserved for CSRs, index memory and scheduling slack.
    pub fn fits(&self, sram_mb: f64) -> bool {
        self.resident_bytes() <= sram_mb * 1e6 * SRAM_USABLE_FRAC
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn gpt3_wl() -> Workload {
        Workload::new(ModelSpec::gpt3(), 2048, 256)
    }

    #[test]
    fn table2_gpt3_fits_its_chip() {
        // Table 2: GPT-3 on 13,056 chips × 225.8 MB.
        let mapping = Mapping { tp: 136, pp: 96, microbatch: 2 };
        let p = profile(&gpt3_wl(), &mapping);
        assert!(p.fits(225.8), "resident={} MB", p.resident_bytes() / 1e6);
        // weights ≈ 350 GB / 13056 ≈ 26.8 MB per chip
        assert!((p.weight_bytes / 1e6 - 26.8).abs() < 1.5);
        // KV ≈ 2.47 TB / 13056 ≈ 189 MB per chip — KV dominates at batch 256
        assert!(p.kv_bytes > p.weight_bytes);
    }

    #[test]
    fn memory_shrinks_with_more_chips() {
        let w = gpt3_wl();
        let small = profile(&w, &Mapping { tp: 64, pp: 96, microbatch: 2 });
        let large = profile(&w, &Mapping { tp: 256, pp: 96, microbatch: 2 });
        assert!(large.resident_bytes() < small.resident_bytes());
    }

    #[test]
    fn flops_scale_with_microbatch() {
        let w = gpt3_wl();
        let m1 = profile(&w, &Mapping { tp: 136, pp: 96, microbatch: 1 });
        let m4 = profile(&w, &Mapping { tp: 136, pp: 96, microbatch: 4 });
        assert!((m4.flops_per_layer_ub / m1.flops_per_layer_ub - 4.0).abs() < 1e-9);
        // but the weight traffic does not (weight reuse across the µbatch)
        assert_eq!(m4.weight_read_per_layer_ub, m1.weight_read_per_layer_ub);
    }

    #[test]
    fn uneven_pp_uses_ceil() {
        let w = gpt3_wl(); // 96 layers
        let p = profile(&w, &Mapping { tp: 8, pp: 36, microbatch: 1 });
        assert_eq!(p.layers_per_stage, 3); // ceil(96/36)
    }
}
