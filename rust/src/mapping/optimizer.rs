//! Mapping search (paper §4.2 "Software Optimizer").
//!
//! For a server design and workload, enumerate (tp, pp, µbatch) candidates:
//! the memory capacity fixes the minimum chip count, whole servers quantize
//! it, pipeline depth ranges over the divisors of the layer count, and the
//! micro-batch over powers of two. The caller scores candidates (Phase 2
//! scores by TCO/Token; a latency-focused user can score by token period).

use crate::arch::ServerDesign;
use crate::config::Workload;
use crate::mapping::Mapping;
use crate::perf::{simulate, DecodePerf};

/// Divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    for d in 1..=n {
        if d * d > n {
            break;
        }
        if n % d == 0 {
            out.push(d);
            if d != n / d {
                out.push(n / d);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Minimum chips needed to hold the workload (weights + KV + activations).
pub fn min_chips(server: &ServerDesign, w: &Workload) -> usize {
    let per_chip = server.chiplet.sram_mb * 1e6 * 0.98;
    (w.resident_bytes() / per_chip).ceil().max(1.0) as usize
}

/// Enumerate candidate mappings for a server/workload pair.
///
/// Chip counts are quantized to whole servers (scale 1×, 2×, 4× beyond the
/// memory minimum — extra replicas trade CapEx for pipeline throughput).
pub fn candidate_mappings(server: &ServerDesign, w: &Workload) -> Vec<Mapping> {
    let cps = server.chips().max(1);
    let n_min = min_chips(server, w);
    let servers_min = n_min.div_ceil(cps);
    let mut out = Vec::new();
    for scale in [1usize, 2, 4] {
        let n = servers_min * scale * cps;
        for &pp in &divisors(w.model.n_layers) {
            if pp > n {
                continue;
            }
            let tp = n / pp;
            if tp == 0 || tp * pp < n_min {
                continue;
            }
            let mut ub = 1usize;
            while ub <= w.batch {
                out.push(Mapping { tp, pp, microbatch: ub });
                ub *= 2;
            }
        }
    }
    out
}

/// Best mapping for a server/workload under a score function
/// (lower = better). Returns the mapping, its simulated performance and
/// score.
pub fn optimize_mapping<F>(
    server: &ServerDesign,
    w: &Workload,
    score: F,
) -> Option<(Mapping, DecodePerf, f64)>
where
    F: Fn(&Mapping, &DecodePerf) -> f64,
{
    let mut best: Option<(Mapping, DecodePerf, f64)> = None;
    for mapping in candidate_mappings(server, w) {
        if let Some(perf) = simulate(server, w, &mapping) {
            let s = score(&mapping, &perf);
            if best.as_ref().map(|(_, _, bs)| s < *bs).unwrap_or(true) {
                best = Some((mapping, perf, s));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipletDesign;
    use crate::config::ModelSpec;

    fn server() -> ServerDesign {
        ServerDesign {
            chiplet: ChipletDesign {
                die_mm2: 140.0,
                sram_mb: 225.8,
                tflops: 5.5,
                mem_bw_gbps: 2750.0,
                n_bank_groups: 172,
                io_link_gbps: 25.0,
                io_links: 4,
                tdp_w: 14.1,
            },
            chips_per_lane: 17,
            lanes: 8,
            server_power_w: 2020.0,
            server_capex: 5300.0,
        }
    }

    #[test]
    fn divisors_of_96() {
        assert_eq!(divisors(96), vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 96]);
        assert_eq!(divisors(1), vec![1]);
    }

    #[test]
    fn min_chips_covers_memory() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let n = min_chips(&server(), &w);
        // weights 350 GB + KV 2.47 TB over 221 MB/chip ⇒ ~12.8k chips
        assert!((11_000..16_000).contains(&n), "n={n}");
    }

    #[test]
    fn candidates_fit_memory_and_layers() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 64);
        let s = server();
        let cands = candidate_mappings(&s, &w);
        assert!(!cands.is_empty());
        let n_min = min_chips(&s, &w);
        for c in &cands {
            assert!(c.n_chips() >= n_min);
            assert!(c.pp <= w.model.n_layers);
            assert!(c.microbatch <= w.batch);
        }
    }

    #[test]
    fn optimizer_prefers_deep_pipelines_for_throughput() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let (mapping, perf, _) =
            optimize_mapping(&server(), &w, |_, p| 1.0 / p.tokens_per_s).expect("feasible");
        // Fig. 9: the throughput-optimal pipeline depth is large (≈ batch,
        // bounded by layers = 96)
        assert!(mapping.pp >= 32, "pp={}", mapping.pp);
        assert!(perf.tokens_per_s > 0.0);
    }
}
