//! Mapping search (paper §4.2 "Software Optimizer").
//!
//! For a server design and workload, enumerate (tp, pp, µbatch) candidates:
//! the memory capacity fixes the minimum chip count, whole servers quantize
//! it, pipeline depth ranges over the divisors of the layer count, and the
//! micro-batch over powers of two. The caller scores candidates (Phase 2
//! scores by TCO/Token; a latency-focused user can score by token period).

use crate::arch::ServerDesign;
use crate::config::Workload;
use crate::mapping::{partition, Mapping};
use crate::perf::kernels::KernelCache;
use crate::perf::{simulate_cached, DecodePerf};

/// Divisors of `n`, ascending. `divisors(0)` is explicitly empty: a
/// zero-layer model admits no pipeline partition, so the caller's candidate
/// enumeration degenerates to "no mappings" rather than dividing by zero
/// downstream.
pub fn divisors(n: usize) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    for d in 1..=n {
        if d * d > n {
            break;
        }
        if n % d == 0 {
            out.push(d);
            if d != n / d {
                out.push(n / d);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Minimum chips needed to hold the workload (weights + KV + activations).
///
/// Saturates to `usize::MAX` when the workload cannot be counted in chips
/// at all — zero/negative per-chip capacity or a model so large the f64
/// chip count exceeds the integer range. Callers treat `usize::MAX` as
/// "unmappable on this server" (no candidates are enumerated); the old
/// unchecked `as usize` cast silently saturated through f64 instead.
pub fn min_chips(server: &ServerDesign, w: &Workload) -> usize {
    let per_chip = server.chiplet.sram_mb * 1e6 * partition::SRAM_USABLE_FRAC;
    if per_chip <= 0.0 {
        return usize::MAX;
    }
    let need = (w.resident_bytes() / per_chip).ceil();
    if !need.is_finite() || need >= usize::MAX as f64 {
        return usize::MAX;
    }
    need.max(1.0) as usize
}

/// Enumerate candidate mappings for a server/workload pair.
///
/// Chip counts are quantized to whole servers (scale 1×, 2×, 4× beyond the
/// memory minimum — extra replicas trade CapEx for pipeline throughput).
/// Unmappable pairs (see [`min_chips`]) and chip counts that would overflow
/// `usize` yield no candidates.
pub fn candidate_mappings(server: &ServerDesign, w: &Workload) -> Vec<Mapping> {
    let cps = server.chips().max(1);
    let n_min = min_chips(server, w);
    if n_min == usize::MAX {
        return Vec::new();
    }
    let servers_min = n_min.div_ceil(cps);
    let mut out = Vec::new();
    for scale in [1usize, 2, 4] {
        let Some(n) = servers_min.checked_mul(scale).and_then(|s| s.checked_mul(cps)) else {
            continue;
        };
        for &pp in &divisors(w.model.n_layers) {
            if pp > n {
                continue;
            }
            let tp = n / pp;
            if tp == 0 || tp * pp < n_min {
                continue;
            }
            let mut ub = 1usize;
            while ub <= w.batch {
                out.push(Mapping { tp, pp, microbatch: ub });
                ub *= 2;
            }
        }
    }
    out
}

/// Counters from one bounded mapping search
/// (`candidates == simulated + pruned + infeasible`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    /// Candidate mappings enumerated.
    pub candidates: usize,
    /// Candidates actually simulated.
    pub simulated: usize,
    /// Candidates skipped by the lower-bound cutoff.
    pub pruned: usize,
    /// Candidates the simulator rejected (do not fit memory/shape).
    pub infeasible: usize,
}

impl SearchStats {
    /// Fold another search's counters into this one.
    pub fn absorb(&mut self, o: &SearchStats) {
        self.candidates += o.candidates;
        self.simulated += o.simulated;
        self.pruned += o.pruned;
        self.infeasible += o.infeasible;
    }
}

/// Best mapping for a server/workload under a score function
/// (lower = better). Returns the mapping, its simulated performance and
/// score. The exhaustive reference path — see [`optimize_mapping_bounded`]
/// for the pruned search the sweep engine uses.
pub fn optimize_mapping<F>(
    server: &ServerDesign,
    w: &Workload,
    score: F,
) -> Option<(Mapping, DecodePerf, f64)>
where
    F: Fn(&Mapping, &DecodePerf) -> f64,
{
    optimize_mapping_bounded(server, w, score, f64::INFINITY, None, &mut KernelCache::default()).0
}

/// Branch-and-bound mapping search.
///
/// `lower_bound`, when given, must **underestimate** the true score of any
/// candidate (an admissible bound); a candidate is skipped without
/// simulation when its bound strictly exceeds the best score seen so far
/// (the local best, further tightened by the caller-provided `incumbent`,
/// e.g. the best score across all servers in a sweep).
///
/// Exactness: a skipped candidate satisfies
/// `true_score >= bound > min(local_best, incumbent)`, so it can never
/// strictly beat the search result — the returned `(mapping, perf, score)`
/// is identical (ties included: first-best-wins on the same deterministic
/// candidate order) to the exhaustive [`optimize_mapping`] whenever
/// `incumbent` is an upper bound on the final global best.
pub fn optimize_mapping_bounded<F>(
    server: &ServerDesign,
    w: &Workload,
    score: F,
    incumbent: f64,
    lower_bound: Option<&dyn Fn(&Mapping) -> f64>,
    cache: &mut KernelCache,
) -> (Option<(Mapping, DecodePerf, f64)>, SearchStats)
where
    F: Fn(&Mapping, &DecodePerf) -> f64,
{
    let mut best: Option<(Mapping, DecodePerf, f64)> = None;
    let mut stats = SearchStats::default();
    for mapping in candidate_mappings(server, w) {
        stats.candidates += 1;
        if let Some(lb) = lower_bound {
            let threshold =
                best.as_ref().map(|(_, _, s)| *s).unwrap_or(f64::INFINITY).min(incumbent);
            if lb(&mapping) > threshold {
                stats.pruned += 1;
                continue;
            }
        }
        if let Some(perf) = simulate_cached(server, w, &mapping, cache) {
            stats.simulated += 1;
            let s = score(&mapping, &perf);
            if best.as_ref().map(|(_, _, bs)| s < *bs).unwrap_or(true) {
                best = Some((mapping, perf, s));
            }
        } else {
            stats.infeasible += 1;
        }
    }
    (best, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipletDesign;
    use crate::config::ModelSpec;

    fn server() -> ServerDesign {
        ServerDesign {
            chiplet: ChipletDesign {
                die_mm2: 140.0,
                sram_mb: 225.8,
                tflops: 5.5,
                mem_bw_gbps: 2750.0,
                n_bank_groups: 172,
                io_link_gbps: 25.0,
                io_links: 4,
                tdp_w: 14.1,
            },
            chips_per_lane: 17,
            lanes: 8,
            server_power_w: 2020.0,
            server_capex: 5300.0,
        }
    }

    #[test]
    fn divisors_of_96() {
        assert_eq!(divisors(96), vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 96]);
        assert_eq!(divisors(1), vec![1]);
    }

    #[test]
    fn divisors_of_zero_is_empty() {
        assert!(divisors(0).is_empty());
    }

    fn zero_layer_model() -> ModelSpec {
        ModelSpec { n_layers: 0, ..ModelSpec::gpt2() }
    }

    #[test]
    fn zero_layer_model_yields_no_mappings() {
        let w = Workload::new(zero_layer_model(), 1024, 8);
        let s = server();
        assert!(candidate_mappings(&s, &w).is_empty());
        assert!(optimize_mapping(&s, &w, |_, p| 1.0 / p.tokens_per_s).is_none());
    }

    #[test]
    fn zero_sram_chip_is_unmappable() {
        let mut s = server();
        s.chiplet.sram_mb = 0.0;
        let w = Workload::new(ModelSpec::gpt2(), 1024, 8);
        assert_eq!(min_chips(&s, &w), usize::MAX);
        assert!(candidate_mappings(&s, &w).is_empty());
        assert!(optimize_mapping(&s, &w, |_, p| 1.0 / p.tokens_per_s).is_none());
    }

    #[test]
    fn oversized_model_saturates_without_overflow() {
        // A model far beyond the f64-countable chip range: min_chips must
        // saturate and the enumeration must not multiply through overflow.
        let mut s = server();
        s.chiplet.sram_mb = 1e-9; // ~1 byte of usable SRAM per chip
        let w = Workload::new(ModelSpec::gpt3(), 4096, 1024);
        assert_eq!(min_chips(&s, &w), usize::MAX);
        assert!(candidate_mappings(&s, &w).is_empty());
    }

    #[test]
    fn bounded_search_matches_exhaustive_with_admissible_bound() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 64);
        let s = server();
        let score = |_: &Mapping, p: &DecodePerf| 1.0 / p.tokens_per_s;
        let exhaustive = optimize_mapping(&s, &w, score).expect("feasible");
        // The trivially admissible bound: zero never exceeds a true score,
        // so nothing may be pruned and the result must be unchanged.
        let lb = |_: &Mapping| 0.0;
        let (bounded, stats) = optimize_mapping_bounded(
            &s,
            &w,
            score,
            f64::INFINITY,
            Some(&lb),
            &mut KernelCache::default(),
        );
        let bounded = bounded.expect("feasible");
        assert_eq!(exhaustive.0, bounded.0, "mapping must match");
        assert_eq!(exhaustive.2.to_bits(), bounded.2.to_bits(), "score must be bit-identical");
        assert_eq!(stats.pruned, 0, "an all-zero bound must never prune");
        assert_eq!(stats.candidates, stats.simulated + stats.pruned + stats.infeasible);
    }

    #[test]
    fn bounded_search_prunes_with_tight_incumbent() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 64);
        let s = server();
        let score = |_: &Mapping, p: &DecodePerf| 1.0 / p.tokens_per_s;
        let best = optimize_mapping(&s, &w, score).unwrap().2;
        // Bound: period of the best mapping is a valid lower bound only for
        // itself; use a constant bound just above it so *everything* worse
        // is pruned once the incumbent equals the optimum.
        let lb = |_: &Mapping| best;
        let (found, stats) = optimize_mapping_bounded(
            &s,
            &w,
            score,
            best, // incumbent = known optimum
            Some(&lb),
            &mut KernelCache::default(),
        );
        // lb == incumbent is NOT strictly greater, so candidates still
        // simulate and the optimum is still found.
        assert_eq!(found.unwrap().2.to_bits(), best.to_bits());
        assert_eq!(stats.pruned, 0);
        // With an incumbent strictly below the optimum everything prunes.
        let (none, stats2) = optimize_mapping_bounded(
            &s,
            &w,
            score,
            best * 0.5,
            Some(&lb),
            &mut KernelCache::default(),
        );
        assert!(none.is_none());
        assert_eq!(stats2.pruned, stats2.candidates);
    }

    #[test]
    fn min_chips_covers_memory() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let n = min_chips(&server(), &w);
        // weights 350 GB + KV 2.47 TB over 221 MB/chip ⇒ ~12.8k chips
        assert!((11_000..16_000).contains(&n), "n={n}");
    }

    #[test]
    fn candidates_fit_memory_and_layers() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 64);
        let s = server();
        let cands = candidate_mappings(&s, &w);
        assert!(!cands.is_empty());
        let n_min = min_chips(&s, &w);
        for c in &cands {
            assert!(c.n_chips() >= n_min);
            assert!(c.pp <= w.model.n_layers);
            assert!(c.microbatch <= w.batch);
        }
    }

    #[test]
    fn optimizer_prefers_deep_pipelines_for_throughput() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let (mapping, perf, _) =
            optimize_mapping(&server(), &w, |_, p| 1.0 / p.tokens_per_s).expect("feasible");
        // Fig. 9: the throughput-optimal pipeline depth is large (≈ batch,
        // bounded by layers = 96)
        assert!(mapping.pp >= 32, "pp={}", mapping.pp);
        assert!(perf.tokens_per_s > 0.0);
    }
}
