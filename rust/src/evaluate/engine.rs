//! The co-design sweep engine: parallel, Pareto-guided, branch-and-bound
//! Phase-2 evaluation.
//!
//! The exhaustive Phase-2 procedure scores every feasible server design
//! against a workload (or a whole Table-2 workload grid) by searching its
//! mapping space with the analytical simulator. That product —
//! thousands of servers × 33 grid points × hundreds of candidate mappings —
//! is the hottest path in the codebase. The engine attacks it three ways,
//! none of which changes the answer:
//!
//! 1. **Parallelism** — servers (and workload×server pairs) are evaluated
//!    across a scoped-thread (or rayon) fork-join with deterministic,
//!    input-order reduction ([`crate::util::parallel`]).
//! 2. **Pruning** — an admissible TCO/Token lower bound (CapEx-only TCO at
//!    the roofline-ideal token throughput, [`WorkloadBounds`]) skips whole
//!    servers and individual candidate mappings whose bound already
//!    exceeds the incumbent best, which is shared across workers through an
//!    atomic f64. Because the bound never overestimates and the cutoff is
//!    strict, the surviving optimum is **identical** to the exhaustive
//!    search — ties included (first-in-order wins, as in the sequential
//!    path).
//! 3. **Ordering** — Pareto-frontier servers ([`crate::explore::pareto`])
//!    are evaluated first so the incumbent drops to near-optimal almost
//!    immediately and the dominated bulk of the space prunes cheaply. Order
//!    affects wall-clock only, never results.
//!
//! `SweepEngine::default()` is what [`crate::evaluate::sweep`],
//! [`crate::evaluate::best_point`] and [`crate::evaluate::best_over_grid`]
//! run; `SweepEngine::sequential()` reproduces the seed's single-threaded
//! exhaustive behaviour for benchmarks and regression tests.

use crate::arch::{ChipletDesign, ServerDesign};
use crate::config::hardware::ExploreSpace;
use crate::config::Workload;
use crate::cost::tco::{TcoModel, YEAR_S};
use crate::evaluate::{system_tco, DesignPoint};
use crate::explore::pareto;
use crate::mapping::optimizer::{optimize_mapping_bounded, SearchStats};
use crate::mapping::{partition, Mapping};
use crate::perf::kernels::{KernelCache, MAC_EFFICIENCY};
use crate::perf::DecodePerf;
use crate::util::parallel::{self, AtomicF64};

/// Aggregated counters from one engine run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepStats {
    /// (workload, server) pairs considered.
    pub servers: usize,
    /// Pairs skipped entirely by the server-level lower bound.
    pub servers_pruned: usize,
    /// Candidate mappings enumerated across all searches.
    pub candidates: usize,
    /// Candidate mappings simulated.
    pub simulated: usize,
    /// Candidate mappings skipped by the mapping-level lower bound.
    pub mappings_pruned: usize,
    /// Candidate mappings the simulator rejected (memory/shape misfit).
    pub mappings_infeasible: usize,
}

/// Admissible per-workload bounds: model-derived constants from which a
/// server-independent upper bound on achievable tokens/s (and hence a lower
/// bound on TCO/Token) follows.
///
/// Derivation (all quantities per generated-token round of the whole
/// batch): every mapping runs at least `F = (2·P_layer + 4·ctx·d_attn)·L`
/// FLOPs per batch element, streams the stored weights at least once and
/// each sequence's KV cache exactly once per round, and the pipeline period
/// is at least the aggregate roofline time of that work spread over the
/// mapping's `n` chips (epilogue and communication terms only add to it).
/// Dividing the CapEx-only TCO rate by that ideal throughput cancels `n`,
/// giving a bound that holds for *every* mapping on the server.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadBounds {
    /// Minimum decode FLOPs per generated token per sequence.
    flops_per_token: f64,
    /// Weight bytes streamed at least once per token round.
    weight_bytes_round: f64,
    /// KV bytes streamed per sequence per token round.
    kv_bytes_per_seq_round: f64,
    /// Batch size (sequences decoded concurrently).
    batch: f64,
}

impl WorkloadBounds {
    /// Compute the bounds for one workload.
    pub fn new(w: &Workload) -> WorkloadBounds {
        let m = &w.model;
        let layers = m.n_layers as f64;
        let p_layer = partition::params_per_layer(m);
        WorkloadBounds {
            flops_per_token: (2.0 * p_layer + 4.0 * w.ctx as f64 * m.d_attn() as f64) * layers,
            weight_bytes_round: p_layer * m.bytes_per_param * w.weight_read_scale * layers,
            kv_bytes_per_seq_round: 2.0
                * w.ctx as f64
                * (m.kv_heads() * m.d_head) as f64
                * m.bytes_per_param
                * layers,
            batch: w.batch as f64,
        }
    }

    /// Upper bound on sustainable decode tokens/s **per chip** for any
    /// mapping of this workload onto `chip` (compute and memory rooflines).
    pub fn ideal_tokens_per_s_chip(&self, chip: &ChipletDesign) -> f64 {
        let peak = chip.tflops * 1e12 * MAC_EFFICIENCY;
        let compute = if self.flops_per_token > 0.0 {
            peak / self.flops_per_token
        } else {
            f64::INFINITY
        };
        let bytes = self.weight_bytes_round + self.batch * self.kv_bytes_per_seq_round;
        let memory = if bytes > 0.0 {
            chip.mem_bw_gbps * 1e9 * self.batch / bytes
        } else {
            f64::INFINITY
        };
        compute.min(memory)
    }

    /// Lower bound on TCO/Token achievable by **any** mapping on `server`:
    /// CapEx-only TCO at the ideal token throughput (the chip count
    /// cancels). Returns 0.0 (never prunes) when the bound is degenerate.
    pub fn server_lower_bound(&self, space: &ExploreSpace, server: &ServerDesign) -> f64 {
        let tpsc = self.ideal_tokens_per_s_chip(&server.chiplet);
        if !tpsc.is_finite() || tpsc <= 0.0 {
            return 0.0;
        }
        let cps = server.chips().max(1) as f64;
        server.server_capex / (cps * space.server.server_life_years * YEAR_S * tpsc)
    }
}

/// The sweep engine configuration. See the module docs.
#[derive(Clone, Copy, Debug)]
pub struct SweepEngine {
    /// Worker threads; 0 = auto (`CC_SWEEP_THREADS` or the machine width).
    pub threads: usize,
    /// Enable the branch-and-bound lower-bound cutoff.
    pub prune: bool,
    /// Evaluate Pareto-frontier servers first (wall-clock heuristic only).
    pub pareto_order: bool,
}

impl Default for SweepEngine {
    /// The production configuration; `CC_SWEEP_PRUNE=0` / `CC_SWEEP_PARETO=0`
    /// environment knobs disable the respective stage (the `ccloud --seq`
    /// flag sets all three knobs back to the seed's sequential behaviour).
    fn default() -> Self {
        let on = |var: &str| std::env::var(var).map(|v| v != "0").unwrap_or(true);
        SweepEngine {
            threads: 0,
            prune: on("CC_SWEEP_PRUNE"),
            pareto_order: on("CC_SWEEP_PARETO"),
        }
    }
}

impl SweepEngine {
    /// The seed's exhaustive single-threaded path: no parallelism, no
    /// pruning, no reordering. The reference for regression tests and the
    /// baseline of `bench_sweep_engine`.
    pub fn sequential() -> SweepEngine {
        SweepEngine { threads: 1, prune: false, pareto_order: false }
    }

    fn order(&self, servers: &[ServerDesign]) -> Vec<usize> {
        if self.pareto_order {
            pareto::frontier_first_order(servers)
        } else {
            (0..servers.len()).collect()
        }
    }

    /// Phase-2 over a set of servers: the best point **per server** (the
    /// Fig.-7 scatter). Per-server results are exact (pruning uses only the
    /// server's own incumbent), and the output order matches `servers`.
    pub fn sweep(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> Vec<DesignPoint> {
        let wb = WorkloadBounds::new(w);
        parallel::par_map(servers, self.threads, |s| {
            evaluate_server_bounded(space, s, w, &wb, self.prune, f64::INFINITY).0
        })
        .into_iter()
        .flatten()
        .collect()
    }

    /// Global TCO/Token-optimal point for a workload: the exhaustive
    /// optimum, with exact `tco_per_token` ties resolved to the first
    /// server in input order — every engine configuration (sequential,
    /// parallel, pruned) implements this same reduction, so they agree
    /// bit-for-bit even on ties.
    pub fn best_point(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> Option<DesignPoint> {
        self.best_over_grid_indexed(space, servers, std::slice::from_ref(w)).0.map(|(_, p)| p)
    }

    /// [`SweepEngine::best_point`] with engine counters.
    pub fn best_point_stats(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> (Option<DesignPoint>, SweepStats) {
        let (best, stats) = self.best_over_grid_indexed(space, servers, std::slice::from_ref(w));
        (best.map(|(_, p)| p), stats)
    }

    /// Best point for a model across a workload grid (the Table-2
    /// procedure), evaluating all (workload, server) pairs in parallel
    /// under one shared incumbent.
    pub fn best_over_grid(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        grid: &[Workload],
    ) -> Option<(Workload, DesignPoint)> {
        self.best_over_grid_stats(space, servers, grid).0
    }

    /// [`SweepEngine::best_over_grid`] with engine counters.
    pub fn best_over_grid_stats(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        grid: &[Workload],
    ) -> (Option<(Workload, DesignPoint)>, SweepStats) {
        let (best, stats) = self.best_over_grid_indexed(space, servers, grid);
        (best.map(|(wi, p)| (grid[wi].clone(), p)), stats)
    }

    /// Core reduction: evaluate all (workload, server) pairs, sharing one
    /// atomic incumbent, and return the argmin by
    /// (score, workload index, server index) — exactly the sequential
    /// first-minimum semantics. Only scores travel through the parallel
    /// reduction; the winner's full design point is recomputed exactly once
    /// at the end.
    fn best_over_grid_indexed(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        grid: &[Workload],
    ) -> (Option<(usize, DesignPoint)>, SweepStats) {
        let bounds: Vec<WorkloadBounds> = grid.iter().map(WorkloadBounds::new).collect();
        let order = self.order(servers);
        let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(grid.len() * order.len());
        for wi in 0..grid.len() {
            for &si in &order {
                pairs.push((wi, si));
            }
        }
        let incumbent = AtomicF64::new(f64::INFINITY);
        let results = parallel::par_map(&pairs, self.threads, |&(wi, si)| {
            let server = &servers[si];
            let wb = &bounds[wi];
            if self.prune && wb.server_lower_bound(space, server) > incumbent.load() {
                return (f64::INFINITY, SearchStats::default(), true);
            }
            let (point, stats) =
                evaluate_server_bounded(space, server, &grid[wi], wb, self.prune, incumbent.load());
            match point {
                Some(p) => {
                    incumbent.fetch_min(p.tco_per_token);
                    (p.tco_per_token, stats, false)
                }
                None => (f64::INFINITY, stats, false),
            }
        });

        let mut stats = SweepStats { servers: pairs.len(), ..Default::default() };
        let mut best: Option<(f64, usize, usize)> = None; // (score, wi, si)
        for (i, (score, st, server_pruned)) in results.iter().enumerate() {
            stats.candidates += st.candidates;
            stats.simulated += st.simulated;
            stats.mappings_pruned += st.pruned;
            stats.mappings_infeasible += st.infeasible;
            if *server_pruned {
                stats.servers_pruned += 1;
            }
            if !score.is_finite() {
                continue;
            }
            let (wi, si) = pairs[i];
            let better = match best {
                None => true,
                Some((bs, bwi, bsi)) => {
                    *score < bs || (*score == bs && (wi, si) < (bwi, bsi))
                }
            };
            if better {
                best = Some((*score, wi, si));
            }
        }
        let winner = best.map(|(_, wi, si)| {
            // Exact, unpruned recomputation of the winning pair (cheap: one
            // server × one workload).
            let point = evaluate_server_bounded(
                space,
                &servers[si],
                &grid[wi],
                &bounds[wi],
                false,
                f64::INFINITY,
            )
            .0
            .expect("winning pair must re-evaluate");
            (wi, point)
        });
        (winner, stats)
    }
}

/// Evaluate one server design for a workload with the TCO/Token objective,
/// the admissible mapping-level lower bound, and an external incumbent.
/// With `prune == false` this is exactly the seed's `evaluate_server`.
pub(crate) fn evaluate_server_bounded(
    space: &ExploreSpace,
    server: &ServerDesign,
    w: &Workload,
    wb: &WorkloadBounds,
    prune: bool,
    incumbent: f64,
) -> (Option<DesignPoint>, SearchStats) {
    let tcom = TcoModel { server: space.server.clone(), dc: space.dc.clone() };
    let cps = server.chips().max(1);
    let score = |mapping: &Mapping, perf: &DecodePerf| -> f64 {
        let n_servers = mapping.n_chips().div_ceil(cps);
        system_tco(space, &tcom, server, n_servers, perf).per_token(perf.tokens_per_s)
    };
    let life = space.server.server_life_years;
    let tpsc = wb.ideal_tokens_per_s_chip(&server.chiplet);
    let lb = |mapping: &Mapping| -> f64 {
        let n = mapping.n_chips();
        let n_servers = n.div_ceil(cps) as f64;
        let capex_rate = server.server_capex * n_servers / (life * YEAR_S);
        let tps_ub = n as f64 * tpsc;
        if tps_ub > 0.0 && tps_ub.is_finite() {
            capex_rate / tps_ub
        } else {
            0.0
        }
    };
    let bound: Option<&dyn Fn(&Mapping) -> f64> = if prune { Some(&lb) } else { None };
    let mut cache = KernelCache::default();
    let (found, stats) = optimize_mapping_bounded(
        server,
        w,
        score,
        if prune { incumbent } else { f64::INFINITY },
        bound,
        &mut cache,
    );
    let point = found.map(|(mapping, perf, tco_per_token)| {
        let n_servers = mapping.n_chips().div_ceil(cps);
        let tco = system_tco(space, &tcom, server, n_servers, &perf);
        DesignPoint { server: server.clone(), mapping, n_servers, perf, tco, tco_per_token }
    });
    (point, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::explore::phase1;

    fn setup() -> (ExploreSpace, Vec<ServerDesign>) {
        let space = ExploreSpace::coarse();
        let (servers, _) = phase1(&space);
        (space, servers)
    }

    #[test]
    fn engine_configurations_agree_on_best_point() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        let seq = SweepEngine::sequential().best_point(&space, &servers, &w).expect("feasible");
        for engine in [
            SweepEngine { threads: 0, prune: false, pareto_order: false },
            SweepEngine { threads: 0, prune: true, pareto_order: false },
            SweepEngine { threads: 0, prune: true, pareto_order: true },
        ] {
            let got = engine.best_point(&space, &servers, &w).expect("feasible");
            assert_eq!(got.mapping, seq.mapping);
            assert_eq!(got.server, seq.server);
            assert_eq!(got.n_servers, seq.n_servers);
            assert_eq!(got.tco_per_token.to_bits(), seq.tco_per_token.to_bits());
        }
    }

    #[test]
    fn pruning_actually_prunes() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let engine = SweepEngine { threads: 0, prune: true, pareto_order: true };
        let (_, stats) = engine.best_point_stats(&space, &servers, &w);
        assert!(
            stats.mappings_pruned + stats.servers_pruned > 0,
            "lower-bound cutoff never fired: {stats:?}"
        );
        assert_eq!(
            stats.candidates,
            stats.simulated + stats.mappings_pruned + stats.mappings_infeasible
        );
    }

    #[test]
    fn server_lower_bound_is_admissible_on_real_points() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 32);
        let wb = WorkloadBounds::new(&w);
        let points = SweepEngine::sequential().sweep(&space, &servers, &w);
        assert!(!points.is_empty());
        for p in &points {
            let lb = wb.server_lower_bound(&space, &p.server);
            assert!(
                lb <= p.tco_per_token * (1.0 + 1e-12),
                "bound {lb} exceeds true score {} for {:?}",
                p.tco_per_token,
                p.server.chiplet
            );
        }
    }
}
