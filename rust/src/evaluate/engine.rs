//! The co-design sweep engine: parallel, Pareto-guided, branch-and-bound
//! Phase-2 evaluation.
//!
//! The exhaustive Phase-2 procedure scores every feasible server design
//! against a workload (or a whole Table-2 workload grid) by searching its
//! mapping space with the analytical simulator. That product —
//! thousands of servers × 33 grid points × hundreds of candidate mappings —
//! is the hottest path in the codebase. The engine attacks it three ways,
//! none of which changes the answer:
//!
//! 1. **Parallelism** — servers (and workload×server pairs) are evaluated
//!    across a scoped-thread (or rayon) fork-join with deterministic,
//!    input-order reduction ([`crate::util::parallel`]).
//! 2. **Pruning** — an admissible TCO/Token lower bound (CapEx-only TCO at
//!    the roofline-ideal token throughput, [`WorkloadBounds`]) skips whole
//!    servers and individual candidate mappings whose bound already
//!    exceeds the incumbent best, which is shared across workers through an
//!    atomic f64. Because the bound never overestimates and the cutoff is
//!    strict, the surviving optimum is **identical** to the exhaustive
//!    search — ties included (first-in-order wins, as in the sequential
//!    path).
//! 3. **Ordering** — Pareto-frontier servers ([`crate::explore::pareto`])
//!    are evaluated first so the incumbent drops to near-optimal almost
//!    immediately and the dominated bulk of the space prunes cheaply. Order
//!    affects wall-clock only, never results.
//!
//! `SweepEngine::default()` is what [`crate::evaluate::sweep`],
//! [`crate::evaluate::best_point`] and [`crate::evaluate::best_over_grid`]
//! run; `SweepEngine::sequential()` reproduces the seed's single-threaded
//! exhaustive behaviour for benchmarks and regression tests.

use crate::arch::{ChipletDesign, ServerDesign};
use crate::config::hardware::ExploreSpace;
use crate::config::workload::{ServeSpec, SloSpec, TrafficSpec};
use crate::config::Workload;
use crate::cost::tco::{TcoModel, YEAR_S};
use crate::evaluate::{system_tco, DesignPoint};
use crate::explore::pareto;
use crate::mapping::optimizer::{candidate_mappings, optimize_mapping_bounded, SearchStats};
use crate::mapping::{partition, Mapping};
use crate::perf::events::{
    open_loop_trace, simulate_replicated_faults, simulate_replicated_on,
    simulate_replicated_stream, simulate_replicated_stream_faults, unserved_report, IterCost,
    ServeReport, SimConfig,
};
use crate::perf::trace::TraceFile;
use crate::perf::kernels::{KernelCache, MAC_EFFICIENCY};
use crate::perf::{simulate_cached, DecodePerf};
use crate::sched::{ContinuousBatch, KvBudget};
use crate::util::parallel::{self, AtomicF64};

/// Aggregated counters from one engine run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepStats {
    /// (workload, server) pairs considered.
    pub servers: usize,
    /// Pairs skipped entirely by the server-level lower bound.
    pub servers_pruned: usize,
    /// Candidate mappings enumerated across all searches.
    pub candidates: usize,
    /// Candidate mappings simulated.
    pub simulated: usize,
    /// Candidate mappings skipped by the mapping-level lower bound.
    pub mappings_pruned: usize,
    /// Candidate mappings the simulator rejected (memory/shape misfit).
    pub mappings_infeasible: usize,
}

/// Admissible per-workload bounds: model-derived constants from which a
/// server-independent upper bound on achievable tokens/s (and hence a lower
/// bound on TCO/Token) follows.
///
/// Derivation (all quantities per generated-token round of the whole
/// batch): every mapping runs at least `F = (2·P_layer + 4·ctx·d_attn)·L`
/// FLOPs per batch element, streams the stored weights at least once and
/// each sequence's KV cache exactly once per round, and the pipeline period
/// is at least the aggregate roofline time of that work spread over the
/// mapping's `n` chips (epilogue and communication terms only add to it).
/// Dividing the CapEx-only TCO rate by that ideal throughput cancels `n`,
/// giving a bound that holds for *every* mapping on the server.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadBounds {
    /// Minimum decode FLOPs per generated token per sequence.
    flops_per_token: f64,
    /// Weight bytes streamed at least once per token round.
    weight_bytes_round: f64,
    /// KV bytes streamed per sequence per token round.
    kv_bytes_per_seq_round: f64,
    /// Batch size (sequences decoded concurrently).
    batch: f64,
}

impl WorkloadBounds {
    /// Compute the bounds for one workload.
    pub fn new(w: &Workload) -> WorkloadBounds {
        let m = &w.model;
        let layers = m.n_layers as f64;
        let p_layer = partition::params_per_layer(m);
        WorkloadBounds {
            flops_per_token: (2.0 * p_layer + 4.0 * w.ctx as f64 * m.d_attn() as f64) * layers,
            weight_bytes_round: p_layer * m.bytes_per_param * w.weight_read_scale * layers,
            kv_bytes_per_seq_round: 2.0
                * w.ctx as f64
                * (m.kv_heads() * m.d_head) as f64
                * m.bytes_per_param
                * layers,
            batch: w.batch as f64,
        }
    }

    /// Upper bound on sustainable decode tokens/s **per chip** for any
    /// mapping of this workload onto `chip` (compute and memory rooflines).
    pub fn ideal_tokens_per_s_chip(&self, chip: &ChipletDesign) -> f64 {
        let peak = chip.tflops * 1e12 * MAC_EFFICIENCY;
        let compute = if self.flops_per_token > 0.0 {
            peak / self.flops_per_token
        } else {
            f64::INFINITY
        };
        let bytes = self.weight_bytes_round + self.batch * self.kv_bytes_per_seq_round;
        let memory = if bytes > 0.0 {
            chip.mem_bw_gbps * 1e9 * self.batch / bytes
        } else {
            f64::INFINITY
        };
        compute.min(memory)
    }

    /// Lower bound on TCO/Token achievable by **any** mapping on `server`:
    /// CapEx-only TCO at the ideal token throughput (the chip count
    /// cancels). Returns 0.0 (never prunes) when the bound is degenerate.
    pub fn server_lower_bound(&self, space: &ExploreSpace, server: &ServerDesign) -> f64 {
        let tpsc = self.ideal_tokens_per_s_chip(&server.chiplet);
        if !tpsc.is_finite() || tpsc <= 0.0 {
            return 0.0;
        }
        let cps = server.chips().max(1) as f64;
        server.server_capex / (cps * space.server.server_life_years * YEAR_S * tpsc)
    }
}

/// The sweep engine configuration. See the module docs.
#[derive(Clone, Copy, Debug)]
pub struct SweepEngine {
    /// Worker threads; 0 = auto (`CC_SWEEP_THREADS` or the machine width).
    pub threads: usize,
    /// Enable the branch-and-bound lower-bound cutoff.
    pub prune: bool,
    /// Evaluate Pareto-frontier servers first (wall-clock heuristic only).
    pub pareto_order: bool,
    /// Fast stage-2 SLO validation: decode fast-forward in the event
    /// simulator plus early abort of provably-infeasible candidates (see
    /// [`crate::perf::events`]). The selected design and its confirming
    /// report are byte-identical either way — a passing validation never
    /// aborts and fast-forward replays the reference stepping to the bit —
    /// so this knob only exists for the regression tests and benches that
    /// time the reference path.
    pub fast_sim: bool,
}

impl Default for SweepEngine {
    /// The production configuration; `CC_SWEEP_PRUNE=0` / `CC_SWEEP_PARETO=0`
    /// / `CC_SWEEP_FASTSIM=0` environment knobs disable the respective
    /// stage (the `ccloud --seq` flag sets every knob back to the seed's
    /// sequential behaviour).
    fn default() -> Self {
        let on = |var: &str| std::env::var(var).map(|v| v != "0").unwrap_or(true);
        SweepEngine {
            threads: 0,
            prune: on("CC_SWEEP_PRUNE"),
            pareto_order: on("CC_SWEEP_PARETO"),
            fast_sim: on("CC_SWEEP_FASTSIM"),
        }
    }
}

impl SweepEngine {
    /// The seed's exhaustive single-threaded path: no parallelism, no
    /// pruning, no reordering, reference-stepped stage-2 validation. The
    /// reference for regression tests and the baseline of
    /// `bench_sweep_engine`.
    pub fn sequential() -> SweepEngine {
        SweepEngine { threads: 1, prune: false, pareto_order: false, fast_sim: false }
    }

    fn order(&self, servers: &[ServerDesign]) -> Vec<usize> {
        if self.pareto_order {
            pareto::frontier_first_order(servers)
        } else {
            (0..servers.len()).collect()
        }
    }

    /// Phase-2 over a set of servers: the best point **per server** (the
    /// Fig.-7 scatter). Per-server results are exact (pruning uses only the
    /// server's own incumbent), and the output order matches `servers`.
    pub fn sweep(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> Vec<DesignPoint> {
        let wb = WorkloadBounds::new(w);
        parallel::par_map(servers, self.threads, |s| {
            evaluate_server_bounded(space, s, w, &wb, self.prune, f64::INFINITY).0
        })
        .into_iter()
        .flatten()
        .collect()
    }

    /// Global TCO/Token-optimal point for a workload: the exhaustive
    /// optimum, with exact `tco_per_token` ties resolved to the first
    /// server in input order — every engine configuration (sequential,
    /// parallel, pruned) implements this same reduction, so they agree
    /// bit-for-bit even on ties.
    pub fn best_point(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> Option<DesignPoint> {
        self.best_over_grid_argmin(space, servers, std::slice::from_ref(w)).0.map(|(_, _, p)| p)
    }

    /// [`SweepEngine::best_point`] with engine counters.
    pub fn best_point_stats(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> (Option<DesignPoint>, SweepStats) {
        let (best, stats) = self.best_over_grid_argmin(space, servers, std::slice::from_ref(w));
        (best.map(|(_, _, p)| p), stats)
    }

    /// Best point for a model across a workload grid (the Table-2
    /// procedure), evaluating all (workload, server) pairs in parallel
    /// under one shared incumbent.
    pub fn best_over_grid(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        grid: &[Workload],
    ) -> Option<(Workload, DesignPoint)> {
        self.best_over_grid_stats(space, servers, grid).0
    }

    /// [`SweepEngine::best_over_grid`] with engine counters.
    pub fn best_over_grid_stats(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        grid: &[Workload],
    ) -> (Option<(Workload, DesignPoint)>, SweepStats) {
        let (best, stats) = self.best_over_grid_argmin(space, servers, grid);
        (best.map(|(wi, _, p)| (grid[wi].clone(), p)), stats)
    }

    /// Core reduction: evaluate all (workload, server) pairs, sharing one
    /// atomic incumbent, and return the argmin by
    /// (score, workload index, server index) — exactly the sequential
    /// first-minimum semantics. Only scores travel through the parallel
    /// reduction; the winner's full design point is recomputed exactly once
    /// at the end. The winning `(workload index, server index)` is part of
    /// the return value: it is the optimum's identity under the tie-break
    /// order, which the shard merge needs to recombine partial sweeps
    /// bit-identically (`pub(crate)` for the experiment layer).
    pub(crate) fn best_over_grid_argmin(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        grid: &[Workload],
    ) -> (Option<(usize, usize, DesignPoint)>, SweepStats) {
        let bounds: Vec<WorkloadBounds> = grid.iter().map(WorkloadBounds::new).collect();
        let order = self.order(servers);
        let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(grid.len() * order.len());
        for wi in 0..grid.len() {
            for &si in &order {
                pairs.push((wi, si));
            }
        }
        let incumbent = AtomicF64::new(f64::INFINITY);
        let results = parallel::par_map(&pairs, self.threads, |&(wi, si)| {
            let server = &servers[si];
            let wb = &bounds[wi];
            if self.prune && wb.server_lower_bound(space, server) > incumbent.load() {
                return (f64::INFINITY, SearchStats::default(), true);
            }
            let (point, stats) =
                evaluate_server_bounded(space, server, &grid[wi], wb, self.prune, incumbent.load());
            match point {
                Some(p) => {
                    incumbent.fetch_min(p.tco_per_token);
                    (p.tco_per_token, stats, false)
                }
                None => (f64::INFINITY, stats, false),
            }
        });

        let mut stats = SweepStats { servers: pairs.len(), ..Default::default() };
        let mut best: Option<(f64, usize, usize)> = None; // (score, wi, si)
        for (i, (score, st, server_pruned)) in results.iter().enumerate() {
            stats.candidates += st.candidates;
            stats.simulated += st.simulated;
            stats.mappings_pruned += st.pruned;
            stats.mappings_infeasible += st.infeasible;
            if *server_pruned {
                stats.servers_pruned += 1;
            }
            if !score.is_finite() {
                continue;
            }
            let (wi, si) = pairs[i];
            let better = match best {
                None => true,
                Some((bs, bwi, bsi)) => {
                    *score < bs || (*score == bs && (wi, si) < (bwi, bsi))
                }
            };
            if better {
                best = Some((*score, wi, si));
            }
        }
        let winner = best.and_then(|(_, wi, si)| {
            // Exact, unpruned recomputation of the winning pair (cheap: one
            // server × one workload). The pair scored finite above, so the
            // unpruned re-evaluation yields a point; `and_then` keeps that
            // invariant a no-winner outcome instead of a panic.
            evaluate_server_bounded(
                space,
                &servers[si],
                &grid[wi],
                &bounds[wi],
                false,
                f64::INFINITY,
            )
            .0
            .map(|point| (wi, si, point))
        });
        (winner, stats)
    }
}

/// Outcome of an SLO-constrained selection ([`SweepEngine::best_point_slo`]).
#[derive(Clone, Debug)]
pub struct SloSelection {
    /// The cheapest design the event simulator confirmed SLO-feasible.
    pub point: DesignPoint,
    /// The confirming event-sim report (continuous batching on the spec's
    /// traffic).
    pub report: ServeReport,
    /// Servers whose constrained mapping search passed the steady-state
    /// bound (stage-1 survivors).
    pub bound_feasible: usize,
    /// Event-sim validations run (stage-2 cost). The speculative parallel
    /// scan validates candidates in waves, so this can exceed the winner's
    /// rank in the ascending-TCO order — it counts simulations actually
    /// paid for, including speculative ones.
    pub validated: usize,
    /// Validations the simulator aborted early as provably SLO-infeasible
    /// (a subset of `validated`; 0 when `fast_sim` is off).
    pub aborted_early: usize,
    /// Replica count of the confirmed fleet: `spec.replicas` on fault-free
    /// runs, possibly larger when an availability target sized spares in
    /// (see [`SweepEngine::best_point_slo`]'s redundancy sizing).
    pub replicas: usize,
}

/// Optimistic (admissible) steady-state TTFT bound for one request of
/// `prompt_tokens` on a design: its per-token share of the whole-batch
/// prefill, with zero queueing. Derived from the *same* [`IterCost`] the
/// event simulator charges, so the bound stays admissible by construction
/// under every serving-model knob: chunked prefill splits the prompt into
/// iterations whose prefill costs *sum* to this bound (decode interleaves
/// only add to TTFT), paged accounting changes admission but never makes
/// a prefill cheaper, and multi-replica routing only reduces queueing —
/// which the bound already assumes is zero.
fn prefill_bound_s(perf: &DecodePerf, w: &Workload, prompt_tokens: usize) -> f64 {
    IterCost::from_perf(perf, w).prefill_s_per_token * prompt_tokens as f64
}

impl SweepEngine {
    /// SLO-constrained optimum: the cheapest TCO/Token design that meets
    /// the latency targets *under traffic*, per the paper's "cheapest
    /// token that still meets the latency target" question.
    ///
    /// Two stages:
    /// 1. **Steady-state bound filter** — each server's mapping search
    ///    drops SLO-infeasible mappings using admissible bounds (token
    ///    period vs TPOT, per-sequence prefill share vs TTFT) and keeps
    ///    its cheapest [`SLO_MAPPINGS_PER_SERVER`] survivors. The bounds
    ///    are optimistic, so no truly feasible mapping is dropped here.
    /// 2. **Event-sim validation** — surviving candidates are validated in
    ///    ascending TCO/Token order by the discrete-event simulator
    ///    ([`crate::perf::events`]) with continuous batching on the
    ///    spec's traffic, under the spec's serving model (chunked
    ///    prefill, paged-KV accounting, replicas — see
    ///    [`validate_design_slo`]); the first design whose simulated p99
    ///    tails meet the SLO wins. Queueing and partial batches can push
    ///    a bound-feasible design over its targets, which is exactly what
    ///    the steady-state sweep alone cannot see.
    ///
    /// Stage 2 is **speculatively parallel**: candidates are simulated in
    /// waves through [`crate::util::parallel`] — sized 1, 2, 4, … up to
    /// `threads` — and the results committed in ascending-TCO order, so
    /// the *first* feasible candidate returned is byte-identical to a
    /// sequential scan; waves only trade wasted speculative simulations
    /// for wall-clock, and the geometric ramp bounds that waste near 2x
    /// the winner's rank. The first wave is a single candidate: with a
    /// loose SLO the cheapest design passes immediately and nothing
    /// speculative is paid. Each validation runs with decode fast-forward
    /// and early abort when [`SweepEngine::fast_sim`] is on (the default)
    /// — both are answer-preserving, see [`crate::perf::events`].
    ///
    /// With `spec.paged_kv` the validation admits by each request's
    /// *actual* footprint instead of a full-context reservation, so a
    /// design whose concurrency was KV-capacity-starved under full
    /// reservation can pass — the selection is never costlier than the
    /// full-reservation one on the same traffic.
    pub fn best_point_slo(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
        spec: &ServeSpec,
    ) -> Option<SloSelection> {
        let slo = validation_slo(spec);
        // Deliberately exhaustive per server (no shared incumbent / cost
        // pruning), keeping each server's cheapest few bound-feasible
        // mappings rather than one: stage 2 may reject the cheapest
        // candidate on queueing, and the runner-up that validation needs
        // can be another mapping of the *same* server.
        let per_server = parallel::par_map(servers, self.threads, |s| {
            evaluate_server_slo(space, s, w, slo, &spec.traffic)
        });
        let bound_feasible = per_server.iter().filter(|l| !l.is_empty()).count();
        // (server index, per-server rank, point) — ascending cost with the
        // same first-minimum tie semantics as the unconstrained engine.
        let mut pts: Vec<(usize, usize, DesignPoint)> = Vec::new();
        for (si, list) in per_server.into_iter().enumerate() {
            for (rank, p) in list.into_iter().enumerate() {
                pts.push((si, rank, p));
            }
        }
        pts.sort_by(|a, b| {
            crate::util::stats::total_cmp_f64(&a.2.tco_per_token, &b.2.tco_per_token)
                .then(a.0.cmp(&b.0))
                .then(a.1.cmp(&b.1))
        });
        // A non-none fault model changes what "meets the SLO" means (and,
        // with an availability target, how many replicas to buy), so the
        // whole stage-2 scan moves to the failure-aware sequential path.
        // The fault-free scan below is untouched — existing goldens stay
        // byte-identical.
        if !spec.faults.is_none() {
            return self.size_redundancy(w, spec, pts, bound_feasible);
        }
        // Cross-candidate warm start: every stage-2 validation replays the
        // *same* seeded traffic, so the open-loop trace is materialized
        // once here and shared across all waves instead of being re-drawn
        // inside every simulation. Byte-identical by construction — the
        // shared list is exactly what each simulation would generate
        // (closed-loop traffic materializes empty and synthesizes its
        // arrivals during the run, as before).
        // A trace file replaces the synthetic warm start: each validation
        // re-streams the validated file (two sequential scans, O(1) memory)
        // instead of sharing a materialized Vec.
        let tfile = match &spec.trace_file {
            Some(p) if !pts.is_empty() => match TraceFile::open(p) {
                Ok(tf) => Some(tf),
                // Callers validated the path up front; a file that vanished
                // since means no candidate can be confirmed.
                Err(_) => return None,
            },
            _ => None,
        };
        let trace = if pts.is_empty() || tfile.is_some() {
            Vec::new()
        } else {
            open_loop_trace(&spec.traffic)
        };
        // Speculative parallel scan: waves of candidates, results committed
        // in input (ascending-TCO) order. Wave sizes ramp geometrically
        // 1, 2, 4, … up to `threads`, so the common loose-SLO case
        // (cheapest candidate passes) pays exactly one simulation like the
        // sequential scan, and an early-rank winner wastes at most ~2x its
        // rank in speculative simulations rather than a full thread-width
        // wave.
        let threads = parallel::resolve(self.threads).max(1);
        let mut validated = 0usize;
        let mut aborted_early = 0usize;
        let mut start = 0usize;
        let mut wave = 1usize;
        while start < pts.len() {
            let n = wave.min(pts.len() - start);
            let batch = &pts[start..start + n];
            let reports = parallel::par_map(batch, self.threads, |(_, _, point)| {
                let mut cfg = slo_sim_config(point, w, spec);
                cfg.reference_step = !self.fast_sim;
                cfg.early_abort = self.fast_sim;
                match &tfile {
                    Some(tf) => match tf.arrivals() {
                        Ok(src) => simulate_replicated_stream(
                            &cfg,
                            spec.replicas,
                            spec.route,
                            &ContinuousBatch,
                            &spec.traffic,
                            tf.requests(),
                            src,
                            slo,
                        ),
                        // Mid-scan loss of the file: an unserved report
                        // never meets a binding SLO, so the candidate is
                        // (conservatively) rejected.
                        Err(_) => unserved_report("continuous", spec.replicas, tf.requests()),
                    },
                    None => simulate_replicated_on(
                        &cfg,
                        spec.replicas,
                        spec.route,
                        &ContinuousBatch,
                        &spec.traffic,
                        &trace,
                        slo,
                    ),
                }
            });
            // The whole wave was simulated before any result commits, so
            // the cost counters cover every member — including speculative
            // ones past the winner.
            validated += reports.len();
            aborted_early += reports.iter().filter(|r| r.aborted_early).count();
            for (offset, report) in reports.into_iter().enumerate() {
                if serve_verdict(&report, spec) {
                    let point = pts[start + offset].2.clone();
                    return Some(SloSelection {
                        point,
                        report,
                        bound_feasible,
                        validated,
                        aborted_early,
                        replicas: spec.replicas.max(1),
                    });
                }
            }
            start += n;
            wave = (wave * 2).min(threads);
        }
        None
    }

    /// Failure-aware stage 2: validate candidates under the spec's
    /// [`crate::config::workload::FaultSpec`] and, when an availability
    /// target is set, size redundancy — for each candidate try replica
    /// counts `base..=base + max_spares` and commit the first
    /// (candidate, fleet) whose faulted report passes
    /// [`ServeReport::meets_available`].
    ///
    /// Pairs are scanned in ascending *fleet* cost order: a fleet of `n`
    /// replicas of a design costs `tco_per_token * n / base` relative to
    /// the base fleet the traffic was sized for (same offered tokens,
    /// `n/base` times the hardware), so the first pass is the cheapest
    /// fleet whose SLO holds under faults. Ties break by candidate rank
    /// then by `n` (fewest spares first), keeping the scan deterministic.
    ///
    /// Sequential on purpose: faulted runs never arm the early-abort
    /// proof (re-dispatched arrivals break its sorted-queue argument —
    /// see [`crate::perf::events`]), and the N+k grid is small, so the
    /// speculative wave machinery buys little here and the simple scan
    /// keeps commit order trivially identical to cost order. Without an
    /// availability target (`availability == 0.0`) no spares are tried:
    /// the scan degenerates to "does the base fleet hold the SLO *under
    /// faults*", which is still [`ServeReport::meets_available`] — its
    /// completed-fraction term is vacuous at 0.0 and only the latency
    /// tails bind.
    fn size_redundancy(
        &self,
        w: &Workload,
        spec: &ServeSpec,
        pts: Vec<(usize, usize, DesignPoint)>,
        bound_feasible: usize,
    ) -> Option<SloSelection> {
        let base = spec.replicas.max(1);
        let spares = if spec.faults.availability > 0.0 { spec.faults.max_spares } else { 0 };
        let tfile = match &spec.trace_file {
            Some(p) if !pts.is_empty() => match TraceFile::open(p) {
                Ok(tf) => Some(tf),
                // Callers validated the path up front; a file that vanished
                // since means no candidate can be confirmed.
                Err(_) => return None,
            },
            _ => None,
        };
        // (candidate index, fleet size, relative fleet cost).
        let mut plan: Vec<(usize, usize, f64)> = Vec::new();
        for (pi, (_, _, point)) in pts.iter().enumerate() {
            for n in base..=base + spares {
                plan.push((pi, n, point.tco_per_token * n as f64 / base as f64));
            }
        }
        plan.sort_by(|a, b| {
            crate::util::stats::total_cmp_f64(&a.2, &b.2)
                .then(a.0.cmp(&b.0))
                .then(a.1.cmp(&b.1))
        });
        let mut validated = 0usize;
        for (pi, n, _) in plan {
            let point = &pts[pi].2;
            let mut cfg = slo_sim_config(point, w, spec);
            cfg.reference_step = !self.fast_sim;
            // Ignored by the faulted simulator, but kept off so the
            // configuration states what actually runs.
            cfg.early_abort = false;
            let report = match &tfile {
                Some(tf) => match tf.arrivals() {
                    Ok(src) => simulate_replicated_stream_faults(
                        &cfg,
                        n,
                        spec.route,
                        &ContinuousBatch,
                        &spec.traffic,
                        tf.requests(),
                        src,
                        &spec.faults,
                        &spec.slo,
                    ),
                    // Mid-scan loss of the file: an unserved report never
                    // meets an availability target, so the pair is
                    // (conservatively) rejected.
                    Err(_) => unserved_report("continuous", n, tf.requests()),
                },
                None => simulate_replicated_faults(
                    &cfg,
                    n,
                    spec.route,
                    &ContinuousBatch,
                    &spec.traffic,
                    &spec.faults,
                    &spec.slo,
                ),
            };
            validated += 1;
            if serve_verdict_available(&report, spec) {
                return Some(SloSelection {
                    point: point.clone(),
                    report,
                    bound_feasible,
                    validated,
                    aborted_early: 0,
                    replicas: n,
                });
            }
        }
        None
    }

    /// Dispatch on the workload's own [`crate::config::ServeSpec`]: with a
    /// spec attached this is the SLO-constrained selection (and returns the
    /// confirming report); without one it is the plain TCO/Token optimum.
    ///
    /// An attached spec with *unconstrained* SLOs takes the pruned
    /// unconstrained engine (identical result, far cheaper than the
    /// exhaustive per-server SLO search) and simulates the winner once for
    /// the traffic report.
    ///
    /// *Deprecated shim*: the supported dispatcher is the declarative one —
    /// [`crate::experiment::Engine::run`] over a
    /// [`crate::config::Experiment`] — which routes to the same selection
    /// code; this stays for tests that prove that identity.
    pub fn best_point_serve(
        &self,
        space: &ExploreSpace,
        servers: &[ServerDesign],
        w: &Workload,
    ) -> Option<(DesignPoint, Option<ServeReport>)> {
        match &w.serve {
            Some(spec) if validation_slo(spec).is_unconstrained() => {
                self.best_point(space, servers, w).map(|p| {
                    let report = validate_design_slo(&p, w, spec);
                    (p, Some(report))
                })
            }
            Some(spec) => self
                .best_point_slo(space, servers, w, spec)
                .map(|s| (s.point, Some(s.report))),
            None => self.best_point(space, servers, w).map(|p| (p, None)),
        }
    }
}

/// How many of a server's cheapest bound-feasible mappings survive into
/// stage-2 validation. More than one, so a server whose optimum fails the
/// event sim on queueing can still win with its next mapping; small, so
/// the candidate list stays bounded on the full space.
const SLO_MAPPINGS_PER_SERVER: usize = 4;

/// A kept stage-1 candidate before it is materialized into a
/// [`DesignPoint`]: everything but the `ServerDesign`, which is shared by
/// every candidate of the server and cloned only for the final keeps —
/// the insertion-sorted keep list churns (insert + truncate) on every
/// better candidate, and cloning the server into each churned entry was
/// pure allocation waste.
struct SloCandidate {
    mapping: Mapping,
    n_servers: usize,
    perf: DecodePerf,
    tco: crate::cost::tco::Tco,
    tco_per_token: f64,
}

/// One server's cheapest [`SLO_MAPPINGS_PER_SERVER`] mappings subject to
/// the steady-state SLO bounds, ascending TCO/Token (candidate-enumeration
/// order on exact ties, matching the unconstrained search's first-minimum
/// semantics); empty when no mapping both fits and can meet the SLO.
pub(crate) fn evaluate_server_slo(
    space: &ExploreSpace,
    server: &ServerDesign,
    w: &Workload,
    slo: &SloSpec,
    traffic: &TrafficSpec,
) -> Vec<DesignPoint> {
    let tcom = TcoModel { server: space.server.clone(), dc: space.dc.clone() };
    let cps = server.chips().max(1);
    let mut cache = KernelCache::default();
    let mut kept: Vec<SloCandidate> = Vec::new();
    for mapping in candidate_mappings(server, w) {
        let Some(perf) = simulate_cached(server, w, &mapping, &mut cache) else { continue };
        if perf.token_period > slo.tpot_p99_s
            || prefill_bound_s(&perf, w, traffic.prompt_tokens) > slo.ttft_p99_s
        {
            continue;
        }
        let n_servers = mapping.n_chips().div_ceil(cps);
        let tco = system_tco(space, &tcom, server, n_servers, &perf);
        let tco_per_token = tco.per_token(perf.tokens_per_s);
        if !tco_per_token.is_finite() {
            continue;
        }
        if kept.len() == SLO_MAPPINGS_PER_SERVER
            && tco_per_token >= kept.last().map(|p| p.tco_per_token).unwrap_or(f64::INFINITY)
        {
            continue;
        }
        // Strict `<` keeps the earlier-enumerated candidate ahead on ties.
        let pos = kept
            .iter()
            .position(|p| tco_per_token < p.tco_per_token)
            .unwrap_or(kept.len());
        kept.insert(pos, SloCandidate { mapping, n_servers, perf, tco, tco_per_token });
        kept.truncate(SLO_MAPPINGS_PER_SERVER);
    }
    kept.into_iter()
        .map(|c| DesignPoint {
            server: server.clone(),
            mapping: c.mapping,
            n_servers: c.n_servers,
            perf: c.perf,
            tco: c.tco,
            tco_per_token: c.tco_per_token,
        })
        .collect()
}

/// The event-simulator configuration [`validate_design_slo`] runs a design
/// point under: the design's own analytic iteration costs and KV budget
/// plus the spec's serving-model knobs. Public so benches and tests can
/// flip the execution knobs (`reference_step`, `early_abort`) on exactly
/// the configuration the sweep uses.
pub fn slo_sim_config(point: &DesignPoint, w: &Workload, spec: &ServeSpec) -> SimConfig {
    let mut cfg = SimConfig::new(
        w.batch.max(1),
        KvBudget::from_design(&point.server, w, &point.mapping),
        IterCost::from_perf(&point.perf, w).with_chunk(spec.prefill_chunk),
        spec.paged_kv,
    );
    cfg.quantum = spec.quantum;
    cfg.overcommit = spec.overcommit;
    cfg.window_s = spec.goodput_window_s;
    cfg
}

/// The SLO stage 1 filters and stage 2 validates against: the interactive
/// tier's targets when the traffic is tiered (only that tier's tails are
/// held — batch absorbs queueing and preemption), the spec's run-wide SLO
/// otherwise. Identical to `&spec.slo` for untiered specs, so existing
/// selections are untouched.
pub fn validation_slo(spec: &ServeSpec) -> &SloSpec {
    match &spec.traffic.tiers {
        Some(ts) => &ts.interactive_slo,
        None => &spec.slo,
    }
}

/// The stage-2 verdict on one candidate's report: tiered specs pass on
/// the interactive tier ([`ServeReport::meets_tier`]), untiered ones on
/// the run-wide tails ([`ServeReport::meets`]).
fn serve_verdict(report: &ServeReport, spec: &ServeSpec) -> bool {
    match &spec.traffic.tiers {
        Some(ts) => report.meets_tier(0, &ts.interactive_slo),
        None => report.meets(&spec.slo),
    }
}

/// [`serve_verdict`] under faults: the completion requirement relaxes to
/// the availability fraction either way.
fn serve_verdict_available(report: &ServeReport, spec: &ServeSpec) -> bool {
    let availability = spec.faults.availability;
    match &spec.traffic.tiers {
        Some(ts) => report.meets_tier_available(0, &ts.interactive_slo, availability),
        None => report.meets_available(&spec.slo, availability),
    }
}

/// Event-sim validation of one design point: continuous batching over the
/// spec's traffic at the design's analytic iteration costs, with the KV
/// budget its own mapping affords and the spec's serving model — chunked
/// prefill, paged-KV accounting, and `spec.replicas` independent replicas
/// of this design behind the spec's routing policy (the traffic then
/// spreads across them, so the per-token cost of the *design* is
/// unchanged; only queueing changes).
///
/// Always a *complete* simulation (decode fast-forward on, early abort
/// off): the report is full-fidelity and suitable for display. The sweep's
/// internal stage-2 scan additionally enables early abort — see
/// [`SweepEngine::best_point_slo`].
///
/// Runs through the failure-aware entry points, which delegate to the
/// exact fault-free code path when `spec.faults` is none — so fault-free
/// reports stay byte-identical to the pre-fault simulator.
pub fn validate_design_slo(point: &DesignPoint, w: &Workload, spec: &ServeSpec) -> ServeReport {
    let cfg = slo_sim_config(point, w, spec);
    if let Some(p) = &spec.trace_file {
        let stream = match TraceFile::open(p) {
            Ok(tf) => tf.arrivals().ok().map(|src| (src, tf.requests())),
            Err(_) => None,
        };
        return match stream {
            Some((src, offered)) => simulate_replicated_stream_faults(
                &cfg,
                spec.replicas,
                spec.route,
                &ContinuousBatch,
                &spec.traffic,
                offered,
                src,
                &spec.faults,
                &spec.slo,
            ),
            // Callers validated the path; a vanished file degrades to an
            // unserved (never SLO-meeting) report.
            None => unserved_report("continuous", spec.replicas, spec.traffic.requests),
        };
    }
    simulate_replicated_faults(
        &cfg,
        spec.replicas,
        spec.route,
        &ContinuousBatch,
        &spec.traffic,
        &spec.faults,
        &spec.slo,
    )
}

/// Evaluate one server design for a workload with the TCO/Token objective,
/// the admissible mapping-level lower bound, and an external incumbent.
/// With `prune == false` this is exactly the seed's `evaluate_server`.
pub(crate) fn evaluate_server_bounded(
    space: &ExploreSpace,
    server: &ServerDesign,
    w: &Workload,
    wb: &WorkloadBounds,
    prune: bool,
    incumbent: f64,
) -> (Option<DesignPoint>, SearchStats) {
    let tcom = TcoModel { server: space.server.clone(), dc: space.dc.clone() };
    let cps = server.chips().max(1);
    let score = |mapping: &Mapping, perf: &DecodePerf| -> f64 {
        let n_servers = mapping.n_chips().div_ceil(cps);
        system_tco(space, &tcom, server, n_servers, perf).per_token(perf.tokens_per_s)
    };
    let life = space.server.server_life_years;
    let tpsc = wb.ideal_tokens_per_s_chip(&server.chiplet);
    let lb = |mapping: &Mapping| -> f64 {
        let n = mapping.n_chips();
        let n_servers = n.div_ceil(cps) as f64;
        let capex_rate = server.server_capex * n_servers / (life * YEAR_S);
        let tps_ub = n as f64 * tpsc;
        if tps_ub > 0.0 && tps_ub.is_finite() {
            capex_rate / tps_ub
        } else {
            0.0
        }
    };
    let bound: Option<&dyn Fn(&Mapping) -> f64> = if prune { Some(&lb) } else { None };
    let mut cache = KernelCache::default();
    let (found, stats) = optimize_mapping_bounded(
        server,
        w,
        score,
        if prune { incumbent } else { f64::INFINITY },
        bound,
        &mut cache,
    );
    let point = found.map(|(mapping, perf, tco_per_token)| {
        let n_servers = mapping.n_chips().div_ceil(cps);
        let tco = system_tco(space, &tcom, server, n_servers, &perf);
        DesignPoint { server: server.clone(), mapping, n_servers, perf, tco, tco_per_token }
    });
    (point, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::explore::phase1;

    fn setup() -> (ExploreSpace, Vec<ServerDesign>) {
        let space = ExploreSpace::coarse();
        let (servers, _) = phase1(&space);
        (space, servers)
    }

    #[test]
    fn engine_configurations_agree_on_best_point() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        let seq = SweepEngine::sequential().best_point(&space, &servers, &w).expect("feasible");
        for engine in [
            SweepEngine { threads: 0, prune: false, pareto_order: false, fast_sim: true },
            SweepEngine { threads: 0, prune: true, pareto_order: false, fast_sim: true },
            SweepEngine { threads: 0, prune: true, pareto_order: true, fast_sim: true },
        ] {
            let got = engine.best_point(&space, &servers, &w).expect("feasible");
            assert_eq!(got.mapping, seq.mapping);
            assert_eq!(got.server, seq.server);
            assert_eq!(got.n_servers, seq.n_servers);
            assert_eq!(got.tco_per_token.to_bits(), seq.tco_per_token.to_bits());
        }
    }

    #[test]
    fn pruning_actually_prunes() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let engine = SweepEngine { threads: 0, prune: true, pareto_order: true, fast_sim: true };
        let (_, stats) = engine.best_point_stats(&space, &servers, &w);
        assert!(
            stats.mappings_pruned + stats.servers_pruned > 0,
            "lower-bound cutoff never fired: {stats:?}"
        );
        assert_eq!(
            stats.candidates,
            stats.simulated + stats.mappings_pruned + stats.mappings_infeasible
        );
    }

    #[test]
    fn unconstrained_slo_selection_matches_best_point() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        let slo = SloSpec::unconstrained();
        let spec = ServeSpec::new(TrafficSpec::poisson(2.0, 40, 16, 4, 16), slo);
        let engine = SweepEngine::default();
        let sel = engine.best_point_slo(&space, &servers, &w, &spec).expect("feasible");
        let best = engine.best_point(&space, &servers, &w).expect("feasible");
        // With no constraint the filter passes everything and the first
        // (cheapest) candidate validates trivially — the unconstrained
        // optimum, bit for bit.
        assert_eq!(sel.point.mapping, best.mapping);
        assert_eq!(sel.point.server, best.server);
        assert_eq!(sel.point.tco_per_token.to_bits(), best.tco_per_token.to_bits());
        assert_eq!(sel.validated, 1);
        assert!(sel.report.meets(&slo));
        assert_eq!(sel.report.completed, 40);
    }

    #[test]
    fn impossible_slo_returns_none() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        let slo = SloSpec::new(f64::INFINITY, 1e-15); // no pipeline decodes in 1 fs
        let spec = ServeSpec::new(TrafficSpec::poisson(2.0, 10, 16, 4, 8), slo);
        assert!(SweepEngine::default().best_point_slo(&space, &servers, &w, &spec).is_none());
    }

    /// The acceptance scenario: a binding TPOT constraint makes the engine
    /// return a (possibly different) optimum, and the event simulator
    /// confirms it feasible.
    #[test]
    fn binding_slo_optimum_is_sim_confirmed_and_never_cheaper() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        let engine = SweepEngine::default();
        let best = engine.best_point(&space, &servers, &w).expect("feasible");
        // Target the fastest token period any per-server optimum achieves:
        // guaranteed attainable by at least that design's own mapping.
        let points = SweepEngine::sequential().sweep(&space, &servers, &w);
        let fastest = points
            .iter()
            .map(|p| p.perf.token_period)
            .fold(f64::INFINITY, f64::min);
        let slo = SloSpec::new(f64::INFINITY, fastest * 1.001);
        // Single-request trace: validation reduces to the exact steady
        // bounds, so stage-2 must confirm whatever stage 1 admits.
        let spec = ServeSpec::new(TrafficSpec::poisson(1.0, 1, 8, 4, 4), slo);
        let sel = engine
            .best_point_slo(&space, &servers, &w, &spec)
            .expect("a design achieving the fastest period exists");
        assert!(sel.point.perf.token_period <= slo.tpot_p99_s);
        assert!(sel.report.meets(&slo), "event sim must confirm the selection");
        // Constraining can never find a cheaper token than the
        // unconstrained optimum...
        assert!(sel.point.tco_per_token >= best.tco_per_token * (1.0 - 1e-12));
        // ...and when the unconstrained optimum violates the target, the
        // constrained selection must be a different design.
        if best.perf.token_period > slo.tpot_p99_s {
            assert!(
                sel.point.server != best.server || sel.point.mapping != best.mapping,
                "SLO-violating unconstrained optimum cannot be re-selected"
            );
        }
    }

    /// Fast stage 2 (fast-forward + early abort + speculative parallel
    /// waves) against the sequential reference scan on a binding SLO under
    /// real queueing: same design to the bit, and the winner's confirming
    /// report identical too (a passing validation never aborts and
    /// fast-forward replays the reference stepping exactly).
    #[test]
    fn fast_stage2_matches_sequential_reference_scan() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        let fastest = SweepEngine::sequential()
            .sweep(&space, &servers, &w)
            .iter()
            .map(|p| p.perf.token_period)
            .fold(f64::INFINITY, f64::min);
        assert!(fastest.is_finite());
        // A mid-band TPOT target over a queueing trace: cheap candidates
        // fail validation (exercising abort + speculation), some design
        // passes.
        let slo = SloSpec::new(f64::INFINITY, fastest * 4.0);
        let spec = ServeSpec::new(TrafficSpec::closed_loop(8, 0.0, 60, 16, 8, 32), slo);
        let reference = SweepEngine::sequential().best_point_slo(&space, &servers, &w, &spec);
        let fast = SweepEngine { threads: 0, prune: true, pareto_order: true, fast_sim: true }
            .best_point_slo(&space, &servers, &w, &spec);
        match (reference, fast) {
            (Some(r), Some(f)) => {
                assert_eq!(f.point.mapping, r.point.mapping);
                assert_eq!(f.point.server, r.point.server);
                assert_eq!(f.point.n_servers, r.point.n_servers);
                assert_eq!(f.point.tco_per_token.to_bits(), r.point.tco_per_token.to_bits());
                assert!(f.report.meets(&slo) && r.report.meets(&slo));
                assert!(!f.report.aborted_early);
                assert_eq!(f.report.completed, r.report.completed);
                assert_eq!(f.report.iterations, r.report.iterations);
                assert_eq!(f.report.ttft_p99_s.to_bits(), r.report.ttft_p99_s.to_bits());
                assert_eq!(f.report.tpot_p99_s.to_bits(), r.report.tpot_p99_s.to_bits());
                assert_eq!(f.report.makespan_s.to_bits(), r.report.makespan_s.to_bits());
            }
            (None, None) => {} // both infeasible is also agreement
            (r, f) => panic!(
                "engines disagree on feasibility: reference {:?} vs fast {:?}",
                r.is_some(),
                f.is_some()
            ),
        }
    }

    #[test]
    fn workload_serve_spec_dispatches_the_selection() {
        let (space, servers) = setup();
        let engine = SweepEngine::default();
        let plain = Workload::new(ModelSpec::megatron(), 1024, 64);
        let (p0, r0) = engine.best_point_serve(&space, &servers, &plain).expect("feasible");
        assert!(r0.is_none());
        let spec =
            ServeSpec::new(TrafficSpec::poisson(2.0, 20, 16, 4, 8), SloSpec::unconstrained());
        let (p1, r1) = engine
            .best_point_serve(&space, &servers, &plain.clone().with_serve(spec))
            .expect("feasible");
        assert_eq!(p0.mapping, p1.mapping);
        assert_eq!(r1.expect("spec attached → report").completed, 20);
    }

    /// The redundancy-sizing acceptance shape in miniature: a scripted,
    /// never-recovering kill of replica 0 plus an availability target
    /// forces the selection to buy at least one spare over the fault-free
    /// optimum — a strictly more redundant and strictly costlier fleet.
    #[test]
    fn availability_target_buys_a_spare_replica() {
        use crate::config::workload::FaultSpec;
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 64);
        // Generous-but-finite tails: only the availability term binds.
        let slo = SloSpec::new(1e6, 1e6);
        let traffic = TrafficSpec::poisson(2.0, 20, 16, 4, 8);
        let engine = SweepEngine::default();
        let free = engine
            .best_point_slo(&space, &servers, &w, &ServeSpec::new(traffic.clone(), slo))
            .expect("fault-free selection feasible");
        assert_eq!(free.replicas, 1);
        let faults = FaultSpec::scripted(FaultSpec::parse_plan("fail:0@0.05").expect("plan"))
            .with_availability(0.9);
        let spec = ServeSpec::new(traffic, slo).with_faults(faults);
        let sized = engine
            .best_point_slo(&space, &servers, &w, &spec)
            .expect("a spare makes the fleet available");
        // A one-replica fleet loses (almost) the whole run to the
        // unrecovered kill, so the target forces at least one spare...
        assert!(
            sized.replicas > free.replicas,
            "expected a spare over the fault-free fleet of {}",
            free.replicas
        );
        // ...making the chosen fleet strictly costlier than the fault-free
        // optimum's.
        assert!(
            sized.point.tco_per_token * sized.replicas as f64
                > free.point.tco_per_token * free.replicas as f64
        );
        assert!(sized.report.meets_available(&slo, 0.9));
        assert_eq!(
            sized.report.completed + sized.report.rejected + sized.report.lost,
            sized.report.offered,
            "faulted-run conservation broke"
        );
    }

    #[test]
    fn server_lower_bound_is_admissible_on_real_points() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::megatron(), 1024, 32);
        let wb = WorkloadBounds::new(&w);
        let points = SweepEngine::sequential().sweep(&space, &servers, &w);
        assert!(!points.is_empty());
        for p in &points {
            let lb = wb.server_lower_bound(&space, &p.server);
            assert!(
                lb <= p.tco_per_token * (1.0 + 1e-12),
                "bound {lb} exceeds true score {} for {:?}",
                p.tco_per_token,
                p.server.chiplet
            );
        }
    }
}
