//! Design-choice ablations: quantify each Chiplet Cloud architectural
//! decision by switching it off and re-running the two-phase search.

use crate::config::hardware::ExploreSpace;
use crate::config::{ModelSpec, Workload};
use crate::cost::die::cost_per_mm2;
use crate::evaluate::best_point;
use crate::explore::phase1;
use crate::util::table::Table;

/// One ablation row: what was disabled, and the TCO/Token penalty.
#[derive(Clone, Debug)]
pub struct Ablation {
    /// Ablation name.
    pub name: String,
    /// TCO/Token with the feature on (the full system).
    pub with_feature: f64,
    /// TCO/Token with the feature disabled.
    pub without: f64,
}

impl Ablation {
    /// Penalty factor for removing the feature.
    pub fn penalty(&self) -> f64 {
        self.without / self.with_feature
    }
}

/// Run the ablation suite for a model at an operating point.
pub fn ablate(
    space: &ExploreSpace,
    model: &ModelSpec,
    ctx: usize,
    batch: usize,
) -> Vec<Ablation> {
    let (servers, _) = phase1(space);
    let w = Workload::new(model.clone(), ctx, batch);
    let Some(full) = best_point(space, &servers, &w) else { return Vec::new() };
    let mut out = Vec::new();

    // 1. Chiplets → monolithic: restrict to reticle-class dies.
    let mono: Vec<_> =
        servers.iter().filter(|s| s.chiplet.die_mm2 >= 700.0).cloned().collect();
    if let Some(p) = best_point(space, &mono, &w) {
        out.push(Ablation {
            name: "chiplets (vs >=700mm2 monolithic)".into(),
            with_feature: full.tco_per_token,
            without: p.tco_per_token,
        });
    }

    // 2. 2D weight-stationary mapping → 1D tensor parallelism.
    if let Some(p) = best_point(space, &servers, &w.clone().with_1d_comm()) {
        out.push(Ablation {
            name: "2D weight-stationary (vs 1D comm)".into(),
            with_feature: full.tco_per_token,
            without: p.tco_per_token,
        });
    }

    // 3. Micro-batch tuning → fixed microbatch of 1. The per-server
    // re-scoring is embarrassingly parallel; min-reduction over the costs
    // is order-independent, so the fork-join changes wall-clock only.
    {
        use crate::cost::tco::TcoModel;
        use crate::mapping::optimizer;
        let tcom = TcoModel { server: space.server.clone(), dc: space.dc.clone() };
        let costs = crate::util::parallel::par_map(&servers, 0, |s| -> Option<f64> {
            let score = |mapping: &crate::mapping::Mapping, perf: &crate::perf::DecodePerf| {
                let n_servers = mapping.n_chips().div_ceil(s.chips().max(1));
                crate::evaluate::system_tco(space, &tcom, s, n_servers, perf)
                    .per_token(perf.tokens_per_s)
            };
            let (m, _perf, cost) = optimizer::optimize_mapping(s, &w, score)?;
            if m.microbatch == 1 {
                Some(cost)
            } else {
                // re-evaluate at microbatch 1 with the same tp/pp
                let m1 = crate::mapping::Mapping { microbatch: 1, ..m };
                let p1 = crate::perf::simulate(s, &w, &m1)?;
                let n_servers = m1.n_chips().div_ceil(s.chips().max(1));
                Some(
                    crate::evaluate::system_tco(space, &tcom, s, n_servers, &p1)
                        .per_token(p1.tokens_per_s),
                )
            }
        });
        let best = costs.into_iter().flatten().fold(None, |acc: Option<f64>, c| {
            Some(acc.map_or(c, |b| b.min(c)))
        });
        if let Some(c) = best {
            out.push(Ablation {
                name: "micro-batch tuning (vs ub=1)".into(),
                with_feature: full.tco_per_token,
                without: c,
            });
        }
    }

    // 4. Batch-size tuning → batch 1.
    if let Some(p) = best_point(space, &servers, &Workload::new(model.clone(), ctx, 1)) {
        out.push(Ablation {
            name: "batching (vs batch=1)".into(),
            with_feature: full.tco_per_token,
            without: p.tco_per_token,
        });
    }

    out
}

/// Yield-model ablation: the negative-binomial clustering assumption vs a
/// Poisson model (α → ∞). Returns ($/mm² ratio big/small die) under each —
/// clustering is why big dies are *less* catastrophic than Poisson predicts.
pub fn yield_model_ablation(space: &ExploreSpace) -> (f64, f64) {
    let nb = cost_per_mm2(&space.tech, 750.0) / cost_per_mm2(&space.tech, 150.0);
    let mut poisson_tech = space.tech.clone();
    poisson_tech.yield_alpha = 1e6;
    let poisson = cost_per_mm2(&poisson_tech, 750.0) / cost_per_mm2(&poisson_tech, 150.0);
    (nb, poisson)
}

/// Render the ablation suite as a table.
pub fn ablation_table(space: &ExploreSpace, model: &ModelSpec, ctx: usize, batch: usize) -> Table {
    let mut t = Table::new(vec!["Design choice", "TCO/Token penalty when removed"])
        .with_title(format!(
            "Ablations: {} @ ctx {ctx}, batch {batch} (coarse sweep)",
            model.display
        ));
    for a in ablate(space, model, ctx, batch) {
        t.row(vec![a.name.clone(), format!("{:.2}x", a.penalty())]);
    }
    let (nb, poisson) = yield_model_ablation(space);
    t.row(vec![
        "negative-binomial yield (vs Poisson)".into(),
        format!("big-die $/mm2 ratio {:.2}x vs {:.2}x", nb, poisson),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_all_penalize() {
        let space = ExploreSpace::coarse();
        let rows = ablate(&space, &ModelSpec::gpt3(), 2048, 256);
        assert!(rows.len() >= 3, "expected >=3 ablations, got {}", rows.len());
        for a in &rows {
            assert!(
                a.penalty() >= 0.99,
                "removing '{}' should not help: {:.3}x",
                a.name,
                a.penalty()
            );
        }
        // chiplets and batching are the big levers
        let chiplet = rows.iter().find(|a| a.name.starts_with("chiplets")).unwrap();
        assert!(chiplet.penalty() > 1.2, "chiplet penalty {:.2}", chiplet.penalty());
        let batching = rows.iter().find(|a| a.name.starts_with("batching")).unwrap();
        assert!(batching.penalty() > 1.5, "batching penalty {:.2}", batching.penalty());
    }

    #[test]
    fn clustering_softens_big_die_cost() {
        let space = ExploreSpace::coarse();
        let (nb, poisson) = yield_model_ablation(&space);
        assert!(nb < poisson, "negative binomial must be kinder to big dies");
        assert!((1.5..=2.5).contains(&nb), "paper's ~2x claim: {nb}");
    }
}
