//! Sparse-model evaluation (paper §6.2, Fig. 13).
//!
//! Weights are stored tile-CSR compressed in CC-MEM (Store-as-Compressed)
//! and decoded to dense on load (Load-as-Dense), so sparsity changes the
//! *memory footprint* (fewer chips needed) and — below the decoder's knee —
//! the *weight-read time*, while the compute units stay sparsity-agnostic.
//! TCO/Token of the system is capacity-limited, so footprint drives cost.

use crate::arch::ServerDesign;
use crate::config::hardware::ExploreSpace;
use crate::config::{ModelSpec, Workload};
use crate::evaluate::{best_point, DesignPoint};
use crate::sparse::stats::opt175b_perplexity;

/// One row of the Fig. 13 (top) sweep.
#[derive(Clone, Debug)]
pub struct SparsityPoint {
    /// Weight sparsity (fraction of zeros).
    pub sparsity: f64,
    /// TCO/Token-optimal design at this sparsity.
    pub point: DesignPoint,
    /// TCO/Token change vs dense (negative = cheaper).
    pub tco_delta_frac: f64,
    /// Model perplexity at this sparsity (quoted from SparseGPT [15]).
    pub perplexity: f64,
}

/// Sweep sparsity for a model (Fig. 13 top: OPT-175B, 0..80%).
pub fn sparsity_sweep(
    space: &ExploreSpace,
    servers: &[ServerDesign],
    model: &ModelSpec,
    ctx: usize,
    batch: usize,
    sparsities: &[f64],
) -> Vec<SparsityPoint> {
    let dense = best_point(space, servers, &Workload::new(model.clone(), ctx, batch));
    let Some(dense) = dense else { return Vec::new() };
    let mut out = Vec::new();
    for &s in sparsities {
        let w = Workload::new(model.clone(), ctx, batch).with_sparsity(s);
        if let Some(point) = best_point(space, servers, &w) {
            let delta = point.tco_per_token / dense.tco_per_token - 1.0;
            out.push(SparsityPoint {
                sparsity: s,
                point,
                tco_delta_frac: delta,
                perplexity: opt175b_perplexity(s),
            });
        }
    }
    out
}

/// Largest model (parameter multiple of `model`) servable on a *fixed*
/// system at the given sparsity (Fig. 13 bottom: 1.7× at 60%).
pub fn max_model_scale_on_system(
    model: &ModelSpec,
    ctx: usize,
    batch: usize,
    system_bytes: f64,
    sparsity: f64,
) -> f64 {
    let w = Workload::new(model.clone(), ctx, batch).with_sparsity(sparsity);
    // scale s.t. scale·(stored weights) + scale·KV = capacity
    // (KV is not compressed; model scale grows KV proportionally via layers/d)
    let per_scale = w.stored_weight_bytes() + w.kv_bytes();
    system_bytes / per_scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::phase1;

    #[test]
    fn sparsity_sweep_reproduces_fig13_shape() {
        let space = ExploreSpace::coarse();
        let (servers, _) = phase1(&space);
        // OPT-175B at modest batch (keeps the coarse sweep fast)
        let pts = sparsity_sweep(
            &space,
            &servers,
            &ModelSpec::opt_175b(),
            2048,
            64,
            &[0.1, 0.2, 0.6],
        );
        assert_eq!(pts.len(), 3);
        let at = |s: f64| pts.iter().find(|p| (p.sparsity - s).abs() < 1e-9).unwrap();
        // Low sparsity: encoding overhead ⇒ TCO does not improve.
        assert!(at(0.1).tco_delta_frac > -0.02, "10%: {}", at(0.1).tco_delta_frac);
        // 60%: TCO improves (paper: −7.4%).
        assert!(at(0.6).tco_delta_frac < -0.01, "60%: {}", at(0.6).tco_delta_frac);
        // and perplexity is still near-dense at 60%
        assert!(at(0.6).perplexity < 8.7);
    }

    #[test]
    fn model_scale_at_60pct_close_to_paper() {
        // Fig. 13 bottom: 1.7× at 60% sparsity. The scale approaches the
        // codec's 1.78× in the weights-dominated regime (small batch);
        // large batches dilute it because the KV cache is not compressed.
        use crate::config::Workload;
        let m = ModelSpec::opt_175b();
        let dense_sys = {
            let w = Workload::new(m.clone(), 2048, 4);
            w.stored_weight_bytes() + w.kv_bytes()
        };
        let scale = max_model_scale_on_system(&m, 2048, 4, dense_sys, 0.6);
        assert!((1.5..=1.85).contains(&scale), "scale={scale}");
        // and the dilution effect itself:
        let big_sys = {
            let w = Workload::new(m.clone(), 2048, 256);
            w.stored_weight_bytes() + w.kv_bytes()
        };
        let diluted = max_model_scale_on_system(&m, 2048, 256, big_sys, 0.6);
        assert!(diluted < scale);
    }
}
