//! Input-sensitivity analysis (paper Fig. 10's ±15%/±30% variance bands,
//! §6.1: "we also add variance to 2 inputs that are difficult to
//! accurately estimate: the TCO of GPU and TPU clouds, and the NRE of
//! Chiplet Cloud").
//!
//! Rather than scaling the final ratio, we perturb the actual *inputs*
//! (baseline rental rate, NRE total, and optionally our own wafer price /
//! electricity) and report the induced interval on the improvement factor
//! — the honest version of the paper's shaded regions.

use crate::cost::nre::NreModel;

/// One perturbable input with its relative uncertainty.
#[derive(Clone, Copy, Debug)]
pub struct Uncertain {
    /// Nominal value.
    pub nominal: f64,
    /// Relative half-width (0.30 = ±30%).
    pub rel: f64,
}

impl Uncertain {
    /// Lower bound.
    pub fn lo(&self) -> f64 {
        self.nominal * (1.0 - self.rel)
    }

    /// Upper bound.
    pub fn hi(&self) -> f64 {
        self.nominal * (1.0 + self.rel)
    }
}

/// Improvement-factor interval for `baseline_per_token / (cc + nre/tokens)`
/// under uncertainty in the baseline cost and the NRE.
#[derive(Clone, Copy, Debug)]
pub struct ImprovementBand {
    /// Nominal improvement factor.
    pub nominal: f64,
    /// Worst case (baseline low, NRE high).
    pub lo: f64,
    /// Best case (baseline high, NRE low).
    pub hi: f64,
}

/// Compute the Fig.-10 band at a given cumulative token volume.
pub fn improvement_band(
    baseline_per_token: Uncertain,
    nre_total: Uncertain,
    cc_per_token: f64,
    total_tokens: f64,
) -> ImprovementBand {
    let f = |base: f64, nre: f64| {
        let model = NreModel {
            masks: nre,
            cad_tools: 0.0,
            ip_licensing: 0.0,
            labor: 0.0,
            package_and_server: 0.0,
        };
        base / model.nre_plus_tco_per_token(cc_per_token, total_tokens)
    };
    ImprovementBand {
        nominal: f(baseline_per_token.nominal, nre_total.nominal),
        lo: f(baseline_per_token.lo(), nre_total.hi()),
        hi: f(baseline_per_token.hi(), nre_total.lo()),
    }
}

/// One-at-a-time sensitivity of a TCO/Token figure to the model's economic
/// constants: returns (input name, −rel, +rel) → relative change in the
/// output, for tornado-style reporting.
pub fn tco_tornado(
    space: &crate::config::hardware::ExploreSpace,
    servers: &[crate::arch::ServerDesign],
    w: &crate::config::Workload,
    rel: f64,
) -> Vec<(String, f64, f64)> {
    let nominal = match crate::evaluate::best_point(space, servers, w) {
        Some(p) => p.tco_per_token,
        None => return Vec::new(),
    };
    let mut out = Vec::new();
    let mut eval_with = |name: &str, f: &dyn Fn(&mut crate::config::hardware::ExploreSpace)| {
        let mut lo_space = space.clone();
        f(&mut lo_space);
        // Phase-1 geometry depends on tech constants: re-run it.
        let (lo_servers, _) = crate::explore::phase1(&lo_space);
        if let Some(p) = crate::evaluate::best_point(&lo_space, &lo_servers, w) {
            out.push((name.to_string(), p.tco_per_token / nominal - 1.0, 0.0));
        }
    };
    let r = rel;
    eval_with("wafer_cost +", &|s| s.tech.wafer_cost *= 1.0 + r);
    eval_with("wafer_cost -", &|s| s.tech.wafer_cost *= 1.0 - r);
    eval_with("electricity +", &|s| s.dc.electricity_per_kwh *= 1.0 + r);
    eval_with("electricity -", &|s| s.dc.electricity_per_kwh *= 1.0 - r);
    eval_with("defect_density +", &|s| s.tech.defect_density_per_cm2 *= 1.0 + r);
    eval_with("server_life +", &|s| s.server.server_life_years *= 1.0 + r);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_orientation() {
        let band = improvement_band(
            Uncertain { nominal: 17e-6, rel: 0.30 },
            Uncertain { nominal: 35e6, rel: 0.30 },
            0.15e-6,
            1e15,
        );
        assert!(band.lo < band.nominal && band.nominal < band.hi);
        // paper: ±30% keeps the GPU improvement within 66x..129x of ~97x —
        // i.e. the band is roughly ±33% around nominal at large volume
        assert!(band.lo / band.nominal > 0.6);
        assert!(band.hi / band.nominal < 1.5);
    }

    #[test]
    fn nre_matters_only_at_small_volume() {
        let base = Uncertain { nominal: 17e-6, rel: 0.0 };
        let nre = Uncertain { nominal: 35e6, rel: 0.30 };
        let small = improvement_band(base, nre, 0.15e-6, 1e12);
        let large = improvement_band(base, nre, 0.15e-6, 1e17);
        let small_spread = small.hi / small.lo;
        let large_spread = large.hi / large.lo;
        assert!(small_spread > large_spread, "{small_spread} vs {large_spread}");
        assert!(large_spread < 1.01, "NRE uncertainty vanishes at volume");
    }

    #[test]
    fn tornado_directions() {
        let space = crate::config::hardware::ExploreSpace::coarse();
        let (servers, _) = crate::explore::phase1(&space);
        let w = crate::config::Workload::new(crate::config::ModelSpec::megatron(), 1024, 64);
        let rows = tco_tornado(&space, &servers, &w, 0.3);
        assert!(rows.len() >= 4);
        let get = |name: &str| rows.iter().find(|(n, _, _)| n == name).map(|(_, d, _)| *d);
        // costlier wafers / power / defects raise TCO; longer life lowers it
        assert!(get("wafer_cost +").unwrap() > 0.0);
        assert!(get("electricity +").unwrap() > 0.0);
        assert!(get("wafer_cost -").unwrap() < 0.0);
        assert!(get("server_life +").unwrap() < 0.0);
    }
}
