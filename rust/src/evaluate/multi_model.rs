//! Chip flexibility across models (paper §6.3, Fig. 14).
//!
//! One chip design can serve different models by re-sizing the server count
//! and re-optimizing the mapping. This module evaluates a *fixed chiplet*
//! across models (via the server designs that share it) and implements the
//! multi-model objective: minimize the geometric mean of TCO/Token over a
//! model set.

use crate::arch::{ChipletDesign, ServerDesign};
use crate::config::hardware::ExploreSpace;
use crate::config::{ModelSpec, Workload};
use crate::evaluate::{best_point, DesignPoint};
use crate::util::stats::geomean;

/// All feasible server designs built from one specific chiplet
/// (chips-per-lane re-swept; the chip itself is fixed silicon).
pub fn servers_for_chip(space: &ExploreSpace, chip: &ChipletDesign) -> Vec<ServerDesign> {
    let tp = crate::thermal::ThermalParams::default();
    space
        .chips_per_lane
        .iter()
        .filter_map(|&cpl| crate::explore::check_server(space, &tp, chip, cpl).ok())
        .collect()
}

/// Best TCO/Token achievable for `model` using a fixed chip design.
pub fn best_for_chip(
    space: &ExploreSpace,
    chip: &ChipletDesign,
    model: &ModelSpec,
    ctx: usize,
    batch: usize,
) -> Option<DesignPoint> {
    let servers = servers_for_chip(space, chip);
    best_point(space, &servers, &Workload::new(model.clone(), ctx, batch))
}

/// Result of the multi-model chip search.
#[derive(Clone, Debug)]
pub struct MultiModelResult {
    /// The winning chip.
    pub chip: ChipletDesign,
    /// Geomean TCO/Token across the model set.
    pub geomean_tco_per_token: f64,
    /// Per-model best points with this chip (same order as the input set).
    pub per_model: Vec<DesignPoint>,
}

/// Search `chips` for the design minimizing geomean TCO/Token across
/// `models` (each evaluated at its own (ctx, batch) operating point).
///
/// Candidate chips are scored in parallel via the sweep engine's fork-join;
/// the winner is reduced in input order (first minimum), so the result is
/// deterministic and identical to the sequential search.
pub fn multi_model_search(
    space: &ExploreSpace,
    chips: &[ChipletDesign],
    models: &[(ModelSpec, usize, usize)],
) -> Option<MultiModelResult> {
    let scored = crate::util::parallel::par_map(chips, 0, |chip| {
        let mut pts = Vec::with_capacity(models.len());
        for (m, ctx, batch) in models {
            match best_for_chip(space, chip, m, *ctx, *batch) {
                Some(p) => pts.push(p),
                None => return None,
            }
        }
        let g = geomean(&pts.iter().map(|p| p.tco_per_token).collect::<Vec<_>>());
        Some(MultiModelResult { chip: chip.clone(), geomean_tco_per_token: g, per_model: pts })
    });
    let mut best: Option<MultiModelResult> = None;
    for candidate in scored.into_iter().flatten() {
        if best
            .as_ref()
            .map(|b| candidate.geomean_tco_per_token < b.geomean_tco_per_token)
            .unwrap_or(true)
        {
            best = Some(candidate);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::phase1;

    #[test]
    fn cross_model_overhead_is_bounded() {
        // Fig. 14: a chip optimized for model A runs model B at 1.1–1.5×
        // the B-optimized TCO/Token.
        let space = ExploreSpace::coarse();
        let (servers, _) = phase1(&space);
        let gpt3 = ModelSpec::gpt3();
        let llama = ModelSpec::llama2_70b();
        let w_gpt3 = Workload::new(gpt3.clone(), 2048, 64);
        let w_llama = Workload::new(llama.clone(), 2048, 64);
        let gpt3_opt = best_point(&space, &servers, &w_gpt3).unwrap();
        let llama_opt = best_point(&space, &servers, &w_llama).unwrap();
        // run llama on the gpt3-optimal chip
        let cross = best_for_chip(&space, &gpt3_opt.server.chiplet, &llama, 2048, 64).unwrap();
        let overhead = cross.tco_per_token / llama_opt.tco_per_token;
        assert!(
            (1.0..=2.2).contains(&overhead),
            "cross-model overhead {overhead} (paper: 1.1–1.5×)"
        );
    }

    #[test]
    fn multi_model_chip_beats_worst_single_choice() {
        let space = ExploreSpace::coarse();
        let (servers, _) = phase1(&space);
        let models: Vec<(ModelSpec, usize, usize)> = vec![
            (ModelSpec::megatron(), 1024, 32),
            (ModelSpec::llama2_70b(), 1024, 32),
        ];
        // candidate chips: each model's optimal chip
        let chips: Vec<_> = models
            .iter()
            .filter_map(|(m, ctx, b)| {
                best_point(&space, &servers, &Workload::new(m.clone(), *ctx, *b))
                    .map(|p| p.server.chiplet)
            })
            .collect();
        let result = multi_model_search(&space, &chips, &models).expect("feasible");
        assert_eq!(result.per_model.len(), 2);
        // geomean of the winner ≤ geomean of any candidate by construction;
        // sanity: positive and finite
        assert!(result.geomean_tco_per_token.is_finite());
        assert!(result.geomean_tco_per_token > 0.0);
    }
}
