//! Phase 2 — software evaluation (paper §4.2, Fig. 5(b)) and the
//! system cost-performance analysis.
//!
//! For each feasible server design and workload, search the mapping space,
//! simulate decode performance, build the system TCO, and keep the
//! TCO/Token-optimal points. Also exposes the sweep data the evaluation
//! figures plot (TCO vs die size, batch sweeps, multi-model objectives).

pub mod ablation;
pub mod engine;
pub mod multi_model;
pub mod sensitivity;
pub mod sparsity;

pub use engine::{
    slo_sim_config, validate_design_slo, validation_slo, SloSelection, SweepEngine, SweepStats,
    WorkloadBounds,
};

use crate::arch::ServerDesign;
use crate::config::hardware::ExploreSpace;
use crate::config::Workload;
use crate::cost::tco::{Tco, TcoModel};
use crate::mapping::Mapping;
use crate::perf::DecodePerf;
use crate::power;

/// A fully evaluated design point: hardware + mapping + performance + cost.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    /// The server design.
    pub server: ServerDesign,
    /// The chosen mapping.
    pub mapping: Mapping,
    /// Whole servers deployed (mapping chips / chips-per-server, ceil).
    pub n_servers: usize,
    /// Simulated decode performance.
    pub perf: DecodePerf,
    /// System TCO over the server life (all servers).
    pub tco: Tco,
    /// $ per generated token.
    pub tco_per_token: f64,
}

impl DesignPoint {
    /// $ per 1K tokens (Fig. 8's axis).
    pub fn tco_per_ktok(&self) -> f64 {
        self.tco_per_token * 1e3
    }

    /// $ per 1M tokens (Table 2's row).
    pub fn tco_per_mtok(&self) -> f64 {
        self.tco_per_token * 1e6
    }
}

/// Evaluate one server design for a workload: find its TCO/Token-optimal
/// mapping. Returns None if nothing fits. (The exhaustive single-server
/// path: delegates to the engine's bounded evaluator with pruning off so
/// the objective and DesignPoint assembly live in exactly one place.)
pub fn evaluate_server(
    space: &ExploreSpace,
    server: &ServerDesign,
    w: &Workload,
) -> Option<DesignPoint> {
    engine::evaluate_server_bounded(
        space,
        server,
        w,
        &WorkloadBounds::new(w),
        false,
        f64::INFINITY,
    )
    .0
}

/// System TCO: `n_servers` replicas at the utilization the simulation found.
pub fn system_tco(
    space: &ExploreSpace,
    tcom: &TcoModel,
    server: &ServerDesign,
    n_servers: usize,
    perf: &DecodePerf,
) -> Tco {
    let avg_wall = power::server_avg_power(
        server,
        &space.tech,
        &space.server,
        perf.compute_util,
        perf.mem_util,
    );
    let per_server = tcom.server_tco(server.server_capex, avg_wall);
    Tco {
        capex: per_server.capex * n_servers as f64,
        energy: per_server.energy * n_servers as f64,
        facility: per_server.facility * n_servers as f64,
        maintenance: per_server.maintenance * n_servers as f64,
        life_years: per_server.life_years,
    }
}

/// Phase-2 over a set of servers: the best point per server (the scatter
/// the paper's Fig. 7 plots) — use [`best_point`] for the global optimum.
///
/// Runs on the default [`SweepEngine`] (parallel + pruned); per-server
/// results and their order are identical to the sequential evaluation.
///
/// *Deprecated shim*: new callers should describe the run as a
/// [`crate::config::Experiment`] and dispatch through
/// [`crate::experiment::Engine::run`], which routes to exactly this code —
/// the shims stay so the figure harnesses and the behavioral-identity
/// tests keep their direct handles.
pub fn sweep(space: &ExploreSpace, servers: &[ServerDesign], w: &Workload) -> Vec<DesignPoint> {
    SweepEngine::default().sweep(space, servers, w)
}

/// Global TCO/Token-optimal point for a workload, via the default
/// [`SweepEngine`] — the same optimal value and mapping as the exhaustive
/// sweep. Exact ties on `tco_per_token` resolve to the **first** server in
/// input order (the seed's `min_by` took the last; first-minimum is what
/// both `SweepEngine::sequential()` and the parallel engine implement, so
/// pruned/parallel/sequential all agree bit-for-bit).
///
/// *Deprecated shim* — see [`sweep`]; prefer [`crate::experiment::Engine::run`].
pub fn best_point(
    space: &ExploreSpace,
    servers: &[ServerDesign],
    w: &Workload,
) -> Option<DesignPoint> {
    SweepEngine::default().best_point(space, servers, w)
}

/// Best point for a model across a workload grid (the Table-2 procedure:
/// ctx ∈ {1024, 2048, 4096} × batch 1..1024, keep the global optimum), via
/// the default [`SweepEngine`].
///
/// *Deprecated shim* — see [`sweep`]; prefer [`crate::experiment::Engine::run`].
pub fn best_over_grid(
    space: &ExploreSpace,
    servers: &[ServerDesign],
    grid: &[Workload],
) -> Option<(Workload, DesignPoint)> {
    SweepEngine::default().best_over_grid(space, servers, grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::explore::phase1;

    fn setup() -> (ExploreSpace, Vec<ServerDesign>) {
        let space = ExploreSpace::coarse();
        let (servers, _) = phase1(&space);
        (space, servers)
    }

    #[test]
    fn finds_a_gpt3_optimum() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let p = best_point(&space, &servers, &w).expect("feasible design exists");
        // Table 2: $0.161 / 1M tokens; coarse grid within ~3x
        assert!(
            (0.05..=0.5).contains(&p.tco_per_mtok()),
            "TCO/1M tok = {}",
            p.tco_per_mtok()
        );
        // CapEx-dominated (paper: >80% for most designs)
        assert!(p.tco.capex_frac() > 0.5, "capex frac {}", p.tco.capex_frac());
    }

    #[test]
    fn optimal_die_is_small_not_reticle() {
        let (space, servers) = setup();
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        let p = best_point(&space, &servers, &w).unwrap();
        // Fig. 7: optima live below ~300 mm², far from the 800 mm² limit
        assert!(p.server.chiplet.die_mm2 <= 400.0, "die={}", p.server.chiplet.die_mm2);
    }

    #[test]
    fn small_model_costs_less_per_token() {
        let (space, servers) = setup();
        let small = best_point(&space, &servers, &Workload::new(ModelSpec::gpt2(), 1024, 128))
            .unwrap()
            .tco_per_token;
        let large = best_point(&space, &servers, &Workload::new(ModelSpec::gpt3(), 1024, 128))
            .unwrap()
            .tco_per_token;
        // Table 2: GPT-2 $0.001/M vs GPT-3 $0.161/M — orders of magnitude
        assert!(large / small > 20.0, "ratio={}", large / small);
    }

    #[test]
    fn grid_optimum_not_worse_than_members() {
        let (space, servers) = setup();
        let m = ModelSpec::megatron();
        let grid: Vec<Workload> =
            [32usize, 128].iter().map(|&b| Workload::new(m.clone(), 1024, b)).collect();
        let (_, best) = best_over_grid(&space, &servers, &grid).unwrap();
        for w in &grid {
            if let Some(p) = best_point(&space, &servers, w) {
                assert!(best.tco_per_token <= p.tco_per_token + 1e-15);
            }
        }
    }
}
