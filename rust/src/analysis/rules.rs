//! The lint rules and the token-stream matcher behind `ccloud lint`.
//!
//! Rules are project-specific invariants clippy cannot express — they
//! encode *which modules* are allowed to panic, read the wall clock,
//! iterate unordered containers into serialized output, or compare floats
//! for equality. See the README "Static analysis" section for the rule
//! table and the rationale behind each scope.

use std::fmt;

use crate::analysis::lexer::{lex, LintComment, Tok, Token};

/// Which tree a file came from — decides which rules apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    /// `src/**` except `src/main.rs`: the library every consumer links.
    Library,
    /// `src/main.rs`: the CLI driver (panics and exits are its job).
    Binary,
    /// `tests/**`: integration tests.
    Tests,
    /// `benches/**`: the figure/bench harnesses.
    Benches,
}

/// Lint rule identifiers (`rule-id` in findings and suppressions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// R1: no `unwrap`/`expect`/`panic!`/`todo!`/`unimplemented!` in
    /// library code.
    NoPanic,
    /// R2: no `Instant::now`/`SystemTime` outside the live-serving and
    /// process-supervision modules.
    NoWallclock,
    /// R3: no `HashMap`/`HashSet` in modules whose iteration order can
    /// reach serialized output.
    NoUnorderedIter,
    /// R4: no bare float `==`/`!=`, no `partial_cmp(..).unwrap()`.
    NoFloatEq,
    /// R5: no `std::process::exit` outside `main.rs`.
    NoProcessExit,
    /// Meta: a `cc-lint:` comment that is malformed or lacks a reason.
    BadSuppression,
    /// Meta: a well-formed suppression that suppressed nothing.
    UnusedSuppression,
}

impl Rule {
    /// The stable id used in findings, suppressions and the JSON report.
    pub fn id(&self) -> &'static str {
        match self {
            Rule::NoPanic => "no-panic",
            Rule::NoWallclock => "no-wallclock",
            Rule::NoUnorderedIter => "no-unordered-iter",
            Rule::NoFloatEq => "no-float-eq",
            Rule::NoProcessExit => "no-process-exit",
            Rule::BadSuppression => "bad-suppression",
            Rule::UnusedSuppression => "unused-suppression",
        }
    }

    /// Parse a rule id as written in an `allow(...)` suppression. The meta
    /// rules are not suppressible, so they are not accepted here.
    pub fn from_id(s: &str) -> Option<Rule> {
        match s {
            "no-panic" => Some(Rule::NoPanic),
            "no-wallclock" => Some(Rule::NoWallclock),
            "no-unordered-iter" => Some(Rule::NoUnorderedIter),
            "no-float-eq" => Some(Rule::NoFloatEq),
            "no-process-exit" => Some(Rule::NoProcessExit),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One lint finding, rendered as `path:line: rule-id message`.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the workspace root, `/`-separated.
    pub path: String,
    /// 1-indexed source line.
    pub line: u32,
    /// The violated rule.
    pub rule: Rule,
    /// Human-oriented explanation with the suggested fix.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} {}", self.path, self.line, self.rule.id(), self.message)
    }
}

/// Files (relative to the workspace root) allowed to panic: the property
/// testing harness, whose *contract* is to panic on a failed property.
const PANIC_ALLOWLIST: &[&str] = &["src/util/prop.rs"];

/// Modules allowed to read the wall clock: the live serving stack
/// (coordinator measures real request latency), the bench harness, and
/// the OS-process supervisors (orchestrator timeouts, proc backoff).
const WALLCLOCK_ALLOWLIST_PREFIXES: &[&str] = &["src/coordinator/"];
const WALLCLOCK_ALLOWLIST_FILES: &[&str] =
    &["src/util/bench.rs", "src/util/proc.rs", "src/experiment/orchestrator.rs"];

/// Modules whose container iteration order reaches serialized output
/// (JSON/CSV codecs, report tables, experiment outcomes): unordered maps
/// are banned outright — `BTreeMap`/`BTreeSet` or an explicit sort.
const ORDERED_OUTPUT_PREFIXES: &[&str] = &["src/report/", "src/experiment/"];
const ORDERED_OUTPUT_FILES: &[&str] =
    &["src/util/json.rs", "src/util/csv.rs", "src/config/experiment.rs"];

fn in_panic_scope(class: FileClass, path: &str) -> bool {
    class == FileClass::Library && !PANIC_ALLOWLIST.contains(&path)
}

fn in_wallclock_scope(class: FileClass, path: &str) -> bool {
    class == FileClass::Library
        && !WALLCLOCK_ALLOWLIST_PREFIXES.iter().any(|p| path.starts_with(p))
        && !WALLCLOCK_ALLOWLIST_FILES.contains(&path)
}

fn in_ordered_output_scope(class: FileClass, path: &str) -> bool {
    class == FileClass::Library
        && (ORDERED_OUTPUT_PREFIXES.iter().any(|p| path.starts_with(p))
            || ORDERED_OUTPUT_FILES.contains(&path))
}

/// A parsed suppression: `// cc-lint: allow(rule-id) reason`, plus a
/// consumption mark so stale suppressions can be reported.
struct Suppression {
    line: u32,
    rule: Rule,
    used: bool,
}

/// Parse the body of a `cc-lint:` comment into a suppression, or a
/// `bad-suppression` finding when malformed or reason-less.
fn parse_suppression(c: &LintComment, path: &str) -> Result<Suppression, Finding> {
    let bad = |msg: String| Finding {
        path: path.to_string(),
        line: c.line,
        rule: Rule::BadSuppression,
        message: msg,
    };
    let body = c.body.trim();
    let Some(rest) = body.strip_prefix("allow(") else {
        return Err(bad(format!(
            "unrecognized cc-lint directive '{body}' — expected `cc-lint: allow(rule-id) reason`"
        )));
    };
    let Some((id, reason)) = rest.split_once(')') else {
        return Err(bad("missing ')' after the rule id".to_string()));
    };
    let Some(rule) = Rule::from_id(id.trim()) else {
        return Err(bad(format!("unknown rule id '{}' in allow(...)", id.trim())));
    };
    if reason.trim().is_empty() {
        return Err(bad(format!(
            "allow({}) requires a reason: `cc-lint: allow({}) why this is sound`",
            rule.id(),
            rule.id()
        )));
    }
    Ok(Suppression { line: c.line, rule, used: false })
}

/// Scan one file's source and return its findings.
///
/// `path` is the workspace-relative path used both for scoping (which
/// rules apply) and for rendering. Findings inside `#[cfg(test)]` items
/// are dropped for every rule except the two that pierce test code
/// (`partial_cmp(..).unwrap()` — a NaN hazard breaks determinism wherever
/// it sorts — and `process::exit`, which kills the whole test harness).
pub fn scan_source(path: &str, class: FileClass, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let test_region = mark_test_regions(&lexed.tokens);
    let mut sups: Vec<Suppression> = Vec::new();
    let mut findings: Vec<Finding> = Vec::new();
    for c in &lexed.lint_comments {
        match parse_suppression(c, path) {
            Ok(s) => sups.push(s),
            Err(f) => findings.push(f),
        }
    }

    let mut emit = |line: u32, rule: Rule, message: String, sups: &mut [Suppression]| {
        // A suppression covers findings on its own line (trailing comment)
        // or on the line directly below (comment-above style).
        if let Some(s) = sups
            .iter_mut()
            .find(|s| s.rule == rule && (s.line == line || s.line + 1 == line))
        {
            s.used = true;
            return;
        }
        findings.push(Finding { path: path.to_string(), line, rule, message });
    };

    let toks = &lexed.tokens;
    let lib_rules = |i: usize| !test_region[i];
    for i in 0..toks.len() {
        let line = toks[i].line;
        match &toks[i].tok {
            Tok::Op(".") if in_panic_scope(class, path) && lib_rules(i) => {
                if let Some(name) = ident_at(toks, i + 1) {
                    if (name == "unwrap" || name == "expect") && is_op(toks, i + 2, "(") {
                        emit(
                            toks[i + 1].line,
                            Rule::NoPanic,
                            format!(
                                "`.{name}()` can panic in library code; return a located \
                                 `crate::Error` or recover (suppress only with a reason)"
                            ),
                            &mut sups,
                        );
                    }
                }
            }
            Tok::Ident(id)
                if (id == "panic" || id == "todo" || id == "unimplemented")
                    && is_op(toks, i + 1, "!")
                    && in_panic_scope(class, path)
                    && lib_rules(i) =>
            {
                emit(
                    line,
                    Rule::NoPanic,
                    format!("`{id}!` aborts library callers; return a located `crate::Error`"),
                    &mut sups,
                );
            }
            Tok::Ident(id)
                if id == "Instant"
                    && is_op(toks, i + 1, "::")
                    && ident_at(toks, i + 2) == Some("now")
                    && in_wallclock_scope(class, path)
                    && lib_rules(i) =>
            {
                emit(
                    line,
                    Rule::NoWallclock,
                    "`Instant::now()` leaks wall-clock time into a simulation/engine path; \
                     thread a virtual clock through instead"
                        .to_string(),
                    &mut sups,
                );
            }
            Tok::Ident(id)
                if id == "SystemTime" && in_wallclock_scope(class, path) && lib_rules(i) =>
            {
                emit(
                    line,
                    Rule::NoWallclock,
                    "`SystemTime` is wall-clock state; simulation and engine paths must be \
                     clock-free"
                        .to_string(),
                    &mut sups,
                );
            }
            Tok::Ident(id)
                if (id == "HashMap" || id == "HashSet")
                    && in_ordered_output_scope(class, path)
                    && lib_rules(i) =>
            {
                emit(
                    line,
                    Rule::NoUnorderedIter,
                    format!(
                        "`{id}` iteration order is nondeterministic and this module feeds \
                         serialized output; use `BTreeMap`/`BTreeSet` or sort explicitly"
                    ),
                    &mut sups,
                );
            }
            Tok::Op(op @ ("==" | "!="))
                if class == FileClass::Library && lib_rules(i) && float_operand(toks, i) =>
            {
                emit(
                    line,
                    Rule::NoFloatEq,
                    format!(
                        "bare float `{op}` — use an epsilon, `total_cmp`, or `to_bits()` \
                         (exact-representation comparisons need a suppression explaining \
                         why they are exact)"
                    ),
                    &mut sups,
                );
            }
            Tok::Ident(id) if id == "partial_cmp" && is_op(toks, i + 1, "(") => {
                // Pierces tests/benches and #[cfg(test)]: a NaN-panicking
                // sort comparator is a determinism bug wherever it runs.
                if let Some(j) = matching_paren(toks, i + 1) {
                    if is_op(toks, j + 1, ".")
                        && matches!(ident_at(toks, j + 2), Some("unwrap") | Some("expect"))
                    {
                        emit(
                            line,
                            Rule::NoFloatEq,
                            "`partial_cmp(..).unwrap()` panics on NaN; use \
                             `util::stats::total_cmp_f64` (NaN sorts last) instead"
                                .to_string(),
                            &mut sups,
                        );
                    }
                }
            }
            Tok::Ident(id)
                if id == "process"
                    && is_op(toks, i + 1, "::")
                    && ident_at(toks, i + 2) == Some("exit")
                    && class != FileClass::Binary =>
            {
                // Pierces tests: exit() in a test kills the whole harness.
                emit(
                    line,
                    Rule::NoProcessExit,
                    "`process::exit` skips destructors and is only the CLI driver's \
                     (`src/main.rs`) prerogative; return an error instead"
                        .to_string(),
                    &mut sups,
                );
            }
            _ => {}
        }
    }

    for s in &sups {
        if !s.used {
            findings.push(Finding {
                path: path.to_string(),
                line: s.line,
                rule: Rule::UnusedSuppression,
                message: format!(
                    "allow({}) suppresses nothing on this or the next line — remove it",
                    s.rule.id()
                ),
            });
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Identifier text at token index `i`, if any.
fn ident_at(toks: &[Token], i: usize) -> Option<&str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

/// Is token `i` the operator `op`?
fn is_op(toks: &[Token], i: usize, op: &str) -> bool {
    matches!(toks.get(i).map(|t| &t.tok), Some(Tok::Op(o)) if *o == op)
}

/// Index of the `)` matching the `(` at `open` (same nesting level).
fn matching_paren(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.tok {
            Tok::Op("(") => depth += 1,
            Tok::Op(")") => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

/// Does the `==`/`!=` at token `i` have a float-literal operand? Checks
/// the token before and after, looking through a unary minus on the right
/// (`x == -1.0`). This is a spelling-level heuristic: it catches literal
/// comparisons (the common determinism hazard) and leaves typed-variable
/// comparisons to review.
fn float_operand(toks: &[Token], i: usize) -> bool {
    if i > 0 && matches!(toks[i - 1].tok, Tok::Float) {
        return true;
    }
    match toks.get(i + 1).map(|t| &t.tok) {
        Some(Tok::Float) => true,
        Some(Tok::Op("-")) => matches!(toks.get(i + 2).map(|t| &t.tok), Some(Tok::Float)),
        _ => false,
    }
}

/// Mark every token covered by a `#[cfg(test)]` item (attribute included).
///
/// Recognition is token-level: a `#[...]` attribute whose content mentions
/// `cfg` and `test` (and not `not`) starts an exempt region that runs to
/// the end of the annotated item — the matching `}` of the item's first
/// brace, or the first `;` when no brace opens (e.g. a `use`). This covers
/// the `#[cfg(test)] mod tests { ... }` idiom (and single test items); it
/// deliberately does not try to be a full attribute grammar.
fn mark_test_regions(toks: &[Token]) -> Vec<bool> {
    let mut exempt = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if is_op(toks, i, "#") && is_op(toks, i + 1, "[") {
            // Scan the attribute to its closing bracket.
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut saw_cfg = false;
            let mut saw_test = false;
            let mut saw_not = false;
            while j < toks.len() {
                match &toks[j].tok {
                    Tok::Op("[") => depth += 1,
                    Tok::Op("]") => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    Tok::Ident(s) if s == "cfg" => saw_cfg = true,
                    Tok::Ident(s) if s == "test" => saw_test = true,
                    Tok::Ident(s) if s == "not" => saw_not = true,
                    _ => {}
                }
                j += 1;
            }
            if saw_cfg && saw_test && !saw_not && j < toks.len() {
                // Exempt from the attribute through the end of the item.
                let end = item_end(toks, j + 1);
                for flag in exempt.iter_mut().take(end.min(toks.len())).skip(i) {
                    *flag = true;
                }
                i = end;
                continue;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    exempt
}

/// End (exclusive token index) of the item starting at `start`: just past
/// the `}` matching its first `{`, or just past the first top-level `;`.
fn item_end(toks: &[Token], start: usize) -> usize {
    let mut j = start;
    while j < toks.len() {
        match toks[j].tok {
            Tok::Op("{") => {
                let mut depth = 0usize;
                while j < toks.len() {
                    match toks[j].tok {
                        Tok::Op("{") => depth += 1,
                        Tok::Op("}") => {
                            depth -= 1;
                            if depth == 0 {
                                return j + 1;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                return j;
            }
            Tok::Op(";") => return j + 1,
            _ => j += 1,
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(path: &str, class: FileClass, src: &str) -> Vec<Finding> {
        scan_source(path, class, src)
    }

    #[test]
    fn unwrap_in_library_flagged_but_not_in_tests_class() {
        let src = "fn f() { x.unwrap(); }";
        let fs = scan("src/a.rs", FileClass::Library, src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::NoPanic);
        assert_eq!(fs[0].line, 1);
        assert!(scan("tests/a.rs", FileClass::Tests, src).is_empty());
        assert!(scan("benches/a.rs", FileClass::Benches, src).is_empty());
        assert!(scan("src/main.rs", FileClass::Binary, src).is_empty());
    }

    #[test]
    fn unwrap_variants_are_not_flagged() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(|e| e.into_inner()); \
                   z.unwrap_or_default(); p.expect_byte(b'x'); }";
        assert!(scan("src/a.rs", FileClass::Library, src).is_empty());
    }

    #[test]
    fn cfg_test_mod_is_exempt_for_panics_only() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); \
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n}\n";
        let fs = scan("src/a.rs", FileClass::Library, src);
        // The unwrap is exempt (test code); the NaN-hazard comparator is not.
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, Rule::NoFloatEq);
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn suppression_consumes_and_requires_reason() {
        let ok = "fn f() { x.unwrap(); } // cc-lint: allow(no-panic) invariant: x was checked\n";
        assert!(scan("src/a.rs", FileClass::Library, ok).is_empty());
        let above =
            "// cc-lint: allow(no-panic) poisoning recovered by design\nfn f() { x.unwrap(); }\n";
        assert!(scan("src/a.rs", FileClass::Library, above).is_empty());
        let noreason = "fn f() { x.unwrap(); } // cc-lint: allow(no-panic)\n";
        let fs = scan("src/a.rs", FileClass::Library, noreason);
        assert_eq!(fs.len(), 2, "{fs:?}"); // bad-suppression + the unsuppressed finding
        assert!(fs.iter().any(|f| f.rule == Rule::BadSuppression));
        assert!(fs.iter().any(|f| f.rule == Rule::NoPanic));
    }

    #[test]
    fn unused_and_unknown_suppressions_are_findings() {
        let stale = "// cc-lint: allow(no-panic) nothing here panics\nfn f() {}\n";
        let fs = scan("src/a.rs", FileClass::Library, stale);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::UnusedSuppression);
        let unknown = "// cc-lint: allow(no-such-rule) whatever\nfn f() {}\n";
        let fs = scan("src/a.rs", FileClass::Library, unknown);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::BadSuppression);
    }

    #[test]
    fn wallclock_scoping() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(scan("src/perf/events.rs", FileClass::Library, src).len(), 1);
        assert!(scan("src/coordinator/batcher.rs", FileClass::Library, src).is_empty());
        assert!(scan("src/util/bench.rs", FileClass::Library, src).is_empty());
        assert!(scan("src/util/proc.rs", FileClass::Library, src).is_empty());
        assert!(scan("src/experiment/orchestrator.rs", FileClass::Library, src).is_empty());
        // `Instant` as a type (no ::now) is fine anywhere.
        assert!(scan("src/perf/events.rs", FileClass::Library, "fn f(t: Instant) {}").is_empty());
        let sys = "fn f() { let t = SystemTime::now(); }";
        let fs = scan("src/perf/events.rs", FileClass::Library, sys);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::NoWallclock);
    }

    #[test]
    fn unordered_iter_scoping() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32>; }";
        let fs = scan("src/report/mod.rs", FileClass::Library, src);
        assert_eq!(fs.len(), 2, "use + type mention: {fs:?}");
        assert!(fs.iter().all(|f| f.rule == Rule::NoUnorderedIter));
        // Outside the serialization-adjacent modules the rule is silent.
        assert!(scan("src/explore/pareto.rs", FileClass::Library, src).is_empty());
    }

    #[test]
    fn float_eq_literal_heuristic() {
        for bad in
            ["x == 0.0", "0.5 != y", "x == -1.0", "x == 1e15", "a.b() == 2.5", "x != 1E-3"]
        {
            let src = format!("fn f() {{ if {bad} {{}} }}");
            let fs = scan("src/a.rs", FileClass::Library, &src);
            assert_eq!(fs.len(), 1, "{bad}: {fs:?}");
            assert_eq!(fs[0].rule, Rule::NoFloatEq, "{bad}");
        }
        for ok in ["x == 0", "x != y", "i == n - 1", "x <= 0.5", "x == '.'", "s == \"0.5\""] {
            let src = format!("fn f() {{ if {ok} {{}} }}");
            assert!(
                scan("src/a.rs", FileClass::Library, &src).is_empty(),
                "{ok} must not be flagged"
            );
        }
        // Tests may assert exact float equality freely.
        assert!(scan("tests/a.rs", FileClass::Tests, "fn f() { if x == 0.0 {} }").is_empty());
    }

    #[test]
    fn partial_cmp_unwrap_pierces_everywhere() {
        let src = "fn f() { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        for (path, class) in [
            ("src/a.rs", FileClass::Library),
            ("tests/a.rs", FileClass::Tests),
            ("benches/a.rs", FileClass::Benches),
        ] {
            let fs = scan(path, class, src);
            assert_eq!(fs.len(), 1, "{path}: {fs:?}");
            assert_eq!(fs[0].rule, Rule::NoFloatEq);
        }
        // The multi-line chained form must match too (the engine's
        // pts.sort_by spans lines), and `unwrap_or(...)` must not.
        let chained = "fn f() { xs.sort_by(|a, b| {\n a.x\n .partial_cmp(&b.x)\n \
                       .unwrap()\n });\n}";
        assert_eq!(scan("src/a.rs", FileClass::Library, chained).len(), 1);
        let or = "fn f() { v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal)); }";
        assert!(scan("src/a.rs", FileClass::Library, or).is_empty());
    }

    #[test]
    fn process_exit_only_in_main() {
        let src = "fn f() { std::process::exit(1); }";
        assert!(scan("src/main.rs", FileClass::Binary, src).is_empty());
        for (path, class) in [
            ("src/a.rs", FileClass::Library),
            ("tests/a.rs", FileClass::Tests),
            ("benches/a.rs", FileClass::Benches),
        ] {
            let fs = scan(path, class, src);
            assert_eq!(fs.len(), 1, "{path}");
            assert_eq!(fs[0].rule, Rule::NoProcessExit);
        }
    }

    #[test]
    fn panic_allowlist_and_macros() {
        let src = "fn f() { panic!(\"boom\"); todo!(); unimplemented!(); }";
        let fs = scan("src/a.rs", FileClass::Library, src);
        assert_eq!(fs.len(), 3, "{fs:?}");
        assert!(fs.iter().all(|f| f.rule == Rule::NoPanic));
        assert!(scan("src/util/prop.rs", FileClass::Library, src).is_empty());
        // assert!/debug_assert! are NOT in R1's list (invariant checks stay).
        let asserts = "fn f() { assert!(x > 0); assert_eq!(a, b); debug_assert!(ok); }";
        assert!(scan("src/a.rs", FileClass::Library, asserts).is_empty());
    }
}
