//! String/char/comment/raw-string aware token scanner for `ccloud lint`.
//!
//! This is deliberately **not** a Rust parser: the lint rules only need a
//! faithful token stream — identifiers, numeric literals classified as
//! float or integer, and operator/punct tokens — with everything that can
//! *hide* a token (string literals, char literals, line and nested block
//! comments, raw and byte strings, raw identifiers, lifetimes) correctly
//! skipped. Line numbers are tracked per token so findings are clickable
//! `path:line` locations.
//!
//! Line comments whose body *starts* with the `cc-lint:` suppression
//! marker (see [`crate::analysis`] for the syntax) are additionally
//! returned alongside the token stream so the rule engine can honor them.

/// One lexed token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (raw identifiers arrive without the `r#`).
    Ident(String),
    /// Numeric literal that is float-typed by spelling: contains a
    /// fractional part (`2.5`), an exponent (`1e15`), or an `f32`/`f64`
    /// suffix.
    Float,
    /// Any other numeric literal (decimal, hex, octal, binary).
    Int,
    /// Operator / punctuation. Multi-character operators the rules care
    /// about (`==`, `!=`, `::`) are single tokens; everything else is
    /// emitted one character at a time.
    Op(&'static str),
    /// Operator character with no interned spelling (emitted for
    /// completeness; rules never match on it).
    OpChar(char),
}

/// A token plus the 1-indexed source line it starts on.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// Body of a `// cc-lint: ...` comment (text after the marker, trimmed).
#[derive(Clone, Debug)]
pub struct LintComment {
    pub line: u32,
    pub body: String,
}

/// Lexer output: the token stream and every `cc-lint:` comment seen.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub lint_comments: Vec<LintComment>,
}

/// The suppression marker looked for inside line comments.
pub const MARKER: &str = "cc-lint:";

/// Lex `src` into tokens + lint comments. Never fails: unterminated
/// strings/comments simply consume to end of input (the compiler is the
/// authority on well-formedness; the linter only needs to not mis-tokenize
/// code that *does* compile).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                if let Ok(text) = std::str::from_utf8(&b[start..i]) {
                    // Only a comment that *leads* with the marker is a
                    // suppression — prose mentions of `cc-lint:` inside doc
                    // comments (like this module's own) are not.
                    if let Some(body) = text.trim_start().strip_prefix(MARKER) {
                        out.lint_comments
                            .push(LintComment { line, body: body.trim().to_string() });
                    }
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // Block comments nest in Rust.
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => i = skip_string(b, i, &mut line),
            b'\'' => i = skip_char_or_lifetime(b, i, &mut line),
            b'b' | b'r' if is_string_start(b, i) => i = skip_prefixed_string(b, i, &mut line),
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                if let Ok(id) = std::str::from_utf8(&b[start..i]) {
                    out.tokens.push(Token { tok: Tok::Ident(id.to_string()), line });
                }
            }
            c if c.is_ascii_digit() => {
                let (end, is_float) = scan_number(b, i);
                out.tokens.push(Token { tok: if is_float { Tok::Float } else { Tok::Int }, line });
                i = end;
            }
            _ => {
                // Raw identifiers: `r#type` (the raw-string case was
                // handled above, so reaching `r#` here means identifier).
                let two = &b[i..(i + 2).min(b.len())];
                let tok = match two {
                    b"==" => Some(Tok::Op("==")),
                    b"!=" => Some(Tok::Op("!=")),
                    b"::" => Some(Tok::Op("::")),
                    _ => None,
                };
                if let Some(t) = tok {
                    out.tokens.push(Token { tok: t, line });
                    i += 2;
                } else {
                    let t = match c {
                        b'.' => Tok::Op("."),
                        b'(' => Tok::Op("("),
                        b')' => Tok::Op(")"),
                        b'[' => Tok::Op("["),
                        b']' => Tok::Op("]"),
                        b'{' => Tok::Op("{"),
                        b'}' => Tok::Op("}"),
                        b'#' => Tok::Op("#"),
                        b'!' => Tok::Op("!"),
                        b';' => Tok::Op(";"),
                        b'-' => Tok::Op("-"),
                        other => Tok::OpChar(other as char),
                    };
                    out.tokens.push(Token { tok: t, line });
                    i += 1;
                }
            }
        }
    }
    out
}

/// Is the `b`/`r`/`br` at `i` the start of a (raw/byte) string literal,
/// as opposed to an ordinary identifier beginning with those letters?
fn is_string_start(b: &[u8], i: usize) -> bool {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if b.get(j) == Some(&b'r') {
        j += 1;
        // r" or r#...#" — any number of hashes then a quote.
        while b.get(j) == Some(&b'#') {
            j += 1;
        }
        return b.get(j) == Some(&b'"');
    }
    // b"..." / b'...'
    b[i] == b'b' && matches!(b.get(j), Some(&b'"') | Some(&b'\''))
}

/// Skip a plain `"..."` string (escapes honored), returning the index
/// past the closing quote.
fn skip_string(b: &[u8], i: usize, line: &mut u32) -> usize {
    let mut i = i + 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Skip `b"..."`, `r"..."`, `r#"..."#`, `br#"..."#`, `b'x'`.
fn skip_prefixed_string(b: &[u8], i: usize, line: &mut u32) -> usize {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if b.get(j) == Some(&b'\'') {
        return skip_char_or_lifetime(b, j, line);
    }
    let mut hashes = 0usize;
    if b.get(j) == Some(&b'r') {
        j += 1;
        while b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
        // Raw strings: no escapes; closed by `"` followed by `hashes` #s.
        j += 1; // consume the opening quote
        while j < b.len() {
            if b[j] == b'\n' {
                *line += 1;
                j += 1;
            } else if b[j] == b'"'
                && b[j + 1..].iter().take_while(|&&c| c == b'#').count() >= hashes
            {
                return j + 1 + hashes;
            } else {
                j += 1;
            }
        }
        return j;
    }
    skip_string(b, j, line)
}

/// Skip a char literal (`'x'`, `'\n'`, `'\u{1F600}'`) or pass over a
/// lifetime (`'a`, `'static`) without consuming what follows it.
fn skip_char_or_lifetime(b: &[u8], i: usize, line: &mut u32) -> usize {
    // Lifetime: 'ident NOT followed by a closing quote. ('a' is a char
    // literal, 'a.cmp(...) is a lifetime... which cannot actually appear
    // mid-expression, but the disambiguation below is the standard one.)
    let next = b.get(i + 1).copied();
    if let Some(c) = next {
        if (c == b'_' || c.is_ascii_alphabetic()) && b.get(i + 2) != Some(&b'\'') {
            // lifetime or loop label: consume `'` + identifier
            let mut j = i + 1;
            while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            return j;
        }
    }
    // Char literal.
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'\n' => {
                *line += 1;
                j += 1;
            }
            b'\'' => return j + 1,
            _ => j += 1,
        }
    }
    j
}

/// Scan a numeric literal starting at digit `i`; returns (end, is_float).
fn scan_number(b: &[u8], i: usize) -> (usize, bool) {
    let mut j = i;
    let mut is_float = false;
    if b[j] == b'0' && matches!(b.get(j + 1), Some(&b'x') | Some(&b'o') | Some(&b'b')) {
        j += 2;
        while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
        }
        return (j, false);
    }
    while j < b.len() && (b[j].is_ascii_digit() || b[j] == b'_') {
        j += 1;
    }
    // Fractional part: a dot followed by a digit (so `xs.0` tuple access
    // and `1.max(2)` method calls stay integers).
    if b.get(j) == Some(&b'.') && b.get(j + 1).is_some_and(|c| c.is_ascii_digit()) {
        is_float = true;
        j += 1;
        while j < b.len() && (b[j].is_ascii_digit() || b[j] == b'_') {
            j += 1;
        }
    } else if b.get(j) == Some(&b'.')
        && !b.get(j + 1).is_some_and(|c| is_ident_char(*c))
        && b.get(j + 1) != Some(&b'.')
    {
        // Trailing-dot float (`1.`) — but `1.method()` keeps its dot and
        // `1..n` stays an integer range.
        is_float = true;
        j += 1;
    }
    // Exponent.
    if matches!(b.get(j), Some(&b'e') | Some(&b'E')) {
        let mut k = j + 1;
        if matches!(b.get(k), Some(&b'+') | Some(&b'-')) {
            k += 1;
        }
        if b.get(k).is_some_and(|c| c.is_ascii_digit()) {
            is_float = true;
            j = k;
            while j < b.len() && (b[j].is_ascii_digit() || b[j] == b'_') {
                j += 1;
            }
        }
    }
    // Type suffix (f32/f64 force float; u8..i128/usize stay int).
    let sfx_start = j;
    while j < b.len() && is_ident_char(b[j]) {
        j += 1;
    }
    match &b[sfx_start..j] {
        b"f32" | b"f64" => is_float = true,
        _ => {}
    }
    (j, is_float)
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_comments_and_chars_hide_tokens() {
        let src = r###"
            let a = "unwrap() inside a string";
            // unwrap() inside a line comment
            /* unwrap() in /* a nested */ block comment */
            let b = 'u'; let c = r#"raw unwrap() string"#;
            let d = b"byte unwrap()"; let e: &'static str = "x";
            real_ident();
        "###;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(ids.contains(&"real_ident".to_string()));
        // the lifetime must not have eaten `static`'s following tokens
        assert!(ids.contains(&"str".to_string()));
    }

    #[test]
    fn float_vs_int_classification() {
        let toks: Vec<Tok> = lex("0 1 2.5 1e15 1E-3 3f64 7u32 0x1f xs.0 1.max(2)")
            .tokens
            .into_iter()
            .map(|t| t.tok)
            .collect();
        let nums: Vec<&Tok> =
            toks.iter().filter(|t| matches!(t, Tok::Float | Tok::Int)).collect();
        assert_eq!(
            nums,
            vec![
                &Tok::Int,   // 0
                &Tok::Int,   // 1
                &Tok::Float, // 2.5
                &Tok::Float, // 1e15
                &Tok::Float, // 1E-3
                &Tok::Float, // 3f64
                &Tok::Int,   // 7u32
                &Tok::Int,   // 0x1f
                &Tok::Int,   // xs.0's 0
                &Tok::Int,   // 1.max(2)'s 1
                &Tok::Int,   // 1.max(2)'s 2
            ]
        );
    }

    #[test]
    fn ranges_are_not_floats() {
        let toks: Vec<Tok> =
            lex("for i in 0..10 { x[1..=2]; }").tokens.into_iter().map(|t| t.tok).collect();
        assert!(!toks.contains(&Tok::Float), "{toks:?}");
    }

    #[test]
    fn multi_char_operators() {
        let toks: Vec<Tok> = lex("a == b != c::d.e!").tokens.into_iter().map(|t| t.tok).collect();
        assert!(toks.contains(&Tok::Op("==")));
        assert!(toks.contains(&Tok::Op("!=")));
        assert!(toks.contains(&Tok::Op("::")));
        assert!(toks.contains(&Tok::Op(".")));
        assert!(toks.contains(&Tok::Op("!")));
    }

    #[test]
    fn line_numbers_and_lint_comments() {
        let src = "line1();\n// cc-lint: allow(no-panic) locks are poison-safe\nline3();\n";
        let lx = lex(src);
        assert_eq!(lx.lint_comments.len(), 1);
        assert_eq!(lx.lint_comments[0].line, 2);
        assert_eq!(lx.lint_comments[0].body, "allow(no-panic) locks are poison-safe");
        let line3 = lx
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("line3".to_string()))
            .map(|t| t.line);
        assert_eq!(line3, Some(3));
    }

    #[test]
    fn raw_string_with_hashes_spans_lines() {
        let src = "a();\nlet s = r##\"multi\nline \"# unwrap() \"##;\nb();";
        let lx = lex(src);
        let b_line = lx
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("b".to_string()))
            .map(|t| t.line);
        assert_eq!(b_line, Some(3));
        assert!(!lx.tokens.iter().any(|t| t.tok == Tok::Ident("unwrap".to_string())));
    }
}
