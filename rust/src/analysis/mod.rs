//! `ccloud lint` — a dependency-free determinism & robustness analyzer.
//!
//! Every fast path in this workspace is pinned bit-identical (or
//! epsilon-bounded) to a slow reference; that contract is otherwise
//! enforced only by runtime property tests. This module proves the
//! hazards that break it are absent *at the source level*:
//!
//! | rule id             | invariant |
//! |---------------------|-----------|
//! | `no-panic`          | no `unwrap`/`expect`/`panic!`/`todo!`/`unimplemented!` in library modules |
//! | `no-wallclock`      | no `Instant::now`/`SystemTime` outside the serving/bench/proc modules |
//! | `no-unordered-iter` | no `HashMap`/`HashSet` where iteration order reaches serialized output |
//! | `no-float-eq`       | no bare float `==`/`!=`; no `partial_cmp(..).unwrap()` anywhere |
//! | `no-process-exit`   | no `std::process::exit` outside `src/main.rs` |
//!
//! A finding is suppressed with `// cc-lint: allow(rule-id) reason` on the
//! same line or the line above; the reason is mandatory, and a suppression
//! that matches nothing is itself a finding (`unused-suppression`). The
//! scanner is token-level (see [`lexer`]) — string/char/comment/raw-string
//! aware, no full parser — and the rule set is deliberately project-shaped
//! rather than general (see [`rules`] for the scopes and allowlists).
//!
//! The pass runs over its own workspace in CI (`ccloud lint`) and in a
//! `cargo test` self-check, so the tree must stay finding-free.

pub mod lexer;
pub mod rules;

pub use rules::{scan_source, FileClass, Finding, Rule};

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Directories walked under the workspace root.
const WALK_DIRS: &[&str] = &["src", "tests", "benches"];

/// Subtrees excluded from the walk: the fixture corpus exists to contain
/// deliberate violations for the linter's own tests.
const EXCLUDE_PREFIXES: &[&str] = &["tests/lint_fixtures/"];

/// Classify a workspace-relative path (`/`-separated) for rule scoping.
pub fn classify(rel: &str) -> FileClass {
    if rel == "src/main.rs" {
        FileClass::Binary
    } else if rel.starts_with("tests/") {
        FileClass::Tests
    } else if rel.starts_with("benches/") {
        FileClass::Benches
    } else {
        FileClass::Library
    }
}

/// Lint one file's source. `rel` is the workspace-relative path used for
/// scoping and rendering; the class is derived via [`classify`].
pub fn scan_file(rel: &str, src: &str) -> Vec<Finding> {
    scan_source(rel, classify(rel), src)
}

/// Run the analyzer over the workspace rooted at `root` (the directory
/// holding `src/`, `tests/`, `benches/`). Returns all findings sorted by
/// (path, line, rule); an empty vector means the tree is clean. The walk
/// is sorted at every level, so output order is deterministic.
pub fn run(root: &Path) -> Result<Vec<Finding>> {
    let mut findings = Vec::new();
    let mut saw_dir = false;
    for dir in WALK_DIRS {
        let d = root.join(dir);
        if !d.is_dir() {
            continue;
        }
        saw_dir = true;
        let mut files = Vec::new();
        collect_rs_files(&d, &mut files)?;
        files.sort();
        for path in files {
            let rel = relative_slash(root, &path);
            if EXCLUDE_PREFIXES.iter().any(|p| rel.starts_with(p)) {
                continue;
            }
            let src = std::fs::read_to_string(&path).map_err(|e| {
                Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
            })?;
            findings.extend(scan_file(&rel, &src));
        }
    }
    if !saw_dir {
        return Err(Error::Config(format!(
            "{}: not a workspace root (no src/, tests/ or benches/ directory)",
            root.display()
        )));
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(findings)
}

/// Recursively collect `*.rs` files under `dir`.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `path` relative to `root`, `/`-separated regardless of platform.
fn relative_slash(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Render findings as the machine-readable JSON report emitted by
/// `ccloud lint --json`:
/// `{"version": 1, "root": "...", "count": N, "findings": [{path, line, rule, message}]}`.
pub fn report_json(root: &Path, findings: &[Finding]) -> String {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("path".to_string(), Json::Str(f.path.clone()));
            m.insert("line".to_string(), Json::Num(f64::from(f.line)));
            m.insert("rule".to_string(), Json::Str(f.rule.id().to_string()));
            m.insert("message".to_string(), Json::Str(f.message.clone()));
            Json::Obj(m)
        })
        .collect();
    let mut top = std::collections::BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("root".to_string(), Json::Str(root.display().to_string()));
    top.insert("count".to_string(), Json::Num(findings.len() as f64));
    top.insert("findings".to_string(), Json::Arr(items));
    Json::Obj(top).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_matches_tree_layout() {
        assert_eq!(classify("src/main.rs"), FileClass::Binary);
        assert_eq!(classify("src/util/stats.rs"), FileClass::Library);
        assert_eq!(classify("tests/integration_dse.rs"), FileClass::Tests);
        assert_eq!(classify("benches/fig7.rs"), FileClass::Benches);
    }

    #[test]
    fn report_json_is_parseable_and_sorted() {
        let fs = vec![Finding {
            path: "src/a.rs".to_string(),
            line: 3,
            rule: Rule::NoPanic,
            message: "msg".to_string(),
        }];
        let s = report_json(Path::new("rust"), &fs);
        let v = Json::parse(&s).expect("report must be valid JSON");
        assert_eq!(v.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(v.get("count").and_then(Json::as_usize), Some(1));
        let arr = v.get("findings").and_then(Json::as_arr).expect("findings array");
        assert_eq!(arr[0].get("rule").and_then(Json::as_str), Some("no-panic"));
        assert_eq!(arr[0].get("line").and_then(Json::as_usize), Some(3));
    }

    #[test]
    fn run_rejects_a_non_workspace_root() {
        let err = run(Path::new("/definitely/not/a/workspace/root"));
        assert!(err.is_err());
    }
}
