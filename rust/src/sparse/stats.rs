//! Analytic compression statistics — the memory-footprint side of the
//! sparsity evaluation (Fig. 13).
//!
//! A dense fp16 value is 16 bits; a stored sparse word is 24 bits plus the
//! (amortized, tiny) index memory. At sparsity `s` the expected compressed
//! size per dense bit is `(1−s)·24/16`, so compression only *wins* above
//! s = 1/3 — exactly the paper's observation that low sparsity (10–20%)
//! **increases** TCO/Token due to encoding overhead.

/// Compressed bytes for a model of `weight_bytes` dense fp16 bytes at
/// unstructured sparsity `s` (0..1), including tile-index overhead.
pub fn sparse_bytes(weight_bytes: f64, sparsity: f64) -> f64 {
    assert!((0.0..=1.0).contains(&sparsity));
    let elems = weight_bytes / 2.0; // fp16
    let nnz = elems * (1.0 - sparsity);
    let data = nnz * 3.0; // 24-bit words
    let tiles = elems / (crate::sparse::TILE_ROWS * crate::sparse::TILE_COLS) as f64;
    let index = tiles * 4.0;
    data + index
}

/// Compression ratio (dense / compressed); >1 means compression wins.
pub fn compression_ratio(sparsity: f64) -> f64 {
    sparse_bytes(1e9, sparsity).recip() * 1e9
}

/// How much *larger* a model fits in the same memory at sparsity `s`
/// (Fig. 13 bottom: 1.7× at 60%).
pub fn max_model_scale(sparsity: f64) -> f64 {
    compression_ratio(sparsity)
}

/// SparseGPT [15] perplexity of OPT-175B under unstructured sparsity —
/// quoted values (the paper does the same), WikiText2.
pub fn opt175b_perplexity(sparsity: f64) -> f64 {
    // (sparsity, perplexity) — 8.34 dense; negligible rise through 60%.
    const TABLE: &[(f64, f64)] = &[
        (0.0, 8.34),
        (0.1, 8.34),
        (0.2, 8.35),
        (0.3, 8.36),
        (0.4, 8.39),
        (0.5, 8.40),
        (0.6, 8.62),
        (0.7, 10.05),
        (0.8, 17.52),
    ];
    // piecewise-linear interpolation
    let mut prev = TABLE[0];
    for &(s, p) in TABLE {
        if sparsity <= s {
            if s == prev.0 {
                return p;
            }
            let t = (sparsity - prev.0) / (s - prev.0);
            return prev.1 + t * (p - prev.1);
        }
        prev = (s, p);
    }
    prev.1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakeven_at_one_third() {
        assert!(compression_ratio(0.0) < 1.0);
        assert!(compression_ratio(0.2) < 1.0, "20% sparsity should still lose");
        assert!(compression_ratio(0.34) > 1.0);
        assert!(compression_ratio(0.6) > 1.0);
    }

    /// Fig. 13 bottom: 1.7× larger model at 60% sparsity.
    #[test]
    fn sixty_pct_supports_1_7x_model() {
        let scale = max_model_scale(0.6);
        assert!((scale - 1.7).abs() < 0.1, "scale={scale}");
    }

    #[test]
    fn perplexity_table_shape() {
        // negligible rise through 60%, rapid increase after
        assert!(opt175b_perplexity(0.6) - opt175b_perplexity(0.0) < 0.3);
        assert!(opt175b_perplexity(0.8) > 2.0 * opt175b_perplexity(0.0));
        // interpolation is monotone here
        assert!(opt175b_perplexity(0.65) > opt175b_perplexity(0.6));
    }

    #[test]
    fn sparse_bytes_monotone() {
        let w = 350e9;
        let mut prev = f64::INFINITY;
        for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
            let b = sparse_bytes(w, s);
            assert!(b < prev);
            prev = b;
        }
    }

    /// Cross-check the analytic model against the actual codec.
    #[test]
    fn analytic_matches_codec() {
        use crate::sparse::SparseMatrix;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let (rows, cols) = (512, 512);
        let dense: Vec<u16> =
            (0..rows * cols).map(|_| if rng.chance(0.6) { 0 } else { 1 }).collect();
        let m = SparseMatrix::encode(&dense, rows, cols);
        let analytic = sparse_bytes((rows * cols) as f64 * 2.0, m.sparsity());
        let rel = (m.total_bytes() - analytic).abs() / analytic;
        assert!(rel < 0.02, "rel={rel}");
    }
}
