//! Tile-based compressed sparse row format (paper §3.2, after
//! TileSpMV [34]) — the storage format behind *Store-as-Compressed,
//! Load-as-Dense*.
//!
//! A weight matrix is divided into tiles of shape (32, 8). Non-zero values
//! (16-bit) are encoded with a 5-bit row index and a 3-bit column index,
//! forming a **24-bit sparse word** stored in data memory. Per-tile start
//! offsets live in a separate index memory (placed with the crossbar
//! routing tracks in hardware). The decoder streams up to 8 sparse words
//! per cycle and emits fully dense tiles (see [`crate::ccmem::decoder`]).

pub mod stats;
pub mod tilecsr;

pub use stats::{compression_ratio, max_model_scale, sparse_bytes};
pub use tilecsr::{SparseMatrix, SparseTile, SparseWord, TILE_COLS, TILE_ROWS};
