//! Tile-CSR encode/decode (the software model of the CC-MEM decoder's
//! storage format).

/// Tile height (row index is 5 bits ⇒ 32 rows).
pub const TILE_ROWS: usize = 32;
/// Tile width (column index is 3 bits ⇒ 8 columns).
pub const TILE_COLS: usize = 8;
/// Values per tile.
pub const TILE_ELEMS: usize = TILE_ROWS * TILE_COLS;

/// One 24-bit sparse word: 16-bit value + 5-bit row + 3-bit column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SparseWord(pub u32);

impl SparseWord {
    /// Pack (value, row, col) into a sparse word.
    pub fn pack(value: u16, row: u8, col: u8) -> SparseWord {
        debug_assert!((row as usize) < TILE_ROWS && (col as usize) < TILE_COLS);
        SparseWord(((value as u32) << 8) | ((row as u32) << 3) | col as u32)
    }

    /// The 16-bit payload value.
    pub fn value(self) -> u16 {
        (self.0 >> 8) as u16
    }

    /// Row index within the tile (0..32).
    pub fn row(self) -> u8 {
        ((self.0 >> 3) & 0x1f) as u8
    }

    /// Column index within the tile (0..8).
    pub fn col(self) -> u8 {
        (self.0 & 0x7) as u8
    }
}

/// A compressed (32, 8) tile: its non-zero words in row-major CSR order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseTile {
    /// Non-zero words, sorted by (row, col).
    pub words: Vec<SparseWord>,
}

impl SparseTile {
    /// Encode a dense tile (row-major, length [`TILE_ELEMS`]); zeros are
    /// dropped. Values are raw 16-bit payloads (fp16/bf16 bit patterns).
    pub fn encode(dense: &[u16]) -> SparseTile {
        assert_eq!(dense.len(), TILE_ELEMS, "tile must be 32x8");
        let mut words = Vec::new();
        for r in 0..TILE_ROWS {
            for c in 0..TILE_COLS {
                let v = dense[r * TILE_COLS + c];
                if v != 0 {
                    words.push(SparseWord::pack(v, r as u8, c as u8));
                }
            }
        }
        SparseTile { words }
    }

    /// Decode back to a dense row-major tile — the reference behaviour the
    /// hardware decoder (and the Pallas kernel) must match.
    pub fn decode(&self) -> [u16; TILE_ELEMS] {
        let mut out = [0u16; TILE_ELEMS];
        for w in &self.words {
            out[w.row() as usize * TILE_COLS + w.col() as usize] = w.value();
        }
        out
    }

    /// Number of non-zero values.
    pub fn nnz(&self) -> usize {
        self.words.len()
    }

    /// Storage bits in data memory (24 bits per sparse word).
    pub fn storage_bits(&self) -> usize {
        self.nnz() * 24
    }
}

/// A matrix stored in tile-CSR: tile grid + per-tile offsets (the "index
/// memory") and the flattened word stream (the "data memory").
#[derive(Clone, Debug)]
pub struct SparseMatrix {
    /// Rows of the dense matrix.
    pub rows: usize,
    /// Columns of the dense matrix.
    pub cols: usize,
    /// Tile grid dimensions (tiles_r, tiles_c).
    pub tiles: (usize, usize),
    /// Per-tile (start, end) offsets into `words` — the index memory.
    pub index: Vec<(u32, u32)>,
    /// Concatenated sparse words — the data memory.
    pub words: Vec<SparseWord>,
}

impl SparseMatrix {
    /// Encode a dense row-major matrix; dimensions must be tile multiples.
    pub fn encode(dense: &[u16], rows: usize, cols: usize) -> SparseMatrix {
        assert_eq!(dense.len(), rows * cols);
        assert_eq!(rows % TILE_ROWS, 0, "rows must be a multiple of 32");
        assert_eq!(cols % TILE_COLS, 0, "cols must be a multiple of 8");
        let (tr, tc) = (rows / TILE_ROWS, cols / TILE_COLS);
        let mut index = Vec::with_capacity(tr * tc);
        let mut words = Vec::new();
        let mut tile_buf = [0u16; TILE_ELEMS];
        for ti in 0..tr {
            for tj in 0..tc {
                for r in 0..TILE_ROWS {
                    let src = (ti * TILE_ROWS + r) * cols + tj * TILE_COLS;
                    tile_buf[r * TILE_COLS..(r + 1) * TILE_COLS]
                        .copy_from_slice(&dense[src..src + TILE_COLS]);
                }
                let start = words.len() as u32;
                let tile = SparseTile::encode(&tile_buf);
                words.extend_from_slice(&tile.words);
                index.push((start, words.len() as u32));
            }
        }
        SparseMatrix { rows, cols, tiles: (tr, tc), index, words }
    }

    /// Decode the full dense matrix (row-major).
    pub fn decode(&self) -> Vec<u16> {
        let mut out = vec![0u16; self.rows * self.cols];
        let (_, tc) = self.tiles;
        for (t, &(start, end)) in self.index.iter().enumerate() {
            let (ti, tj) = (t / tc, t % tc);
            for w in &self.words[start as usize..end as usize] {
                let r = ti * TILE_ROWS + w.row() as usize;
                let c = tj * TILE_COLS + w.col() as usize;
                out[r * self.cols + c] = w.value();
            }
        }
        out
    }

    /// Tile word range — what the decoder fetches from index memory.
    pub fn tile_range(&self, ti: usize, tj: usize) -> (u32, u32) {
        self.index[ti * self.tiles.1 + tj]
    }

    /// Total non-zeros.
    pub fn nnz(&self) -> usize {
        self.words.len()
    }

    /// Measured sparsity (fraction of zeros).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Bytes in data memory (24-bit words, packed).
    pub fn data_bytes(&self) -> f64 {
        self.nnz() as f64 * 3.0
    }

    /// Bytes in index memory (two 32-bit offsets per tile; hardware stores
    /// start-only + next-start, i.e. 4 B per tile amortized).
    pub fn index_bytes(&self) -> f64 {
        self.index.len() as f64 * 4.0
    }

    /// Total compressed bytes.
    pub fn total_bytes(&self) -> f64 {
        self.data_bytes() + self.index_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_tile(rng: &mut Rng, sparsity: f64) -> Vec<u16> {
        (0..TILE_ELEMS)
            .map(|_| {
                if rng.chance(sparsity) {
                    0
                } else {
                    // never 0 for a kept value so nnz is exact
                    (1 + rng.below(65535)) as u16
                }
            })
            .collect()
    }

    #[test]
    fn word_pack_unpack() {
        let w = SparseWord::pack(0xBEEF, 31, 7);
        assert_eq!(w.value(), 0xBEEF);
        assert_eq!(w.row(), 31);
        assert_eq!(w.col(), 7);
        assert!(w.0 < (1 << 24), "must fit in 24 bits");
    }

    #[test]
    fn tile_roundtrip_property() {
        check("tile encode/decode roundtrip", 200, |rng| {
            let sparsity = rng.f64();
            let dense = random_tile(rng, sparsity);
            let tile = SparseTile::encode(&dense);
            assert_eq!(tile.decode().to_vec(), dense);
        });
    }

    #[test]
    fn matrix_roundtrip_property() {
        check("matrix encode/decode roundtrip", 50, |rng| {
            let rows = TILE_ROWS * (1 + rng.below(4));
            let cols = TILE_COLS * (1 + rng.below(8));
            let dense: Vec<u16> = (0..rows * cols)
                .map(|_| if rng.chance(0.6) { 0 } else { rng.below(65536) as u16 })
                .collect();
            let m = SparseMatrix::encode(&dense, rows, cols);
            assert_eq!(m.decode(), dense);
        });
    }

    #[test]
    fn csr_order_within_tile() {
        let mut dense = vec![0u16; TILE_ELEMS];
        dense[5] = 10; // row 0 col 5
        dense[TILE_COLS * 3 + 2] = 20; // row 3 col 2
        dense[TILE_COLS * 3 + 7] = 30; // row 3 col 7
        let t = SparseTile::encode(&dense);
        let rc: Vec<(u8, u8)> = t.words.iter().map(|w| (w.row(), w.col())).collect();
        assert_eq!(rc, vec![(0, 5), (3, 2), (3, 7)]);
    }

    #[test]
    fn empty_and_full_tiles() {
        let zeros = vec![0u16; TILE_ELEMS];
        assert_eq!(SparseTile::encode(&zeros).nnz(), 0);
        let ones = vec![1u16; TILE_ELEMS];
        let full = SparseTile::encode(&ones);
        assert_eq!(full.nnz(), TILE_ELEMS);
        // fully dense tile stored sparse costs 24/16 = 1.5x the dense bits
        assert_eq!(full.storage_bits(), TILE_ELEMS * 24);
    }

    #[test]
    fn measured_sparsity_close_to_requested() {
        let mut rng = Rng::new(1234);
        let rows = 256;
        let cols = 256;
        let dense: Vec<u16> =
            (0..rows * cols).map(|_| if rng.chance(0.6) { 0 } else { 1 }).collect();
        let m = SparseMatrix::encode(&dense, rows, cols);
        assert!((m.sparsity() - 0.6).abs() < 0.02);
    }

    #[test]
    fn index_memory_is_small() {
        let dense = vec![1u16; 1024 * 1024];
        let m = SparseMatrix::encode(&dense, 1024, 1024);
        assert!(m.index_bytes() < 0.01 * m.data_bytes());
    }
}
