//! Server thermal model (paper Table 1: "Server Thermal — adapted from
//! ASIC Clouds [29]").
//!
//! A 1U, 19-inch server has `lanes` front-to-back airflow lanes. Chips in a
//! lane share the airstream: air heats up as it passes over each chip, so
//! downstream chips see a hotter inlet. A chip is thermally feasible when
//!
//! `T_junction = T_air_local + P_chip · θ_sa  ≤  T_j,max`
//!
//! where `θ_sa` is the sink-to-air resistance of the per-chip heatsink at
//! the lane's airflow, and `T_air_local` is the inlet temperature plus the
//! cumulative heating from upstream chips (`ΔT = P_upstream / (ṁ·c_p)`).
//! This is the mechanism that makes *many small chips* thermally easier
//! than few large ones — a key Chiplet Cloud effect.

/// Thermal constants for a 1U lane.
#[derive(Clone, Debug)]
pub struct ThermalParams {
    /// Datacenter cold-aisle inlet temperature, °C.
    pub inlet_c: f64,
    /// Max junction temperature, °C.
    pub tj_max_c: f64,
    /// Volumetric airflow per lane, CFM (1U high-static-pressure fans).
    pub cfm_per_lane: f64,
    /// Sink-to-air resistance of a *full-lane-length* 1U duct heatsink at
    /// the lane airflow, °C/W. With `n` chips sharing the lane each chip's
    /// sink is 1/n of the length, so per-chip θ_sa = `theta_sa_ref · n`.
    pub theta_sa_ref: f64,
    /// Heat-spreading floor on θ_sa, °C/W: one small die cannot exploit an
    /// arbitrarily long sink (base-spreading resistance dominates). This is
    /// what makes one big hot chip worse than many small cool ones.
    pub theta_sa_min: f64,
    /// Junction-to-case + TIM resistance, °C/W·cm² (scales inversely with
    /// die area: bigger dies spread heat better).
    pub theta_jc_cm2: f64,
}

impl Default for ThermalParams {
    fn default() -> Self {
        ThermalParams {
            inlet_c: 30.0,
            tj_max_c: 85.0,
            cfm_per_lane: 12.0,
            theta_sa_ref: 0.08,
            theta_sa_min: 0.25,
            theta_jc_cm2: 0.15,
        }
    }
}

/// Mass-flow heat capacity of a lane's airstream, W/°C.
///
/// 1 CFM of air carries ≈ 0.566 W/°C (ρ·c_p at ~35 °C).
pub fn lane_w_per_c(tp: &ThermalParams) -> f64 {
    0.566 * tp.cfm_per_lane
}

/// Junction temperature of the hottest (most downstream) chip in a lane of
/// `n_chips` chips each dissipating `p_chip` W with die area `die_mm2`.
pub fn worst_tj(tp: &ThermalParams, n_chips: usize, p_chip: f64, die_mm2: f64) -> f64 {
    if n_chips == 0 {
        return tp.inlet_c;
    }
    // Heatsink per chip: lane-length is shared, so each chip's sink gets
    // 1/n of the lane; θ_sa scales inversely with sink length, floored by
    // base-spreading resistance.
    let theta_sa = (tp.theta_sa_ref * n_chips as f64).max(tp.theta_sa_min);
    let theta_jc = tp.theta_jc_cm2 / (die_mm2 / 100.0);
    // Air heating upstream of the last chip.
    let d_t_air = (n_chips as f64 - 1.0) * p_chip / lane_w_per_c(tp);
    tp.inlet_c + d_t_air + p_chip * (theta_sa + theta_jc)
}

/// Is a lane of `n_chips` × (`p_chip` W, `die_mm2`) chips thermally feasible?
pub fn lane_feasible(tp: &ThermalParams, n_chips: usize, p_chip: f64, die_mm2: f64) -> bool {
    worst_tj(tp, n_chips, p_chip, die_mm2) <= tp.tj_max_c
}

/// Max total lane power (W) for which some chip count in `1..=max_chips`
/// is feasible — used to refine the Table-1 250 W/lane cap per design.
pub fn max_feasible_lane_power(tp: &ThermalParams, p_chip: f64, die_mm2: f64, max_chips: usize) -> f64 {
    let mut best = 0.0f64;
    for n in 1..=max_chips {
        if lane_feasible(tp, n, p_chip, die_mm2) {
            best = best.max(n as f64 * p_chip);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_lane_is_at_inlet() {
        let tp = ThermalParams::default();
        assert_eq!(worst_tj(&tp, 0, 10.0, 100.0), tp.inlet_c);
    }

    #[test]
    fn downstream_chips_run_hotter() {
        let tp = ThermalParams::default();
        let t4 = worst_tj(&tp, 4, 14.0, 140.0);
        let t12 = worst_tj(&tp, 12, 14.0, 140.0);
        assert!(t12 > t4);
    }

    /// The paper's Table-2 designs (≈14 W chips, ≈17/lane) must pass.
    #[test]
    fn table2_lane_is_feasible() {
        let tp = ThermalParams::default();
        assert!(lane_feasible(&tp, 17, 14.1, 140.0), "tj={}", worst_tj(&tp, 17, 14.1, 140.0));
    }

    /// One 700 mm² / ~400 W monolithic die per lane is NOT feasible with 1U
    /// air cooling — the reason GPUs need liquid cooling at these densities.
    #[test]
    fn monolithic_hot_chip_infeasible() {
        let tp = ThermalParams::default();
        assert!(!lane_feasible(&tp, 1, 400.0, 700.0));
    }

    #[test]
    fn many_small_beats_one_big_at_equal_power() {
        let tp = ThermalParams::default();
        // 200 W total per lane: 16×12.5 W is fine, 1×200 W hits the
        // spreading floor and violates Tj.
        let small = worst_tj(&tp, 16, 12.5, 100.0);
        let big = worst_tj(&tp, 1, 200.0, 400.0);
        // small-chip lane stays under Tj; single 200 W package exceeds it
        assert!(small <= tp.tj_max_c, "small={small}");
        assert!(big > tp.tj_max_c, "big={big}");
    }

    /// Table-1's 250 W/lane envelope emerges from the thermal model: at
    /// ~12.5 W per chip the 20-chip lane sits right at the Tj limit.
    #[test]
    fn lane_envelope_matches_table1() {
        let tp = ThermalParams::default();
        let max_p = max_feasible_lane_power(&tp, 12.5, 140.0, 20);
        assert!((200.0..=260.0).contains(&max_p), "max lane power {max_p}");
    }

    #[test]
    fn max_power_monotone_in_chip_power() {
        let tp = ThermalParams::default();
        let lo = max_feasible_lane_power(&tp, 10.0, 140.0, 20);
        assert!(lo > 0.0);
        // An infeasible chip yields zero budget.
        assert_eq!(max_feasible_lane_power(&tp, 1000.0, 140.0, 20), 0.0);
    }
}
