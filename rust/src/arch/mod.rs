//! Architecture design-point datatypes: chiplet → server → system.
//!
//! These are *descriptions* produced by Phase 1 ([`crate::explore`]) and
//! consumed by Phase 2 ([`crate::evaluate`]); the cycle-level behaviour of
//! the memory system they describe is modelled in [`crate::ccmem`].

/// One chiplet accelerator module (paper Fig. 3(b)): SIMD cores + CC-MEM.
#[derive(Clone, Debug, PartialEq)]
pub struct ChipletDesign {
    /// Die area, mm².
    pub die_mm2: f64,
    /// CC-MEM capacity, MB.
    pub sram_mb: f64,
    /// Peak compute, TFLOPS (fp16 MAC).
    pub tflops: f64,
    /// CC-MEM aggregate read bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Number of CC-MEM bank groups (crossbar radix on the memory side).
    pub n_bank_groups: usize,
    /// Chip-to-chip IO bandwidth per link, GB/s.
    pub io_link_gbps: f64,
    /// Number of chip-to-chip links.
    pub io_links: usize,
    /// Peak (TDP) power, W.
    pub tdp_w: f64,
}

impl ChipletDesign {
    /// Peak aggregate off-chip bandwidth, GB/s.
    pub fn io_bw_gbps(&self) -> f64 {
        self.io_link_gbps * self.io_links as f64
    }

    /// Peak arithmetic intensity the chip can feed from CC-MEM
    /// (FLOP per byte at which compute and memory are balanced).
    pub fn balance_flop_per_byte(&self) -> f64 {
        self.tflops * 1e12 / (self.mem_bw_gbps * 1e9)
    }

    /// Power density, W/mm².
    pub fn power_density(&self) -> f64 {
        self.tdp_w / self.die_mm2
    }
}

/// A 1U Chiplet Cloud server (paper Fig. 3(c)): lanes of chiplets on a PCB
/// with a controller and an off-PCB NIC, chiplets in a 2D torus.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerDesign {
    /// The replicated chiplet.
    pub chiplet: ChipletDesign,
    /// Chips per lane.
    pub chips_per_lane: usize,
    /// Lanes per server.
    pub lanes: usize,
    /// Peak server power at the wall (after PSU/DCDC losses), W.
    pub server_power_w: f64,
    /// Server CapEx (dies + packages + BOM), $.
    pub server_capex: f64,
}

impl ServerDesign {
    /// Total chips per server.
    pub fn chips(&self) -> usize {
        self.chips_per_lane * self.lanes
    }

    /// Total CC-MEM capacity per server, MB.
    pub fn sram_mb(&self) -> f64 {
        self.chiplet.sram_mb * self.chips() as f64
    }

    /// Total compute per server, TFLOPS.
    pub fn tflops(&self) -> f64 {
        self.chiplet.tflops * self.chips() as f64
    }

    /// Total silicon per server, mm².
    pub fn silicon_mm2(&self) -> f64 {
        self.chiplet.die_mm2 * self.chips() as f64
    }
}

/// A full Chiplet Cloud deployment for one workload: `n_servers` replicas
/// of a server design running a specific parallel mapping.
#[derive(Clone, Debug)]
pub struct SystemDesign {
    /// The replicated server.
    pub server: ServerDesign,
    /// Number of servers the model is partitioned across (pipeline axis
    /// spans servers; tensor parallel axis spans chips within a server).
    pub n_servers: usize,
}

impl SystemDesign {
    /// Total chips in the system.
    pub fn total_chips(&self) -> usize {
        self.server.chips() * self.n_servers
    }

    /// Total CC-MEM capacity, bytes.
    pub fn total_sram_bytes(&self) -> f64 {
        self.server.sram_mb() * 1e6 * self.n_servers as f64
    }

    /// Total peak compute, TFLOPS.
    pub fn total_tflops(&self) -> f64 {
        self.server.tflops() * self.n_servers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_chiplet() -> ChipletDesign {
        ChipletDesign {
            die_mm2: 140.0,
            sram_mb: 225.8,
            tflops: 5.5,
            mem_bw_gbps: 2750.0,
            n_bank_groups: 64,
            io_link_gbps: 25.0,
            io_links: 4,
            tdp_w: 7.15,
        }
    }

    #[test]
    fn chiplet_derived_metrics() {
        let c = sample_chiplet();
        assert_eq!(c.io_bw_gbps(), 100.0);
        // 5.5 TFLOPS / 2.75 TB/s = 2 FLOP/byte balance point
        assert!((c.balance_flop_per_byte() - 2.0).abs() < 1e-9);
        assert!(c.power_density() < 1.0);
    }

    #[test]
    fn server_aggregation() {
        let s = ServerDesign {
            chiplet: sample_chiplet(),
            chips_per_lane: 17,
            lanes: 8,
            server_power_w: 1200.0,
            server_capex: 40_000.0,
        };
        assert_eq!(s.chips(), 136); // Table 2 GPT-3 row
        assert!((s.sram_mb() - 225.8 * 136.0).abs() < 1e-6);
        let sys = SystemDesign { server: s, n_servers: 96 };
        assert_eq!(sys.total_chips(), 13056);
    }
}
