//! Power model (paper §4.1 "Thermal Power Evaluation").
//!
//! The paper normalizes the A100's TDP to W/FLOPS (1.3 W/TFLOPS, Table 1) —
//! a deliberately conservative estimate since a large share of GPU power is
//! DRAM, which Chiplet Cloud does not have. We add explicit CC-MEM access
//! energy (SRAM + crossbar) and chip-to-chip link energy so the OpEx side of
//! TCO responds to the memory-system design, then enforce the ≤1 W/mm²
//! density cap from Table 1.

use crate::arch::{ChipletDesign, ServerDesign};
use crate::config::hardware::{ServerParams, TechParams};

/// Peak (TDP) power of a chiplet, W.
///
/// `bw_gbps` is the provisioned CC-MEM bandwidth; at peak the burst engines
/// stream at full rate and every byte crosses the crossbar once.
pub fn chip_tdp(tech: &TechParams, tflops: f64, bw_gbps: f64) -> f64 {
    let compute = tech.compute_w_per_tflops * tflops;
    let sram = bw_gbps * tech.sram_pj_per_byte * 1e-3; // GB/s · pJ/B = mW·1e3
    let xbar = bw_gbps * tech.xbar_pj_per_byte * 1e-3;
    let io = (tech.io_link_gbps * tech.io_links as f64) * tech.io_pj_per_byte * 1e-3;
    compute + sram + xbar + io
}

/// Average power of a chiplet at a given utilization of compute and memory.
///
/// Leakage + clocking floor is modelled as 15% of TDP (always-on), the rest
/// scales with the utilization of the respective resource.
pub fn chip_avg_power(chip: &ChipletDesign, tech: &TechParams, compute_util: f64, mem_util: f64) -> f64 {
    let compute = tech.compute_w_per_tflops * chip.tflops;
    let sram = chip.mem_bw_gbps * tech.sram_pj_per_byte * 1e-3;
    let xbar = chip.mem_bw_gbps * tech.xbar_pj_per_byte * 1e-3;
    let io = chip.io_bw_gbps() * tech.io_pj_per_byte * 1e-3;
    let dynamic = compute * compute_util + (sram + xbar) * mem_util + io * mem_util;
    0.15 * chip.tdp_w + 0.85 * dynamic.min(chip.tdp_w)
}

/// Peak wall power of a server: chip TDPs divided by the PSU and DC-DC
/// conversion efficiencies, plus fans and the controller/NIC.
pub fn server_wall_power(chips_tdp_w: f64, sp: &ServerParams) -> f64 {
    let fans = sp.lanes as f64 * 12.0; // ~12 W per lane of 1U fans
    let controller = 25.0;
    (chips_tdp_w + fans + controller) / (sp.psu_efficiency * sp.dcdc_efficiency)
}

/// Average wall power of a server at the given utilizations.
pub fn server_avg_power(
    server: &ServerDesign,
    tech: &TechParams,
    sp: &ServerParams,
    compute_util: f64,
    mem_util: f64,
) -> f64 {
    let per_chip = chip_avg_power(&server.chiplet, tech, compute_util, mem_util);
    server_wall_power(per_chip * server.chips() as f64, sp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt3_chip() -> ChipletDesign {
        ChipletDesign {
            die_mm2: 140.0,
            sram_mb: 225.8,
            tflops: 5.5,
            mem_bw_gbps: 2750.0,
            n_bank_groups: 172,
            io_link_gbps: 25.0,
            io_links: 4,
            tdp_w: 0.0,
        }
    }

    #[test]
    fn tdp_components_reasonable() {
        let t = TechParams::default();
        let tdp = chip_tdp(&t, 5.5, 2750.0);
        // compute 7.15 W + sram 4.4 W + xbar 1.65 W + io ~0.94 W ≈ 14.1 W
        assert!((tdp - 14.1).abs() < 1.0, "tdp={tdp}");
        // Table-1 lane budget: 17 such chips/lane ≈ 240 W < 250 W ✓
        assert!(tdp * 17.0 < 250.0);
    }

    #[test]
    fn density_cap_binding_for_compute_heavy() {
        let t = TechParams::default();
        // 100 TFLOPS in 100 mm² would be 1.3 W/mm² — above the cap.
        let tdp = chip_tdp(&t, 100.0, 1000.0);
        assert!(tdp / 100.0 > t.max_power_density_w_mm2);
    }

    #[test]
    fn avg_power_below_tdp_and_floor() {
        let t = TechParams::default();
        let mut c = gpt3_chip();
        c.tdp_w = chip_tdp(&t, c.tflops, c.mem_bw_gbps);
        let idle = chip_avg_power(&c, &t, 0.0, 0.0);
        let full = chip_avg_power(&c, &t, 1.0, 1.0);
        assert!(idle > 0.0 && idle < 0.25 * c.tdp_w);
        assert!(full <= c.tdp_w * 1.0 + 1e-9);
        assert!(full > idle);
    }

    #[test]
    fn psu_losses_increase_wall_power() {
        let sp = ServerParams::default();
        let wall = server_wall_power(1000.0, &sp);
        assert!(wall > 1000.0 / (0.95 * 0.95));
    }
}
