//! Compression decoder unit (paper §3.2, Fig. 4).
//!
//! Sits inside each bank group. To read sparse data the decoder fetches the
//! tile's (start, end) from index memory, streams sparse words from data
//! memory into a double buffer at up to 8 words/cycle, inserts zeros per
//! the (row, col) indices, and emits dense words at a constant 8/cycle —
//! as long as the input side keeps up.
//!
//! Rate analysis (what [`dense_bytes_per_cycle`](Decoder::dense_bytes_per_cycle)
//! models): the SRAM port moves [`PORT_BYTES`] = 16 B/cycle. A sparse word
//! is 24 bits, so the input side reads 16·8/24 ≈ 5.33 words/cycle. Filling
//! one (32,8) tile of 256 dense words takes `nnz/5.33` cycles; draining it
//! takes 32 cycles at 8 dense words/cycle. With double buffering the output
//! is constant while `nnz ≤ 5.33·32 ≈ 170` (sparsity ≥ ~33%); below that
//! the decoder is input-limited and the dense-equivalent bandwidth drops by
//! `170.6/nnz` — the paper's encoding-overhead regime (Fig. 13, 10–20%).
//!
//! The cycle-accurate double-buffer behaviour is exercised by
//! [`decode_tile_trace`](Decoder::decode_tile_trace), which replays an
//! actual [`SparseTile`](crate::sparse::SparseTile) word-by-word and must
//! agree with both the rate model and the software codec's output.

use super::PORT_BYTES;
use crate::sparse::{SparseTile, TILE_COLS, TILE_ROWS};

/// Dense words per output cycle (Fig. 4: "constantly output 8 dense words
/// per cycle").
pub const DENSE_WORDS_PER_CYCLE: usize = 8;
/// Max sparse words accepted per cycle from data memory (Fig. 4: "reads
/// data memory at a rate of up to 8 sparse words per cycle"), before the
/// port-width bound.
pub const SPARSE_WORDS_PER_CYCLE: usize = 8;
/// Bits per sparse word (16b value + 5b row + 3b col).
pub const SPARSE_WORD_BITS: usize = 24;
/// Dense elements per tile.
pub const TILE_ELEMS: usize = TILE_ROWS * TILE_COLS;

/// Decoder state for the active sparse region.
pub struct Decoder {
    /// Average non-zeros per tile of the active region (rate model input).
    nnz_per_tile: u16,
    /// Tiles decoded (stats).
    pub tiles_decoded: u64,
}

impl Decoder {
    /// New idle decoder.
    pub fn new() -> Decoder {
        Decoder { nnz_per_tile: 0, tiles_decoded: 0 }
    }

    /// Begin decoding a region with the given average tile occupancy.
    pub fn start_region(&mut self, nnz_per_tile: u16) {
        assert!((nnz_per_tile as usize) <= TILE_ELEMS);
        self.nnz_per_tile = nnz_per_tile;
    }

    /// Effective sparse-word input rate, words/cycle: the lesser of the
    /// decoder's 8/cycle and what the 128-bit port sustains at 24 b/word.
    pub fn input_words_per_cycle() -> f64 {
        (PORT_BYTES as f64 * 8.0 / SPARSE_WORD_BITS as f64).min(SPARSE_WORDS_PER_CYCLE as f64)
    }

    /// Steady-state dense-equivalent output, bytes/cycle, for the active
    /// region (double-buffered; see module docs for the derivation).
    pub fn dense_bytes_per_cycle(&self) -> usize {
        let nnz = self.nnz_per_tile.max(1) as f64;
        let fill_cycles = nnz / Self::input_words_per_cycle();
        let drain_cycles = (TILE_ELEMS / DENSE_WORDS_PER_CYCLE) as f64;
        let out_rate = DENSE_WORDS_PER_CYCLE as f64 * (drain_cycles / fill_cycles.max(drain_cycles));
        (out_rate * 2.0) as usize // 2 B per dense fp16 word
    }

    /// Cycle-accurate decode of one tile: returns (dense tile, cycles).
    ///
    /// Replays Fig. 4 exactly: read ≤ input-rate sparse words per cycle into
    /// the working buffer (inserting zeros by index), then the double buffer
    /// swaps and drains at 8 dense words/cycle while the next fill proceeds;
    /// for a single tile the cycle count is fill + drain.
    pub fn decode_tile_trace(&mut self, tile: &SparseTile) -> (Vec<u16>, u64) {
        let mut dense = vec![0u16; TILE_ELEMS];
        let in_rate = Self::input_words_per_cycle();
        let mut credit = 0.0f64;
        let mut consumed = 0usize;
        let mut fill_cycles = 0u64;
        while consumed < tile.words.len() {
            fill_cycles += 1;
            credit += in_rate;
            while credit >= 1.0 && consumed < tile.words.len() {
                let w = tile.words[consumed];
                dense[w.row() as usize * TILE_COLS + w.col() as usize] = w.value();
                consumed += 1;
                credit -= 1.0;
            }
        }
        let drain_cycles = (TILE_ELEMS / DENSE_WORDS_PER_CYCLE) as u64;
        self.tiles_decoded += 1;
        (dense, fill_cycles.max(drain_cycles))
    }
}

impl Default for Decoder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn input_rate_is_port_limited() {
        // 16 B × 8 b / 24 b = 5.33 words/cycle < the decoder's 8/cycle max
        assert!((Decoder::input_words_per_cycle() - 5.333).abs() < 0.01);
    }

    #[test]
    fn high_sparsity_sustains_dense_rate() {
        let mut d = Decoder::new();
        d.start_region(102); // 60% sparse
        assert_eq!(d.dense_bytes_per_cycle(), PORT_BYTES);
    }

    #[test]
    fn low_sparsity_is_input_limited() {
        let mut d = Decoder::new();
        d.start_region(230); // 10% sparse
        let rate = d.dense_bytes_per_cycle();
        assert!(rate < PORT_BYTES, "rate={rate}");
        // analytic: 8 * (32 / (230/5.333)) * 2B ≈ 11 B/cycle
        assert!((10..=13).contains(&rate), "rate={rate}");
    }

    #[test]
    fn breakeven_occupancy() {
        // nnz = 170 is the knee: ≥ dense rate up to there
        let mut d = Decoder::new();
        d.start_region(170);
        assert_eq!(d.dense_bytes_per_cycle(), PORT_BYTES);
        d.start_region(180);
        assert!(d.dense_bytes_per_cycle() < PORT_BYTES);
    }

    /// The cycle-accurate trace must reproduce the software codec's dense
    /// output exactly, for any tile contents.
    #[test]
    fn trace_matches_codec_property() {
        check("decoder trace == codec decode", 100, |rng| {
            let dense: Vec<u16> = (0..TILE_ELEMS)
                .map(|_| if rng.chance(0.6) { 0 } else { rng.below(65536) as u16 })
                .collect();
            let tile = SparseTile::encode(&dense);
            let mut d = Decoder::new();
            let (decoded, cycles) = d.decode_tile_trace(&tile);
            assert_eq!(decoded, dense);
            // cycle count ≥ drain time, and ≥ fill time at the port rate
            let fill = (tile.nnz() as f64 / Decoder::input_words_per_cycle()).ceil() as u64;
            assert_eq!(cycles, fill.max(32));
        });
    }

    /// Trace cycle counts agree with the steady-state rate model within
    /// one cycle of quantization.
    #[test]
    fn trace_agrees_with_rate_model() {
        for sparsity in [0.1, 0.33, 0.6, 0.9] {
            let nnz = ((1.0 - sparsity) * TILE_ELEMS as f64) as usize;
            let mut dense = vec![0u16; TILE_ELEMS];
            for (i, v) in dense.iter_mut().enumerate().take(nnz) {
                *v = (i + 1) as u16;
            }
            let tile = SparseTile::encode(&dense);
            let mut d = Decoder::new();
            d.start_region(nnz as u16);
            let model_rate = d.dense_bytes_per_cycle() as f64; // B/cycle
            let (_, cycles) = d.decode_tile_trace(&tile);
            let trace_rate = (TILE_ELEMS * 2) as f64 / cycles as f64;
            let rel = (model_rate - trace_rate).abs() / trace_rate;
            assert!(rel < 0.15, "sparsity={sparsity}: model={model_rate} trace={trace_rate}");
        }
    }
}
