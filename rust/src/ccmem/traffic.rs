//! Traffic generators + whole-memory simulations.
//!
//! Two patterns matter for the paper's claims:
//!
//! * **Scheduled GEMM streaming** — each core walks its own set of bank
//!   groups with burst reads (weight-stationary inner loop). Conflict-free
//!   by construction ⇒ the crossbar must reach ~100% of the *cores'* port
//!   bandwidth ("100% saturated throughput with reasonable network
//!   scheduling").
//! * **Random access** — uniformly random bank targets, the worst case the
//!   paper's scheduling avoids; measures the conflict penalty.

use super::bank::{Burst, BurstMode};
use super::{CcMem, CcMemConfig, PORT_BYTES};
use crate::util::rng::Rng;

/// Result of a traffic run.
#[derive(Clone, Debug)]
pub struct TrafficResult {
    /// Cycles taken.
    pub cycles: u64,
    /// Dense-equivalent bytes delivered.
    pub bytes: u64,
    /// Fraction of the cores' aggregate port bandwidth achieved.
    pub core_bw_utilization: f64,
    /// Conflict rate per request.
    pub conflict_rate: f64,
}

/// Run a scheduled GEMM-style stream: core `i` bursts through bank groups
/// `i, i+n_cores, i+2·n_cores, …`, `bytes_per_group` from each, in `mode`.
/// The static schedule never collides, modelling the paper's network
/// scheduling of highly structured GEMM kernels.
pub fn run_gemm_stream(
    cfg: &CcMemConfig,
    bytes_per_group: usize,
    mode: BurstMode,
) -> TrafficResult {
    let mut mem = CcMem::new(cfg.clone());
    // Each core owns a disjoint stripe of groups.
    let mut core_groups: Vec<Vec<usize>> = vec![Vec::new(); cfg.n_cores];
    for g in 0..cfg.n_groups {
        core_groups[g % cfg.n_cores].push(g);
    }
    // Program every group's burst up front (CSR setup phase).
    for g in 0..cfg.n_groups {
        mem.program_burst(g, Burst { base: 0, len: bytes_per_group, mode });
    }
    let mut cursor = vec![0usize; cfg.n_cores]; // which stripe entry each core drains
    let mut total = 0u64;
    loop {
        let requests: Vec<Option<usize>> = (0..cfg.n_cores)
            .map(|c| {
                while cursor[c] < core_groups[c].len() {
                    let g = core_groups[c][cursor[c]];
                    if mem.groups[g].busy() {
                        return Some(g);
                    }
                    cursor[c] += 1;
                }
                None
            })
            .collect();
        if requests.iter().all(|r| r.is_none()) {
            break;
        }
        let delivered = mem.tick(&requests);
        total += delivered.iter().sum::<usize>() as u64;
    }
    let cycles = mem.stats.cycles;
    TrafficResult {
        cycles,
        bytes: total,
        core_bw_utilization: total as f64
            / (cycles as f64 * (cfg.n_cores * PORT_BYTES) as f64),
        conflict_rate: mem.stats.conflict_rate(),
    }
}

/// Run uniformly random single-beat reads for `n_cycles` cycles.
pub fn run_random(cfg: &CcMemConfig, n_cycles: u64, seed: u64) -> TrafficResult {
    let mut mem = CcMem::new(cfg.clone());
    let mut rng = Rng::new(seed);
    // Keep every group loaded with a full-capacity dense burst so beats are
    // available; re-arm when drained.
    let arm = |mem: &mut CcMem, g: usize| {
        let len = mem.groups[g].capacity;
        mem.program_burst(g, Burst { base: 0, len, mode: BurstMode::Dense });
    };
    for g in 0..cfg.n_groups {
        arm(&mut mem, g);
    }
    let mut total = 0u64;
    for _ in 0..n_cycles {
        for g in 0..cfg.n_groups {
            if !mem.groups[g].busy() {
                arm(&mut mem, g);
            }
        }
        let requests: Vec<Option<usize>> =
            (0..cfg.n_cores).map(|_| Some(rng.below(cfg.n_groups))).collect();
        total += mem.tick(&requests).iter().sum::<usize>() as u64;
    }
    TrafficResult {
        cycles: n_cycles,
        bytes: total,
        core_bw_utilization: total as f64
            / (n_cycles as f64 * (cfg.n_cores * PORT_BYTES) as f64),
        conflict_rate: mem.stats.conflict_rate(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline CC-MEM claim: scheduled GEMM traffic saturates the
    /// cores' bandwidth (>99%).
    #[test]
    fn gemm_stream_saturates() {
        let cfg = CcMemConfig::small();
        let r = run_gemm_stream(&cfg, 4096, BurstMode::Dense);
        assert!(r.core_bw_utilization > 0.99, "util={}", r.core_bw_utilization);
        assert_eq!(r.conflict_rate, 0.0);
    }

    /// Random traffic suffers conflicts; with cores ≪ groups the loss is
    /// modest (birthday-style collisions).
    #[test]
    fn random_traffic_conflicts() {
        let cfg = CcMemConfig::small(); // 4 cores on 32 groups
        let r = run_random(&cfg, 5_000, 42);
        assert!(r.conflict_rate > 0.02, "should see conflicts: {}", r.conflict_rate);
        assert!(r.core_bw_utilization > 0.80, "util={}", r.core_bw_utilization);
        // Analytic check: P(lose) ≈ 1 − (1 − 1/32)^3/… ~ 4.6%; allow slack.
        assert!(r.conflict_rate < 0.10);
    }

    /// 60%-sparse streams deliver the same *dense-equivalent* bytes at full
    /// rate; 10%-sparse streams take measurably longer (input-limited).
    #[test]
    fn sparse_stream_dense_equivalence() {
        let cfg = CcMemConfig::small();
        let dense = run_gemm_stream(&cfg, 4096, BurstMode::Dense);
        let s60 = run_gemm_stream(&cfg, 4096, BurstMode::Sparse { nnz_per_tile: 102 });
        let s10 = run_gemm_stream(&cfg, 4096, BurstMode::Sparse { nnz_per_tile: 230 });
        assert_eq!(dense.bytes, s60.bytes);
        assert_eq!(dense.cycles, s60.cycles, "60% sparsity must not cost bandwidth");
        assert!(s10.cycles > dense.cycles * 5 / 4, "10% sparsity must be slower");
    }

    /// More cores on the same groups: aggregate delivered bandwidth is
    /// capped by the groups, not the cores.
    #[test]
    fn group_bandwidth_caps_aggregate() {
        let cfg = CcMemConfig { n_groups: 4, group_bytes: 1 << 20, n_cores: 8, xbar_depth: 6 };
        let r = run_random(&cfg, 2_000, 7);
        let group_peak = (cfg.n_groups * PORT_BYTES) as f64;
        let achieved = r.bytes as f64 / r.cycles as f64;
        assert!(achieved <= group_peak + 1e-9);
        assert!(achieved > 0.5 * group_peak);
    }
}
