//! Pipelined crossbar switch (paper §3.1).
//!
//! Full crossbar between core ports and bank groups: low latency (fixed
//! pipeline depth), 100% saturated throughput achievable under conflict-free
//! scheduling, and simple conflict semantics — at most one core is granted
//! per bank group per cycle, round-robin arbitration among contenders.
//! (Its quadratic *area* lives in [`crate::area::crossbar_mm2`]; NoC
//! symbiosis makes that affordable.)

/// Crossbar state: arbitration priorities per output (bank-group) port.
pub struct Crossbar {
    /// Number of input (core) ports.
    pub n_cores: usize,
    /// Number of output (bank group) ports.
    pub n_groups: usize,
    /// Pipeline depth, cycles.
    pub depth: usize,
    /// Round-robin pointer per output port.
    rr: Vec<usize>,
    /// Grants issued (stats).
    pub grants: u64,
    /// Requests rejected due to conflicts (stats).
    pub rejects: u64,
}

impl Crossbar {
    /// New crossbar with all priorities at core 0.
    pub fn new(n_cores: usize, n_groups: usize, depth: usize) -> Crossbar {
        Crossbar { n_cores, n_groups, depth, rr: vec![0; n_groups], grants: 0, rejects: 0 }
    }

    /// One cycle of arbitration. `requests[i]` = bank group requested by
    /// core `i` (None = idle). Returns per-core grant flags.
    ///
    /// Complexity is O(cores²) per cycle — it scans the (few) requesters
    /// rather than every one of the (many) bank groups, which measured
    /// ~3.4× faster on GEMM-stream simulation (EXPERIMENTS.md §Perf).
    pub fn arbitrate(&mut self, requests: &[Option<usize>]) -> Vec<bool> {
        debug_assert_eq!(requests.len(), self.n_cores);
        let mut granted = vec![false; self.n_cores];
        let mut group_done = [usize::MAX; 64]; // groups granted this cycle
        let mut n_done = 0usize;
        for core in 0..self.n_cores {
            let Some(g) = requests[core] else { continue };
            if group_done[..n_done].contains(&g) {
                continue;
            }
            // round-robin winner among this group's contenders: smallest
            // cyclic distance at-or-after the RR pointer
            let mut winner = core;
            let mut best = usize::MAX;
            for (c2, r) in requests.iter().enumerate() {
                if *r == Some(g) {
                    let dist = (c2 + self.n_cores - self.rr[g]) % self.n_cores;
                    if dist < best {
                        best = dist;
                        winner = c2;
                    }
                }
            }
            granted[winner] = true;
            self.rr[g] = (winner + 1) % self.n_cores;
            self.grants += 1;
            if n_done < group_done.len() {
                group_done[n_done] = g;
                n_done += 1;
            }
        }
        for (core, req) in requests.iter().enumerate() {
            if req.is_some() && !granted[core] {
                self.rejects += 1;
            }
        }
        granted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn conflict_free_requests_all_granted() {
        let mut xb = Crossbar::new(4, 8, 6);
        let grants = xb.arbitrate(&[Some(0), Some(1), Some(2), Some(3)]);
        assert!(grants.iter().all(|&g| g));
        assert_eq!(xb.rejects, 0);
    }

    #[test]
    fn conflicting_requests_grant_exactly_one() {
        let mut xb = Crossbar::new(4, 8, 6);
        let grants = xb.arbitrate(&[Some(5), Some(5), Some(5), Some(5)]);
        assert_eq!(grants.iter().filter(|&&g| g).count(), 1);
        assert_eq!(xb.rejects, 3);
    }

    /// Round-robin is fair: under persistent 4-way conflict every core is
    /// served exactly n/4 times over n cycles.
    #[test]
    fn round_robin_fairness() {
        let mut xb = Crossbar::new(4, 8, 6);
        let mut served = [0usize; 4];
        for _ in 0..400 {
            let grants = xb.arbitrate(&[Some(3), Some(3), Some(3), Some(3)]);
            for (c, &g) in grants.iter().enumerate() {
                if g {
                    served[c] += 1;
                }
            }
        }
        assert_eq!(served, [100, 100, 100, 100]);
    }

    /// Safety property: never two grants for the same group, and a grant
    /// implies a matching request.
    #[test]
    fn arbitration_invariants_property() {
        check("xbar grant invariants", 200, |rng| {
            let n_cores = 1 + rng.below(8);
            let n_groups = 1 + rng.below(16);
            let mut xb = Crossbar::new(n_cores, n_groups, 6);
            for _ in 0..20 {
                let reqs: Vec<Option<usize>> = (0..n_cores)
                    .map(|_| if rng.chance(0.7) { Some(rng.below(n_groups)) } else { None })
                    .collect();
                let grants = xb.arbitrate(&reqs);
                // grants only where requested
                for (c, &g) in grants.iter().enumerate() {
                    if g {
                        assert!(reqs[c].is_some());
                    }
                }
                // one grant per group max
                let mut per_group = vec![0usize; n_groups];
                for (c, &g) in grants.iter().enumerate() {
                    if g {
                        per_group[reqs[c].unwrap()] += 1;
                    }
                }
                assert!(per_group.iter().all(|&n| n <= 1));
                // work-conserving: any requested group grants someone
                for g in 0..n_groups {
                    let requested = reqs.iter().any(|r| *r == Some(g));
                    if requested {
                        assert_eq!(per_group[g], 1, "group {g} requested but idle");
                    }
                }
            }
        });
    }
}
