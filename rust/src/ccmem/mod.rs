//! CC-MEM: cycle-level simulator of the Chiplet Cloud memory system
//! (paper §3.1–§3.2, Fig. 3(a) and Fig. 4).
//!
//! The CC-MEM is the main memory of each chiplet: SRAM bank groups behind a
//! pipelined crossbar. Each bank group contains a burst-mode control unit
//! (programmed through memory-mapped CSRs) and a compression decoder that
//! implements *Store-as-Compressed, Load-as-Dense*: tiles are stored in
//! tile-CSR ([`crate::sparse`]) and emerge from the bank group fully dense,
//! so compute units are sparsity-agnostic.
//!
//! The simulator exists to *validate the analytic summaries* Phase 1 feeds
//! on: crossbar saturation under scheduled GEMM traffic, conflict behaviour
//! under random traffic, burst-mode command amortization, and the sparse
//! bandwidth derating (24-bit sparse words through a 128-bit port).
//!
//! Hierarchy: [`bank`] (bank group + burst engine) → [`decoder`]
//! (compression decoder) → [`xbar`] (pipelined crossbar) → [`CcMem`]
//! (whole memory system) driven by [`traffic`] generators.

pub mod bank;
pub mod decoder;
pub mod traffic;
pub mod xbar;

use bank::BankGroup;
use xbar::Crossbar;

/// Bytes per cycle per bank-group port (128-bit datapath).
pub const PORT_BYTES: usize = 16;

/// Configuration of a CC-MEM instance.
#[derive(Clone, Debug)]
pub struct CcMemConfig {
    /// Number of bank groups.
    pub n_groups: usize,
    /// Capacity per bank group, bytes.
    pub group_bytes: usize,
    /// Number of requester (core) ports on the crossbar.
    pub n_cores: usize,
    /// Crossbar pipeline depth, cycles (log-radix plus register stages).
    pub xbar_depth: usize,
}

impl CcMemConfig {
    /// A CC-MEM shaped like the Table-2 GPT-3 chiplet (scaled down for
    /// simulation speed): 32 groups × 1 MB, 4 cores.
    pub fn small() -> Self {
        CcMemConfig { n_groups: 32, group_bytes: 1 << 20, n_cores: 4, xbar_depth: 6 }
    }

    /// Total capacity, bytes.
    pub fn capacity(&self) -> usize {
        self.n_groups * self.group_bytes
    }

    /// Peak read bandwidth, bytes/cycle (all groups streaming).
    pub fn peak_bytes_per_cycle(&self) -> usize {
        self.n_groups * PORT_BYTES
    }
}

/// Counters accumulated over a simulation run.
#[derive(Clone, Debug, Default)]
pub struct CcMemStats {
    /// Cycles simulated.
    pub cycles: u64,
    /// Dense-equivalent bytes delivered to cores.
    pub bytes_delivered: u64,
    /// Requests that lost crossbar arbitration (bank conflict) and retried.
    pub conflicts: u64,
    /// Requests issued.
    pub requests: u64,
    /// Burst commands programmed.
    pub burst_cmds: u64,
}

impl CcMemStats {
    /// Achieved bandwidth as a fraction of the peak.
    pub fn bw_utilization(&self, cfg: &CcMemConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.bytes_delivered as f64 / (self.cycles as f64 * cfg.peak_bytes_per_cycle() as f64)
    }

    /// Conflict rate per request.
    pub fn conflict_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.conflicts as f64 / self.requests as f64
    }
}

/// The CC-MEM: bank groups + crossbar, advanced cycle by cycle.
pub struct CcMem {
    /// Configuration.
    pub cfg: CcMemConfig,
    /// Bank groups (each with burst engine + decoder).
    pub groups: Vec<BankGroup>,
    /// The crossbar connecting cores to groups.
    pub xbar: Crossbar,
    /// Accumulated statistics.
    pub stats: CcMemStats,
}

impl CcMem {
    /// Build a CC-MEM from a configuration.
    pub fn new(cfg: CcMemConfig) -> CcMem {
        let groups = (0..cfg.n_groups).map(|_| BankGroup::new(cfg.group_bytes)).collect();
        let xbar = Crossbar::new(cfg.n_cores, cfg.n_groups, cfg.xbar_depth);
        CcMem { cfg, groups, xbar, stats: CcMemStats::default() }
    }

    /// Advance one cycle: arbitrate core requests through the crossbar,
    /// let granted bank groups serve one port-width beat each.
    ///
    /// `requests[i]` is core `i`'s target bank group this cycle (None =
    /// idle). Returns, per core, the bytes delivered this cycle (0 if the
    /// request lost arbitration or the group's burst has drained).
    pub fn tick(&mut self, requests: &[Option<usize>]) -> Vec<usize> {
        debug_assert_eq!(requests.len(), self.cfg.n_cores);
        self.stats.cycles += 1;
        let grants = self.xbar.arbitrate(requests);
        let mut delivered = vec![0usize; self.cfg.n_cores];
        for (core, req) in requests.iter().enumerate() {
            let Some(group) = *req else { continue };
            self.stats.requests += 1;
            if grants[core] {
                let bytes = self.groups[group].serve_beat();
                delivered[core] = bytes;
                self.stats.bytes_delivered += bytes as u64;
            } else {
                self.stats.conflicts += 1;
            }
        }
        delivered
    }

    /// Program a burst read on a bank group (CSR write in hardware).
    pub fn program_burst(&mut self, group: usize, burst: bank::Burst) {
        self.stats.burst_cmds += 1;
        self.groups[group].program(burst);
    }

    /// Latency in cycles for a single isolated read (crossbar pipeline +
    /// bank access) — the "low latency" the paper claims for the crossbar.
    pub fn read_latency(&self) -> usize {
        self.cfg.xbar_depth + bank::BANK_ACCESS_CYCLES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_and_peak() {
        let cfg = CcMemConfig::small();
        assert_eq!(cfg.capacity(), 32 << 20);
        assert_eq!(cfg.peak_bytes_per_cycle(), 512);
    }

    #[test]
    fn single_read_latency_is_small() {
        let mem = CcMem::new(CcMemConfig::small());
        assert!(mem.read_latency() <= 10, "CC-MEM latency must be ~ns-scale");
    }

    #[test]
    fn idle_ticks_deliver_nothing() {
        let mut mem = CcMem::new(CcMemConfig::small());
        let d = mem.tick(&[None, None, None, None]);
        assert!(d.iter().all(|&b| b == 0));
        assert_eq!(mem.stats.bytes_delivered, 0);
        assert_eq!(mem.stats.cycles, 1);
    }
}
