//! Bank group: a virtual single-port SRAM cluster with a burst-mode control
//! unit and a compression decoder (paper §3.1–3.2).
//!
//! The control unit is programmed through memory-mapped CSRs with
//! (base, length, mode); during GEMM execution bursts make up the vast
//! majority of operations, keeping the port at near-peak throughput without
//! per-beat commands from the compute unit.

use super::decoder::Decoder;
use super::PORT_BYTES;

/// SRAM bank access latency, cycles (pipelined; affects latency not rate).
pub const BANK_ACCESS_CYCLES: usize = 2;

/// Burst descriptor (the CSR contents).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Burst {
    /// Start address within the group, bytes.
    pub base: usize,
    /// Length, bytes (dense-equivalent length for sparse regions).
    pub len: usize,
    /// Access mode.
    pub mode: BurstMode,
}

/// Dense stream or sparse region decoded on the fly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BurstMode {
    /// Raw dense data: one port beat per [`PORT_BYTES`].
    Dense,
    /// Tile-CSR data decoded by the bank group's compression decoder;
    /// `nnz_per_tile` sets the input-side cost (see [`Decoder`]).
    Sparse {
        /// Average non-zeros per (32,8) tile in the region.
        nnz_per_tile: u16,
    },
}

/// A bank group with its burst engine state.
pub struct BankGroup {
    /// Capacity, bytes.
    pub capacity: usize,
    /// Active burst (None = idle).
    active: Option<Burst>,
    /// Bytes of the active burst already delivered.
    served: usize,
    /// The compression decoder attached to this group.
    pub decoder: Decoder,
    /// Total beats served (stats).
    pub beats: u64,
}

impl BankGroup {
    /// New idle bank group.
    pub fn new(capacity: usize) -> BankGroup {
        BankGroup { capacity, active: None, served: 0, decoder: Decoder::new(), beats: 0 }
    }

    /// Program the burst CSRs. Panics if the burst exceeds the capacity
    /// (hardware would raise a bus error).
    pub fn program(&mut self, burst: Burst) {
        assert!(burst.base + burst.len <= self.capacity, "burst beyond bank group");
        self.active = Some(burst);
        self.served = 0;
        if let BurstMode::Sparse { nnz_per_tile } = burst.mode {
            self.decoder.start_region(nnz_per_tile);
        }
    }

    /// True while a burst has data left.
    pub fn busy(&self) -> bool {
        match self.active {
            Some(b) => self.served < b.len,
            None => false,
        }
    }

    /// Serve one crossbar beat: returns dense-equivalent bytes delivered
    /// this cycle (0 when idle/drained; sparse bursts can deliver partial
    /// beats when the decoder is input-limited at low sparsity).
    pub fn serve_beat(&mut self) -> usize {
        let Some(b) = self.active else { return 0 };
        if self.served >= b.len {
            self.active = None;
            return 0;
        }
        let bytes = match b.mode {
            BurstMode::Dense => PORT_BYTES,
            BurstMode::Sparse { .. } => self.decoder.dense_bytes_per_cycle(),
        };
        let bytes = bytes.min(b.len - self.served);
        self.served += bytes;
        self.beats += 1;
        if self.served >= b.len {
            self.active = None;
        }
        bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_burst_streams_at_port_rate() {
        let mut g = BankGroup::new(1 << 20);
        g.program(Burst { base: 0, len: 160, mode: BurstMode::Dense });
        let mut total = 0;
        let mut cycles = 0;
        while g.busy() {
            total += g.serve_beat();
            cycles += 1;
        }
        assert_eq!(total, 160);
        assert_eq!(cycles, 10); // 160 B at 16 B/cycle
    }

    #[test]
    fn burst_tail_is_partial() {
        let mut g = BankGroup::new(1 << 20);
        g.program(Burst { base: 0, len: 20, mode: BurstMode::Dense });
        assert_eq!(g.serve_beat(), 16);
        assert_eq!(g.serve_beat(), 4);
        assert_eq!(g.serve_beat(), 0);
        assert!(!g.busy());
    }

    #[test]
    #[should_panic(expected = "burst beyond bank group")]
    fn oversize_burst_faults() {
        let mut g = BankGroup::new(1024);
        g.program(Burst { base: 1000, len: 100, mode: BurstMode::Dense });
    }

    /// Sparse bursts at high sparsity sustain the full dense rate; at low
    /// sparsity they are input-limited (24-bit words through a 128-bit
    /// port) — the paper's "compressed data ultimately has a lower
    /// bandwidth than dense data".
    #[test]
    fn sparse_rate_depends_on_sparsity() {
        // 60% sparsity: nnz ≈ 102 per 256-elem tile
        let mut hi = BankGroup::new(1 << 20);
        hi.program(Burst { base: 0, len: 512, mode: BurstMode::Sparse { nnz_per_tile: 102 } });
        assert_eq!(hi.serve_beat(), PORT_BYTES, "60% sparse streams dense-rate");
        // 10% sparsity: nnz ≈ 230 — input-limited below the port rate
        let mut lo = BankGroup::new(1 << 20);
        lo.program(Burst { base: 0, len: 512, mode: BurstMode::Sparse { nnz_per_tile: 230 } });
        assert!(lo.serve_beat() < PORT_BYTES);
    }
}
