//! The declarative experiment spec: one serializable description of a
//! whole co-design run.
//!
//! The paper's evaluation is a *campaign* — the same two-phase methodology
//! applied across eight LLMs, workload grids and serving regimes. An
//! [`Experiment`] captures everything one such run needs (task, models,
//! exploration space, workload point, traffic + SLO + serving-model knobs,
//! engine knobs) as plain data, so campaigns live in checked-in
//! `experiments/*.json` files instead of bespoke CLI invocations or code.
//!
//! * [`Experiment::from_json_str`] / [`Experiment::to_json`] — a strict,
//!   dependency-free JSON codec over [`crate::util::json`]. Round-trip is
//!   guaranteed (`parse ∘ serialize = id` under `PartialEq`); **unknown
//!   fields are rejected** with the offending key and its location, so a
//!   typo'd knob fails loudly instead of silently running the default.
//! * [`Experiment::validate`] — semantic checks (known models, task/field
//!   compatibility, traffic sanity) shared by the JSON and CLI paths.
//! * [`crate::experiment::Engine::run`] — executes a spec and returns a
//!   structured [`crate::experiment::Outcome`].
//!
//! SLO targets serialize as JSON `null` when unconstrained (JSON has no
//! `Infinity`); integers round-trip exactly up to 2^53 (they travel as
//! f64, like every JSON number).

use std::collections::BTreeMap;

use crate::config::models::ModelSpec;
use crate::config::workload::{
    ArrivalProcess, FaultSpec, OvercommitSpec, ResidencyEstimate, ServeSpec, SloSpec, TierSpec,
    TokenDist, TrafficSpec,
};
use crate::sched::RoutePolicy;
use crate::util::json::Json;

/// The one set of spec defaults shared by the JSON codec (omitted fields)
/// and the CLI translation (absent flags), so `ccloud serve-sim` and an
/// equivalent JSON spec can never silently diverge.
pub mod defaults {
    /// Requests per synthetic trace.
    pub const REQUESTS: usize = 400;
    /// Prompt tokens per request.
    pub const PROMPT_TOKENS: usize = 64;
    /// Minimum generated tokens per request.
    pub const NEW_TOKENS_LO: usize = 16;
    /// Maximum generated tokens per request.
    pub const NEW_TOKENS_HI: usize = 128;
    /// Trace PRNG seed.
    pub const SEED: u64 = 42;
    /// Requests per burst (bursty arrivals).
    pub const BURST: usize = 8;
    /// Concurrent clients (closed-loop arrivals).
    pub const CLIENTS: usize = 64;
    /// Open-loop rate resolution: fraction of the design's capacity.
    pub const LOAD: f64 = 0.8;
}

/// Which question an experiment asks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Sweep-engine report over the model's full study grid: frontier and
    /// pruning counters, the TCO/Token optimum, and — when a serving spec
    /// with a binding SLO is attached — the SLO-constrained selection
    /// (`ccloud sweep`).
    Sweep,
    /// Discrete-event serving simulation on the model's optimal design:
    /// static vs continuous batching, routing policies across replicas,
    /// and the SLO-constrained selection under a binding SLO
    /// (`ccloud serve-sim`).
    ServeSim,
    /// TCO/Token-optimal system per model over the study grid — one row
    /// per model, the Table-2 procedure (`ccloud optimize` / `table2`).
    Optimize,
}

impl Task {
    /// Stable spelling used in JSON specs and derived experiment names.
    pub fn name(&self) -> &'static str {
        match self {
            Task::Sweep => "sweep",
            Task::ServeSim => "serve-sim",
            Task::Optimize => "optimize",
        }
    }

    /// Parse a JSON/CLI spelling.
    pub fn parse(s: &str) -> Option<Task> {
        match s {
            "sweep" => Some(Task::Sweep),
            "serve-sim" => Some(Task::ServeSim),
            "optimize" => Some(Task::Optimize),
            _ => None,
        }
    }
}

/// Which Phase-1 exploration space the experiment sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceSpec {
    /// The reduced sweep ([`crate::config::hardware::ExploreSpace::coarse`]):
    /// seconds end to end, same qualitative optima.
    Coarse,
    /// The paper-scale Table-1 ranges
    /// ([`crate::config::hardware::ExploreSpace::default`]).
    Full,
}

impl SpaceSpec {
    /// Stable spelling used in JSON specs.
    pub fn name(&self) -> &'static str {
        match self {
            SpaceSpec::Coarse => "coarse",
            SpaceSpec::Full => "full",
        }
    }

    /// Parse a JSON/CLI spelling.
    pub fn parse(s: &str) -> Option<SpaceSpec> {
        match s {
            "coarse" => Some(SpaceSpec::Coarse),
            "full" => Some(SpaceSpec::Full),
            _ => None,
        }
    }

    /// Materialize the exploration space.
    pub fn space(&self) -> crate::config::hardware::ExploreSpace {
        match self {
            SpaceSpec::Coarse => crate::config::hardware::ExploreSpace::coarse(),
            SpaceSpec::Full => crate::config::hardware::ExploreSpace::default(),
        }
    }
}

/// A fixed workload operating point (serve-sim experiments; sweep and
/// optimize explore the whole study grid instead).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkloadPoint {
    /// Context length (prompt + generated) budget per sequence.
    pub ctx: usize,
    /// Batch size (sequences decoded concurrently).
    pub batch: usize,
}

/// Sweep-engine execution knobs. These never change *what* an experiment
/// answers — only how fast — so they sit apart from the scientific fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineKnobs {
    /// Worker threads; 0 = auto (`CC_SWEEP_THREADS` or the machine width).
    pub threads: usize,
    /// Sequential reference path: single-threaded, no pruning, no Pareto
    /// ordering, reference-stepped stage-2 validation without early abort —
    /// the behaviour fast runs are held byte-identical to.
    pub seq: bool,
}

impl Default for EngineKnobs {
    fn default() -> Self {
        EngineKnobs { threads: 0, seq: false }
    }
}

/// Shard identity: marks a spec as one slice of a parent experiment split
/// by [`crate::experiment::shard::plan`]. The `parent` fingerprint ties
/// every shard outcome back to the spec it was cut from, so a merge (or a
/// `--resume`d run directory) can refuse mixed or stale shards instead of
/// silently combining them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSel {
    /// This shard's position in the plan, `0 <= index < of`.
    pub index: usize,
    /// Total shards in the plan.
    pub of: usize,
    /// Hex [`Experiment::fingerprint`] of the parent spec.
    pub parent: String,
    /// Model count of the parent spec — the shape of the merged outcome
    /// (1 = a bare outcome, >1 = a per-model campaign).
    pub parent_models: usize,
    /// Half-open study-grid slice `[lo, hi)` this shard searches
    /// (`None` = the whole grid). Only meaningful on single-model sweeps.
    pub grid: Option<(usize, usize)>,
    /// Half-open Phase-1 server slice `[lo, hi)` this shard searches
    /// (`None` = all feasible servers). Only meaningful on single-model
    /// sweeps, and only used when workers outnumber grid points.
    pub servers: Option<(usize, usize)>,
}

/// A fully described co-design experiment: the one serializable input of
/// [`crate::experiment::Engine::run`]. See the module docs for the JSON
/// schema and `experiments/*.json` for checked-in examples.
#[derive(Clone, Debug, PartialEq)]
pub struct Experiment {
    /// Identifier for reports and output files. Defaults to
    /// `"<task>-<models>"` when absent from a JSON spec.
    pub name: String,
    /// The question being asked.
    pub task: Task,
    /// Model short names ([`ModelSpec::by_name`]); several models turn a
    /// sweep/serve-sim into a per-model campaign and an optimize into the
    /// multi-model Table-2 procedure.
    pub models: Vec<String>,
    /// Phase-1 exploration space.
    pub space: SpaceSpec,
    /// Fixed workload point (serve-sim only; `None` = study grid).
    pub workload: Option<WorkloadPoint>,
    /// Serving spec: traffic, SLO targets and serving-model knobs.
    /// Required for serve-sim; arms the SLO-constrained selection on a
    /// sweep; must be absent on optimize.
    pub serve: Option<ServeSpec>,
    /// Open-loop rate resolution: a non-positive Poisson/bursty rate in
    /// `serve.traffic` resolves to `load` × the evaluated design's
    /// steady-state capacity (closed-loop traffic self-paces).
    pub load: f64,
    /// Engine execution knobs.
    pub engine: EngineKnobs,
    /// Shard identity when this spec is one slice of a distributed
    /// campaign (`None` for ordinary specs). See [`ShardSel`].
    pub shard: Option<ShardSel>,
}

impl Experiment {
    /// The default experiment name: `"<task>-<model>[+<model>...]"`.
    pub fn default_name(task: Task, models: &[String]) -> String {
        format!("{}-{}", task.name(), models.join("+"))
    }

    /// Parse a spec from JSON text. Strict: unknown fields, wrong types
    /// and malformed documents are all errors with the offending location.
    pub fn from_json_str(s: &str) -> Result<Experiment, String> {
        let v = Json::parse(s)?;
        Experiment::from_json(&v)
    }

    /// Parse a spec from a parsed [`Json`] document (see
    /// [`Experiment::from_json_str`]).
    pub fn from_json(v: &Json) -> Result<Experiment, String> {
        let m = as_obj(v, "experiment")?;
        check_fields(
            m,
            "experiment",
            &["name", "task", "models", "space", "workload", "serve", "load", "engine", "shard"],
        )?;
        let task_s = get_str(m, "experiment", "task")?
            .ok_or("experiment is missing the required field 'task'")?;
        let task = Task::parse(&task_s).ok_or_else(|| {
            format!("field 'task': unknown task '{task_s}' (expected sweep, serve-sim or optimize)")
        })?;
        let models = match m.get("models") {
            None => return Err("experiment is missing the required field 'models'".into()),
            Some(Json::Arr(xs)) => {
                let mut out = Vec::with_capacity(xs.len());
                for (i, x) in xs.iter().enumerate() {
                    out.push(
                        x.as_str()
                            .ok_or_else(|| format!("field 'models[{i}]': expected a model name"))?
                            .to_string(),
                    );
                }
                out
            }
            Some(_) => {
                return Err("field 'models': expected an array of model names, \
                            e.g. [\"gpt3\"]"
                    .into())
            }
        };
        let name = get_str(m, "experiment", "name")?
            .unwrap_or_else(|| Experiment::default_name(task, &models));
        let space = match get_str(m, "experiment", "space")? {
            None => SpaceSpec::Coarse,
            Some(s) => SpaceSpec::parse(&s).ok_or_else(|| {
                format!("field 'space': unknown space '{s}' (expected coarse or full)")
            })?,
        };
        let workload = match m.get("workload") {
            None | Some(Json::Null) => None,
            Some(v) => Some(workload_from_json(v)?),
        };
        let serve = match m.get("serve") {
            None | Some(Json::Null) => None,
            Some(v) => Some(serve_from_json(v)?),
        };
        let load = get_f64(m, "experiment", "load")?.unwrap_or(defaults::LOAD);
        let engine = match m.get("engine") {
            None | Some(Json::Null) => EngineKnobs::default(),
            Some(v) => engine_from_json(v)?,
        };
        let shard = match m.get("shard") {
            None | Some(Json::Null) => None,
            Some(v) => Some(shard_from_json(v)?),
        };
        Ok(Experiment { name, task, models, space, workload, serve, load, engine, shard })
    }

    /// Canonical JSON form: every field emitted explicitly, so
    /// `from_json(to_json(e)) == e` for every valid spec.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("task".into(), Json::Str(self.task.name().into()));
        m.insert(
            "models".into(),
            Json::Arr(self.models.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        m.insert("space".into(), Json::Str(self.space.name().into()));
        m.insert(
            "workload".into(),
            match &self.workload {
                None => Json::Null,
                Some(w) => workload_to_json(w),
            },
        );
        m.insert(
            "serve".into(),
            match &self.serve {
                None => Json::Null,
                Some(s) => serve_to_json(s),
            },
        );
        m.insert("load".into(), Json::Num(self.load));
        m.insert("engine".into(), engine_to_json(&self.engine));
        m.insert(
            "shard".into(),
            match &self.shard {
                None => Json::Null,
                Some(s) => shard_to_json(s),
            },
        );
        Json::Obj(m)
    }

    /// [`Experiment::to_json`] rendered as a compact string.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Stable hex fingerprint of the spec's *scientific* content: the
    /// canonical JSON with the engine knobs reset to default and any shard
    /// marker stripped. Two specs that answer the same question get the
    /// same fingerprint regardless of thread count, `--seq`, or which
    /// shard of a plan they are — the identity shard/merge and
    /// checkpoint-resume use to reject mismatched pieces.
    pub fn fingerprint(&self) -> String {
        let mut canon = self.clone();
        canon.engine = EngineKnobs::default();
        canon.shard = None;
        format!("{:016x}", crate::util::fnv1a64(canon.to_json_string().as_bytes()))
    }

    /// Semantic validation shared by the JSON and CLI paths. Field-shape
    /// errors (unknown fields, wrong types) are caught earlier by the
    /// parser; this checks what the parser cannot: model names, task/field
    /// compatibility and traffic sanity.
    pub fn validate(&self) -> Result<(), String> {
        if self.models.is_empty() {
            return Err("'models' must name at least one model".into());
        }
        for name in &self.models {
            if ModelSpec::by_name(name).is_none() {
                return Err(format!(
                    "unknown model '{name}' (known: gpt2, megatron, gpt3, gopher, mt-nlg, \
                     bloom, palm, llama2-70b, opt-175b, cc-tiny, cc-gpt-mini)"
                ));
            }
        }
        if !self.load.is_finite() || self.load <= 0.0 {
            return Err(format!("'load' must be positive and finite (got {})", self.load));
        }
        match self.task {
            Task::Sweep => {
                if self.workload.is_some() {
                    return Err("a sweep explores the whole study grid; drop 'workload' \
                                (use task serve-sim for a fixed operating point)"
                        .into());
                }
                if let Some(s) = &self.serve {
                    if s.slo.is_unconstrained() {
                        return Err("a sweep with a 'serve' spec needs binding SLO targets \
                                    (serve.slo) — the serving model only enters the sweep \
                                    through the SLO-constrained selection"
                            .into());
                    }
                }
            }
            Task::ServeSim => {
                if self.workload.is_none() {
                    return Err(
                        "serve-sim needs a 'workload' operating point ({\"ctx\": .., \
                         \"batch\": ..})"
                            .into(),
                    );
                }
                if self.serve.is_none() {
                    return Err("serve-sim needs a 'serve' spec (traffic + slo)".into());
                }
            }
            Task::Optimize => {
                if self.workload.is_some() || self.serve.is_some() {
                    return Err("optimize explores the study grid without a serving model; \
                                drop 'workload' and 'serve'"
                        .into());
                }
            }
        }
        if let Some(w) = &self.workload {
            if w.ctx == 0 || w.batch == 0 {
                return Err(format!(
                    "'workload' needs ctx >= 1 and batch >= 1 (got ctx {}, batch {})",
                    w.ctx, w.batch
                ));
            }
        }
        if let Some(s) = &self.serve {
            validate_serve(s)?;
        }
        if let Some(sh) = &self.shard {
            if sh.of == 0 {
                return Err("'shard.of' must be >= 1".into());
            }
            if sh.index >= sh.of {
                return Err(format!(
                    "'shard.index' must be < 'shard.of' (got {} of {})",
                    sh.index, sh.of
                ));
            }
            if sh.parent.is_empty() {
                return Err("'shard.parent' must carry the parent spec fingerprint".into());
            }
            if sh.parent_models == 0 {
                return Err("'shard.parent_models' must be >= 1".into());
            }
            for (name, range) in [("grid", sh.grid), ("servers", sh.servers)] {
                if let Some((lo, hi)) = range {
                    if lo >= hi {
                        return Err(format!(
                            "'shard.{name}' must be a non-empty half-open range \
                             (got [{lo}, {hi}))"
                        ));
                    }
                }
            }
            if (sh.grid.is_some() || sh.servers.is_some())
                && (self.task != Task::Sweep || self.models.len() != 1)
            {
                return Err("'shard.grid'/'shard.servers' slices only apply to a \
                            single-model sweep shard"
                    .into());
            }
        }
        Ok(())
    }
}

fn validate_serve(s: &ServeSpec) -> Result<(), String> {
    let t = &s.traffic;
    if t.requests == 0 {
        return Err("'serve.traffic.requests' must be >= 1".into());
    }
    if t.new_tokens_lo == 0 {
        return Err("'serve.traffic.new_tokens_lo' must be >= 1".into());
    }
    if t.new_tokens_lo > t.new_tokens_hi {
        return Err(format!(
            "'serve.traffic.new_tokens_lo' ({}) exceeds 'new_tokens_hi' ({})",
            t.new_tokens_lo, t.new_tokens_hi
        ));
    }
    match t.arrival {
        ArrivalProcess::Poisson { rps } | ArrivalProcess::Bursty { rps, .. } => {
            if !rps.is_finite() || rps < 0.0 {
                return Err(format!(
                    "'serve.traffic.arrival.rps' must be finite and >= 0 \
                     (0 = resolve from 'load' × design capacity; got {rps})"
                ));
            }
        }
        ArrivalProcess::ClosedLoop { clients, think_s } => {
            if clients == 0 {
                return Err("'serve.traffic.arrival.clients' must be >= 1".into());
            }
            if !think_s.is_finite() || think_s < 0.0 {
                return Err(format!(
                    "'serve.traffic.arrival.think_s' must be finite and >= 0 (got {think_s})"
                ));
            }
        }
    }
    if let ArrivalProcess::Bursty { burst, .. } = t.arrival {
        if burst == 0 {
            return Err("'serve.traffic.arrival.burst' must be >= 1".into());
        }
    }
    if let TokenDist::Pareto { alpha } = t.new_tokens_dist {
        if !alpha.is_finite() || alpha <= 0.0 {
            return Err(format!(
                "'serve.traffic.new_tokens_dist.alpha' must be finite and > 0 (got {alpha})"
            ));
        }
    }
    if let Some(tiers) = &t.tiers {
        if !(0.0..=1.0).contains(&tiers.interactive_share) || tiers.interactive_share.is_nan() {
            return Err(format!(
                "'serve.traffic.tiers.interactive_share' must be in [0, 1] (got {})",
                tiers.interactive_share
            ));
        }
        if tiers.interactive_new_tokens_lo == 0 {
            return Err("'serve.traffic.tiers.interactive_new_tokens_lo' must be >= 1".into());
        }
        if tiers.interactive_new_tokens_lo > tiers.interactive_new_tokens_hi {
            return Err(format!(
                "'serve.traffic.tiers.interactive_new_tokens_lo' ({}) exceeds \
                 'interactive_new_tokens_hi' ({})",
                tiers.interactive_new_tokens_lo, tiers.interactive_new_tokens_hi
            ));
        }
        for (name, v) in [
            ("interactive_slo.ttft_p99_s", tiers.interactive_slo.ttft_p99_s),
            ("interactive_slo.tpot_p99_s", tiers.interactive_slo.tpot_p99_s),
            ("batch_slo.ttft_p99_s", tiers.batch_slo.ttft_p99_s),
            ("batch_slo.tpot_p99_s", tiers.batch_slo.tpot_p99_s),
        ] {
            if v.is_nan() || v <= 0.0 {
                return Err(format!(
                    "'serve.traffic.tiers.{name}' must be positive \
                     (null = unconstrained; got {v})"
                ));
            }
        }
        if s.trace_file.is_some() {
            return Err("'serve.traffic.tiers' needs synthetic arrivals (a CSV trace \
                        carries no tier tags); drop 'serve.trace_file'"
                .into());
        }
    }
    if let Some(oc) = &s.overcommit {
        if !s.paged_kv {
            return Err("'serve.overcommit' needs block-granular accounting; set \
                        'serve.paged_kv' to true"
                .into());
        }
        if let ResidencyEstimate::Quantile(q) = oc.estimate {
            if !q.is_finite() || q <= 0.0 || q >= 1.0 {
                return Err(format!(
                    "'serve.overcommit.quantile' must be in (0, 1) (got {q})"
                ));
            }
        }
    }
    // cc-lint: allow(no-float-eq) 0.0 is the exact spec-default sentinel the codec writes for an absent window; no arithmetic ever produces it
    if s.goodput_window_s != 0.0 && !(s.goodput_window_s > 0.0 && s.goodput_window_s.is_finite())
    {
        return Err(format!(
            "'serve.goodput_window_s' must be a finite positive number of seconds \
             (null/0 = no windowed rows; got {})",
            s.goodput_window_s
        ));
    }
    for (name, v) in [("ttft_p99_s", s.slo.ttft_p99_s), ("tpot_p99_s", s.slo.tpot_p99_s)] {
        if v.is_nan() || v <= 0.0 {
            return Err(format!(
                "'serve.slo.{name}' must be positive (null = unconstrained; got {v})"
            ));
        }
    }
    if s.replicas == 0 {
        return Err("'serve.replicas' must be >= 1".into());
    }
    // cc-lint: allow(no-float-eq) 0.0 is the exact spec-default sentinel the codec writes for an absent quantum; no arithmetic ever produces it
    if s.quantum != 0.0 && !(s.quantum > 0.0 && s.quantum.is_finite()) {
        return Err(format!(
            "'serve.quantum' must be a finite positive number of seconds \
             (null/0 = exact decode replay; got {})",
            s.quantum
        ));
    }
    if let Some(p) = &s.trace_file {
        if p.is_empty() {
            return Err("'serve.trace_file' must be a non-empty path".into());
        }
        // The trace fixes the arrival times itself; any synthetic arrival
        // shape alongside it would silently be ignored, so reject all but
        // the rate-unset default.
        if s.traffic.arrival != (ArrivalProcess::Poisson { rps: 0.0 }) {
            return Err("'serve.trace_file' replaces synthetic arrivals; drop \
                        'serve.traffic.arrival' (and any --trace/--rps flags)"
                .into());
        }
    }
    validate_faults(s)?;
    Ok(())
}

fn validate_faults(s: &ServeSpec) -> Result<(), String> {
    let f = &s.faults;
    if !f.mtbf_s.is_finite() || f.mtbf_s < 0.0 {
        return Err(format!(
            "'serve.faults.mtbf_s' must be finite and >= 0 (0 = no stochastic \
             failures; got {})",
            f.mtbf_s
        ));
    }
    if f.mtbf_s > 0.0 && !(f.mttr_s.is_finite() && f.mttr_s > 0.0) {
        return Err(format!(
            "'serve.faults.mttr_s' must be positive and finite when mtbf_s > 0 \
             (got {})",
            f.mttr_s
        ));
    }
    for e in &f.plan {
        if e.replica >= s.replicas.max(1) {
            return Err(format!(
                "'serve.faults.plan' names replica {} but the spec serves {} \
                 replica(s)",
                e.replica,
                s.replicas.max(1)
            ));
        }
    }
    if f.availability < 0.0 || f.availability > 1.0 || f.availability.is_nan() {
        return Err(format!(
            "'serve.faults.availability' must be in [0, 1] (0 = no redundancy \
             sizing; got {})",
            f.availability
        ));
    }
    if f.availability > 0.0 && f.is_none() {
        return Err("'serve.faults.availability' sizes redundancy *under faults*; \
                    give mtbf_s/mttr_s or a scripted plan (or drop the target)"
            .into());
    }
    if !f.is_none() {
        if let ArrivalProcess::ClosedLoop { .. } = s.traffic.arrival {
            return Err("'serve.faults' needs an open-loop arrival process \
                        (poisson/bursty or a trace file) — closed-loop clients \
                        are partitioned per replica and cannot fail over"
                .into());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// JSON helpers: strict object access with located, actionable errors.

fn as_obj<'a>(v: &'a Json, path: &str) -> Result<&'a BTreeMap<String, Json>, String> {
    match v {
        Json::Obj(m) => Ok(m),
        _ => Err(format!("{path}: expected a JSON object")),
    }
}

/// Reject keys outside `allowed` — the "unknown fields rejected" contract.
fn check_fields(m: &BTreeMap<String, Json>, path: &str, allowed: &[&str]) -> Result<(), String> {
    for key in m.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(format!(
                "unknown field '{key}' in {path} (expected one of: {})",
                allowed.join(", ")
            ));
        }
    }
    Ok(())
}

fn get_str(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<Option<String>, String> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(_) => Err(format!("field '{key}' in {path}: expected a string")),
    }
}

fn get_f64(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<Option<f64>, String> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Num(x)) => Ok(Some(*x)),
        Some(_) => Err(format!("field '{key}' in {path}: expected a number")),
    }
}

fn get_usize(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<Option<usize>, String> {
    match m.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| format!("field '{key}' in {path}: expected a non-negative integer")),
    }
}

fn get_bool(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<Option<bool>, String> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(format!("field '{key}' in {path}: expected true or false")),
    }
}

/// SLO target: number, or null/absent = unconstrained (JSON has no ∞).
fn get_slo_target(m: &BTreeMap<String, Json>, path: &str, key: &str) -> Result<f64, String> {
    match m.get(key) {
        None | Some(Json::Null) => Ok(f64::INFINITY),
        Some(Json::Num(x)) => Ok(*x),
        Some(_) => Err(format!(
            "field '{key}' in {path}: expected a number of seconds or null (unconstrained)"
        )),
    }
}

fn workload_from_json(v: &Json) -> Result<WorkloadPoint, String> {
    let m = as_obj(v, "workload")?;
    check_fields(m, "workload", &["ctx", "batch"])?;
    Ok(WorkloadPoint {
        ctx: get_usize(m, "workload", "ctx")?
            .ok_or("workload is missing the required field 'ctx'")?,
        batch: get_usize(m, "workload", "batch")?
            .ok_or("workload is missing the required field 'batch'")?,
    })
}

fn workload_to_json(w: &WorkloadPoint) -> Json {
    let mut m = BTreeMap::new();
    m.insert("ctx".into(), Json::Num(w.ctx as f64));
    m.insert("batch".into(), Json::Num(w.batch as f64));
    Json::Obj(m)
}

fn arrival_from_json(v: &Json) -> Result<ArrivalProcess, String> {
    let m = as_obj(v, "serve.traffic.arrival")?;
    let kind = get_str(m, "serve.traffic.arrival", "kind")?
        .ok_or("serve.traffic.arrival is missing the required field 'kind'")?;
    let path = "serve.traffic.arrival";
    match kind.as_str() {
        "poisson" => {
            check_fields(m, path, &["kind", "rps"])?;
            Ok(ArrivalProcess::Poisson { rps: get_f64(m, path, "rps")?.unwrap_or(0.0) })
        }
        "bursty" => {
            check_fields(m, path, &["kind", "rps", "burst"])?;
            Ok(ArrivalProcess::Bursty {
                rps: get_f64(m, path, "rps")?.unwrap_or(0.0),
                burst: get_usize(m, path, "burst")?.unwrap_or(defaults::BURST),
            })
        }
        "closed" => {
            check_fields(m, path, &["kind", "clients", "think_s"])?;
            Ok(ArrivalProcess::ClosedLoop {
                clients: get_usize(m, path, "clients")?.unwrap_or(defaults::CLIENTS),
                think_s: get_f64(m, path, "think_s")?.unwrap_or(0.0),
            })
        }
        other => Err(format!(
            "field 'kind' in {path}: unknown arrival kind '{other}' \
             (expected poisson, bursty or closed)"
        )),
    }
}

fn arrival_to_json(a: &ArrivalProcess) -> Json {
    let mut m = BTreeMap::new();
    match a {
        ArrivalProcess::Poisson { rps } => {
            m.insert("kind".into(), Json::Str("poisson".into()));
            m.insert("rps".into(), Json::Num(*rps));
        }
        ArrivalProcess::Bursty { rps, burst } => {
            m.insert("kind".into(), Json::Str("bursty".into()));
            m.insert("rps".into(), Json::Num(*rps));
            m.insert("burst".into(), Json::Num(*burst as f64));
        }
        ArrivalProcess::ClosedLoop { clients, think_s } => {
            m.insert("kind".into(), Json::Str("closed".into()));
            m.insert("clients".into(), Json::Num(*clients as f64));
            m.insert("think_s".into(), Json::Num(*think_s));
        }
    }
    Json::Obj(m)
}

fn token_dist_from_json(v: &Json) -> Result<TokenDist, String> {
    let path = "serve.traffic.new_tokens_dist";
    let m = as_obj(v, path)?;
    let kind = get_str(m, path, "kind")?
        .ok_or(format!("{path} is missing the required field 'kind'"))?;
    match kind.as_str() {
        "uniform" => {
            check_fields(m, path, &["kind"])?;
            Ok(TokenDist::Uniform)
        }
        "pareto" => {
            check_fields(m, path, &["kind", "alpha"])?;
            let alpha = get_f64(m, path, "alpha")?
                .ok_or(format!("{path} with kind 'pareto' needs the field 'alpha'"))?;
            Ok(TokenDist::Pareto { alpha })
        }
        other => Err(format!(
            "field 'kind' in {path}: unknown distribution '{other}' \
             (expected uniform or pareto)"
        )),
    }
}

fn token_dist_to_json(d: &TokenDist) -> Json {
    let mut m = BTreeMap::new();
    match d {
        TokenDist::Uniform => {
            m.insert("kind".into(), Json::Str("uniform".into()));
        }
        TokenDist::Pareto { alpha } => {
            m.insert("kind".into(), Json::Str("pareto".into()));
            m.insert("alpha".into(), Json::Num(*alpha));
        }
    }
    Json::Obj(m)
}

fn tiers_from_json(v: &Json) -> Result<TierSpec, String> {
    let path = "serve.traffic.tiers";
    let m = as_obj(v, path)?;
    check_fields(
        m,
        path,
        &[
            "interactive_share",
            "interactive_new_tokens_lo",
            "interactive_new_tokens_hi",
            "interactive_slo",
            "batch_slo",
            "max_consecutive_interactive",
        ],
    )?;
    let slo_of = |key: &str| -> Result<SloSpec, String> {
        match m.get(key) {
            None | Some(Json::Null) => Ok(SloSpec::unconstrained()),
            Some(v) => {
                let sm = as_obj(v, path)?;
                let p = format!("{path}.{key}");
                check_fields(sm, &p, &["ttft_p99_s", "tpot_p99_s"])?;
                Ok(SloSpec {
                    ttft_p99_s: get_slo_target(sm, &p, "ttft_p99_s")?,
                    tpot_p99_s: get_slo_target(sm, &p, "tpot_p99_s")?,
                })
            }
        }
    };
    let share = get_f64(m, path, "interactive_share")?
        .ok_or(format!("{path} is missing the required field 'interactive_share'"))?;
    Ok(TierSpec {
        interactive_share: share,
        interactive_new_tokens_lo: get_usize(m, path, "interactive_new_tokens_lo")?
            .unwrap_or(defaults::NEW_TOKENS_LO),
        interactive_new_tokens_hi: get_usize(m, path, "interactive_new_tokens_hi")?
            .unwrap_or(defaults::NEW_TOKENS_HI),
        interactive_slo: slo_of("interactive_slo")?,
        batch_slo: slo_of("batch_slo")?,
        max_consecutive_interactive: get_usize(m, path, "max_consecutive_interactive")?
            .unwrap_or(8),
    })
}

fn tiers_to_json(t: &TierSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("interactive_share".into(), Json::Num(t.interactive_share));
    m.insert("interactive_new_tokens_lo".into(), Json::Num(t.interactive_new_tokens_lo as f64));
    m.insert("interactive_new_tokens_hi".into(), Json::Num(t.interactive_new_tokens_hi as f64));
    m.insert("interactive_slo".into(), slo_to_json(&t.interactive_slo));
    m.insert("batch_slo".into(), slo_to_json(&t.batch_slo));
    m.insert(
        "max_consecutive_interactive".into(),
        Json::Num(t.max_consecutive_interactive as f64),
    );
    Json::Obj(m)
}

fn traffic_from_json(v: &Json) -> Result<TrafficSpec, String> {
    let m = as_obj(v, "serve.traffic")?;
    let path = "serve.traffic";
    check_fields(
        m,
        path,
        &[
            "arrival",
            "requests",
            "prompt_tokens",
            "new_tokens_lo",
            "new_tokens_hi",
            "new_tokens_dist",
            "tiers",
            "seed",
        ],
    )?;
    let arrival = match m.get("arrival") {
        None => return Err("serve.traffic is missing the required field 'arrival'".into()),
        Some(v) => arrival_from_json(v)?,
    };
    let new_tokens_dist = match m.get("new_tokens_dist") {
        None | Some(Json::Null) => TokenDist::Uniform,
        Some(v) => token_dist_from_json(v)?,
    };
    let tiers = match m.get("tiers") {
        None | Some(Json::Null) => None,
        Some(v) => Some(tiers_from_json(v)?),
    };
    Ok(TrafficSpec {
        arrival,
        requests: get_usize(m, path, "requests")?.unwrap_or(defaults::REQUESTS),
        prompt_tokens: get_usize(m, path, "prompt_tokens")?.unwrap_or(defaults::PROMPT_TOKENS),
        new_tokens_lo: get_usize(m, path, "new_tokens_lo")?.unwrap_or(defaults::NEW_TOKENS_LO),
        new_tokens_hi: get_usize(m, path, "new_tokens_hi")?.unwrap_or(defaults::NEW_TOKENS_HI),
        new_tokens_dist,
        tiers,
        seed: get_usize(m, path, "seed")?.unwrap_or(defaults::SEED as usize) as u64,
    })
}

fn traffic_to_json(t: &TrafficSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("arrival".into(), arrival_to_json(&t.arrival));
    m.insert("requests".into(), Json::Num(t.requests as f64));
    m.insert("prompt_tokens".into(), Json::Num(t.prompt_tokens as f64));
    m.insert("new_tokens_lo".into(), Json::Num(t.new_tokens_lo as f64));
    m.insert("new_tokens_hi".into(), Json::Num(t.new_tokens_hi as f64));
    // Defaults stay un-emitted so pre-tier specs (and their fingerprints)
    // round-trip byte-identically (absent ↔ Uniform / None).
    if t.new_tokens_dist != TokenDist::Uniform {
        m.insert("new_tokens_dist".into(), token_dist_to_json(&t.new_tokens_dist));
    }
    if let Some(tiers) = &t.tiers {
        m.insert("tiers".into(), tiers_to_json(tiers));
    }
    m.insert("seed".into(), Json::Num(t.seed as f64));
    Json::Obj(m)
}

fn slo_from_json(v: &Json) -> Result<SloSpec, String> {
    let m = as_obj(v, "serve.slo")?;
    check_fields(m, "serve.slo", &["ttft_p99_s", "tpot_p99_s"])?;
    Ok(SloSpec {
        ttft_p99_s: get_slo_target(m, "serve.slo", "ttft_p99_s")?,
        tpot_p99_s: get_slo_target(m, "serve.slo", "tpot_p99_s")?,
    })
}

fn slo_to_json(s: &SloSpec) -> Json {
    let target = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    let mut m = BTreeMap::new();
    m.insert("ttft_p99_s".into(), target(s.ttft_p99_s));
    m.insert("tpot_p99_s".into(), target(s.tpot_p99_s));
    Json::Obj(m)
}

fn serve_from_json(v: &Json) -> Result<ServeSpec, String> {
    let m = as_obj(v, "serve")?;
    let path = "serve";
    check_fields(
        m,
        path,
        &[
            "traffic",
            "slo",
            "prefill_chunk",
            "paged_kv",
            "replicas",
            "route",
            "quantum",
            "trace_file",
            "faults",
            "overcommit",
            "goodput_window_s",
        ],
    )?;
    let traffic = match m.get("traffic") {
        None => return Err("serve is missing the required field 'traffic'".into()),
        Some(v) => traffic_from_json(v)?,
    };
    let slo = match m.get("slo") {
        None | Some(Json::Null) => SloSpec::unconstrained(),
        Some(v) => slo_from_json(v)?,
    };
    let route = match get_str(m, path, "route")? {
        None => RoutePolicy::RoundRobin,
        Some(s) => RoutePolicy::parse(&s).ok_or_else(|| {
            format!("field 'route' in serve: unknown policy '{s}' (expected rr, jsq or jsq-tokens)")
        })?,
    };
    // Quantum: number of seconds, or null/absent = exact (fast-forward)
    // decode replay.
    let quantum = match m.get("quantum") {
        None | Some(Json::Null) => 0.0,
        Some(Json::Num(x)) => *x,
        Some(_) => {
            return Err(
                "field 'quantum' in serve: expected a number of seconds or null (exact)".into()
            )
        }
    };
    let trace_file = match m.get("trace_file") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(_) => {
            return Err(
                "field 'trace_file' in serve: expected a path string or null (synthetic)".into()
            )
        }
    };
    let faults = match m.get("faults") {
        None | Some(Json::Null) => FaultSpec::none(),
        Some(v) => faults_from_json(v)?,
    };
    let overcommit = match m.get("overcommit") {
        None | Some(Json::Null) => None,
        Some(v) => Some(overcommit_from_json(v)?),
    };
    let goodput_window_s = match m.get("goodput_window_s") {
        None | Some(Json::Null) => 0.0,
        Some(Json::Num(x)) => *x,
        Some(_) => {
            return Err("field 'goodput_window_s' in serve: expected a number of \
                        seconds or null (no windowed rows)"
                .into())
        }
    };
    Ok(ServeSpec {
        traffic,
        slo,
        prefill_chunk: get_usize(m, path, "prefill_chunk")?.unwrap_or(0),
        paged_kv: get_bool(m, path, "paged_kv")?.unwrap_or(false),
        replicas: get_usize(m, path, "replicas")?.unwrap_or(1),
        route,
        quantum,
        trace_file,
        faults,
        overcommit,
        goodput_window_s,
    })
}

fn overcommit_from_json(v: &Json) -> Result<OvercommitSpec, String> {
    let path = "serve.overcommit";
    let m = as_obj(v, path)?;
    check_fields(m, path, &["estimate", "quantile"])?;
    let estimate = get_str(m, path, "estimate")?
        .ok_or(format!("{path} is missing the required field 'estimate'"))?;
    match estimate.as_str() {
        "quantile" => {
            let q = get_f64(m, path, "quantile")?.unwrap_or(0.5);
            Ok(OvercommitSpec::quantile(q))
        }
        "mean" => {
            if m.contains_key("quantile") {
                return Err(format!(
                    "field 'quantile' in {path}: only valid with estimate 'quantile'"
                ));
            }
            Ok(OvercommitSpec::running_mean())
        }
        other => Err(format!(
            "field 'estimate' in {path}: unknown estimator '{other}' \
             (expected quantile or mean)"
        )),
    }
}

fn overcommit_to_json(o: &OvercommitSpec) -> Json {
    let mut m = BTreeMap::new();
    match o.estimate {
        ResidencyEstimate::Quantile(q) => {
            m.insert("estimate".into(), Json::Str("quantile".into()));
            m.insert("quantile".into(), Json::Num(q));
        }
        ResidencyEstimate::RunningMean => {
            m.insert("estimate".into(), Json::Str("mean".into()));
        }
    }
    Json::Obj(m)
}

fn faults_from_json(v: &Json) -> Result<FaultSpec, String> {
    let m = as_obj(v, "serve.faults")?;
    let path = "serve.faults";
    check_fields(
        m,
        path,
        &["mtbf_s", "mttr_s", "seed", "plan", "max_redispatch", "availability", "max_spares"],
    )?;
    let plan = match m.get("plan") {
        None | Some(Json::Null) => Vec::new(),
        Some(Json::Str(s)) => FaultSpec::parse_plan(s)
            .map_err(|e| format!("field 'plan' in {path}: {e}"))?,
        Some(_) => {
            return Err(format!(
                "field 'plan' in {path}: expected a scripted-plan string \
                 (e.g. \"fail:0@10,recover:0@30\") or null"
            ))
        }
    };
    let defaults = FaultSpec::none();
    Ok(FaultSpec {
        mtbf_s: get_f64(m, path, "mtbf_s")?.unwrap_or(0.0),
        mttr_s: get_f64(m, path, "mttr_s")?.unwrap_or(0.0),
        seed: get_usize(m, path, "seed")?.unwrap_or(0) as u64,
        plan,
        max_redispatch: get_usize(m, path, "max_redispatch")?.unwrap_or(defaults.max_redispatch),
        availability: get_f64(m, path, "availability")?.unwrap_or(0.0),
        max_spares: get_usize(m, path, "max_spares")?.unwrap_or(defaults.max_spares),
    })
}

pub(crate) fn faults_to_json(f: &FaultSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("mtbf_s".into(), Json::Num(f.mtbf_s));
    m.insert("mttr_s".into(), Json::Num(f.mttr_s));
    m.insert("seed".into(), Json::Num(f.seed as f64));
    if !f.plan.is_empty() {
        m.insert("plan".into(), Json::Str(f.plan_string()));
    }
    m.insert("max_redispatch".into(), Json::Num(f.max_redispatch as f64));
    m.insert("availability".into(), Json::Num(f.availability));
    m.insert("max_spares".into(), Json::Num(f.max_spares as f64));
    Json::Obj(m)
}

fn serve_to_json(s: &ServeSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("traffic".into(), traffic_to_json(&s.traffic));
    m.insert("slo".into(), slo_to_json(&s.slo));
    m.insert("prefill_chunk".into(), Json::Num(s.prefill_chunk as f64));
    m.insert("paged_kv".into(), Json::Bool(s.paged_kv));
    m.insert("replicas".into(), Json::Num(s.replicas as f64));
    m.insert("route".into(), Json::Str(s.route.name().into()));
    // Defaults stay un-emitted so pre-quantum specs round-trip byte-
    // identically (absent ↔ 0.0 / None above).
    // cc-lint: allow(no-float-eq) exact round-trip of the codec's own 0.0 absent-field sentinel, mirroring the validate() check
    if s.quantum != 0.0 {
        m.insert("quantum".into(), Json::Num(s.quantum));
    }
    if let Some(p) = &s.trace_file {
        m.insert("trace_file".into(), Json::Str(p.clone()));
    }
    // Absent ↔ the full default (not just "inert"): a tweaked-but-inert
    // spec still emits, so `from_json(to_json(e)) == e` holds exactly.
    if s.faults != FaultSpec::none() {
        m.insert("faults".into(), faults_to_json(&s.faults));
    }
    if let Some(oc) = &s.overcommit {
        m.insert("overcommit".into(), overcommit_to_json(oc));
    }
    // cc-lint: allow(no-float-eq) exact round-trip of the codec's own 0.0 absent-field sentinel, mirroring the validate() check
    if s.goodput_window_s != 0.0 {
        m.insert("goodput_window_s".into(), Json::Num(s.goodput_window_s));
    }
    Json::Obj(m)
}

fn engine_from_json(v: &Json) -> Result<EngineKnobs, String> {
    let m = as_obj(v, "engine")?;
    check_fields(m, "engine", &["threads", "seq"])?;
    Ok(EngineKnobs {
        threads: get_usize(m, "engine", "threads")?.unwrap_or(0),
        seq: get_bool(m, "engine", "seq")?.unwrap_or(false),
    })
}

fn engine_to_json(e: &EngineKnobs) -> Json {
    let mut m = BTreeMap::new();
    m.insert("threads".into(), Json::Num(e.threads as f64));
    m.insert("seq".into(), Json::Bool(e.seq));
    Json::Obj(m)
}

/// Half-open `[lo, hi)` index range: a 2-element integer array, or
/// null/absent = the whole axis.
fn get_range(
    m: &BTreeMap<String, Json>,
    path: &str,
    key: &str,
) -> Result<Option<(usize, usize)>, String> {
    match m.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Arr(xs)) if xs.len() == 2 => {
            let lo = xs[0].as_usize();
            let hi = xs[1].as_usize();
            match (lo, hi) {
                (Some(lo), Some(hi)) => Ok(Some((lo, hi))),
                _ => Err(format!(
                    "field '{key}' in {path}: expected two non-negative integers [lo, hi)"
                )),
            }
        }
        Some(_) => Err(format!(
            "field '{key}' in {path}: expected a [lo, hi) integer pair or null (whole axis)"
        )),
    }
}

fn shard_from_json(v: &Json) -> Result<ShardSel, String> {
    let m = as_obj(v, "shard")?;
    let path = "shard";
    check_fields(m, path, &["index", "of", "parent", "parent_models", "grid", "servers"])?;
    Ok(ShardSel {
        index: get_usize(m, path, "index")?
            .ok_or("shard is missing the required field 'index'")?,
        of: get_usize(m, path, "of")?.ok_or("shard is missing the required field 'of'")?,
        parent: get_str(m, path, "parent")?
            .ok_or("shard is missing the required field 'parent'")?,
        parent_models: get_usize(m, path, "parent_models")?.unwrap_or(1),
        grid: get_range(m, path, "grid")?,
        servers: get_range(m, path, "servers")?,
    })
}

fn shard_to_json(s: &ShardSel) -> Json {
    let range = |r: Option<(usize, usize)>| match r {
        None => Json::Null,
        Some((lo, hi)) => Json::Arr(vec![Json::Num(lo as f64), Json::Num(hi as f64)]),
    };
    let mut m = BTreeMap::new();
    m.insert("index".into(), Json::Num(s.index as f64));
    m.insert("of".into(), Json::Num(s.of as f64));
    m.insert("parent".into(), Json::Str(s.parent.clone()));
    m.insert("parent_models".into(), Json::Num(s.parent_models as f64));
    m.insert("grid".into(), range(s.grid));
    m.insert("servers".into(), range(s.servers));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> Experiment {
        Experiment {
            name: "sweep-gpt3".into(),
            task: Task::Sweep,
            models: vec!["gpt3".into()],
            space: SpaceSpec::Coarse,
            workload: None,
            serve: None,
            load: 0.8,
            engine: EngineKnobs::default(),
            shard: None,
        }
    }

    #[test]
    fn minimal_spec_parses_with_defaults() {
        let e = Experiment::from_json_str(r#"{"task": "sweep", "models": ["gpt3"]}"#).unwrap();
        assert_eq!(e, minimal());
        e.validate().unwrap();
    }

    #[test]
    fn round_trip_is_identity() {
        let mut e = minimal();
        assert_eq!(Experiment::from_json_str(&e.to_json_string()).unwrap(), e);
        e.serve = Some(
            ServeSpec::new(TrafficSpec::poisson(12.5, 100, 64, 8, 32), SloSpec::new(0.5, 0.02))
                .with_chunked_prefill(16)
                .with_paged_kv()
                .with_replicas(3, RoutePolicy::JsqTokens),
        );
        assert_eq!(Experiment::from_json_str(&e.to_json_string()).unwrap(), e);
    }

    #[test]
    fn unconstrained_slo_round_trips_through_null() {
        let mut e = minimal();
        e.task = Task::ServeSim;
        e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
        e.serve =
            Some(ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::unconstrained()));
        let s = e.to_json_string();
        assert!(s.contains("\"ttft_p99_s\":null"), "{s}");
        let back = Experiment::from_json_str(&s).unwrap();
        assert_eq!(back, e);
        assert!(back.serve.unwrap().slo.is_unconstrained());
    }

    #[test]
    fn quantum_and_trace_file_round_trip_and_default_to_absent() {
        // Defaults are never emitted: pre-quantum specs serialize
        // byte-identically to before the fields existed.
        let mut e = minimal();
        e.task = Task::ServeSim;
        e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
        e.serve =
            Some(ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::unconstrained()));
        let s = e.to_json_string();
        assert!(!s.contains("quantum") && !s.contains("trace_file"), "{s}");
        assert_eq!(Experiment::from_json_str(&s).unwrap(), e);

        // Set values round-trip, and explicit nulls parse as the defaults.
        let spec = ServeSpec::new(TrafficSpec::poisson(0.0, 10, 8, 4, 8), SloSpec::unconstrained())
            .with_quantum(0.25)
            .with_trace_file("trace.csv");
        e.serve = Some(spec.clone());
        let s = e.to_json_string();
        assert!(s.contains("\"quantum\":0.25"), "{s}");
        assert!(s.contains("\"trace_file\":\"trace.csv\""), "{s}");
        let back = Experiment::from_json_str(&s).unwrap();
        assert_eq!(back, e);
        let nulled = s
            .replace("\"quantum\":0.25", "\"quantum\":null")
            .replace("\"trace_file\":\"trace.csv\"", "\"trace_file\":null");
        let back = Experiment::from_json_str(&nulled).unwrap().serve.unwrap();
        assert_eq!(back.quantum, 0.0);
        assert_eq!(back.trace_file, None);

        // Wrong types are actionable.
        let bad = s.replace("\"quantum\":0.25", "\"quantum\":\"fast\"");
        let err = Experiment::from_json_str(&bad).unwrap_err();
        assert!(err.contains("'quantum'") && err.contains("number of seconds"), "{err}");
        let bad = s.replace("\"trace_file\":\"trace.csv\"", "\"trace_file\":7");
        let err = Experiment::from_json_str(&bad).unwrap_err();
        assert!(err.contains("'trace_file'") && err.contains("path string"), "{err}");
    }

    #[test]
    fn validation_enforces_quantum_and_trace_file_rules() {
        let serve_sim = |spec: ServeSpec| {
            let mut e = minimal();
            e.task = Task::ServeSim;
            e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
            e.serve = Some(spec);
            e.validate()
        };
        let base =
            || ServeSpec::new(TrafficSpec::poisson(0.0, 10, 8, 4, 8), SloSpec::unconstrained());
        serve_sim(base().with_quantum(0.1)).unwrap();
        serve_sim(base().with_trace_file("trace.csv")).unwrap();
        for q in [-1.0, f64::NAN, f64::INFINITY] {
            let err = serve_sim(base().with_quantum(q)).unwrap_err();
            assert!(err.contains("serve.quantum"), "{err}");
        }
        let err = serve_sim(base().with_trace_file("")).unwrap_err();
        assert!(err.contains("non-empty path"), "{err}");
        // A trace fixes arrivals; any synthetic arrival shape is rejected.
        let bursty = TrafficSpec {
            arrival: ArrivalProcess::Bursty { rps: 1.0, burst: 4 },
            ..TrafficSpec::poisson(0.0, 10, 8, 4, 8)
        };
        for t in [
            TrafficSpec::poisson(2.0, 10, 8, 4, 8),
            bursty,
            TrafficSpec::closed_loop(4, 0.1, 10, 8, 4, 8),
        ] {
            let err = serve_sim(
                ServeSpec::new(t, SloSpec::unconstrained()).with_trace_file("trace.csv"),
            )
            .unwrap_err();
            assert!(err.contains("replaces synthetic arrivals"), "{err}");
        }
    }

    #[test]
    fn overcommit_tiers_and_windows_round_trip_and_default_to_absent() {
        use crate::config::workload::{OvercommitSpec, TierSpec, TokenDist};
        // Feature-off specs serialize byte-identically to pre-PR specs:
        // none of the new fields appear, and fingerprints are unmoved.
        let mut e = minimal();
        e.task = Task::ServeSim;
        e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
        e.serve =
            Some(ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::unconstrained()));
        let s = e.to_json_string();
        for field in ["overcommit", "goodput_window_s", "new_tokens_dist", "tiers"] {
            assert!(!s.contains(field), "{field} leaked into {s}");
        }
        assert_eq!(Experiment::from_json_str(&s).unwrap(), e);

        // The full feature surface round-trips exactly.
        let tiers = TierSpec::new(0.7, 4, 16, SloSpec::new(0.5, 0.05), SloSpec::unconstrained())
            .with_fairness(4);
        let traffic = TrafficSpec::poisson(1.0, 10, 8, 4, 256)
            .with_token_dist(TokenDist::Pareto { alpha: 1.25 })
            .with_tiers(tiers);
        e.serve = Some(
            ServeSpec::new(traffic, SloSpec::new(0.5, 0.05))
                .with_paged_kv()
                .with_overcommit(OvercommitSpec::quantile(0.6))
                .with_goodput_window(30.0),
        );
        let s = e.to_json_string();
        assert!(s.contains("\"new_tokens_dist\":{\"alpha\":1.25,\"kind\":\"pareto\"}"), "{s}");
        assert!(s.contains("\"overcommit\":{\"estimate\":\"quantile\",\"quantile\":0.6}"), "{s}");
        assert!(s.contains("\"goodput_window_s\":30"), "{s}");
        assert!(s.contains("\"interactive_share\":0.7"), "{s}");
        assert!(s.contains("\"max_consecutive_interactive\":4"), "{s}");
        let back = Experiment::from_json_str(&s).unwrap();
        assert_eq!(back, e);
        back.validate().unwrap();

        // The running-mean estimator round-trips without a quantile field.
        e.serve.as_mut().unwrap().overcommit = Some(OvercommitSpec::running_mean());
        let s = e.to_json_string();
        assert!(s.contains("\"overcommit\":{\"estimate\":\"mean\"}"), "{s}");
        assert_eq!(Experiment::from_json_str(&s).unwrap(), e);

        // Explicit nulls parse as the defaults.
        let nulled = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"},
                                    "new_tokens_dist":null,"tiers":null},
                         "overcommit":null,"goodput_window_s":null}}"#,
        )
        .unwrap();
        let sv = nulled.serve.unwrap();
        assert_eq!(sv.overcommit, None);
        assert_eq!(sv.traffic.new_tokens_dist, TokenDist::Uniform);
        assert!(sv.traffic.tiers.is_none());
        // cc-lint: allow(no-float-eq) 0.0 is the exact codec default under test
        assert!(sv.goodput_window_s == 0.0);

        // Unknown fields inside the new objects are located errors.
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"},
                                    "new_tokens_dist":{"kind":"zipf"}}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown distribution 'zipf'"), "{err}");
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"overcommit":{"estimate":"mean","quantile":0.5},
                         "traffic":{"arrival":{"kind":"poisson"}}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("only valid with estimate 'quantile'"), "{err}");
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"},
                                    "tiers":{"interactive_share":0.5,"priority":9}}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown field 'priority'") && err.contains("tiers"), "{err}");
    }

    #[test]
    fn validation_enforces_overcommit_and_tier_rules() {
        use crate::config::workload::{OvercommitSpec, TierSpec, TokenDist};
        let check = |spec: ServeSpec| {
            let mut e = minimal();
            e.task = Task::ServeSim;
            e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
            e.serve = Some(spec);
            e.validate()
        };
        let base =
            || ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::unconstrained());
        // Overcommit needs paged KV.
        let err = check(base().with_overcommit(OvercommitSpec::quantile(0.5))).unwrap_err();
        assert!(err.contains("paged_kv"), "{err}");
        check(base().with_paged_kv().with_overcommit(OvercommitSpec::quantile(0.5))).unwrap();
        check(base().with_paged_kv().with_overcommit(OvercommitSpec::running_mean())).unwrap();
        // Quantile strictly inside (0, 1).
        for q in [0.0, 1.0, -0.5, f64::NAN, f64::INFINITY] {
            let err = check(base().with_paged_kv().with_overcommit(OvercommitSpec::quantile(q)))
                .unwrap_err();
            assert!(err.contains("overcommit.quantile"), "{err}");
        }
        // Pareto shape must be finite and positive.
        let with_dist = |alpha: f64| {
            let mut s = base();
            s.traffic = s.traffic.with_token_dist(TokenDist::Pareto { alpha });
            s
        };
        check(with_dist(1.1)).unwrap();
        for alpha in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = check(with_dist(alpha)).unwrap_err();
            assert!(err.contains("new_tokens_dist.alpha"), "{err}");
        }
        // Tier shares, budgets and SLOs are validated.
        let with_tiers = |t: TierSpec| {
            let mut s = base();
            s.traffic = s.traffic.with_tiers(t);
            s
        };
        let good = TierSpec::new(0.5, 4, 16, SloSpec::new(0.5, 0.05), SloSpec::unconstrained());
        check(with_tiers(good)).unwrap();
        let err = check(with_tiers(TierSpec { interactive_share: 1.5, ..good })).unwrap_err();
        assert!(err.contains("interactive_share"), "{err}");
        let err =
            check(with_tiers(TierSpec { interactive_new_tokens_lo: 0, ..good })).unwrap_err();
        assert!(err.contains("interactive_new_tokens_lo"), "{err}");
        let err =
            check(with_tiers(TierSpec { interactive_new_tokens_lo: 99, ..good })).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
        let err = check(with_tiers(TierSpec {
            interactive_slo: SloSpec::new(-1.0, 0.1),
            ..good
        }))
        .unwrap_err();
        assert!(err.contains("interactive_slo.ttft_p99_s"), "{err}");
        // Tiers need synthetic arrivals (no tier tags in a CSV trace).
        let mut s = ServeSpec::new(
            TrafficSpec::poisson(0.0, 10, 8, 4, 8).with_tiers(good),
            SloSpec::unconstrained(),
        )
        .with_trace_file("trace.csv");
        let err = check(s.clone()).unwrap_err();
        assert!(err.contains("no tier tags"), "{err}");
        s.trace_file = None;
        check(s).unwrap();
        // Windows must be finite and non-negative.
        check(base().with_goodput_window(30.0)).unwrap();
        for w in [-1.0, f64::NAN, f64::INFINITY] {
            let err = check(base().with_goodput_window(w)).unwrap_err();
            assert!(err.contains("goodput_window_s"), "{err}");
        }
    }

    #[test]
    fn faults_round_trip_and_default_to_absent() {
        use crate::config::workload::{FaultEvent, FaultSpec};
        // Fault-free specs serialize byte-identically to pre-fault specs.
        let mut e = minimal();
        e.task = Task::ServeSim;
        e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
        e.serve =
            Some(ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::unconstrained()));
        let s = e.to_json_string();
        assert!(!s.contains("faults"), "{s}");
        assert_eq!(Experiment::from_json_str(&s).unwrap(), e);

        // A stochastic spec with a scripted plan round-trips exactly.
        let faults = FaultSpec {
            mtbf_s: 120.0,
            mttr_s: 6.5,
            seed: 9,
            plan: vec![
                FaultEvent { replica: 0, at_s: 10.0, up: false },
                FaultEvent { replica: 0, at_s: 30.5, up: true },
            ],
            max_redispatch: 2,
            availability: 0.995,
            max_spares: 3,
        };
        e.serve = Some(
            ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::new(1.0, 0.1))
                .with_replicas(3, RoutePolicy::Jsq)
                .with_faults(faults),
        );
        let s = e.to_json_string();
        assert!(s.contains("\"plan\":\"fail:0@10,recover:0@30.5\""), "{s}");
        assert_eq!(Experiment::from_json_str(&s).unwrap(), e);
        e.validate().unwrap();
        // Explicit null parses as no faults.
        let nulled = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"}},"faults":null}}"#,
        )
        .unwrap();
        assert!(nulled.serve.unwrap().faults.is_none());
        // Unknown fault fields and bad plan strings are located errors.
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"}},"faults":{"mtbf":5}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown field 'mtbf'") && err.contains("serve.faults"), "{err}");
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"}},"faults":{"plan":"boom:0@1"}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("'plan'") && err.contains("unknown kind"), "{err}");
    }

    #[test]
    fn validation_enforces_fault_rules() {
        use crate::config::workload::{FaultEvent, FaultSpec};
        let check = |spec: ServeSpec| {
            let mut e = minimal();
            e.task = Task::ServeSim;
            e.workload = Some(WorkloadPoint { ctx: 1024, batch: 32 });
            e.serve = Some(spec);
            e.validate()
        };
        let base = || {
            ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::new(1.0, 0.1))
                .with_replicas(3, RoutePolicy::Jsq)
        };
        check(base().with_faults(FaultSpec::mtbf(100.0, 5.0, 1))).unwrap();
        check(base().with_faults(FaultSpec::scripted(
            FaultSpec::parse_plan("fail:2@5,recover:2@9").unwrap(),
        )))
        .unwrap();
        // mtbf without a repair time cannot model recovery.
        let err = check(base().with_faults(FaultSpec::mtbf(100.0, 0.0, 1))).unwrap_err();
        assert!(err.contains("mttr_s"), "{err}");
        let err =
            check(base().with_faults(FaultSpec::mtbf(f64::NAN, 5.0, 1))).unwrap_err();
        assert!(err.contains("mtbf_s"), "{err}");
        // Plan events must name replicas the spec actually serves.
        let err = check(base().with_faults(FaultSpec::scripted(vec![FaultEvent {
            replica: 3,
            at_s: 1.0,
            up: false,
        }])))
        .unwrap_err();
        assert!(err.contains("replica 3"), "{err}");
        // Availability targets need a fault model and live in [0, 1].
        let err = check(
            base().with_faults(FaultSpec::none().with_availability(0.99)),
        )
        .unwrap_err();
        assert!(err.contains("under faults"), "{err}");
        let err = check(
            base().with_faults(FaultSpec::mtbf(100.0, 5.0, 1).with_availability(1.5)),
        )
        .unwrap_err();
        assert!(err.contains("availability"), "{err}");
        // Closed-loop clients cannot fail over.
        let closed = ServeSpec::new(
            TrafficSpec::closed_loop(4, 0.1, 10, 8, 4, 8),
            SloSpec::new(1.0, 0.1),
        )
        .with_replicas(3, RoutePolicy::RoundRobin)
        .with_faults(FaultSpec::mtbf(100.0, 5.0, 1));
        let err = check(closed).unwrap_err();
        assert!(err.contains("open-loop"), "{err}");
    }

    #[test]
    fn unknown_fields_are_rejected_with_location() {
        let err = Experiment::from_json_str(r#"{"task":"sweep","models":["gpt3"],"turbo":1}"#)
            .unwrap_err();
        assert!(err.contains("unknown field 'turbo'") && err.contains("experiment"), "{err}");
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "serve":{"traffic":{"arrival":{"kind":"poisson"},"rsp":3}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown field 'rsp'") && err.contains("serve.traffic"), "{err}");
    }

    #[test]
    fn wrong_types_are_actionable() {
        let err = Experiment::from_json_str(r#"{"task":"sweep","models":"gpt3"}"#).unwrap_err();
        assert!(err.contains("array of model names"), "{err}");
        let err = Experiment::from_json_str(
            r#"{"task":"serve-sim","models":["gpt2"],"workload":{"ctx":"big","batch":4}}"#,
        )
        .unwrap_err();
        assert!(err.contains("'ctx'") && err.contains("integer"), "{err}");
        let err = Experiment::from_json_str(r#"{"task":"explore","models":["gpt3"]}"#).unwrap_err();
        assert!(err.contains("unknown task 'explore'"), "{err}");
    }

    #[test]
    fn validation_enforces_task_shapes() {
        let mut e = minimal();
        e.models = vec!["gpt9".into()];
        assert!(e.validate().unwrap_err().contains("unknown model 'gpt9'"));

        let mut e = minimal();
        e.workload = Some(WorkloadPoint { ctx: 1024, batch: 8 });
        assert!(e.validate().unwrap_err().contains("study grid"));

        let mut e = minimal();
        e.task = Task::ServeSim;
        assert!(e.validate().unwrap_err().contains("workload"));

        let mut e = minimal();
        e.serve =
            Some(ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::unconstrained()));
        assert!(e.validate().unwrap_err().contains("binding SLO"));

        let mut e = minimal();
        e.task = Task::Optimize;
        e.serve =
            Some(ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::new(1.0, 0.1)));
        assert!(e.validate().unwrap_err().contains("optimize"));
    }

    #[test]
    fn shard_round_trips_and_rejects_unknown_fields() {
        let mut e = minimal();
        e.shard = Some(ShardSel {
            index: 2,
            of: 8,
            parent: e.fingerprint(),
            parent_models: 1,
            grid: Some((8, 12)),
            servers: None,
        });
        e.validate().unwrap();
        let s = e.to_json_string();
        assert!(s.contains("\"grid\":[8,12]") && s.contains("\"servers\":null"), "{s}");
        assert_eq!(Experiment::from_json_str(&s).unwrap(), e);
        // Plain specs emit "shard":null and parse back to None.
        let plain = minimal();
        assert!(plain.to_json_string().contains("\"shard\":null"));
        assert_eq!(Experiment::from_json_str(&plain.to_json_string()).unwrap(), plain);
        // Unknown shard fields are rejected with location.
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "shard":{"index":0,"of":1,"parent":"ab","slice":[0,4]}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown field 'slice'") && err.contains("shard"), "{err}");
        // A malformed range is a located error, not a silent whole-axis.
        let err = Experiment::from_json_str(
            r#"{"task":"sweep","models":["gpt3"],
                "shard":{"index":0,"of":1,"parent":"ab","grid":[1]}}"#,
        )
        .unwrap_err();
        assert!(err.contains("'grid'") && err.contains("[lo, hi)"), "{err}");
    }

    #[test]
    fn shard_validation_rules() {
        let fp = minimal().fingerprint();
        let with = |f: &dyn Fn(&mut ShardSel, &mut Experiment)| {
            let mut e = minimal();
            let mut s = ShardSel {
                index: 0,
                of: 2,
                parent: fp.clone(),
                parent_models: 1,
                grid: None,
                servers: None,
            };
            f(&mut s, &mut e);
            e.shard = Some(s);
            e.validate()
        };
        with(&|_, _| {}).unwrap();
        assert!(with(&|s, _| s.of = 0).unwrap_err().contains("shard.of"));
        assert!(with(&|s, _| s.index = 2).unwrap_err().contains("shard.index"));
        assert!(with(&|s, _| s.parent.clear()).unwrap_err().contains("shard.parent"));
        assert!(with(&|s, _| s.grid = Some((4, 4))).unwrap_err().contains("half-open"));
        // Slices are a single-model-sweep concept only.
        assert!(with(&|s, e| {
            s.grid = Some((0, 4));
            e.models = vec!["gpt2".into(), "gpt3".into()];
        })
        .unwrap_err()
        .contains("single-model sweep"));
        assert!(with(&|s, e| {
            s.servers = Some((0, 4));
            e.task = Task::Optimize;
        })
        .unwrap_err()
        .contains("single-model sweep"));
    }

    #[test]
    fn fingerprint_ignores_engine_and_shard_only() {
        let base = minimal();
        let fp = base.fingerprint();
        // Engine knobs and shard markers do not change identity...
        let mut e = base.clone();
        e.engine = EngineKnobs { threads: 7, seq: true };
        e.shard = Some(ShardSel {
            index: 0,
            of: 2,
            parent: fp.clone(),
            parent_models: 1,
            grid: Some((0, 4)),
            servers: None,
        });
        assert_eq!(e.fingerprint(), fp);
        // ...but every scientific field does.
        let mut e = base.clone();
        e.models = vec!["gpt2".into()];
        assert_ne!(e.fingerprint(), fp);
        let mut e = base.clone();
        e.load = 0.9;
        assert_ne!(e.fingerprint(), fp);
        // 16 lowercase hex digits — stable printable form.
        assert_eq!(fp.len(), 16);
        assert!(fp.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn validation_enforces_traffic_sanity() {
        let serve = |t: TrafficSpec| {
            let mut e = minimal();
            e.serve = Some(ServeSpec::new(t, SloSpec::new(1.0, 0.1)));
            e.validate()
        };
        assert!(serve(TrafficSpec::poisson(1.0, 0, 8, 4, 8)).unwrap_err().contains("requests"));
        assert!(serve(TrafficSpec::poisson(1.0, 10, 8, 9, 3))
            .unwrap_err()
            .contains("new_tokens_lo"));
        assert!(serve(TrafficSpec::poisson(f64::NAN, 10, 8, 4, 8)).unwrap_err().contains("rps"));
        let mut e = minimal();
        let mut s = ServeSpec::new(TrafficSpec::poisson(1.0, 10, 8, 4, 8), SloSpec::new(-1.0, 0.1));
        e.serve = Some(s.clone());
        assert!(e.validate().unwrap_err().contains("ttft_p99_s"));
        s.slo = SloSpec::new(1.0, 0.1);
        s.replicas = 0;
        let mut e = minimal();
        e.serve = Some(s);
        assert!(e.validate().unwrap_err().contains("replicas"));
    }
}
