//! Serving workload descriptions used by Phase 2 and the evaluation figures.

use crate::config::models::ModelSpec;

/// Latency service-level objectives a design must meet under real traffic
/// (the paper's Fig.-11 throughput–latency Pareto, made explicit).
/// Unset targets are `f64::INFINITY`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloSpec {
    /// p99 time-to-first-token target, s.
    pub ttft_p99_s: f64,
    /// p99 time-per-output-token target, s.
    pub tpot_p99_s: f64,
}

impl SloSpec {
    /// Both targets at the given values.
    pub fn new(ttft_p99_s: f64, tpot_p99_s: f64) -> SloSpec {
        SloSpec { ttft_p99_s, tpot_p99_s }
    }

    /// No latency constraint (pure TCO/Token optimization).
    pub fn unconstrained() -> SloSpec {
        SloSpec { ttft_p99_s: f64::INFINITY, tpot_p99_s: f64::INFINITY }
    }

    /// True when neither target binds.
    pub fn is_unconstrained(&self) -> bool {
        self.ttft_p99_s.is_infinite() && self.tpot_p99_s.is_infinite()
    }
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec::unconstrained()
    }
}

/// The request arrival process of a synthetic serving trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open-loop Poisson arrivals at `rps` requests/second.
    Poisson {
        /// Mean request rate, requests/second.
        rps: f64,
    },
    /// Open-loop bursty arrivals: groups of `burst` back-to-back requests,
    /// exponential gaps between groups sized so the long-run mean rate is
    /// still `rps`.
    Bursty {
        /// Long-run mean request rate, requests/second.
        rps: f64,
        /// Requests per burst.
        burst: usize,
    },
    /// Closed-loop: `clients` users, each submitting a new request
    /// `think_s` seconds after its previous one completes.
    ClosedLoop {
        /// Concurrent users.
        clients: usize,
        /// Think time between a completion and the next submit, s.
        think_s: f64,
    },
}

/// Distribution of per-request generated-token budgets on the inclusive
/// support `[new_tokens_lo, new_tokens_hi]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TokenDist {
    /// Discrete uniform over the support (the seed-model default).
    Uniform,
    /// Bounded (truncated) Pareto with shape `alpha`: heavy-tailed budgets
    /// whose mass concentrates near the low end while rare requests run to
    /// the high end — the regime where expected-residency overcommit beats
    /// max-footprint admission.
    Pareto {
        /// Tail index; smaller = heavier tail. Must be finite and > 0.
        alpha: f64,
    },
}

impl TokenDist {
    /// Mean of the distribution on `[lo, hi]` (support clamped to at least
    /// `[1, 1]`). The `Uniform` arm reproduces the historical
    /// `(lo + hi).max(2) / 2` capacity-planning mean bit-for-bit.
    pub fn mean(&self, lo: usize, hi: usize) -> f64 {
        match *self {
            TokenDist::Uniform => (lo + hi).max(2) as f64 / 2.0,
            TokenDist::Pareto { alpha } => {
                let l = lo.max(1) as f64;
                let h = hi.max(lo.max(1)) as f64;
                if h <= l {
                    return l;
                }
                if (alpha - 1.0).abs() < 1e-9 {
                    // α → 1 limit of the bounded-Pareto mean.
                    (h * l / (h - l)) * (h / l).ln()
                } else {
                    let ratio = (l / h).powf(alpha);
                    (l.powf(alpha) / (1.0 - ratio))
                        * (alpha / (alpha - 1.0))
                        * (l.powf(1.0 - alpha) - h.powf(1.0 - alpha))
                }
            }
        }
    }

    /// Inverse CDF at `q` ∈ [0, 1) on `[lo, hi]`, in fractional tokens.
    pub fn quantile(&self, q: f64, lo: usize, hi: usize) -> f64 {
        let l = lo.max(1) as f64;
        let h = hi.max(lo.max(1)) as f64;
        let q = q.clamp(0.0, 1.0 - 1e-12);
        match *self {
            TokenDist::Uniform => l + q * (h - l),
            TokenDist::Pareto { alpha } => {
                let ratio = (l / h).powf(alpha);
                l / (1.0 - q * (1.0 - ratio)).powf(1.0 / alpha)
            }
        }
    }

    /// Draw a token budget from one uniform variate `u` ∈ [0, 1), rounded
    /// and clamped to the inclusive support. The synthetic-arrival
    /// generator only calls this for non-uniform distributions (`Uniform`
    /// keeps its historical `rng.range(lo, hi)` draw so uniform token
    /// streams stay byte-identical).
    pub fn sample_unit(&self, u: f64, lo: usize, hi: usize) -> usize {
        let lo = lo.max(1);
        let hi = hi.max(lo);
        (self.quantile(u, lo, hi).round() as usize).clamp(lo, hi)
    }
}

impl Default for TokenDist {
    fn default() -> Self {
        TokenDist::Uniform
    }
}

/// Two-tier traffic classes for priority scheduling: an interactive tier
/// (short uniform budgets, tight SLO) sharing the fleet with a batch tier
/// (the base traffic's token-budget distribution, loose SLO). Tier 0 is
/// interactive, tier 1 is batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierSpec {
    /// Fraction of arrivals that are interactive (tier 0), in [0, 1].
    pub interactive_share: f64,
    /// Minimum generated tokens for interactive requests (inclusive).
    pub interactive_new_tokens_lo: usize,
    /// Maximum generated tokens for interactive requests (inclusive).
    pub interactive_new_tokens_hi: usize,
    /// Latency targets the interactive tier must hold — the SLO
    /// `best_point_slo` validates when tiers are active.
    pub interactive_slo: SloSpec,
    /// Latency targets reported for the batch tier (informational; batch
    /// absorbs preemption and is not design-binding).
    pub batch_slo: SloSpec,
    /// Fairness knob bounding batch starvation: after this many
    /// consecutive interactive admissions while batch requests wait, the
    /// next admission must come from the batch tier. 0 = strict priority
    /// (unbounded starvation).
    pub max_consecutive_interactive: usize,
}

impl TierSpec {
    /// Interactive share with uniform interactive budgets in `[lo, hi]`
    /// and the given per-tier SLOs; the fairness bound defaults to 8.
    pub fn new(
        interactive_share: f64,
        lo: usize,
        hi: usize,
        interactive_slo: SloSpec,
        batch_slo: SloSpec,
    ) -> TierSpec {
        TierSpec {
            interactive_share,
            interactive_new_tokens_lo: lo,
            interactive_new_tokens_hi: hi,
            interactive_slo,
            batch_slo,
            max_consecutive_interactive: 8,
        }
    }

    /// Same spec with a different fairness bound.
    pub fn with_fairness(mut self, max_consecutive_interactive: usize) -> TierSpec {
        self.max_consecutive_interactive = max_consecutive_interactive;
        self
    }

    /// Mean interactive token budget (uniform on the interactive range).
    pub fn interactive_mean(&self) -> f64 {
        TokenDist::Uniform.mean(self.interactive_new_tokens_lo, self.interactive_new_tokens_hi)
    }

    /// The SLO a request of `tier` is scored against.
    pub fn slo_for(&self, tier: u8) -> SloSpec {
        if tier == 0 {
            self.interactive_slo
        } else {
            self.batch_slo
        }
    }
}

/// A synthetic traffic description for the serving simulator: arrival
/// process plus per-request shape, all seeded for reproducibility.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrafficSpec {
    /// Arrival process.
    pub arrival: ArrivalProcess,
    /// Total requests in the trace.
    pub requests: usize,
    /// Prompt tokens per request.
    pub prompt_tokens: usize,
    /// Minimum generated tokens per request (inclusive).
    pub new_tokens_lo: usize,
    /// Maximum generated tokens per request (inclusive).
    pub new_tokens_hi: usize,
    /// Distribution of generated-token budgets on `[lo, hi]`
    /// ([`TokenDist::Uniform`] = the seed-model behaviour, byte-identical).
    pub new_tokens_dist: TokenDist,
    /// Optional interactive/batch tier split (`None` = single-class
    /// traffic, byte-identical to the pre-tier paths).
    pub tiers: Option<TierSpec>,
    /// PRNG seed for inter-arrival times and token budgets.
    pub seed: u64,
}

impl TrafficSpec {
    /// Poisson traffic with uniform token budgets in `[lo, hi]`.
    pub fn poisson(rps: f64, requests: usize, prompt: usize, lo: usize, hi: usize) -> TrafficSpec {
        TrafficSpec {
            arrival: ArrivalProcess::Poisson { rps },
            requests,
            prompt_tokens: prompt,
            new_tokens_lo: lo,
            new_tokens_hi: hi,
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: 42,
        }
    }

    /// Closed-loop traffic with uniform token budgets in `[lo, hi]`.
    pub fn closed_loop(
        clients: usize,
        think_s: f64,
        requests: usize,
        prompt: usize,
        lo: usize,
        hi: usize,
    ) -> TrafficSpec {
        TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop { clients, think_s },
            requests,
            prompt_tokens: prompt,
            new_tokens_lo: lo,
            new_tokens_hi: hi,
            new_tokens_dist: TokenDist::Uniform,
            tiers: None,
            seed: 42,
        }
    }

    /// Same spec with a different seed.
    pub fn with_seed(mut self, seed: u64) -> TrafficSpec {
        self.seed = seed;
        self
    }

    /// Same spec with a different token-budget distribution.
    pub fn with_token_dist(mut self, dist: TokenDist) -> TrafficSpec {
        self.new_tokens_dist = dist;
        self
    }

    /// Split arrivals into interactive/batch tiers.
    pub fn with_tiers(mut self, tiers: TierSpec) -> TrafficSpec {
        self.tiers = Some(tiers);
        self
    }

    /// Mean generated tokens per request across tiers — the
    /// capacity-planning mean `resolve_rate` divides fleet throughput by.
    /// Uniform single-tier traffic reproduces the historical
    /// `(lo + hi).max(2) / 2` expression bit-for-bit.
    pub fn mean_new_tokens(&self) -> f64 {
        let base = self.new_tokens_dist.mean(self.new_tokens_lo, self.new_tokens_hi);
        match self.tiers {
            None => base,
            Some(t) => {
                t.interactive_share * t.interactive_mean() + (1.0 - t.interactive_share) * base
            }
        }
    }

    /// Inverse-CDF token budget at `q` for the given tier (tier 0 =
    /// interactive when tiers are configured; otherwise the base
    /// distribution). Drives expected-residency admission charges.
    pub fn quantile_new_tokens(&self, tier: u8, q: f64) -> f64 {
        match self.tiers {
            Some(t) if tier == 0 => TokenDist::Uniform.quantile(
                q,
                t.interactive_new_tokens_lo,
                t.interactive_new_tokens_hi,
            ),
            _ => self.new_tokens_dist.quantile(q, self.new_tokens_lo, self.new_tokens_hi),
        }
    }

    /// Maximum generated tokens a request of `tier` may run to.
    pub fn max_new_tokens(&self, tier: u8) -> usize {
        match self.tiers {
            Some(t) if tier == 0 => {
                t.interactive_new_tokens_hi.max(t.interactive_new_tokens_lo).max(1)
            }
            _ => self.new_tokens_hi.max(self.new_tokens_lo).max(1),
        }
    }
}

/// Expected-residency estimator used by overcommit admission charges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ResidencyEstimate {
    /// Charge `prompt + quantile(q)` of the per-tier token-budget
    /// distribution (`q` ∈ (0, 1)).
    Quantile(f64),
    /// Charge `prompt + running mean` of completed requests' generated
    /// tokens (falls back to the request's own max before any completion
    /// has been observed).
    RunningMean,
}

/// KV overcommit: admit against *expected* residency instead of maximum
/// footprint, and preempt (recompute-on-resume) on block exhaustion.
/// `None` on [`ServeSpec::overcommit`] keeps reserved max-footprint
/// admission — byte-identical to the pre-overcommit paths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OvercommitSpec {
    /// How the admission charge is estimated.
    pub estimate: ResidencyEstimate,
}

impl OvercommitSpec {
    /// Charge the `q`-quantile of the token-budget distribution.
    pub fn quantile(q: f64) -> OvercommitSpec {
        OvercommitSpec { estimate: ResidencyEstimate::Quantile(q) }
    }

    /// Charge the observed running mean of completed budgets.
    pub fn running_mean() -> OvercommitSpec {
        OvercommitSpec { estimate: ResidencyEstimate::RunningMean }
    }
}

/// One scripted fault-plan event: a replica going down or coming back up
/// at a fixed virtual-time instant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Replica index the event applies to.
    pub replica: usize,
    /// Virtual time of the transition, seconds since trace start.
    pub at_s: f64,
    /// `true` = the replica recovers; `false` = it fails.
    pub up: bool,
}

/// Replica failure model for the multi-replica serving simulator: either a
/// seeded per-replica MTBF/MTTR renewal process (exponential up/down
/// durations) or an explicit scripted plan of `fail`/`recover` events —
/// the plan, when non-empty, replaces the stochastic process entirely, so
/// tests and CI get exact schedules. [`FaultSpec::none`] (the default)
/// disables the whole mechanism: fault-free runs take the unmodified
/// simulation path and stay byte-identical to pre-fault reports.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures per replica, seconds; 0 = no stochastic
    /// failures (scripted `plan` events may still fire).
    pub mtbf_s: f64,
    /// Mean time to repair per failure, seconds.
    pub mttr_s: f64,
    /// PRNG seed of the stochastic failure/recovery processes (one
    /// independent stream per replica).
    pub seed: u64,
    /// Scripted transitions; non-empty replaces the stochastic process.
    pub plan: Vec<FaultEvent>,
    /// Re-dispatches a request may survive before it counts as `lost`
    /// (each crash of its replica costs one try; recompute starts from
    /// scratch on the new replica).
    pub max_redispatch: usize,
    /// Availability target for redundancy sizing: the SLO-constrained
    /// sweep searches N+k replica counts and selects the cheapest fleet
    /// whose SLO holds under faults with at least this completed/offered
    /// fraction. `0.0` (default) keeps the fixed replica count.
    pub availability: f64,
    /// Maximum spare replicas the redundancy search may add on top of the
    /// spec's base replica count.
    pub max_spares: usize,
}

impl FaultSpec {
    /// No failures: the simulator takes the unmodified fault-free path.
    pub fn none() -> FaultSpec {
        FaultSpec {
            mtbf_s: 0.0,
            mttr_s: 0.0,
            seed: 0,
            plan: Vec::new(),
            max_redispatch: 3,
            availability: 0.0,
            max_spares: 4,
        }
    }

    /// Seeded stochastic failures: exponential up-times with mean
    /// `mtbf_s`, exponential repair times with mean `mttr_s`.
    pub fn mtbf(mtbf_s: f64, mttr_s: f64, seed: u64) -> FaultSpec {
        FaultSpec { mtbf_s, mttr_s, seed, ..FaultSpec::none() }
    }

    /// Scripted failures only (see [`FaultSpec::parse_plan`] for the
    /// string grammar).
    pub fn scripted(plan: Vec<FaultEvent>) -> FaultSpec {
        FaultSpec { plan, ..FaultSpec::none() }
    }

    /// Same spec with an availability target for redundancy sizing.
    pub fn with_availability(mut self, availability: f64) -> FaultSpec {
        self.availability = availability;
        self
    }

    /// True when the spec disables the fault model entirely — no
    /// stochastic process and no scripted events. The simulator entry
    /// points delegate to the fault-free path in this case, which is what
    /// keeps `FaultSpec::none()` runs byte-identical by construction.
    pub fn is_none(&self) -> bool {
        // cc-lint: allow(no-float-eq) 0.0 is the exact spec-default sentinel for "no stochastic process"; no arithmetic ever produces it
        self.mtbf_s == 0.0 && self.plan.is_empty()
    }

    /// Parse a scripted plan: comma-separated `fail:<replica>@<t>` /
    /// `recover:<replica>@<t>` entries (seconds of virtual time),
    /// mirroring the orchestrator's `CC_FAULT_PLAN` grammar. Empty (or
    /// all-whitespace) means no events.
    pub fn parse_plan(s: &str) -> Result<Vec<FaultEvent>, String> {
        let mut plan = Vec::new();
        for raw in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, target) = raw
                .split_once(':')
                .ok_or_else(|| format!("fault '{raw}': expected <kind>:<replica>@<t>"))?;
            let up = match kind {
                "fail" => false,
                "recover" => true,
                other => {
                    return Err(format!(
                        "fault '{raw}': unknown kind '{other}' (expected fail or recover)"
                    ))
                }
            };
            let (replica, at) = target
                .split_once('@')
                .ok_or_else(|| format!("fault '{raw}': expected <replica>@<t>"))?;
            let replica: usize = replica
                .parse()
                .map_err(|_| format!("fault '{raw}': bad replica index '{replica}'"))?;
            let at_s: f64 = at
                .parse()
                .map_err(|_| format!("fault '{raw}': bad time '{at}'"))?;
            if !at_s.is_finite() || at_s < 0.0 {
                return Err(format!("fault '{raw}': time must be finite and >= 0"));
            }
            plan.push(FaultEvent { replica, at_s, up });
        }
        Ok(plan)
    }

    /// Render the scripted plan back to the [`FaultSpec::parse_plan`]
    /// grammar (round-trips exactly: Rust's shortest-float formatting
    /// re-parses to the same bits).
    pub fn plan_string(&self) -> String {
        self.plan
            .iter()
            .map(|e| {
                format!("{}:{}@{}", if e.up { "recover" } else { "fail" }, e.replica, e.at_s)
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::none()
    }
}

/// Traffic plus the SLO it must be served under — the serving-layer spec a
/// [`Workload`] optionally carries into the sweep — and the serving-model
/// knobs the event simulator honours: chunked prefill, paged-KV
/// accounting, and multi-replica routing.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSpec {
    /// Synthetic traffic description (shape/volume defaults still apply
    /// when a trace file provides the arrivals — see `trace_file`).
    pub traffic: TrafficSpec,
    /// Latency targets.
    pub slo: SloSpec,
    /// Prompt tokens prefilled per slot per iteration during admission;
    /// 0 = the whole prompt in one admission iteration (the
    /// stall-the-batch model).
    pub prefill_chunk: usize,
    /// Per-slot paged KV accounting (block-granular ledger over the
    /// design's spare CC-MEM) instead of full-context-per-slot
    /// reservation.
    pub paged_kv: bool,
    /// Serving replicas (independent queues fed by `route`); >= 1.
    pub replicas: usize,
    /// Arrival routing policy across replicas.
    pub route: crate::sched::RoutePolicy,
    /// Quantized-time decode stretches: maximum seconds of virtual time
    /// the simulator advances per closed-form jump
    /// ([`crate::perf::events::SimConfig::quantum`]). `0.0` (default)
    /// keeps the bit-identical fast-forward path; positive values trade
    /// a documented epsilon on the latency tails for O(1) decode
    /// stretches.
    pub quantum: f64,
    /// Replay arrivals from an on-disk CSV trace
    /// (`at_s,prompt_tokens,new_tokens` — see [`crate::perf::trace`])
    /// instead of synthesizing them from `traffic.arrival`. The trace
    /// fixes arrival instants, prompt lengths and token budgets; the
    /// request count comes from the file. Mutually exclusive with a
    /// non-default synthetic arrival process.
    pub trace_file: Option<String>,
    /// Replica failure model ([`FaultSpec::none`] = every replica is up
    /// forever — the pre-fault behaviour, byte-identical).
    pub faults: FaultSpec,
    /// KV overcommit + preemption (`None` = reserved max-footprint
    /// admission, the pre-overcommit behaviour, byte-identical). Requires
    /// `paged_kv`.
    pub overcommit: Option<OvercommitSpec>,
    /// Width of the sketch-backed windowed-goodput buckets, seconds of
    /// virtual time; `0.0` (default) disables windowed rows entirely.
    pub goodput_window_s: f64,
}

impl ServeSpec {
    /// Seed-model semantics: whole-prompt admission, full-context KV
    /// reservation, one replica, synthetic arrivals, bit-exact timing.
    pub fn new(traffic: TrafficSpec, slo: SloSpec) -> ServeSpec {
        ServeSpec {
            traffic,
            slo,
            prefill_chunk: 0,
            paged_kv: false,
            replicas: 1,
            route: crate::sched::RoutePolicy::RoundRobin,
            quantum: 0.0,
            trace_file: None,
            faults: FaultSpec::none(),
            overcommit: None,
            goodput_window_s: 0.0,
        }
    }

    /// Enable chunked prefill at `chunk` tokens per iteration.
    pub fn with_chunked_prefill(mut self, chunk: usize) -> ServeSpec {
        self.prefill_chunk = chunk;
        self
    }

    /// Enable per-slot paged-KV accounting.
    pub fn with_paged_kv(mut self) -> ServeSpec {
        self.paged_kv = true;
        self
    }

    /// Serve with `replicas` replicas routed by `route`.
    pub fn with_replicas(mut self, replicas: usize, route: crate::sched::RoutePolicy) -> ServeSpec {
        self.replicas = replicas.max(1);
        self.route = route;
        self
    }

    /// Enable quantized-time decode stretches at `quantum` seconds of
    /// virtual time per jump (see the `quantum` field).
    pub fn with_quantum(mut self, quantum: f64) -> ServeSpec {
        self.quantum = quantum;
        self
    }

    /// Replay arrivals from a CSV trace file instead of synthesizing them.
    pub fn with_trace_file<S: Into<String>>(mut self, path: S) -> ServeSpec {
        self.trace_file = Some(path.into());
        self
    }

    /// Serve under the given replica failure model.
    pub fn with_faults(mut self, faults: FaultSpec) -> ServeSpec {
        self.faults = faults;
        self
    }

    /// Enable KV overcommit + preemption (expected-residency admission).
    pub fn with_overcommit(mut self, overcommit: OvercommitSpec) -> ServeSpec {
        self.overcommit = Some(overcommit);
        self
    }

    /// Enable sketch-backed windowed-goodput rows at `window_s`-second
    /// buckets of virtual time.
    pub fn with_goodput_window(mut self, window_s: f64) -> ServeSpec {
        self.goodput_window_s = window_s;
        self
    }
}

/// A serving workload: a model plus the traffic shape to optimize for.
#[derive(Clone, Debug)]
pub struct Workload {
    /// The model being served.
    pub model: ModelSpec,
    /// Context length (prompt + generated) budget per sequence.
    pub ctx: usize,
    /// Batch size (sequences decoded concurrently).
    pub batch: usize,
    /// Tokens generated per request (used for prefill amortization and the
    /// Google-search-scale projections; paper assumes 500).
    pub tokens_per_request: usize,
    /// Prompt length for the prefill phase.
    pub prompt_len: usize,
    /// Weight *storage* scale factor — < 1 when weights are stored
    /// tile-CSR-compressed in CC-MEM (Store-as-Compressed). 1.0 = dense.
    pub weight_store_scale: f64,
    /// Weight *read-time* scale factor — ≥ 1 when the compression decoder
    /// is input-limited at low sparsity (Load-as-Dense never beats the
    /// dense port rate; see [`crate::ccmem::decoder`]). 1.0 = dense.
    pub weight_read_scale: f64,
    /// Use conventional 1D tensor-parallel communication instead of the 2D
    /// weight-stationary layout [37] — the Fig.-11 ablation knob.
    pub comm_1d: bool,
    /// Optional serving-layer spec: the traffic shape and latency SLOs the
    /// design must hold up under (drives the event simulator and the
    /// SLO-constrained sweep; `None` = steady-state optimization only).
    pub serve: Option<ServeSpec>,
}

impl Workload {
    /// Standard workload shape used in the paper's evaluation:
    /// 500 generated tokens per query, prompt is the remaining context.
    pub fn new(model: ModelSpec, ctx: usize, batch: usize) -> Self {
        let tokens_per_request = 500.min(ctx / 2);
        Workload {
            model,
            ctx,
            batch,
            tokens_per_request,
            prompt_len: ctx - tokens_per_request,
            weight_store_scale: 1.0,
            weight_read_scale: 1.0,
            comm_1d: false,
            serve: None,
        }
    }

    /// Attach a serving-layer traffic+SLO spec.
    pub fn with_serve(mut self, serve: ServeSpec) -> Workload {
        self.serve = Some(serve);
        self
    }

    /// Fig.-11 ablation: fall back to 1D tensor-parallel communication.
    pub fn with_1d_comm(mut self) -> Workload {
        self.comm_1d = true;
        self
    }

    /// Serve the model pruned to unstructured `sparsity`, stored tile-CSR
    /// compressed (Fig. 13). Sets the storage scale from the codec's
    /// 24-bit-word economics and the read scale from the decoder's
    /// input-limit knee.
    pub fn with_sparsity(mut self, sparsity: f64) -> Workload {
        let dense = self.model.weight_bytes();
        self.weight_store_scale = crate::sparse::sparse_bytes(dense, sparsity) / dense;
        // Decoder output ≤ dense port rate; below the 1/3-sparsity knee the
        // input side (24b words through a 128b port) limits throughput.
        self.weight_read_scale = (1.5 * (1.0 - sparsity)).max(1.0);
        self
    }

    /// The paper's design-space study grid: ctx ∈ {1024, 2048, 4096},
    /// batch ∈ {1, 2, 4, ..., 1024}.
    pub fn study_grid(model: &ModelSpec) -> Vec<Workload> {
        let mut out = Vec::new();
        for ctx in [1024usize, 2048, 4096] {
            let mut b = 1usize;
            while b <= 1024 {
                out.push(Workload::new(model.clone(), ctx, b));
                b *= 2;
            }
        }
        out
    }

    /// Total KV-cache bytes across the batch.
    pub fn kv_bytes(&self) -> f64 {
        self.model.kv_bytes_per_seq(self.ctx) * self.batch as f64
    }

    /// Weight bytes as stored (after optional compression).
    pub fn stored_weight_bytes(&self) -> f64 {
        self.model.weight_bytes() * self.weight_store_scale
    }

    /// Total resident bytes (weights + KV cache + activations margin).
    pub fn resident_bytes(&self) -> f64 {
        // Activations during decode are tiny (batch × d per layer boundary);
        // reserve 2× that as double-buffering margin.
        let act = 2.0 * self.batch as f64 * self.model.d_model as f64 * self.model.bytes_per_param;
        self.stored_weight_bytes() + self.kv_bytes() + act * self.model.n_layers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_grid_shape() {
        let g = Workload::study_grid(&ModelSpec::gpt3());
        // 3 context lengths × 11 batch sizes (1..1024 powers of two)
        assert_eq!(g.len(), 33);
        assert!(g.iter().any(|w| w.batch == 1024 && w.ctx == 4096));
    }

    #[test]
    fn paper_memory_example() {
        // §2.2.1 workload: GPT-3, ctx 2K, batch 256. Weights ≈ 350 GB
        // (paper's figure holds); KV = 256 × 9.66 GB with the standard
        // formula (see models.rs: gpt3_kv_cache_standard_formula).
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        assert!((w.kv_bytes() / 1e12 - 2.47).abs() < 0.05, "kv={}", w.kv_bytes() / 1e12);
        assert!((w.model.weight_bytes() / 1e9 - 350.0).abs() / 350.0 < 0.05);
    }

    #[test]
    fn serve_spec_is_optional_and_attachable() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        assert!(w.serve.is_none());
        let spec =
            ServeSpec::new(TrafficSpec::poisson(10.0, 100, 64, 8, 32), SloSpec::new(0.5, 0.02));
        let w = w.with_serve(spec);
        let s = w.serve.expect("attached");
        assert_eq!(s.traffic.requests, 100);
        assert!(!s.slo.is_unconstrained());
        assert!(SloSpec::unconstrained().is_unconstrained());
        // seed-model defaults: stall-the-batch, full reservation, 1 replica
        assert_eq!(s.prefill_chunk, 0);
        assert!(!s.paged_kv);
        assert_eq!(s.replicas, 1);
        assert!(s.faults.is_none());
    }

    #[test]
    fn serve_spec_builders_set_the_serving_model() {
        let s = ServeSpec::new(TrafficSpec::poisson(10.0, 10, 64, 8, 32), SloSpec::unconstrained())
            .with_chunked_prefill(64)
            .with_paged_kv()
            .with_replicas(3, crate::sched::RoutePolicy::Jsq);
        assert_eq!(s.prefill_chunk, 64);
        assert!(s.paged_kv);
        assert_eq!(s.replicas, 3);
        assert_eq!(s.route, crate::sched::RoutePolicy::Jsq);
        // replicas clamp to >= 1
        let s = s.with_replicas(0, crate::sched::RoutePolicy::RoundRobin);
        assert_eq!(s.replicas, 1);
    }

    #[test]
    fn resident_dominated_by_weights_at_small_batch() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 1);
        assert!(w.resident_bytes() < w.model.weight_bytes() * 1.05);
    }

    #[test]
    fn fault_plan_grammar_parses_and_round_trips() {
        let plan = FaultSpec::parse_plan("fail:0@5.5, recover:0@12 ,fail:2@100").unwrap();
        assert_eq!(
            plan,
            vec![
                FaultEvent { replica: 0, at_s: 5.5, up: false },
                FaultEvent { replica: 0, at_s: 12.0, up: true },
                FaultEvent { replica: 2, at_s: 100.0, up: false },
            ]
        );
        let spec = FaultSpec::scripted(plan.clone());
        assert!(!spec.is_none());
        assert_eq!(FaultSpec::parse_plan(&spec.plan_string()).unwrap(), plan);
        assert!(FaultSpec::parse_plan("").unwrap().is_empty());
        assert!(FaultSpec::parse_plan("   ").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_grammar_rejects_malformed_entries() {
        for bad in ["explode:0@1", "fail:0", "fail:x@1", "fail:0@soon", "fail:0@-1", "fail:0@inf"]
        {
            let err = FaultSpec::parse_plan(bad).unwrap_err();
            assert!(err.contains("fault"), "{bad}: {err}");
        }
    }

    #[test]
    fn uniform_token_dist_mean_matches_the_historical_expression() {
        let t = TrafficSpec::poisson(10.0, 100, 64, 16, 128);
        assert_eq!(t.new_tokens_dist, TokenDist::Uniform);
        assert!(t.tiers.is_none());
        // cc-lint: allow(no-float-eq) bit-identity with the historical capacity-planning mean is the contract under test
        assert!(t.mean_new_tokens() == (16 + 128).max(2) as f64 / 2.0);
    }

    #[test]
    fn bounded_pareto_tail_mass_and_mean_match_the_analytic_form() {
        let (lo, hi, alpha) = (16usize, 2048usize, 1.2f64);
        let dist = TokenDist::Pareto { alpha };
        let mut rng = crate::util::rng::Rng::new(1234);
        let n = 200_000usize;
        let q90 = dist.quantile(0.9, lo, hi);
        let mut sum = 0.0f64;
        let mut above_q90 = 0usize;
        let mut lo_seen = usize::MAX;
        let mut hi_seen = 0usize;
        for _ in 0..n {
            let x = dist.sample_unit(rng.f64(), lo, hi);
            sum += x as f64;
            if (x as f64) > q90 {
                above_q90 += 1;
            }
            lo_seen = lo_seen.min(x);
            hi_seen = hi_seen.max(x);
        }
        let mean = sum / n as f64;
        let analytic = dist.mean(lo, hi);
        assert!((mean - analytic).abs() / analytic < 0.02, "mean={mean} analytic={analytic}");
        // Tail mass: ~10% of draws exceed the analytic 90th percentile
        // (rounding to integers smears the threshold slightly).
        let tail = above_q90 as f64 / n as f64;
        assert!((tail - 0.1).abs() < 0.02, "tail={tail}");
        // Support is respected and both ends are reachable.
        assert!(lo_seen >= lo && hi_seen <= hi, "seen=[{lo_seen},{hi_seen}]");
        assert_eq!(lo_seen, lo);
        // Heavy tail: the mean sits far below the midpoint of the support.
        assert!(analytic < (lo + hi) as f64 / 4.0, "analytic={analytic}");
    }

    #[test]
    fn pareto_mean_is_continuous_through_alpha_one() {
        let (lo, hi) = (16usize, 2048usize);
        let at_one = TokenDist::Pareto { alpha: 1.0 }.mean(lo, hi);
        let near = TokenDist::Pareto { alpha: 1.0 + 1e-7 }.mean(lo, hi);
        assert!((at_one - near).abs() / at_one < 1e-3, "at_one={at_one} near={near}");
        // Degenerate support falls back to the point mass.
        // cc-lint: allow(no-float-eq) exact fallback value is the contract
        assert!(TokenDist::Pareto { alpha: 1.5 }.mean(8, 8) == 8.0);
    }

    #[test]
    fn tier_spec_selects_per_tier_budgets_and_slos() {
        let tiers = TierSpec::new(0.75, 8, 32, SloSpec::new(0.5, 0.05), SloSpec::new(5.0, 0.5));
        let t = TrafficSpec::poisson(10.0, 100, 64, 16, 2048)
            .with_token_dist(TokenDist::Pareto { alpha: 1.2 })
            .with_tiers(tiers);
        // Interactive tier draws from the uniform [8, 32] range.
        assert!((t.quantile_new_tokens(0, 0.5) - 20.0).abs() < 1e-9);
        assert_eq!(t.max_new_tokens(0), 32);
        // Batch tier draws from the heavy-tailed base distribution.
        assert!(t.quantile_new_tokens(1, 0.99) > 100.0);
        assert_eq!(t.max_new_tokens(1), 2048);
        // Tier-weighted mean interpolates interactive and base means.
        let base = TokenDist::Pareto { alpha: 1.2 }.mean(16, 2048);
        let want = 0.75 * 20.0 + 0.25 * base;
        assert!((t.mean_new_tokens() - want).abs() < 1e-9);
        // Per-tier SLO lookup.
        assert!((tiers.slo_for(0).ttft_p99_s - 0.5).abs() < 1e-12);
        assert!((tiers.slo_for(1).ttft_p99_s - 5.0).abs() < 1e-12);
        assert_eq!(tiers.max_consecutive_interactive, 8);
        assert_eq!(tiers.with_fairness(3).max_consecutive_interactive, 3);
    }

    #[test]
    fn overcommit_spec_builders_and_serve_defaults() {
        let s = ServeSpec::new(TrafficSpec::poisson(10.0, 10, 64, 8, 32), SloSpec::unconstrained());
        assert!(s.overcommit.is_none());
        // cc-lint: allow(no-float-eq) 0.0 is the exact "windows off" spec default
        assert!(s.goodput_window_s == 0.0);
        let s = s.with_paged_kv().with_overcommit(OvercommitSpec::quantile(0.5));
        assert_eq!(s.overcommit, Some(OvercommitSpec::quantile(0.5)));
        assert_eq!(
            OvercommitSpec::running_mean().estimate,
            ResidencyEstimate::RunningMean
        );
        let s = s.with_goodput_window(30.0);
        assert!((s.goodput_window_s - 30.0).abs() < 1e-12);
    }

    #[test]
    fn fault_spec_none_is_inert_and_detectable() {
        assert!(FaultSpec::none().is_none());
        assert!(FaultSpec::default().is_none());
        assert!(!FaultSpec::mtbf(100.0, 5.0, 7).is_none());
        let s = ServeSpec::new(TrafficSpec::poisson(10.0, 10, 64, 8, 32), SloSpec::unconstrained())
            .with_faults(FaultSpec::mtbf(100.0, 5.0, 7).with_availability(0.99));
        assert!((s.faults.availability - 0.99).abs() < 1e-12);
        assert_eq!(s.faults.seed, 7);
    }
}
