//! Serving workload descriptions used by Phase 2 and the evaluation figures.

use crate::config::models::ModelSpec;

/// Latency service-level objectives a design must meet under real traffic
/// (the paper's Fig.-11 throughput–latency Pareto, made explicit).
/// Unset targets are `f64::INFINITY`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloSpec {
    /// p99 time-to-first-token target, s.
    pub ttft_p99_s: f64,
    /// p99 time-per-output-token target, s.
    pub tpot_p99_s: f64,
}

impl SloSpec {
    /// Both targets at the given values.
    pub fn new(ttft_p99_s: f64, tpot_p99_s: f64) -> SloSpec {
        SloSpec { ttft_p99_s, tpot_p99_s }
    }

    /// No latency constraint (pure TCO/Token optimization).
    pub fn unconstrained() -> SloSpec {
        SloSpec { ttft_p99_s: f64::INFINITY, tpot_p99_s: f64::INFINITY }
    }

    /// True when neither target binds.
    pub fn is_unconstrained(&self) -> bool {
        self.ttft_p99_s.is_infinite() && self.tpot_p99_s.is_infinite()
    }
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec::unconstrained()
    }
}

/// The request arrival process of a synthetic serving trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open-loop Poisson arrivals at `rps` requests/second.
    Poisson {
        /// Mean request rate, requests/second.
        rps: f64,
    },
    /// Open-loop bursty arrivals: groups of `burst` back-to-back requests,
    /// exponential gaps between groups sized so the long-run mean rate is
    /// still `rps`.
    Bursty {
        /// Long-run mean request rate, requests/second.
        rps: f64,
        /// Requests per burst.
        burst: usize,
    },
    /// Closed-loop: `clients` users, each submitting a new request
    /// `think_s` seconds after its previous one completes.
    ClosedLoop {
        /// Concurrent users.
        clients: usize,
        /// Think time between a completion and the next submit, s.
        think_s: f64,
    },
}

/// A synthetic traffic description for the serving simulator: arrival
/// process plus per-request shape, all seeded for reproducibility.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrafficSpec {
    /// Arrival process.
    pub arrival: ArrivalProcess,
    /// Total requests in the trace.
    pub requests: usize,
    /// Prompt tokens per request.
    pub prompt_tokens: usize,
    /// Minimum generated tokens per request (inclusive).
    pub new_tokens_lo: usize,
    /// Maximum generated tokens per request (inclusive).
    pub new_tokens_hi: usize,
    /// PRNG seed for inter-arrival times and token budgets.
    pub seed: u64,
}

impl TrafficSpec {
    /// Poisson traffic with uniform token budgets in `[lo, hi]`.
    pub fn poisson(rps: f64, requests: usize, prompt: usize, lo: usize, hi: usize) -> TrafficSpec {
        TrafficSpec {
            arrival: ArrivalProcess::Poisson { rps },
            requests,
            prompt_tokens: prompt,
            new_tokens_lo: lo,
            new_tokens_hi: hi,
            seed: 42,
        }
    }

    /// Closed-loop traffic with uniform token budgets in `[lo, hi]`.
    pub fn closed_loop(
        clients: usize,
        think_s: f64,
        requests: usize,
        prompt: usize,
        lo: usize,
        hi: usize,
    ) -> TrafficSpec {
        TrafficSpec {
            arrival: ArrivalProcess::ClosedLoop { clients, think_s },
            requests,
            prompt_tokens: prompt,
            new_tokens_lo: lo,
            new_tokens_hi: hi,
            seed: 42,
        }
    }

    /// Same spec with a different seed.
    pub fn with_seed(mut self, seed: u64) -> TrafficSpec {
        self.seed = seed;
        self
    }
}

/// One scripted fault-plan event: a replica going down or coming back up
/// at a fixed virtual-time instant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Replica index the event applies to.
    pub replica: usize,
    /// Virtual time of the transition, seconds since trace start.
    pub at_s: f64,
    /// `true` = the replica recovers; `false` = it fails.
    pub up: bool,
}

/// Replica failure model for the multi-replica serving simulator: either a
/// seeded per-replica MTBF/MTTR renewal process (exponential up/down
/// durations) or an explicit scripted plan of `fail`/`recover` events —
/// the plan, when non-empty, replaces the stochastic process entirely, so
/// tests and CI get exact schedules. [`FaultSpec::none`] (the default)
/// disables the whole mechanism: fault-free runs take the unmodified
/// simulation path and stay byte-identical to pre-fault reports.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures per replica, seconds; 0 = no stochastic
    /// failures (scripted `plan` events may still fire).
    pub mtbf_s: f64,
    /// Mean time to repair per failure, seconds.
    pub mttr_s: f64,
    /// PRNG seed of the stochastic failure/recovery processes (one
    /// independent stream per replica).
    pub seed: u64,
    /// Scripted transitions; non-empty replaces the stochastic process.
    pub plan: Vec<FaultEvent>,
    /// Re-dispatches a request may survive before it counts as `lost`
    /// (each crash of its replica costs one try; recompute starts from
    /// scratch on the new replica).
    pub max_redispatch: usize,
    /// Availability target for redundancy sizing: the SLO-constrained
    /// sweep searches N+k replica counts and selects the cheapest fleet
    /// whose SLO holds under faults with at least this completed/offered
    /// fraction. `0.0` (default) keeps the fixed replica count.
    pub availability: f64,
    /// Maximum spare replicas the redundancy search may add on top of the
    /// spec's base replica count.
    pub max_spares: usize,
}

impl FaultSpec {
    /// No failures: the simulator takes the unmodified fault-free path.
    pub fn none() -> FaultSpec {
        FaultSpec {
            mtbf_s: 0.0,
            mttr_s: 0.0,
            seed: 0,
            plan: Vec::new(),
            max_redispatch: 3,
            availability: 0.0,
            max_spares: 4,
        }
    }

    /// Seeded stochastic failures: exponential up-times with mean
    /// `mtbf_s`, exponential repair times with mean `mttr_s`.
    pub fn mtbf(mtbf_s: f64, mttr_s: f64, seed: u64) -> FaultSpec {
        FaultSpec { mtbf_s, mttr_s, seed, ..FaultSpec::none() }
    }

    /// Scripted failures only (see [`FaultSpec::parse_plan`] for the
    /// string grammar).
    pub fn scripted(plan: Vec<FaultEvent>) -> FaultSpec {
        FaultSpec { plan, ..FaultSpec::none() }
    }

    /// Same spec with an availability target for redundancy sizing.
    pub fn with_availability(mut self, availability: f64) -> FaultSpec {
        self.availability = availability;
        self
    }

    /// True when the spec disables the fault model entirely — no
    /// stochastic process and no scripted events. The simulator entry
    /// points delegate to the fault-free path in this case, which is what
    /// keeps `FaultSpec::none()` runs byte-identical by construction.
    pub fn is_none(&self) -> bool {
        // cc-lint: allow(no-float-eq) 0.0 is the exact spec-default sentinel for "no stochastic process"; no arithmetic ever produces it
        self.mtbf_s == 0.0 && self.plan.is_empty()
    }

    /// Parse a scripted plan: comma-separated `fail:<replica>@<t>` /
    /// `recover:<replica>@<t>` entries (seconds of virtual time),
    /// mirroring the orchestrator's `CC_FAULT_PLAN` grammar. Empty (or
    /// all-whitespace) means no events.
    pub fn parse_plan(s: &str) -> Result<Vec<FaultEvent>, String> {
        let mut plan = Vec::new();
        for raw in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, target) = raw
                .split_once(':')
                .ok_or_else(|| format!("fault '{raw}': expected <kind>:<replica>@<t>"))?;
            let up = match kind {
                "fail" => false,
                "recover" => true,
                other => {
                    return Err(format!(
                        "fault '{raw}': unknown kind '{other}' (expected fail or recover)"
                    ))
                }
            };
            let (replica, at) = target
                .split_once('@')
                .ok_or_else(|| format!("fault '{raw}': expected <replica>@<t>"))?;
            let replica: usize = replica
                .parse()
                .map_err(|_| format!("fault '{raw}': bad replica index '{replica}'"))?;
            let at_s: f64 = at
                .parse()
                .map_err(|_| format!("fault '{raw}': bad time '{at}'"))?;
            if !at_s.is_finite() || at_s < 0.0 {
                return Err(format!("fault '{raw}': time must be finite and >= 0"));
            }
            plan.push(FaultEvent { replica, at_s, up });
        }
        Ok(plan)
    }

    /// Render the scripted plan back to the [`FaultSpec::parse_plan`]
    /// grammar (round-trips exactly: Rust's shortest-float formatting
    /// re-parses to the same bits).
    pub fn plan_string(&self) -> String {
        self.plan
            .iter()
            .map(|e| {
                format!("{}:{}@{}", if e.up { "recover" } else { "fail" }, e.replica, e.at_s)
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::none()
    }
}

/// Traffic plus the SLO it must be served under — the serving-layer spec a
/// [`Workload`] optionally carries into the sweep — and the serving-model
/// knobs the event simulator honours: chunked prefill, paged-KV
/// accounting, and multi-replica routing.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSpec {
    /// Synthetic traffic description (shape/volume defaults still apply
    /// when a trace file provides the arrivals — see `trace_file`).
    pub traffic: TrafficSpec,
    /// Latency targets.
    pub slo: SloSpec,
    /// Prompt tokens prefilled per slot per iteration during admission;
    /// 0 = the whole prompt in one admission iteration (the
    /// stall-the-batch model).
    pub prefill_chunk: usize,
    /// Per-slot paged KV accounting (block-granular ledger over the
    /// design's spare CC-MEM) instead of full-context-per-slot
    /// reservation.
    pub paged_kv: bool,
    /// Serving replicas (independent queues fed by `route`); >= 1.
    pub replicas: usize,
    /// Arrival routing policy across replicas.
    pub route: crate::sched::RoutePolicy,
    /// Quantized-time decode stretches: maximum seconds of virtual time
    /// the simulator advances per closed-form jump
    /// ([`crate::perf::events::SimConfig::quantum`]). `0.0` (default)
    /// keeps the bit-identical fast-forward path; positive values trade
    /// a documented epsilon on the latency tails for O(1) decode
    /// stretches.
    pub quantum: f64,
    /// Replay arrivals from an on-disk CSV trace
    /// (`at_s,prompt_tokens,new_tokens` — see [`crate::perf::trace`])
    /// instead of synthesizing them from `traffic.arrival`. The trace
    /// fixes arrival instants, prompt lengths and token budgets; the
    /// request count comes from the file. Mutually exclusive with a
    /// non-default synthetic arrival process.
    pub trace_file: Option<String>,
    /// Replica failure model ([`FaultSpec::none`] = every replica is up
    /// forever — the pre-fault behaviour, byte-identical).
    pub faults: FaultSpec,
}

impl ServeSpec {
    /// Seed-model semantics: whole-prompt admission, full-context KV
    /// reservation, one replica, synthetic arrivals, bit-exact timing.
    pub fn new(traffic: TrafficSpec, slo: SloSpec) -> ServeSpec {
        ServeSpec {
            traffic,
            slo,
            prefill_chunk: 0,
            paged_kv: false,
            replicas: 1,
            route: crate::sched::RoutePolicy::RoundRobin,
            quantum: 0.0,
            trace_file: None,
            faults: FaultSpec::none(),
        }
    }

    /// Enable chunked prefill at `chunk` tokens per iteration.
    pub fn with_chunked_prefill(mut self, chunk: usize) -> ServeSpec {
        self.prefill_chunk = chunk;
        self
    }

    /// Enable per-slot paged-KV accounting.
    pub fn with_paged_kv(mut self) -> ServeSpec {
        self.paged_kv = true;
        self
    }

    /// Serve with `replicas` replicas routed by `route`.
    pub fn with_replicas(mut self, replicas: usize, route: crate::sched::RoutePolicy) -> ServeSpec {
        self.replicas = replicas.max(1);
        self.route = route;
        self
    }

    /// Enable quantized-time decode stretches at `quantum` seconds of
    /// virtual time per jump (see the `quantum` field).
    pub fn with_quantum(mut self, quantum: f64) -> ServeSpec {
        self.quantum = quantum;
        self
    }

    /// Replay arrivals from a CSV trace file instead of synthesizing them.
    pub fn with_trace_file<S: Into<String>>(mut self, path: S) -> ServeSpec {
        self.trace_file = Some(path.into());
        self
    }

    /// Serve under the given replica failure model.
    pub fn with_faults(mut self, faults: FaultSpec) -> ServeSpec {
        self.faults = faults;
        self
    }
}

/// A serving workload: a model plus the traffic shape to optimize for.
#[derive(Clone, Debug)]
pub struct Workload {
    /// The model being served.
    pub model: ModelSpec,
    /// Context length (prompt + generated) budget per sequence.
    pub ctx: usize,
    /// Batch size (sequences decoded concurrently).
    pub batch: usize,
    /// Tokens generated per request (used for prefill amortization and the
    /// Google-search-scale projections; paper assumes 500).
    pub tokens_per_request: usize,
    /// Prompt length for the prefill phase.
    pub prompt_len: usize,
    /// Weight *storage* scale factor — < 1 when weights are stored
    /// tile-CSR-compressed in CC-MEM (Store-as-Compressed). 1.0 = dense.
    pub weight_store_scale: f64,
    /// Weight *read-time* scale factor — ≥ 1 when the compression decoder
    /// is input-limited at low sparsity (Load-as-Dense never beats the
    /// dense port rate; see [`crate::ccmem::decoder`]). 1.0 = dense.
    pub weight_read_scale: f64,
    /// Use conventional 1D tensor-parallel communication instead of the 2D
    /// weight-stationary layout [37] — the Fig.-11 ablation knob.
    pub comm_1d: bool,
    /// Optional serving-layer spec: the traffic shape and latency SLOs the
    /// design must hold up under (drives the event simulator and the
    /// SLO-constrained sweep; `None` = steady-state optimization only).
    pub serve: Option<ServeSpec>,
}

impl Workload {
    /// Standard workload shape used in the paper's evaluation:
    /// 500 generated tokens per query, prompt is the remaining context.
    pub fn new(model: ModelSpec, ctx: usize, batch: usize) -> Self {
        let tokens_per_request = 500.min(ctx / 2);
        Workload {
            model,
            ctx,
            batch,
            tokens_per_request,
            prompt_len: ctx - tokens_per_request,
            weight_store_scale: 1.0,
            weight_read_scale: 1.0,
            comm_1d: false,
            serve: None,
        }
    }

    /// Attach a serving-layer traffic+SLO spec.
    pub fn with_serve(mut self, serve: ServeSpec) -> Workload {
        self.serve = Some(serve);
        self
    }

    /// Fig.-11 ablation: fall back to 1D tensor-parallel communication.
    pub fn with_1d_comm(mut self) -> Workload {
        self.comm_1d = true;
        self
    }

    /// Serve the model pruned to unstructured `sparsity`, stored tile-CSR
    /// compressed (Fig. 13). Sets the storage scale from the codec's
    /// 24-bit-word economics and the read scale from the decoder's
    /// input-limit knee.
    pub fn with_sparsity(mut self, sparsity: f64) -> Workload {
        let dense = self.model.weight_bytes();
        self.weight_store_scale = crate::sparse::sparse_bytes(dense, sparsity) / dense;
        // Decoder output ≤ dense port rate; below the 1/3-sparsity knee the
        // input side (24b words through a 128b port) limits throughput.
        self.weight_read_scale = (1.5 * (1.0 - sparsity)).max(1.0);
        self
    }

    /// The paper's design-space study grid: ctx ∈ {1024, 2048, 4096},
    /// batch ∈ {1, 2, 4, ..., 1024}.
    pub fn study_grid(model: &ModelSpec) -> Vec<Workload> {
        let mut out = Vec::new();
        for ctx in [1024usize, 2048, 4096] {
            let mut b = 1usize;
            while b <= 1024 {
                out.push(Workload::new(model.clone(), ctx, b));
                b *= 2;
            }
        }
        out
    }

    /// Total KV-cache bytes across the batch.
    pub fn kv_bytes(&self) -> f64 {
        self.model.kv_bytes_per_seq(self.ctx) * self.batch as f64
    }

    /// Weight bytes as stored (after optional compression).
    pub fn stored_weight_bytes(&self) -> f64 {
        self.model.weight_bytes() * self.weight_store_scale
    }

    /// Total resident bytes (weights + KV cache + activations margin).
    pub fn resident_bytes(&self) -> f64 {
        // Activations during decode are tiny (batch × d per layer boundary);
        // reserve 2× that as double-buffering margin.
        let act = 2.0 * self.batch as f64 * self.model.d_model as f64 * self.model.bytes_per_param;
        self.stored_weight_bytes() + self.kv_bytes() + act * self.model.n_layers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_grid_shape() {
        let g = Workload::study_grid(&ModelSpec::gpt3());
        // 3 context lengths × 11 batch sizes (1..1024 powers of two)
        assert_eq!(g.len(), 33);
        assert!(g.iter().any(|w| w.batch == 1024 && w.ctx == 4096));
    }

    #[test]
    fn paper_memory_example() {
        // §2.2.1 workload: GPT-3, ctx 2K, batch 256. Weights ≈ 350 GB
        // (paper's figure holds); KV = 256 × 9.66 GB with the standard
        // formula (see models.rs: gpt3_kv_cache_standard_formula).
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        assert!((w.kv_bytes() / 1e12 - 2.47).abs() < 0.05, "kv={}", w.kv_bytes() / 1e12);
        assert!((w.model.weight_bytes() / 1e9 - 350.0).abs() / 350.0 < 0.05);
    }

    #[test]
    fn serve_spec_is_optional_and_attachable() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 256);
        assert!(w.serve.is_none());
        let spec =
            ServeSpec::new(TrafficSpec::poisson(10.0, 100, 64, 8, 32), SloSpec::new(0.5, 0.02));
        let w = w.with_serve(spec);
        let s = w.serve.expect("attached");
        assert_eq!(s.traffic.requests, 100);
        assert!(!s.slo.is_unconstrained());
        assert!(SloSpec::unconstrained().is_unconstrained());
        // seed-model defaults: stall-the-batch, full reservation, 1 replica
        assert_eq!(s.prefill_chunk, 0);
        assert!(!s.paged_kv);
        assert_eq!(s.replicas, 1);
        assert!(s.faults.is_none());
    }

    #[test]
    fn serve_spec_builders_set_the_serving_model() {
        let s = ServeSpec::new(TrafficSpec::poisson(10.0, 10, 64, 8, 32), SloSpec::unconstrained())
            .with_chunked_prefill(64)
            .with_paged_kv()
            .with_replicas(3, crate::sched::RoutePolicy::Jsq);
        assert_eq!(s.prefill_chunk, 64);
        assert!(s.paged_kv);
        assert_eq!(s.replicas, 3);
        assert_eq!(s.route, crate::sched::RoutePolicy::Jsq);
        // replicas clamp to >= 1
        let s = s.with_replicas(0, crate::sched::RoutePolicy::RoundRobin);
        assert_eq!(s.replicas, 1);
    }

    #[test]
    fn resident_dominated_by_weights_at_small_batch() {
        let w = Workload::new(ModelSpec::gpt3(), 2048, 1);
        assert!(w.resident_bytes() < w.model.weight_bytes() * 1.05);
    }

    #[test]
    fn fault_plan_grammar_parses_and_round_trips() {
        let plan = FaultSpec::parse_plan("fail:0@5.5, recover:0@12 ,fail:2@100").unwrap();
        assert_eq!(
            plan,
            vec![
                FaultEvent { replica: 0, at_s: 5.5, up: false },
                FaultEvent { replica: 0, at_s: 12.0, up: true },
                FaultEvent { replica: 2, at_s: 100.0, up: false },
            ]
        );
        let spec = FaultSpec::scripted(plan.clone());
        assert!(!spec.is_none());
        assert_eq!(FaultSpec::parse_plan(&spec.plan_string()).unwrap(), plan);
        assert!(FaultSpec::parse_plan("").unwrap().is_empty());
        assert!(FaultSpec::parse_plan("   ").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_grammar_rejects_malformed_entries() {
        for bad in ["explode:0@1", "fail:0", "fail:x@1", "fail:0@soon", "fail:0@-1", "fail:0@inf"]
        {
            let err = FaultSpec::parse_plan(bad).unwrap_err();
            assert!(err.contains("fault"), "{bad}: {err}");
        }
    }

    #[test]
    fn fault_spec_none_is_inert_and_detectable() {
        assert!(FaultSpec::none().is_none());
        assert!(FaultSpec::default().is_none());
        assert!(!FaultSpec::mtbf(100.0, 5.0, 7).is_none());
        let s = ServeSpec::new(TrafficSpec::poisson(10.0, 10, 64, 8, 32), SloSpec::unconstrained())
            .with_faults(FaultSpec::mtbf(100.0, 5.0, 7).with_availability(0.99));
        assert!((s.faults.availability - 0.99).abs() < 1e-12);
        assert_eq!(s.faults.seed, 7);
    }
}
