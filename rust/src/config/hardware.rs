//! Hardware exploration constants and sweep ranges (paper Table 1).
//!
//! All constants are the paper's published inputs. Where the paper scales a
//! 12nm Synopsys implementation to 7nm we encode the resulting 7nm densities
//! directly (High-Density SRAM bitcell area and CPP×MMP routing scaling; the
//! provenance of each substitution is documented on the field it feeds).

/// Technology / economics constants (Table 1 plus §4 text).
#[derive(Clone, Debug)]
pub struct TechParams {
    /// Process node label.
    pub node: &'static str,
    /// Compute density, mm² per TFLOPS (Table 1: 2.65, derived from A100).
    pub compute_mm2_per_tflops: f64,
    /// Compute power, W per TFLOPS (Table 1: 1.3, derived from A100 TDP).
    pub compute_w_per_tflops: f64,
    /// Max chip power density, W/mm² (Table 1: < 1).
    pub max_power_density_w_mm2: f64,
    /// SRAM storage density at 7nm, MB per mm².
    ///
    /// TSMC N7 HD bitcell = 0.027 µm²/bit ⇒ raw 4.63 MB/mm²; array
    /// efficiency (periphery, sense amps, redundancy) ≈ 45% ⇒ effective
    /// ≈ 2.1 MB/mm². This reproduces Table 2's MB-per-chip/die-size ratios
    /// (e.g. GPT-3: 225.8 MB in a 140 mm² die alongside 5.5 TFLOPS).
    pub sram_mb_per_mm2: f64,
    /// CC-MEM bank-group streaming bandwidth, GB/s (128 b/cycle @ 1 GHz).
    /// Chip bandwidth = n_bank_groups × this; Phase 1 sweeps the group
    /// count via the bytes-per-FLOP ratio (`ExploreSpace::bw_ratios`).
    pub bank_group_gbps: f64,
    /// Min/max SRAM capacity per bank group, MB (bank geometry limits from
    /// the 12nm implementation).
    pub bank_group_mb_range: (f64, f64),
    /// Crossbar area coefficient, mm² per port² (quadratic radix scaling,
    /// already discounted for NoC symbiosis — routing rides over the SRAM).
    pub xbar_mm2_per_port2: f64,
    /// Compression decoder + burst control area per bank group, mm².
    pub decoder_mm2_per_group: f64,
    /// SRAM dynamic read energy, pJ per byte at 7nm.
    pub sram_pj_per_byte: f64,
    /// Crossbar transfer energy, pJ per byte per hop.
    pub xbar_pj_per_byte: f64,
    /// Chip-to-chip IO: bandwidth per link, GB/s (Table 1: 25 GB/s).
    pub io_link_gbps: f64,
    /// Chip-to-chip IO links per chip (Table 1: 4).
    pub io_links: usize,
    /// Off-chip link energy, pJ per byte (GRS-class links ≈ 1.17 pJ/b).
    pub io_pj_per_byte: f64,
    /// IO + auxiliary (PHY, controller, PLL) area overhead per chip, mm².
    pub aux_area_mm2: f64,
    /// Wafer cost, $ (Table 1: 10 000 for 7nm 300mm).
    pub wafer_cost: f64,
    /// Wafer diameter, mm (300mm line).
    pub wafer_diameter_mm: f64,
    /// Defect density, defects/cm² (Table 1: 0.1).
    pub defect_density_per_cm2: f64,
    /// Negative-binomial cluster parameter α [12].
    pub yield_alpha: f64,
    /// Per-die test cost, $.
    pub test_cost: f64,
    /// Max die size considered manufacturable (reticle limit ≈ 800 mm²).
    pub reticle_mm2: f64,
}

impl Default for TechParams {
    fn default() -> Self {
        TechParams {
            node: "7nm",
            compute_mm2_per_tflops: 2.65,
            compute_w_per_tflops: 1.3,
            max_power_density_w_mm2: 1.0,
            sram_mb_per_mm2: 2.1,
            bank_group_gbps: 16.0,
            bank_group_mb_range: (0.25, 4.0),
            xbar_mm2_per_port2: 2.0e-4,
            decoder_mm2_per_group: 0.01,
            sram_pj_per_byte: 1.6,
            xbar_pj_per_byte: 0.6,
            io_link_gbps: 25.0,
            io_links: 4,
            io_pj_per_byte: 9.4, // 1.17 pJ/b GRS [38]
            aux_area_mm2: 6.0,
            wafer_cost: 10_000.0,
            wafer_diameter_mm: 300.0,
            defect_density_per_cm2: 0.1,
            yield_alpha: 2.0,
            test_cost: 2.0,
            reticle_mm2: 800.0,
        }
    }
}

/// Server-level constants (Table 1).
#[derive(Clone, Debug)]
pub struct ServerParams {
    /// Lanes per 1U 19-inch server (Table 1: 8).
    pub lanes: usize,
    /// Max total silicon per lane, mm² (Table 1: < 6000).
    pub max_silicon_per_lane_mm2: f64,
    /// Chips per lane sweep bound (Table 1: 1 to 20).
    pub max_chips_per_lane: usize,
    /// Max power per lane, W (Table 1: < 250; refined by thermal model).
    pub max_power_per_lane_w: f64,
    /// Power supply efficiency (Table 1: 0.95).
    pub psu_efficiency: f64,
    /// DC-DC conversion efficiency (Table 1: 0.95).
    pub dcdc_efficiency: f64,
    /// Ethernet NIC cost, $ (Table 1: 100 GbE, $450).
    pub ethernet_cost: f64,
    /// Server life for TCO amortization, years (Table 1: 1.5).
    pub server_life_years: f64,
    /// Controller (FPGA/µC) cost per server, $.
    pub controller_cost: f64,
    /// PCB cost per server, $ (large 1U board, organic substrate chiplets).
    pub pcb_cost: f64,
    /// Heatsink cost per chip, $.
    pub heatsink_cost_per_chip: f64,
    /// Fan cost per lane, $.
    pub fan_cost_per_lane: f64,
    /// PSU cost per server per kW, $.
    pub psu_cost_per_kw: f64,
    /// Package (flip-chip BGA, organic substrate) cost per chip: fixed + per-mm².
    pub package_fixed_cost: f64,
    /// Package cost per mm² of die.
    pub package_cost_per_mm2: f64,
}

impl Default for ServerParams {
    fn default() -> Self {
        ServerParams {
            lanes: 8,
            max_silicon_per_lane_mm2: 6000.0,
            max_chips_per_lane: 20,
            max_power_per_lane_w: 250.0,
            psu_efficiency: 0.95,
            dcdc_efficiency: 0.95,
            ethernet_cost: 450.0,
            server_life_years: 1.5,
            controller_cost: 300.0,
            pcb_cost: 800.0,
            heatsink_cost_per_chip: 10.0,
            fan_cost_per_lane: 16.0,
            psu_cost_per_kw: 120.0,
            package_fixed_cost: 5.0,
            package_cost_per_mm2: 0.05,
        }
    }
}

/// Datacenter (Barroso-style) TCO constants.
#[derive(Clone, Debug)]
pub struct DatacenterParams {
    /// Electricity price, $/kWh (US industrial average).
    pub electricity_per_kwh: f64,
    /// Power usage effectiveness of the facility.
    pub pue: f64,
    /// Datacenter capex amortized per provisioned watt per year, $/W/yr
    /// (build-out ~$10/W over ~12y, Barroso et al.).
    pub facility_capex_per_w_year: f64,
    /// Non-power OpEx (staff, maintenance) as a fraction of server CapEx/yr.
    pub opex_maintenance_frac: f64,
}

impl Default for DatacenterParams {
    fn default() -> Self {
        DatacenterParams {
            electricity_per_kwh: 0.07,
            pue: 1.1,
            facility_capex_per_w_year: 0.8,
            opex_maintenance_frac: 0.03,
        }
    }
}

/// Phase-1 sweep ranges.
#[derive(Clone, Debug)]
pub struct ExploreSpace {
    /// Technology constants.
    pub tech: TechParams,
    /// Server constants.
    pub server: ServerParams,
    /// Datacenter constants.
    pub dc: DatacenterParams,
    /// Die sizes to sweep, mm² (Table 1: 20..800).
    pub die_sizes_mm2: Vec<f64>,
    /// Fractions of die devoted to SRAM (vs compute) to sweep.
    pub sram_fracs: Vec<f64>,
    /// CC-MEM bandwidth provisioning, bytes of SRAM read per FLOP of
    /// compute. Sets the bank-group count: the chip can saturate its MACs
    /// at micro-batch ≈ bytes_per_param / ratio. Table 2 optima land on
    /// 0.125 (PaLM, µb=8) … 0.67 (MT-NLG, µb=1).
    pub bw_ratios: Vec<f64>,
    /// Chips per lane to sweep (Table 1: 1..20).
    pub chips_per_lane: Vec<usize>,
}

impl Default for ExploreSpace {
    fn default() -> Self {
        ExploreSpace {
            tech: TechParams::default(),
            server: ServerParams::default(),
            dc: DatacenterParams::default(),
            die_sizes_mm2: (1..=40).map(|i| i as f64 * 20.0).collect(),
            sram_fracs: (1..=19).map(|i| i as f64 * 0.05).collect(),
            bw_ratios: vec![0.125, 0.25, 0.5, 0.667, 1.0],
            chips_per_lane: (1..=20).collect(),
        }
    }
}

impl ExploreSpace {
    /// A reduced sweep for fast tests and the quickstart example
    /// (~1/8 of the full space, same qualitative optima).
    pub fn coarse() -> Self {
        ExploreSpace {
            die_sizes_mm2: (1..=16).map(|i| i as f64 * 50.0).collect(),
            sram_fracs: (1..=9).map(|i| i as f64 * 0.1).collect(),
            bw_ratios: vec![0.125, 0.25, 0.5, 1.0],
            chips_per_lane: vec![1, 2, 4, 6, 8, 10, 12, 16, 20],
            ..Default::default()
        }
    }

    /// Total number of (die, sram, bw, chips/lane) combinations swept.
    pub fn n_points(&self) -> usize {
        self.die_sizes_mm2.len() * self.sram_fracs.len() * self.bw_ratios.len() * self.chips_per_lane.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let t = TechParams::default();
        assert_eq!(t.compute_mm2_per_tflops, 2.65);
        assert_eq!(t.compute_w_per_tflops, 1.3);
        assert_eq!(t.wafer_cost, 10_000.0);
        assert_eq!(t.defect_density_per_cm2, 0.1);
        assert_eq!(t.io_links, 4);
        assert_eq!(t.io_link_gbps, 25.0);
        let s = ServerParams::default();
        assert_eq!(s.lanes, 8);
        assert_eq!(s.max_chips_per_lane, 20);
        assert_eq!(s.max_power_per_lane_w, 250.0);
        assert_eq!(s.ethernet_cost, 450.0);
        assert!((s.server_life_years - 1.5).abs() < 1e-12);
    }

    #[test]
    fn sweep_covers_table1_ranges() {
        let e = ExploreSpace::default();
        assert_eq!(*e.die_sizes_mm2.first().unwrap(), 20.0);
        assert_eq!(*e.die_sizes_mm2.last().unwrap(), 800.0);
        assert_eq!(*e.chips_per_lane.last().unwrap(), 20);
        assert!(e.n_points() > 10_000, "phase-1 sweep should produce >10k raw points");
    }

    #[test]
    fn sram_density_supports_table2_designs() {
        // Table 2 GPT-3 design: 140 mm² die with 225.8 MB and 5.5 TFLOPS.
        // compute area = 5.5 * 2.65 = 14.6 mm²; aux = 6 mm²;
        // SRAM area available ≈ 119.4 mm² ⇒ need ≥ 1.89 MB/mm².
        let t = TechParams::default();
        let sram_area = 140.0 - 5.5 * t.compute_mm2_per_tflops - t.aux_area_mm2;
        assert!(sram_area * t.sram_mb_per_mm2 >= 225.8, "got {}", sram_area * t.sram_mb_per_mm2);
    }

    #[test]
    fn bw_ratio_sweep_brackets_table2() {
        // Table 2 BW/TFLOPS ratios: PaLM 0.125 … MT-NLG 0.667 B/FLOP.
        let e = ExploreSpace::default();
        let min = e.bw_ratios.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = e.bw_ratios.iter().cloned().fold(0.0, f64::max);
        assert!(min <= 0.125 && max >= 0.667);
    }
}
