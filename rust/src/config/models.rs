//! LLM model specifications.
//!
//! The eight case-study models of the paper (Table 2) plus OPT-175B (used by
//! the sparsity evaluation, Fig. 13) and two small serving configs (`cc-tiny`
//! and `cc-gpt-mini`) that the real PJRT runtime executes end-to-end.
//!
//! All hyper-parameters are the publicly released values the paper uses;
//! no actual weights are involved in the DSE (the paper does the same).

/// Attention variant — determines KV-cache size per token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Attention {
    /// Multi-head attention: KV heads == query heads.
    MultiHead,
    /// Multi-query attention (PaLM): one KV head shared by all query heads.
    MultiQuery,
    /// Grouped-query attention (Llama-2-70B): `n_kv` KV head groups.
    GroupedQuery {
        /// Number of KV head groups.
        n_kv: usize,
    },
}

/// Hyper-parameters of a decoder-only transformer LLM.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Short identifier, e.g. "gpt3".
    pub name: &'static str,
    /// Human-readable name as printed in Table 2.
    pub display: &'static str,
    /// Model (embedding) dimension d_model.
    pub d_model: usize,
    /// Number of transformer decoder layers.
    pub n_layers: usize,
    /// Number of attention (query) heads.
    pub n_heads: usize,
    /// Head dimension. Usually d_model/n_heads, but PaLM decouples them
    /// (d=18432, 48 heads × 256).
    pub d_head: usize,
    /// Feed-forward inner dimension (usually 4·d, PaLM/Llama use variants).
    pub d_ff: usize,
    /// Number of FFN weight matrices: 2 for the classic 2-layer MLP, 3 for
    /// GLU variants (SwiGLU in PaLM and Llama-2 — [47]).
    pub ffn_mats: usize,
    /// Attention variant.
    pub attention: Attention,
    /// Vocabulary size (used for the embedding/unembedding FLOPs + bytes).
    pub vocab: usize,
    /// Max context length the model was trained for.
    pub max_ctx: usize,
    /// Bytes per parameter as served (paper serves fp16 ⇒ 2).
    pub bytes_per_param: f64,
}

impl ModelSpec {
    /// KV heads for this model's attention variant.
    pub fn kv_heads(&self) -> usize {
        match self.attention {
            Attention::MultiHead => self.n_heads,
            Attention::MultiQuery => 1,
            Attention::GroupedQuery { n_kv } => n_kv,
        }
    }

    /// Attention inner width (n_heads × d_head; equals d_model except PaLM).
    pub fn d_attn(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// Total parameter count.
    ///
    /// Decoder layer: attention (q,k,v,o) + FFN (`ffn_mats` mats of d×d_ff)
    /// + small norm/bias terms (ignored, <0.1%). Embedding: vocab×d (tied).
    pub fn n_params(&self) -> f64 {
        let d = self.d_model as f64;
        let d_attn = self.d_attn() as f64;
        let d_kv = (self.kv_heads() * self.d_head) as f64;
        // q: d×d_attn, o: d_attn×d, k and v: d×d_kv each
        let attn = 2.0 * d * d_attn + 2.0 * d * d_kv;
        let ffn = self.ffn_mats as f64 * d * self.d_ff as f64;
        let per_layer = attn + ffn;
        per_layer * self.n_layers as f64 + (self.vocab as f64) * d
    }

    /// Total weight bytes as served.
    pub fn weight_bytes(&self) -> f64 {
        self.n_params() * self.bytes_per_param
    }

    /// KV-cache bytes per sequence at context length `ctx`.
    ///
    /// 2 (K and V) × layers × ctx × kv_heads × d_head × bytes.
    pub fn kv_bytes_per_seq(&self, ctx: usize) -> f64 {
        2.0 * self.n_layers as f64
            * ctx as f64
            * self.kv_heads() as f64
            * self.d_head as f64
            * self.bytes_per_param
    }

    /// FLOPs for one token generation step for one sequence at context `ctx`
    /// (MACs ×2). FC layers dominate: 2·n_params per token plus attention
    /// reads of the KV cache.
    pub fn flops_per_token(&self, ctx: usize) -> f64 {
        let matmul = 2.0 * self.n_params();
        // attention: q·K^T and attn·V over the cached context
        let attn = 2.0
            * 2.0
            * self.n_layers as f64
            * ctx as f64
            * self.d_attn() as f64;
        matmul + attn
    }

    /// The paper's eight case-study models (Table 2) in table order.
    pub fn paper_models() -> Vec<ModelSpec> {
        vec![
            Self::gpt2(),
            Self::megatron(),
            Self::gpt3(),
            Self::gopher(),
            Self::mt_nlg(),
            Self::bloom(),
            Self::palm(),
            Self::llama2_70b(),
        ]
    }

    /// Look up any known model by short name.
    pub fn by_name(name: &str) -> Option<ModelSpec> {
        let all = [
            Self::gpt2(),
            Self::megatron(),
            Self::gpt3(),
            Self::gopher(),
            Self::mt_nlg(),
            Self::bloom(),
            Self::palm(),
            Self::llama2_70b(),
            Self::opt_175b(),
            Self::cc_tiny(),
            Self::cc_gpt_mini(),
        ];
        all.iter().find(|m| m.name == name).cloned()
    }

    /// GPT-2 1.5B [41].
    pub fn gpt2() -> ModelSpec {
        ModelSpec {
            name: "gpt2",
            display: "GPT-2",
            d_model: 1600,
            n_layers: 48,
            n_heads: 25,
            d_head: 64,
            d_ff: 6400,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 50257,
            max_ctx: 1024,
            bytes_per_param: 2.0,
        }
    }

    /// Megatron-LM 8.3B [48].
    pub fn megatron() -> ModelSpec {
        ModelSpec {
            name: "megatron",
            display: "Megatron",
            d_model: 3072,
            n_layers: 72,
            n_heads: 32,
            d_head: 96,
            d_ff: 12288,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 51200,
            max_ctx: 1024,
            bytes_per_param: 2.0,
        }
    }

    /// GPT-3 175B [8].
    pub fn gpt3() -> ModelSpec {
        ModelSpec {
            name: "gpt3",
            display: "GPT-3",
            d_model: 12288,
            n_layers: 96,
            n_heads: 96,
            d_head: 128,
            d_ff: 49152,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 50257,
            max_ctx: 4096,
            bytes_per_param: 2.0,
        }
    }

    /// Gopher 280B [42].
    pub fn gopher() -> ModelSpec {
        ModelSpec {
            name: "gopher",
            display: "Gopher",
            d_model: 16384,
            n_layers: 80,
            n_heads: 128,
            d_head: 128,
            d_ff: 65536,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 32000,
            max_ctx: 2048,
            bytes_per_param: 2.0,
        }
    }

    /// Megatron-Turing NLG 530B [50].
    pub fn mt_nlg() -> ModelSpec {
        ModelSpec {
            name: "mt-nlg",
            display: "MT-NLG",
            d_model: 20480,
            n_layers: 105,
            n_heads: 128,
            d_head: 160,
            d_ff: 81920,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 50257,
            max_ctx: 2048,
            bytes_per_param: 2.0,
        }
    }

    /// BLOOM 176B [7].
    pub fn bloom() -> ModelSpec {
        ModelSpec {
            name: "bloom",
            display: "BLOOM",
            d_model: 14336,
            n_layers: 70,
            n_heads: 112,
            d_head: 128,
            d_ff: 57344,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 250880,
            max_ctx: 2048,
            bytes_per_param: 2.0,
        }
    }

    /// PaLM 540B [9] — multi-query attention.
    pub fn palm() -> ModelSpec {
        ModelSpec {
            name: "palm",
            display: "PaLM",
            d_model: 18432,
            n_layers: 118,
            n_heads: 48,
            d_head: 256,
            d_ff: 73728,
            ffn_mats: 3,
            attention: Attention::MultiQuery,
            vocab: 256000,
            max_ctx: 2048,
            bytes_per_param: 2.0,
        }
    }

    /// Llama-2 70B [55] — grouped-query attention (8 KV groups).
    pub fn llama2_70b() -> ModelSpec {
        ModelSpec {
            name: "llama2-70b",
            display: "Llama-2",
            d_model: 8192,
            n_layers: 80,
            n_heads: 64,
            d_head: 128,
            d_ff: 28672,
            ffn_mats: 3,
            attention: Attention::GroupedQuery { n_kv: 8 },
            vocab: 32000,
            max_ctx: 4096,
            bytes_per_param: 2.0,
        }
    }

    /// OPT-175B [62] — same architecture family as GPT-3; used by the
    /// sparsity study (Fig. 13) because SparseGPT [15] reports its
    /// perplexity under unstructured pruning.
    pub fn opt_175b() -> ModelSpec {
        ModelSpec {
            name: "opt-175b",
            display: "OPT-175B",
            d_model: 12288,
            n_layers: 96,
            n_heads: 96,
            d_head: 128,
            d_ff: 49152,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 50272,
            max_ctx: 2048,
            bytes_per_param: 2.0,
        }
    }

    /// Tiny config for fast tests and the Pallas-backed artifact
    /// (d=256, 4 layers, ≈4.6M params).
    pub fn cc_tiny() -> ModelSpec {
        ModelSpec {
            name: "cc-tiny",
            display: "CC-Tiny",
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_head: 64,
            d_ff: 1024,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 512,
            max_ctx: 128,
            bytes_per_param: 4.0, // served fp32 on the CPU PJRT backend
        }
    }

    /// ~110M-parameter GPT-style model served end-to-end by
    /// `examples/serve_llm.rs` (d=768, 12 layers, GPT-2-small shape).
    pub fn cc_gpt_mini() -> ModelSpec {
        ModelSpec {
            name: "cc-gpt-mini",
            display: "CC-GPT-Mini",
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            d_head: 64,
            d_ff: 3072,
            ffn_mats: 2,
            attention: Attention::MultiHead,
            vocab: 32000,
            max_ctx: 128,
            bytes_per_param: 4.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parameter counts should land near the published sizes (Table 2 row 1).
    #[test]
    fn param_counts_match_published() {
        let cases: &[(ModelSpec, f64, f64)] = &[
            (ModelSpec::gpt2(), 1.5e9, 0.15),
            (ModelSpec::megatron(), 8.3e9, 0.15),
            (ModelSpec::gpt3(), 175e9, 0.05),
            (ModelSpec::gopher(), 280e9, 0.10),
            (ModelSpec::mt_nlg(), 530e9, 0.05),
            (ModelSpec::bloom(), 176e9, 0.10),
            (ModelSpec::palm(), 540e9, 0.10),
            (ModelSpec::llama2_70b(), 70e9, 0.10),
        ];
        for (m, published, tol) in cases {
            let got = m.n_params();
            let rel = (got - published).abs() / published;
            assert!(rel < *tol, "{}: got {:.1}B want {:.0}B (rel {:.2})", m.name, got / 1e9, published / 1e9, rel);
        }
    }

    /// Paper §2.2.1 quotes "2 GB" of KV for GPT-3 at ctx=2K and "512 GB" at
    /// batch 256, which does not follow from the standard KV formula
    /// (2·layers·ctx·heads·d_head·2B = 9.66 GB/seq — the figure Pope et
    /// al. [37] and every serving system use). We keep the standard formula
    /// and pin it here; the deviation is documented in EXPERIMENTS.md.
    #[test]
    fn gpt3_kv_cache_standard_formula() {
        let m = ModelSpec::gpt3();
        let kv = m.kv_bytes_per_seq(2048);
        assert!((kv / 1e9 - 9.66).abs() < 0.05, "kv={:.2} GB", kv / 1e9);
        // and the paper's weights figure does hold: ~350 GB at fp16
        assert!((m.weight_bytes() / 1e9 - 350.0).abs() / 350.0 < 0.05);
    }

    #[test]
    fn attention_variants_shrink_kv() {
        let mh = ModelSpec::gpt3().kv_bytes_per_seq(2048);
        let mut mq = ModelSpec::gpt3();
        mq.attention = Attention::MultiQuery;
        assert!((mh / mq.kv_bytes_per_seq(2048) - 96.0).abs() < 1e-9);
        let mut gq = ModelSpec::gpt3();
        gq.attention = Attention::GroupedQuery { n_kv: 8 };
        assert!((mh / gq.kv_bytes_per_seq(2048) - 12.0).abs() < 1e-9);
    }

    /// §2.1: the FC layers dominate GPT-3 compute (paper: ">99% of MACs";
    /// with the full attention factor included the share is 94–98%
    /// depending on context — we assert dominance, not the rounded claim).
    #[test]
    fn fc_layers_dominate_gpt3() {
        let m = ModelSpec::gpt3();
        for ctx in [1024, 2048, 4096] {
            let total = m.flops_per_token(ctx);
            let attn = total - 2.0 * m.n_params();
            assert!(attn / total < 0.06, "ctx={ctx} attention share {:.4}", attn / total);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(ModelSpec::by_name("gpt3").is_some());
        assert!(ModelSpec::by_name("palm").is_some());
        assert!(ModelSpec::by_name("nonexistent").is_none());
        assert_eq!(ModelSpec::paper_models().len(), 8);
    }

    #[test]
    fn serving_configs_sized_right() {
        let mini = ModelSpec::cc_gpt_mini();
        let p = mini.n_params();
        assert!((85e6..140e6).contains(&p), "cc-gpt-mini params {p}");
        let tiny = ModelSpec::cc_tiny();
        assert!(tiny.n_params() < 10e6);
    }
}
