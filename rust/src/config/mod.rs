//! Workload and hardware configuration.
//!
//! * [`models`] — the eight paper LLMs (Table 2 hyper-parameters) plus the
//!   small serving configs used by the real PJRT runtime.
//! * [`hardware`] — Table 1 exploration constants (technology, wafer
//!   economics, server envelope) and the sweep ranges of Phase 1.
//! * [`workload`] — serving workload descriptions (batch, context, tokens).
//! * [`experiment`] — the declarative, serializable experiment spec every
//!   `ccloud` subcommand translates into (see [`crate::experiment`] for
//!   the runner).

pub mod experiment;
pub mod hardware;
pub mod models;
pub mod workload;

pub use experiment::{EngineKnobs, Experiment, SpaceSpec, Task, WorkloadPoint};
pub use hardware::{ExploreSpace, TechParams};
pub use models::{Attention, ModelSpec};
pub use workload::{
    ArrivalProcess, FaultEvent, FaultSpec, OvercommitSpec, ResidencyEstimate, ServeSpec, SloSpec,
    TierSpec, TokenDist, TrafficSpec, Workload,
};
