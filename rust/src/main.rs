//! `ccloud` — the Chiplet Cloud design tool and serving leader.
//!
//! Subcommands:
//! * `explore`                — Phase-1 hardware exploration summary
//! * `optimize --model NAME`  — full two-phase DSE for one model
//! * `sweep [--model NAME]`   — sweep-engine report (frontier, pruning, wall
//!   time); `--slo-ttft S --slo-tpot S` adds the SLO-constrained optimum
//! * `serve-sim`              — discrete-event serving simulation: static vs
//!   continuous batching on a seeded trace (`--smoke` for the CI preset)
//! * `table2` / `fig7`..`fig15` — regenerate a paper table/figure
//! * `serve`                  — load AOT artifacts and serve a demo stream
//! * `ccmem`                  — run the CC-MEM cycle simulator validations
//!
//! `--full` switches from the coarse sweep (default, seconds) to the
//! paper-scale sweep (Table-1 ranges). `--out results` writes each table as
//! CSV. `--threads N` pins the sweep-engine worker count; `--seq` forces
//! the sequential exhaustive path (no parallelism, no pruning, no Pareto
//! ordering — the reference behaviour).

use std::path::PathBuf;
use std::time::Duration;

use chiplet_cloud::config::hardware::ExploreSpace;
use chiplet_cloud::config::ModelSpec;
use chiplet_cloud::coordinator::{Coordinator, CoordinatorConfig};
use chiplet_cloud::report::{self, Ctx};
use chiplet_cloud::util::cli::Args;
use chiplet_cloud::util::rng::Rng;
use chiplet_cloud::{Error, Result};

fn usage() -> ! {
    eprintln!(
        "usage: ccloud <cmd> [--full] [--out DIR] [--model NAME] [--threads N] [--seq] ...\n\
         cmds: explore optimize sweep serve-sim table2 fig7..fig15 ablate serve ccmem"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| usage());
    let out_dir: Option<PathBuf> = args.get("out").map(PathBuf::from);
    let out = out_dir.as_deref();
    let space = if args.has("full") { ExploreSpace::default() } else { ExploreSpace::coarse() };

    // Sweep-engine knobs (read by SweepEngine::default / util::parallel).
    if let Some(t) = args.get("threads") {
        std::env::set_var("CC_SWEEP_THREADS", t);
    }
    if args.has("seq") {
        std::env::set_var("CC_SWEEP_THREADS", "1");
        std::env::set_var("CC_SWEEP_PRUNE", "0");
        std::env::set_var("CC_SWEEP_PARETO", "0");
    }

    match cmd.as_str() {
        "explore" => {
            let (servers, stats) = chiplet_cloud::explore::phase1(&space);
            let frontier = chiplet_cloud::explore::pareto::frontier_indices(&servers);
            println!(
                "phase 1: swept {} points -> {} feasible servers, {} on the Pareto frontier \
                 (rejected: geometry {}, silicon/lane {}, power {}, thermal {})",
                stats.swept,
                servers.len(),
                frontier.len(),
                stats.rejected_geometry,
                stats.rejected_silicon,
                stats.rejected_power,
                stats.rejected_thermal
            );
        }
        "optimize" => {
            let name = args.get("model").unwrap_or("gpt3");
            let model = ModelSpec::by_name(name)
                .ok_or_else(|| Error::Config(format!("unknown model {name}")))?;
            let ctx = Ctx::new(space);
            let t = report::table2(&ctx, &[model], out);
            print!("{}", t.render());
        }
        "sweep" => {
            let name = args.get("model").unwrap_or("gpt3");
            let model = ModelSpec::by_name(name)
                .ok_or_else(|| Error::Config(format!("unknown model {name}")))?;
            let slo_spec = slo_from_args(&args);
            let serve_spec = if slo_spec.is_unconstrained() {
                None
            } else {
                // The sweep has no per-design rate resolution, so default to
                // a saturating closed loop unless a trace was given.
                let mut traffic = traffic_from_args(&args);
                if !args.has("trace") && !args.has("rps") {
                    traffic.arrival = chiplet_cloud::config::ArrivalProcess::ClosedLoop {
                        clients: args.get_or("clients", 64),
                        think_s: args.get_or("think", 0.0),
                    };
                }
                Some(chiplet_cloud::config::ServeSpec { traffic, slo: slo_spec })
            };
            let ctx = Ctx::new(space);
            let t = report::sweep_summary(&ctx, &model, serve_spec.as_ref(), out);
            print!("{}", t.render());
        }
        "serve-sim" => serve_sim(&args, space, out)?,
        "table2" => {
            let ctx = Ctx::new(space);
            let t = report::table2(&ctx, &ModelSpec::paper_models(), out);
            print!("{}", t.render());
        }
        "fig7" => print!("{}", report::fig7(&Ctx::new(space), out).render()),
        "fig8" => {
            let ctxs = [1024usize, 2048, 4096];
            let batches = [1usize, 4, 16, 64, 256, 1024];
            print!("{}", report::fig8(&Ctx::new(space), &ctxs, &batches, out).render())
        }
        "fig9" => print!("{}", report::fig9(&Ctx::new(space), &[16, 64, 256], out).render()),
        "fig10" => print!("{}", report::fig10(&Ctx::new(space), out).render()),
        "fig11" => print!("{}", report::fig11(&Ctx::new(space), out).render()),
        "fig12" => print!("{}", report::fig12(&Ctx::new(space), out).render()),
        "fig13" => print!("{}", report::fig13(&Ctx::new(space), out).render()),
        "fig14" => print!("{}", report::fig14(&Ctx::new(space), out).render()),
        "fig15" => print!("{}", report::fig15(out).render()),
        "ablate" => {
            let name = args.get("model").unwrap_or("gpt3");
            let model = ModelSpec::by_name(name)
                .ok_or_else(|| Error::Config(format!("unknown model {name}")))?;
            let t = chiplet_cloud::evaluate::ablation::ablation_table(
                &space,
                &model,
                args.get_or("ctx", 2048),
                args.get_or("batch", 256),
            );
            print!("{}", t.render());
        }
        "serve" => serve(&args)?,
        "ccmem" => ccmem(),
        _ => usage(),
    }
    Ok(())
}

/// SLO targets from `--slo-ttft` / `--slo-tpot` (seconds; absent = ∞).
fn slo_from_args(args: &Args) -> chiplet_cloud::config::SloSpec {
    chiplet_cloud::config::SloSpec::new(
        args.get_or("slo-ttft", f64::INFINITY),
        args.get_or("slo-tpot", f64::INFINITY),
    )
}

/// Traffic description from the CLI flags. A zero `--rps` (the default)
/// lets `report::serve_sim` resolve the rate from `--load` × the design's
/// capacity; the `sweep --slo-*` path defaults to a saturating closed loop.
fn traffic_from_args(args: &Args) -> chiplet_cloud::config::TrafficSpec {
    use chiplet_cloud::config::{ArrivalProcess, TrafficSpec};
    let requests: usize = args.get_or("requests", 400);
    let prompt: usize = args.get_or("prompt-tokens", 64);
    let lo: usize = args.get_or("tokens-lo", 16);
    let hi: usize = args.get_or("tokens-hi", 128);
    let rps: f64 = args.get_or("rps", 0.0);
    let arrival = match args.get("trace").unwrap_or("poisson") {
        "bursty" => ArrivalProcess::Bursty { rps, burst: args.get_or("burst", 8) },
        "closed" => ArrivalProcess::ClosedLoop {
            clients: args.get_or("clients", 64),
            think_s: args.get_or("think", 0.0),
        },
        _ => ArrivalProcess::Poisson { rps },
    };
    TrafficSpec {
        arrival,
        requests,
        prompt_tokens: prompt,
        new_tokens_lo: lo,
        new_tokens_hi: hi,
        seed: args.get_or("seed", 42),
    }
}

/// Discrete-event serving simulation (`ccloud serve-sim`): static vs
/// continuous batching on the model's optimal design, plus the
/// SLO-constrained selection when targets are given. `--smoke` is the CI
/// preset: small model, short trace, seconds end to end.
fn serve_sim(args: &Args, space: ExploreSpace, out: Option<&std::path::Path>) -> Result<()> {
    let smoke = args.has("smoke");
    let name = args.get("model").unwrap_or(if smoke { "gpt2" } else { "gpt3" });
    let model = ModelSpec::by_name(name)
        .ok_or_else(|| Error::Config(format!("unknown model {name}")))?;
    let wctx: usize = args.get_or("ctx", 1024);
    let batch: usize = args.get_or("batch", if smoke { 32 } else { 256 });
    let mut traffic = traffic_from_args(args);
    if smoke {
        traffic.requests = args.get_or("requests", 120);
        traffic.prompt_tokens = args.get_or("prompt-tokens", 32);
        traffic.new_tokens_lo = args.get_or("tokens-lo", 8);
        traffic.new_tokens_hi = args.get_or("tokens-hi", 32);
    }
    let slo = slo_from_args(args);
    let w = chiplet_cloud::config::Workload::new(model, wctx, batch);
    let ctx = Ctx::new(space);
    let t = report::serve_sim(&ctx, &w, &traffic, args.get_or("load", 0.8), &slo, out);
    print!("{}", t.render());
    Ok(())
}

/// Demo serving loop on the AOT artifacts (see examples/serve_llm.rs for
/// the full end-to-end driver).
fn serve(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let model = args.get("model").unwrap_or("cc-tiny").to_string();
    let requests: usize = args.get_or("requests", 8);
    let tokens: usize = args.get_or("tokens", 8);
    println!("loading {model} from {dir} ...");
    let coord = Coordinator::start(
        &dir,
        &model,
        CoordinatorConfig {
            max_wait: Duration::from_millis(30),
            replicas: args.get_or("replicas", 1),
            ..CoordinatorConfig::default()
        },
    )?;
    let mut rng = Rng::new(42);
    for _ in 0..requests {
        let len = 4 + rng.below(12);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(400) as i32 + 1).collect();
        coord.submit(prompt, tokens);
    }
    let metrics = coord.metrics.clone();
    let responses = coord.shutdown()?;
    println!("served {} requests", responses.len());
    println!("{}", metrics.summary().render());
    Ok(())
}

/// CC-MEM simulator validation runs (saturation, conflicts, sparse rates).
fn ccmem() {
    use chiplet_cloud::ccmem::bank::BurstMode;
    use chiplet_cloud::ccmem::traffic::{run_gemm_stream, run_random};
    use chiplet_cloud::ccmem::CcMemConfig;
    let cfg = CcMemConfig::small();
    let dense = run_gemm_stream(&cfg, 64 << 10, BurstMode::Dense);
    println!(
        "GEMM stream: {} cycles, core BW util {:.1}%, conflicts {:.2}%",
        dense.cycles,
        dense.core_bw_utilization * 100.0,
        dense.conflict_rate * 100.0
    );
    let s60 = run_gemm_stream(&cfg, 64 << 10, BurstMode::Sparse { nnz_per_tile: 102 });
    let s10 = run_gemm_stream(&cfg, 64 << 10, BurstMode::Sparse { nnz_per_tile: 230 });
    println!(
        "sparse 60%: {} cycles (dense-rate: {}), sparse 10%: {} cycles (input-limited)",
        s60.cycles,
        s60.cycles == dense.cycles,
        s10.cycles
    );
    let rnd = run_random(&cfg, 20_000, 7);
    println!(
        "random traffic: BW util {:.1}%, conflict rate {:.2}%",
        rnd.core_bw_utilization * 100.0,
        rnd.conflict_rate * 100.0
    );
}
